"""Fig. 2 reproduction.

(a) performance scaling vs workload complexity — PFCS speedup over LRU as
    relationship density rises (paper: 2.8x simple -> 13.7x complex);
(b) hit rate vs cache size — PFCS holds its edge across sizes.

Backend: the vectorized engine.  Fig 2a batches ALL densities through
one ``vmap``-ed scan per system (every density trace has the same
shape); Fig 2b compiles once per cache size (capacities are static
shapes) and batches nothing.  ``--scale N`` multiplies trace length;
the scalar loops topped out around 20k accesses — the engine sweeps
200k+ (PR acceptance gate: ``--scale 10`` end-to-end).

    PYTHONPATH=src python -m benchmarks.fig2 --scale 10
"""

from __future__ import annotations

from repro.core import db_join_trace, derive_table1_row, graph_walk_trace
from repro.core.engine import simulate_batch, simulate_trace

from .common import emit, save_json


def run_fig2a(densities=(0.05, 0.2, 0.4, 0.6, 0.8, 1.0), seed: int = 0,
              trace_scale: float = 1.0):
    caps = (("L1", 64), ("L2", 256), ("L3", 1024))
    n_acc = int(20000 * trace_scale)
    traces = [graph_walk_trace(n_keys=6000, relationship_density=d,
                               n_accesses=n_acc, seed=seed)
              for d in densities]
    # one vmapped scan per system across every density
    lru = simulate_batch(traces, "lru", caps)
    # prefetch budget sized to the max relationship group (8) — the
    # paper's §4.2 prefetches *all* discovered relations of a trigger
    pfcs = simulate_batch(traces, "pfcs", caps, prefetch_budget=8)
    out = []
    print("\n== Fig 2a: speedup vs relationship density "
          f"(paper: 2.8x -> 13.7x; {n_acc} accesses/trace) ==")
    for d, sl, sp in zip(densities, lru, pfcs):
        row = derive_table1_row(sp, sl)
        out.append(dict(density=d, speedup=row["speedup"],
                        pfcs_hit=sp.hit_rate, lru_hit=sl.hit_rate))
        print(f"  density={d:4.2f}  speedup={row['speedup']:5.2f}x  "
              f"hit pfcs={sp.hit_rate*100:5.1f}% lru={sl.hit_rate*100:5.1f}%")
        emit(f"fig2a.density_{d:.2f}.speedup", row["speedup"])
    save_json("fig2a", out)
    return out


def run_fig2b(sizes=(256, 512, 1024, 2048, 4096), seed: int = 0,
              trace_scale: float = 1.0):
    out = []
    n_q = int(25000 * trace_scale)
    print(f"\n== Fig 2b: hit rate vs total cache size ({n_q} accesses) ==")
    tr = db_join_trace(n_orders=6000, n_customers=900, n_items=1800,
                       n_queries=n_q, seed=seed)
    tables = None   # discovery tables are capacity-independent: build once
    for size in sizes:
        caps = (("L1", max(16, size // 16)),
                ("L2", max(32, size // 4)),
                ("L3", size - size // 16 - size // 4))
        if tables is None:
            from repro.core.engine import pfcs_tables
            tables = pfcs_tables(tr, caps)
        lru = simulate_trace(tr, "lru", caps)
        arc = simulate_trace(tr, "arc", caps)
        pfcs = simulate_trace(tr, "pfcs", caps, tables=tables)
        out.append(dict(size=size, lru=lru.hit_rate, arc=arc.hit_rate,
                        pfcs=pfcs.hit_rate))
        print(f"  size={size:5d}  pfcs={pfcs.hit_rate*100:5.1f}%  "
              f"arc={arc.hit_rate*100:5.1f}%  lru={lru.hit_rate*100:5.1f}%")
        emit(f"fig2b.size_{size}.pfcs_hit", pfcs.hit_rate * 100)
    save_json("fig2b", out)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="trace-length multiplier (engine handles >=10x)")
    args = ap.parse_args()
    run_fig2a(trace_scale=args.scale)
    run_fig2b(trace_scale=args.scale)
