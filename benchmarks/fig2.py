"""Fig. 2 reproduction.

(a) performance scaling vs workload complexity — PFCS speedup over LRU as
    relationship density rises (paper: 2.8x simple -> 13.7x complex);
(b) hit rate vs cache size — PFCS holds its edge across sizes.
"""

from __future__ import annotations

import numpy as np

from repro.core import (derive_table1_row, graph_walk_trace,
                        run_all_systems, simulate_baseline, simulate_pfcs)

from .common import emit, save_json


def run_fig2a(densities=(0.05, 0.2, 0.4, 0.6, 0.8, 1.0), seed: int = 0):
    caps = (("L1", 64), ("L2", 256), ("L3", 1024))
    out = []
    print("\n== Fig 2a: speedup vs relationship density "
          "(paper: 2.8x -> 13.7x) ==")
    for d in densities:
        tr = graph_walk_trace(n_keys=6000, relationship_density=d,
                              n_accesses=20000, seed=seed)
        # prefetch budget sized to the max relationship group (8) — the
        # paper's §4.2 prefetches *all* discovered relations of a trigger
        res = {"lru": simulate_baseline("lru", tr, caps),
               "pfcs": simulate_pfcs(tr, caps, prefetch_budget=8)}
        row = derive_table1_row(res["pfcs"], res["lru"])
        out.append(dict(density=d, speedup=row["speedup"],
                        pfcs_hit=res["pfcs"].hit_rate,
                        lru_hit=res["lru"].hit_rate))
        print(f"  density={d:4.2f}  speedup={row['speedup']:5.2f}x  "
              f"hit pfcs={res['pfcs'].hit_rate*100:5.1f}% "
              f"lru={res['lru'].hit_rate*100:5.1f}%")
        emit(f"fig2a.density_{d:.2f}.speedup", row["speedup"])
    save_json("fig2a", out)
    return out


def run_fig2b(sizes=(256, 512, 1024, 2048, 4096), seed: int = 0):
    out = []
    print("\n== Fig 2b: hit rate vs total cache size ==")
    from repro.core import db_join_trace
    tr = db_join_trace(n_orders=6000, n_customers=900, n_items=1800,
                       n_queries=25000, seed=seed)
    for size in sizes:
        caps = (("L1", max(16, size // 16)),
                ("L2", max(32, size // 4)),
                ("L3", size - size // 16 - size // 4))
        lru = simulate_baseline("lru", tr, caps)
        arc = simulate_baseline("arc", tr, caps)
        pfcs = simulate_pfcs(tr, caps)
        out.append(dict(size=size, lru=lru.hit_rate, arc=arc.hit_rate,
                        pfcs=pfcs.hit_rate))
        print(f"  size={size:5d}  pfcs={pfcs.hit_rate*100:5.1f}%  "
              f"arc={arc.hit_rate*100:5.1f}%  lru={lru.hit_rate*100:5.1f}%")
        emit(f"fig2b.size_{size}.pfcs_hit", pfcs.hit_rate * 100)
    save_json("fig2b", out)
    return out


if __name__ == "__main__":
    run_fig2a()
    run_fig2b()
