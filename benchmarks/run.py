"""Benchmark entry point — one function per paper table/figure.

Emits ``name,us_per_call,derived`` CSV rows (stdout) plus human-readable
tables; JSON artifacts land in ``artifacts/bench/``.

    PYTHONPATH=src python -m benchmarks.run [--full] [--smoke] [--skip-roofline]
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale trial counts (slower)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-scale CI subset: Table 1 at reduced scale "
                         "plus the serving load case, the elastic "
                         "resize/recovery chaos case, the MoE "
                         "expert-serving case, the multi-tenant QoS "
                         "case, the continuous-batching Poisson "
                         "load case, and the million-element wide-"
                         "registry scale case (exercises every serving "
                         "hot path and the multi-limb arithmetic on "
                         "every PR)")
    ap.add_argument("--skip-roofline", action="store_true",
                    help="skip the dry-run-artifact roofline table")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="trace-length multiplier for table1/fig2 "
                         "(the vectorized engine handles >=10x)")
    ap.add_argument("--quiet", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="suppress case_scale build progress lines "
                         "(default: quiet under --smoke — the CI path — "
                         "and verbose otherwise)")
    ap.add_argument("--shards", type=int, default=None,
                    help="run case_serving's sharded-cache config at "
                         "exactly N shards (default: sweep 1/2/4, smoke "
                         "2); uses shard_map when the host exposes >= N "
                         "devices (XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N), host loop otherwise")
    args = ap.parse_args(argv)
    shards = (args.shards,) if args.shards else None

    t0 = time.time()
    print("name,us_per_call,derived")

    from . import table1, fig2, cases, kernel_bench

    if args.smoke:
        table1.run(n_trials=1, trace_scale=0.2)
        cases.case_serving(smoke=True, shards=shards)
        cases.case_elastic(smoke=True)
        cases.case_moe(smoke=True)
        cases.case_tenancy(smoke=True)
        cases.case_batching(smoke=True)
        cases.case_scale(smoke=True, quiet=args.quiet)
        cases.case_dedup(smoke=True)
        kernel_bench.run_smoke()
        print(f"\ntotal benchmark wall time: {time.time() - t0:.1f}s")
        return

    table1.run(n_trials=20 if args.full else 4, trace_scale=args.scale)
    fig2.run_fig2a(trace_scale=args.scale)
    fig2.run_fig2b(trace_scale=args.scale)
    cases.case_db()
    cases.case_ml()
    cases.case_hft()
    cases.case_serving(shards=shards)
    cases.case_elastic()
    cases.case_moe()
    cases.case_tenancy()
    cases.case_batching()
    cases.case_scale(quiet=args.quiet)
    cases.case_dedup()
    kernel_bench.run()
    kernel_bench.run_smoke()

    if not args.skip_roofline:
        try:
            from . import roofline
            roofline.run()
        except Exception as e:  # artifacts may not exist yet
            print(f"[roofline skipped: {e}]")

    print(f"\ntotal benchmark wall time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
