"""Generate the EXPERIMENTS.md data tables from artifacts (dry-run,
roofline, bench JSONs).  Run after ``dryrun --all`` + ``--probes`` and
``benchmarks.run``:

    PYTHONPATH=src python -m benchmarks.report > EXPERIMENTS.tables.md
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCH_IDS, SHAPES, cells, get_config, shape_applies
from repro.launch.dryrun import ARTIFACT_DIR

BENCH = Path(__file__).resolve().parents[1] / "artifacts" / "bench"


def _load(name):
    p = ARTIFACT_DIR / f"{name}.json"
    return json.loads(p.read_text()) if p.exists() else None


def dryrun_table():
    print("### Dry-run matrix (compile status, per-device memory)\n")
    print("| arch | shape | mesh | status | args GiB | temp GiB | "
          "fits 16 GiB | collective kinds |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shp in SHAPES:
            if not shape_applies(cfg, shp):
                print(f"| {arch} | {shp.name} | — | SKIP (full attention, "
                      f"per assignment) | — | — | — | — |")
                continue
            for mesh in ("pod_16x16", "multipod_2x16x16"):
                r = _load(f"{arch}__{shp.name}__{mesh}")
                if r is None:
                    print(f"| {arch} | {shp.name} | {mesh} | MISSING | | | | |")
                    continue
                mem = r.get("memory", {})
                args = mem.get("argument_size_in_bytes", 0) / 2**30
                temp = mem.get("temp_size_in_bytes", 0) / 2**30
                fits = "yes" if (args + temp) <= 16 else "NO*"
                kinds = ",".join(sorted(r.get("collectives", {})))
                print(f"| {arch} | {shp.name} | {mesh} | {r['status']} | "
                      f"{args:.2f} | {temp:.2f} | {fits} | {kinds} |")
    print()


def roofline_table():
    rl = BENCH / "roofline.json"
    if not rl.exists():
        print("(roofline.json missing — run benchmarks.run first)\n")
        return
    rows = json.loads(rl.read_text())
    print("### Roofline terms (per device, single-pod 16x16, v5e: "
          "197 TF bf16 / 819 GB/s HBM / 50 GB/s ICI)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| MODEL/HLO flops | what would move the dominant term |")
    print("|---|---|---|---|---|---|---|---|")
    for key, r in rows.items():
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4g} | "
              f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | "
              f"{r['dominant']} | {r['useful_ratio']:.3f} | {r['fix']} |")
    print()


def bench_tables():
    for name in ("table1", "fig2a", "fig2b", "case_db", "case_ml",
                 "case_hft", "case_serving", "case_moe", "case_tenancy",
                 "kernel_bench"):
        p = BENCH / f"{name}.json"
        if p.exists():
            print(f"### bench:{name}\n```json")
            print(p.read_text())
            print("```\n")


if __name__ == "__main__":
    dryrun_table()
    roofline_table()
