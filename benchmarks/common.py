"""Shared benchmark helpers: trial aggregation + CSV emission."""

from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable, Dict, List, Sequence

import numpy as np

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts"


def agg(values: Sequence[float]):
    a = np.asarray(list(values), dtype=np.float64)
    return float(a.mean()), float(a.std(ddof=1)) if len(a) > 1 else 0.0


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """One CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.3f},{derived}")


def save_json(name: str, payload) -> Path:
    out = ARTIFACTS / "bench"
    out.mkdir(parents=True, exist_ok=True)
    p = out / f"{name}.json"
    p.write_text(json.dumps(payload, indent=1, default=float))
    return p


def timed(fn: Callable, *args, repeat: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best
