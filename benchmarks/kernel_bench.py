"""Kernel throughput: Pallas factorization/scan/gcd (interpret mode on this
CPU container — wall numbers are correctness-path timings, the TPU story
is the roofline) + host Factorizer stage mix."""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import Factorizer, sieve_primes
from repro.kernels.ops import divisibility_scan, factorize_batch, gcd_batch

from .common import emit, save_bench, save_json, timed


def run_smoke():
    """Tiny kernel pass under the launch-ledger profiler (DESIGN.md
    §13).  The checked-in ``BENCH_kernel_bench.json`` payload is ONLY
    the wall-clock-exempt ``obs`` block — every number in it (walls,
    and calls/items, which track jit cache state) is reporting, not a
    gated deterministic contract; the regression gate skips the whole
    block by its ``obs`` component."""
    from repro.obs import profile

    rng = np.random.default_rng(0)
    primes = sieve_primes(10_000)
    pool = primes[100:100 + 256].astype(np.int64)
    pairs = rng.choice(primes[100:], size=(256, 2), replace=True)
    comps = (pairs[:, 0] * pairs[:, 1]).astype(np.int64)
    with profile.profiling():
        factorize_batch(list(comps), list(pool))
        divisibility_scan(list(comps), list(pool[:64]))
        gcd_batch(list(comps), list(comps[::-1]))
    launches = profile.summary()
    print("\n== kernels (smoke, launch ledger) ==")
    for name, rec in sorted(launches.items()):
        print(f"   {name}: {rec['calls']} call(s), {rec['items']} items, "
              f"{rec['wall_s']*1e3:.1f} ms")
        emit(f"kernel.{name}.wall_s", rec["wall_s"] * 1e6,
             f"calls={rec['calls']}")
    out = {"obs": {"kernel_launches": launches}}
    save_json("kernel_bench_smoke", out)
    save_bench("kernel_bench", out)
    return out


def run():
    rng = np.random.default_rng(0)
    primes = sieve_primes(10_000)
    out = {}

    # batched factorization kernel
    pairs = rng.choice(primes[100:], size=(4096, 2), replace=True)
    comps = (pairs[:, 0] * pairs[:, 1]).astype(np.int64)
    pool = primes[100:100 + 1024].astype(np.int64)
    (facs, _), dt = timed(factorize_batch, list(comps), list(pool), repeat=3)
    per = dt / len(comps) * 1e6
    print(f"\n== kernels == factorize_batch: {len(comps)} composites x "
          f"{len(pool)} primes in {dt*1e3:.1f} ms ({per:.2f} us/composite)")
    emit("kernel.factorize_batch.us_per_composite", per)
    out["factorize_us_per_composite"] = per

    # divisibility scan (prefetch path)
    reg = (rng.choice(primes[100:], size=(8192, 2)).prod(axis=1)).astype(np.int64)
    qs = pool[:512]
    _, dt = timed(divisibility_scan, list(reg), list(qs), repeat=3)
    per_q = dt / len(qs) * 1e6
    print(f"   divisibility_scan: {len(reg)} registry x {len(qs)} queries "
          f"in {dt*1e3:.1f} ms ({per_q:.2f} us/query)")
    emit("kernel.divisibility_scan.us_per_query", per_q)
    out["scan_us_per_query"] = per_q

    # gcd
    a = rng.integers(1, 2**30, size=65536)
    b = rng.integers(1, 2**30, size=65536)
    _, dt = timed(gcd_batch, list(a), list(b), repeat=3)
    per_g = dt / len(a) * 1e6
    print(f"   gcd_batch: {len(a)} pairs in {dt*1e3:.1f} ms "
          f"({per_g:.3f} us/pair)")
    emit("kernel.gcd_batch.us_per_pair", per_g)
    out["gcd_us_per_pair"] = per_g

    # vectorized trace engine vs scalar oracle (same hit counts by
    # construction — tests/test_engine.py — so this is pure wall clock;
    # both sides pay relationship discovery inside the timed region)
    from repro.core import db_join_trace, simulate_baseline, simulate_pfcs
    from repro.core.engine import simulate_trace

    caps = (("L1", 64), ("L2", 256), ("L3", 1024))
    tr = db_join_trace(n_orders=2000, n_customers=400, n_items=800,
                       n_queries=20000, seed=1)
    print("   -- trace engine (20k-access db_join, scalar vs lax.scan) --")
    for sysname in ("lru", "arc", "pfcs"):
        if sysname == "pfcs":
            _, dt_sc = timed(simulate_pfcs, tr, caps, repeat=1)
        else:
            _, dt_sc = timed(simulate_baseline, sysname, tr, caps, repeat=1)
        simulate_trace(tr, sysname, caps)                      # compile
        _, dt_en = timed(simulate_trace, tr, sysname, caps, repeat=3)
        us_sc = dt_sc / tr.length * 1e6
        us_en = dt_en / tr.length * 1e6
        print(f"   engine.{sysname}: scalar {us_sc:6.2f} us/access, "
              f"vectorized {us_en:6.2f} us/access "
              f"({dt_sc / max(dt_en, 1e-12):.1f}x)")
        emit(f"engine.{sysname}.us_per_access", us_en,
             f"scalar={us_sc:.2f}")
        out[f"engine_{sysname}_us_per_access"] = us_en
        out[f"engine_{sysname}_scalar_us_per_access"] = us_sc

    # host factorizer stage mix (Algorithm 2)
    f = Factorizer()
    small = rng.integers(4, 10**6, size=20000)
    t0 = time.perf_counter()
    for c in small:
        f.factorize(int(c))
    dt_small = (time.perf_counter() - t0) / len(small) * 1e9
    big_pairs = rng.choice(sieve_primes(2_000_000)[78_498:], size=(500, 2))
    bigs = [int(p) * int(q) for p, q in big_pairs]
    t0 = time.perf_counter()
    for c in bigs:
        f.factorize(c)
    dt_big = (time.perf_counter() - t0) / len(bigs) * 1e9
    t0 = time.perf_counter()
    for c in bigs:
        f.factorize(c)                       # cache hits
    dt_cached = (time.perf_counter() - t0) / len(bigs) * 1e9
    print(f"   host factorizer: SPF-table path {dt_small:.0f} ns/op, "
          f"rho path {dt_big:.0f} ns/op, cached {dt_cached:.0f} ns/op")
    print(f"   stage mix: {f.stats.as_dict()}")
    emit("host_factorizer.spf_ns", dt_small)
    emit("host_factorizer.rho_ns", dt_big)
    emit("host_factorizer.cached_ns", dt_cached)
    out.update(spf_ns=dt_small, rho_ns=dt_big, cached_ns=dt_cached,
               stages=f.stats.as_dict())
    save_json("kernel_bench", out)
    return out


if __name__ == "__main__":
    run()
