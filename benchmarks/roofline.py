"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape), single-pod 16x16 mesh:

  compute term    = HLO_FLOPs_dev / peak_FLOP/s        (197 TFLOP/s bf16)
  memory term     = HLO_bytes_dev / HBM_bw             (819 GB/s)
  collective term = collective_bytes_dev / link_bw     (50 GB/s ICI)

``cost_analysis`` numbers are already per-device (verified by
calibration), BUT a ``lax.scan`` body is costed once regardless of trip
count.  The sweep therefore compiles two *unrolled* reduced-layer probes
per cell (see ``dryrun.probe_layer_counts``); linear extrapolation
reconstructs the full-depth cost exactly for the layer-stacked models:

    total(L) = probe(L1) + (probe(L2) - probe(L1)) / (L2 - L1) * (L - L1)

Known residual under-count, documented: the sLSTM *time* recurrence in
xlstm (a 4096-step scan that cannot be unrolled) — patched analytically
below; it is <10% of that arch's step FLOPs.

MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for train;
2 N D for prefill; 2 N per token for decode.  The ratio
MODEL_FLOPS / HLO_FLOPs measures useful-compute fraction (remat and
dispatch overheads push it below 1; >1 would mean the HLO undercounts).
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Optional

import numpy as np

from repro.configs import SHAPES, cells, get_config
from repro.launch.dryrun import ARTIFACT_DIR, probe_layer_counts
from repro.launch.mesh import HW
from repro.models.model_zoo import count_params_analytic

from .common import emit, save_json

N_DEV = 256  # single-pod roofline


def _load(arch, shape, mesh="pod_16x16", probe: Optional[int] = None):
    sfx = f"__probe{probe}" if probe is not None else ""
    p = ARTIFACT_DIR / f"{arch}__{shape}__{mesh}{sfx}.json"
    if not p.exists():
        return None
    r = json.loads(p.read_text())
    return r if r.get("status") == "ok" else None


def _layers_of(cfg) -> int:
    if cfg.family == "audio":
        return cfg.encdec.n_encoder_layers  # probes scale enc+dec together
    return cfg.n_layers


def _coll_bytes(rec) -> float:
    return sum(v["bytes"] for v in rec.get("collectives", {}).values())


def _slstm_flops_patch(cfg, shape) -> float:
    """Analytic per-device FLOPs for sLSTM time recurrences (scan bodies
    the probes cannot unroll).  Train: 3x fwd for backward."""
    if cfg.family != "ssm" or shape.kind == "decode":
        return 0.0
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    per_tok = 2 * (d * h * 4 * hd + h * hd * 4 * hd)   # w_x + w_r
    n_s = cfg.n_layers // cfg.xlstm.slstm_every
    toks = shape.seq_len * shape.global_batch / N_DEV
    mult = 4 if shape.kind == "train" else 1           # fwd+bwd+remat
    return per_tok * n_s * toks * mult


def reconstruct(arch: str, shape) -> Optional[Dict]:
    """Full-depth per-device HLO cost for one cell from the two probes."""
    cfg = get_config(arch)
    l1, l2 = probe_layer_counts(cfg)
    p1 = _load(arch, shape.name, probe=l1)
    p2 = _load(arch, shape.name, probe=l2)
    full = _load(arch, shape.name)
    if p1 is None or p2 is None or full is None:
        return None
    L = _layers_of(cfg)
    scale = (L - l1) / (l2 - l1)

    def extrap(f1, f2):
        return f1 + (f2 - f1) * scale

    flops = extrap(p1["flops"], p2["flops"]) + _slstm_flops_patch(cfg, shape)
    bytes_acc = extrap(p1["bytes_accessed"], p2["bytes_accessed"])
    coll = extrap(_coll_bytes(p1), _coll_bytes(p2))
    return {
        "flops_dev": flops,
        "bytes_dev": bytes_acc,
        "coll_bytes_dev": coll,
        "mem_args_gib": full["memory"].get("argument_size_in_bytes", 0) / 2**30,
        "mem_temp_gib": full["memory"].get("temp_size_in_bytes", 0) / 2**30,
        "collective_kinds": full.get("collectives", {}),
    }


def model_flops(cfg, shape) -> float:
    """Per-device useful FLOPs (6ND train / 2ND prefill / 2N decode)."""
    n_act = count_params_analytic(cfg, active_only=True)
    if shape.kind == "train":
        toks = shape.seq_len * shape.global_batch
        return 6.0 * n_act * toks / N_DEV
    if shape.kind == "prefill":
        toks = shape.seq_len * shape.global_batch
        return 2.0 * n_act * toks / N_DEV
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch / N_DEV


def suggestion(dom: str, cfg, shape) -> str:
    if dom == "compute":
        return ("compute-bound: raise MXU utilization (larger per-device "
                "batch or fewer remat recomputes)")
    if dom == "memory":
        if shape.kind == "decode":
            return ("HBM-bound (weights+KV streamed per token): quantize "
                    "KV / batch more requests per weight read")
        return ("HBM-bound: fuse activations, cut f32 intermediates, "
                "bigger attention chunks")
    return ("collective-bound: overlap all-gather/reduce-scatter with "
            "compute, int8-compress DP grads, remap sharding axes")


def engine_roofline(verbose: bool = True) -> Dict:
    """Analytic roofline for the trace-simulation engine's scan step.

    The engine (repro.core.engine) carries fixed-shape state through
    ``lax.scan``; each step touches the whole state once (reads + the
    rewritten carry), so per-access traffic is ~2x the state footprint.
    On HBM that bounds steps/s at BW / bytes; the state for realistic
    configs fits VMEM (<16 MB), where the bound is the VPU instead —
    both are reported so the sweep's wall clock has a sanity anchor.
    """
    caps = (64, 256, 2048)
    n_keys = 20_000
    # PFCS level slots: keys/t/deg int32 + pf bool; per-key where int32
    level_bytes = sum((c + 1) * (4 + 4 + 4 + 1) for c in caps)
    perkey_bytes = 4 * n_keys
    state = level_bytes + perkey_bytes
    traffic = 2 * state                      # read carry + write carry
    steps_s_hbm = HW.HBM_BW / traffic
    row = dict(state_bytes=state, bytes_per_access=traffic,
               hbm_bound_steps_per_s=steps_s_hbm,
               fits_vmem=state < 16 * 2**20)
    if verbose:
        print("\n== Engine roofline (PFCS config L1=64/L2=256/L3=2048, "
              f"K={n_keys}) ==")
        print(f"  state={state/2**10:.0f} KiB  traffic={traffic/2**10:.0f} "
              f"KiB/access  HBM-bound rate={steps_s_hbm/1e6:.2f} M acc/s  "
              f"fits VMEM={row['fits_vmem']}")
        emit("roofline.engine.hbm_bound_macc_s", steps_s_hbm / 1e6)
    save_json("roofline_engine", row)
    return row


def run(verbose: bool = True) -> Dict:
    rows = {}
    rows["engine"] = engine_roofline(verbose)
    hdr = (f"{'arch':22s} {'shape':11s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dominant':>9s} {'MF/HLO':>7s} {'args_GiB':>8s} "
           f"{'temp_GiB':>8s}")
    if verbose:
        print("\n== Roofline (per-device, single-pod 16x16, v5e constants) ==")
        print(hdr)
    for arch, shape in cells():
        rec = reconstruct(arch, shape)
        if rec is None:
            continue
        cfg = get_config(arch)
        t_comp = rec["flops_dev"] / HW.PEAK_BF16_FLOPS
        t_mem = rec["bytes_dev"] / HW.HBM_BW
        t_coll = rec["coll_bytes_dev"] / HW.ICI_BW
        dom = max(("compute", t_comp), ("memory", t_mem),
                  ("collective", t_coll), key=lambda kv: kv[1])[0]
        mf = model_flops(cfg, shape)
        ratio = mf / max(rec["flops_dev"], 1.0)
        row = dict(arch=arch, shape=shape.name, compute_s=t_comp,
                   memory_s=t_mem, collective_s=t_coll, dominant=dom,
                   model_flops_dev=mf, hlo_flops_dev=rec["flops_dev"],
                   useful_ratio=ratio,
                   roofline_fraction=ratio * t_comp / max(
                       t_comp, t_mem, t_coll),
                   mem_args_gib=rec["mem_args_gib"],
                   mem_temp_gib=rec["mem_temp_gib"],
                   fix=suggestion(dom, cfg, shape))
        rows[f"{arch}__{shape.name}"] = row
        if verbose:
            print(f"{arch:22s} {shape.name:11s} {t_comp:10.4f} {t_mem:10.4f} "
                  f"{t_coll:10.4f} {dom:>9s} {ratio:7.3f} "
                  f"{rec['mem_args_gib']:8.2f} {rec['mem_temp_gib']:8.2f}")
            emit(f"roofline.{arch}.{shape.name}.dominant_s",
                 max(t_comp, t_mem, t_coll) * 1e6, dom)
    save_json("roofline", rows)
    return rows


if __name__ == "__main__":
    run()
