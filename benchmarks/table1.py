"""Table 1 reproduction: hit rate / latency / power / relationship accuracy
across LRU, ARC, LIRS, Semantic, PFCS on the paper's workload mix.

Paper's claims (mean over workloads, n=100): LRU 87.3% | ARC 91.2% |
LIRS 92.4% | Semantic 94.1% (acc 86.4%) | PFCS 98.9% (acc 100%),
41.2% latency reduction, 38.1% power reduction vs LRU.

We run n trials with different seeds over the db/ml/hft trace mix and
report mean ± std for each metric, plus the paper's value alongside.

Backend: the vectorized engine (``repro.core.engine``) simulates every
system except the semantic baseline, with all trials of a workload
batched through one ``vmap``-ed scan.  ``--scale N`` multiplies trace
lengths — the scalar loops capped this sweep at ~20k accesses per
trace; the engine runs 10x-100x that (the ``--scale 10`` configuration
is the acceptance gate for the engine PR).

    PYTHONPATH=src python -m benchmarks.table1 --scale 10 --trials 3
"""

from __future__ import annotations

from repro.core import (derive_table1_row, db_join_trace, hft_trace,
                        ml_epoch_trace, simulate_semantic)
from repro.core.engine import VECTORIZED_SYSTEMS, simulate_batch

from .common import agg, emit, save_json, timed

CAPS = (("L1", 64), ("L2", 256), ("L3", 2048))
SYSTEMS = ("lru", "arc", "lirs", "semantic", "pfcs")

PAPER = {
    "lru": dict(hit=87.3, lat=0.0, pow=0.0, acc=None),
    "arc": dict(hit=91.2, lat=12.1, pow=6.8, acc=None),
    "lirs": dict(hit=92.4, lat=15.7, pow=8.2, acc=None),
    "semantic": dict(hit=94.1, lat=22.3, pow=11.5, acc=86.4),
    "pfcs": dict(hit=98.9, lat=41.2, pow=38.1, acc=100.0),
}


def _workloads(scale: float):
    """Workload generators; ``scale`` stretches trace length only (the
    key space stays fixed so hit rates remain comparable across scales)."""
    return {
        "db_join": lambda seed: db_join_trace(
            n_orders=4000, n_customers=600, n_items=1200,
            n_queries=int(20000 * scale), seed=seed),
        "ml_epoch": lambda seed: ml_epoch_trace(
            n_samples=2500, n_feature_rows=600,
            n_epochs=max(1, int(round(3 * scale))), seed=seed),
        "hft": lambda seed: hft_trace(
            n_instruments=2500, n_corr_groups=350,
            n_events=int(20000 * scale), seed=seed),
    }


def run(n_trials: int = 5, seed0: int = 0, trace_scale: float = 1.0,
        engine: str = "auto"):
    rows = {s: {"hit": [], "lat": [], "pow": [], "acc": [], "speed": []}
            for s in SYSTEMS}
    wall = {}
    for wname, gen in _workloads(trace_scale).items():
        traces = [gen(seed0 + t) for t in range(n_trials)]
        per_system = {}
        for s in SYSTEMS:
            if engine != "scalar" and s in VECTORIZED_SYSTEMS:
                stats, dt = timed(simulate_batch, traces, s, CAPS, repeat=1)
            else:
                def scalar_all():
                    if s == "semantic":
                        return [simulate_semantic(tr, CAPS, seed=seed0 + t)
                                for t, tr in enumerate(traces)]
                    from repro.core import simulate_baseline, simulate_pfcs
                    return [simulate_pfcs(tr, CAPS) if s == "pfcs"
                            else simulate_baseline(s, tr, CAPS)
                            for tr in traces]
                stats, dt = timed(scalar_all, repeat=1)
            per_system[s] = stats
            wall[f"{wname}.{s}"] = dt
        for t in range(n_trials):
            base = per_system["lru"][t]
            for s in SYSTEMS:
                row = derive_table1_row(per_system[s][t], base)
                rows[s]["hit"].append(row["hit_rate_pct"])
                rows[s]["lat"].append(row["latency_reduction_pct"])
                rows[s]["pow"].append(row["power_reduction_pct"])
                rows[s]["speed"].append(row["speedup"])
                if row["relationship_accuracy_pct"] is not None:
                    rows[s]["acc"].append(row["relationship_accuracy_pct"])

    table = {}
    n_acc = int(20000 * trace_scale)
    print("\n== Table 1: system comparison "
          f"(ours, mean±std over {n_trials} trials x 3 workloads, "
          f"~{n_acc} accesses/trace | paper) ==")
    print(f"{'system':9s} {'hit%':>16s} {'lat.red%':>16s} {'pow.red%':>16s} "
          f"{'rel.acc%':>14s} {'speedup':>8s}")
    for s in SYSTEMS:
        h, hs = agg(rows[s]["hit"])
        l, ls = agg(rows[s]["lat"])
        p, ps = agg(rows[s]["pow"])
        sp, _ = agg(rows[s]["speed"])
        a = agg(rows[s]["acc"])[0] if rows[s]["acc"] else None
        pp = PAPER[s]
        acc_s = f"{a:6.1f}|{pp['acc']}" if a is not None else "   n/a"
        print(f"{s:9s} {h:6.1f}±{hs:4.2f}|{pp['hit']:5.1f} "
              f"{l:6.1f}±{ls:4.2f}|{pp['lat']:5.1f} "
              f"{p:6.1f}±{ps:4.2f}|{pp['pow']:5.1f} {acc_s:>14s} {sp:7.2f}x")
        table[s] = dict(hit=(h, hs), lat=(l, ls), pow=(p, ps), acc=a,
                        speedup=sp, paper=pp)
        emit(f"table1.{s}.hit_rate_pct", h, f"paper={pp['hit']}")
    table["_wall_s"] = wall
    table["_trace_scale"] = trace_scale
    save_json("table1", table)
    return table


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trials", type=int, default=5)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="trace-length multiplier (engine handles >=10x)")
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "scalar"))
    args = ap.parse_args()
    run(n_trials=args.trials, trace_scale=args.scale, engine=args.engine)
