"""Table 1 reproduction: hit rate / latency / power / relationship accuracy
across LRU, ARC, LIRS, Semantic, PFCS on the paper's workload mix.

Paper's claims (mean over workloads, n=100): LRU 87.3% | ARC 91.2% |
LIRS 92.4% | Semantic 94.1% (acc 86.4%) | PFCS 98.9% (acc 100%),
41.2% latency reduction, 38.1% power reduction vs LRU.

We run n trials with different seeds over the db/ml/hft trace mix and
report mean ± std for each metric, plus the paper's value alongside.
"""

from __future__ import annotations

import numpy as np

from repro.core import (derive_table1_row, db_join_trace, hft_trace,
                        ml_epoch_trace, run_all_systems)

from .common import agg, emit, save_json, timed

CAPS = (("L1", 64), ("L2", 256), ("L3", 2048))
SYSTEMS = ("lru", "arc", "lirs", "semantic", "pfcs")

PAPER = {
    "lru": dict(hit=87.3, lat=0.0, pow=0.0, acc=None),
    "arc": dict(hit=91.2, lat=12.1, pow=6.8, acc=None),
    "lirs": dict(hit=92.4, lat=15.7, pow=8.2, acc=None),
    "semantic": dict(hit=94.1, lat=22.3, pow=11.5, acc=86.4),
    "pfcs": dict(hit=98.9, lat=41.2, pow=38.1, acc=100.0),
}


def _traces(seed: int):
    return [
        db_join_trace(n_orders=4000, n_customers=600, n_items=1200,
                      n_queries=20000, seed=seed),
        ml_epoch_trace(n_samples=2500, n_feature_rows=600, n_epochs=3,
                       seed=seed),
        hft_trace(n_instruments=2500, n_corr_groups=350, n_events=20000,
                  seed=seed),
    ]


def run(n_trials: int = 5, seed0: int = 0):
    rows = {s: {"hit": [], "lat": [], "pow": [], "acc": [], "speed": []}
            for s in SYSTEMS}
    wall = {}
    for t in range(n_trials):
        for tr in _traces(seed0 + t):
            res, dt = timed(run_all_systems, tr, CAPS, SYSTEMS,
                            repeat=1)
            wall[tr.name] = dt
            base = res["lru"]
            for s in SYSTEMS:
                row = derive_table1_row(res[s], base)
                rows[s]["hit"].append(row["hit_rate_pct"])
                rows[s]["lat"].append(row["latency_reduction_pct"])
                rows[s]["pow"].append(row["power_reduction_pct"])
                rows[s]["speed"].append(row["speedup"])
                if row["relationship_accuracy_pct"] is not None:
                    rows[s]["acc"].append(row["relationship_accuracy_pct"])

    table = {}
    print("\n== Table 1: system comparison "
          f"(ours, mean±std over {n_trials} trials x 3 workloads | paper) ==")
    print(f"{'system':9s} {'hit%':>16s} {'lat.red%':>16s} {'pow.red%':>16s} "
          f"{'rel.acc%':>14s} {'speedup':>8s}")
    for s in SYSTEMS:
        h, hs = agg(rows[s]["hit"])
        l, ls = agg(rows[s]["lat"])
        p, ps = agg(rows[s]["pow"])
        sp, _ = agg(rows[s]["speed"])
        a = agg(rows[s]["acc"])[0] if rows[s]["acc"] else None
        pp = PAPER[s]
        acc_s = f"{a:6.1f}|{pp['acc']}" if a is not None else "   n/a"
        print(f"{s:9s} {h:6.1f}±{hs:4.2f}|{pp['hit']:5.1f} "
              f"{l:6.1f}±{ls:4.2f}|{pp['lat']:5.1f} "
              f"{p:6.1f}±{ps:4.2f}|{pp['pow']:5.1f} {acc_s:>14s} {sp:7.2f}x")
        table[s] = dict(hit=(h, hs), lat=(l, ls), pow=(p, ps), acc=a,
                        speedup=sp, paper=pp)
        emit(f"table1.{s}.hit_rate_pct", h, f"paper={pp['hit']}")
    save_json("table1", table)
    return table


if __name__ == "__main__":
    run()
