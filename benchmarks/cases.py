"""§6.3 case studies: database joins, ML training, HFT market data.

Paper claims: DB hit 84.7% -> 97.8% with 43% fewer I/O ops; ML case
"623% faster gradient computation ... bandwidth -39%"; HFT sub-100ns
relationship discovery vs 2.3-7.8 us heuristics with 12.4% FP.
We reproduce the cache-level metrics that drive those numbers and report
the model-derived latency per discovery.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (DEFAULT_COSTS, db_join_trace, hft_trace,
                        ml_epoch_trace, simulate_baseline, simulate_pfcs,
                        simulate_semantic)
from repro.core.pfcs_cache import PFCSCache

from .common import emit, save_bench, save_json


def case_db(seed: int = 0):
    caps = (("L1", 128), ("L2", 512), ("L3", 4096))
    tr = db_join_trace(n_orders=8000, n_customers=1000, n_items=2000,
                       n_queries=30000, seed=seed)
    lru = simulate_baseline("lru", tr, caps)
    pfcs = simulate_pfcs(tr, caps)
    io_reduction = 1.0 - pfcs.misses / max(1, lru.misses)
    print("\n== Case study: production database (paper: 84.7%->97.8% hit, "
          "-43% I/O) ==")
    print(f"  hit rate: {lru.hit_rate*100:.1f}% -> {pfcs.hit_rate*100:.1f}%")
    print(f"  backing-store I/O reduction: {io_reduction*100:.1f}%")
    emit("case_db.hit_lru_pct", lru.hit_rate * 100)
    emit("case_db.hit_pfcs_pct", pfcs.hit_rate * 100)
    emit("case_db.io_reduction_pct", io_reduction * 100)
    out = dict(lru_hit=lru.hit_rate, pfcs_hit=pfcs.hit_rate,
               io_reduction=io_reduction)
    save_json("case_db", out)
    return out


def case_ml(seed: int = 0):
    caps = (("L1", 128), ("L2", 512), ("L3", 2048))
    tr = ml_epoch_trace(n_samples=6000, n_feature_rows=1500, n_epochs=3,
                        seed=seed)
    lru = simulate_baseline("lru", tr, caps)
    pfcs = simulate_pfcs(tr, caps)
    # memory-bandwidth proxy: bytes moved from backing store
    bw = 1.0 - (pfcs.misses + max(0, pfcs.prefetches_issued
                                  - pfcs.prefetches_used)) / max(1, lru.misses)
    speedup = lru.avg_latency_ns() / pfcs.avg_latency_ns()
    print("\n== Case study: ML training data tier (paper: -39% bandwidth) ==")
    print(f"  hit rate: {lru.hit_rate*100:.1f}% -> {pfcs.hit_rate*100:.1f}%")
    print(f"  access speedup: {speedup:.2f}x   bandwidth delta: {bw*100:+.1f}%")
    emit("case_ml.speedup", speedup)
    emit("case_ml.bandwidth_delta_pct", bw * 100)
    out = dict(lru_hit=lru.hit_rate, pfcs_hit=pfcs.hit_rate, speedup=speedup,
               bandwidth_delta=bw)
    save_json("case_ml", out)
    return out


def case_hft(seed: int = 0):
    caps = (("L1", 256), ("L2", 1024), ("L3", 4096))
    tr = hft_trace(n_instruments=3000, n_corr_groups=400, n_events=30000,
                   seed=seed)
    pfcs = simulate_pfcs(tr, caps)
    sem = simulate_semantic(tr, caps, seed=seed)
    # model-derived relationship-discovery latency: weighted stage costs
    c = DEFAULT_COSTS
    ops = pfcs.factor_ops
    n_disc = max(1, sum(ops.values()))
    disc_ns = (ops.get("table", 0) * c.lat_factor_table
               + ops.get("cache", 0) * c.lat_factor_cache
               + ops.get("trial", 0) * c.lat_factor_trial
               + ops.get("rho", 0) * c.lat_factor_rho) / n_disc
    sem_ns = c.lat_embedding
    fp_rate = 1.0 - (sem.prefetch_precision or 1.0)
    print("\n== Case study: HFT market data (paper: <100ns vs 2.3-7.8us, "
          "0% vs 12.4% FP) ==")
    print(f"  PFCS discovery latency (model): {disc_ns:.0f} ns/op "
          f"(stages: {dict(ops)})")
    print(f"  semantic discovery latency (model): {sem_ns:.0f} ns/op, "
          f"false-positive rate {fp_rate*100:.1f}%")
    print(f"  PFCS false positives: "
          f"{(1.0 - (pfcs.prefetch_precision or 1.0))*100:.2f}% (Theorem 1)")
    emit("case_hft.pfcs_discovery_ns", disc_ns)
    emit("case_hft.semantic_fp_pct", fp_rate * 100)
    out = dict(discovery_ns=disc_ns, semantic_fp=fp_rate,
               pfcs_hit=pfcs.hit_rate, semantic_hit=sem.hit_rate)
    save_json("case_hft", out)
    return out


def case_scale(smoke: bool = False, quiet=None):
    """Million-element wide-registry scale case (the former 62-bit
    ceiling, DESIGN.md §11).

    Registers 1M data elements through Algorithm 1's MEM pool, builds
    10k chains 100 deep (pairwise edges: ~990k composites) plus deep
    whole-chain *group* relationships whose canonical chunks exceed
    int64 — exactly the composites PR 6's guard used to reject with
    ``OverflowError`` and the multi-limb registry now represents.  A
    sampled sub-universe is then verified differentially: the limb
    divisibility scan, staged factorization, and pairwise gcd kernels
    against exact Python-int arithmetic, with zero false positives
    asserted by re-factorization (Theorem 1).

    Every reported metric except the ``*_wall_s`` timings is a
    deterministic counter (fixed seeds, ascending allocation), so the
    checked-in ``BENCH_case_scale.json`` gates the whole wide path.
    """
    from repro.core.assignment import PrimeAssigner
    from repro.core.composite import (CompositeRegistry,
                                      encode_relationship)
    from repro.core.primes import CacheLevel, HierarchicalPrimeAllocator
    from repro.kernels import (divisibility_scan_limbs,
                               factorize_batch_exact, gcd_batch_exact)
    from repro.obs import profile
    from repro.obs.telemetry import Progress

    # progress lines default off under smoke (the CI path, where they
    # only bloat logs) and on for interactive full runs; the rate
    # accounting itself always feeds the wall-clock-exempt obs block
    if quiet is None:
        quiet = smoke

    n_chains, depth, max_bits = 10_000, 100, 1024
    group_stride = 16                 # every 16th chain -> 625 groups
    n_verify_chains = 24 if smoke else 64

    registry = CompositeRegistry(max_bits=max_bits)
    assigner = PrimeAssigner(HierarchicalPrimeAllocator(), registry)

    # -- build: 1M elements, 10k chains 100 deep ------------------------
    # streamed batched build (assign_many / register_many) — bit-
    # identical registry state to the per-element loop (pinned in
    # tests/test_pfcs_core.py::test_batched_build_state_identity),
    # minus the ~20s of per-call Python overhead the scalar loop paid
    t0 = time.perf_counter()
    prime_of = assigner.assign_many(range(n_chains * depth),
                                    CacheLevel.MEM)
    assign_wall = time.perf_counter() - t0

    t0 = time.perf_counter()
    prog = Progress(n_chains, label="register chains", quiet=quiet)
    for c in range(n_chains):
        base = c * depth
        row = prime_of[base:base + depth]
        registry.register_many(zip(row, row[1:]), kind="chain")
        if c % group_stride == 0:
            registry.register(row, kind="group")   # -> wide chunks
        prog.advance()
    build_rate = prog.finish()
    register_wall = time.perf_counter() - t0

    comps = registry.composites_list()
    wide = [c for c in comps if c.bit_length() > 63]
    assert wide, "scale case must exercise composites beyond int64"
    max_comp_bits = max(c.bit_length() for c in comps)

    # -- differential verification on a sampled sub-universe ------------
    # half the sampled chains carry a group relationship, half are
    # edge-only; member primes of the sampled chains + small never-
    # assigned primes form the query pool (MEM primes start >= 1e6, so
    # 2..53 can never divide anything — negative controls).
    sample_chains = ([c for c in range(0, n_chains, group_stride)
                      [:n_verify_chains // 2]]
                     + [c for c in range(1, n_chains, group_stride)
                        [:n_verify_chains // 2]])
    pool = sorted({p for c in sample_chains
                   for p in prime_of[c * depth:(c + 1) * depth]})
    negatives = [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43,
                 47, 53]
    sample = []
    for c in sample_chains:
        row = prime_of[c * depth:(c + 1) * depth]
        sample.extend(a * b for a, b in zip(row, row[1:]))
        if c % group_stride == 0:
            sample.extend(encode_relationship(row, max_bits))
    assert all(c in registry._by_composite for c in sample)

    from repro.core.composite import n_limbs_for_bits, pack_limbs
    L = n_limbs_for_bits(max_bits)
    limbs = pack_limbs(sample, L)
    queries = pool[::7] + negatives

    profile.reset()
    profile.enable(True)        # launch ledger -> obs block (exempt)
    t0 = time.perf_counter()
    idx = divisibility_scan_limbs(limbs, queries)
    scan_wall = time.perf_counter() - t0
    scan_hits = 0
    for j, q in enumerate(queries):
        want = [i for i, c in enumerate(sample) if c % q == 0]
        assert list(idx[j]) == want, f"limb scan diverged at prime {q}"
        scan_hits += len(want)
    assert all(not len(idx[len(queries) - 16 + k]) for k in range(16)), \
        "negative-control primes must hit nothing (Theorem 1)"

    t0 = time.perf_counter()
    factors, residual = factorize_batch_exact(sample, pool)
    factor_wall = time.perf_counter() - t0
    false_pos = 0
    for c, fs, r in zip(sample, factors, residual):
        prod = 1
        for p in fs:
            if c % p != 0:
                false_pos += 1
            prod *= p
        assert prod * int(r) == c, "factor recovery must be exact"
        assert int(r) == 1, "pool covers every member: residual must be 1"
    assert false_pos == 0, "Theorem 1: zero false positives"

    # gcd: each sampled group chunk vs its chain's first edge — the
    # shared primes reconstruct exactly
    ga = [c for c in sample if c.bit_length() > 63]
    gb = [prime_of[c * depth] * prime_of[c * depth + 1]
          for c in sample_chains if c % group_stride == 0
          for _ in range(len(encode_relationship(
              prime_of[c * depth:(c + 1) * depth], max_bits)))]
    gb = gb[:len(ga)]
    import math as _math
    gs = gcd_batch_exact(ga, gb, pool)
    assert gs == [_math.gcd(a, b) for a, b in zip(ga, gb)], \
        "limb gcd diverged from exact host gcd"
    gcd_nontrivial = sum(1 for g in gs if g > 1)
    profile.enable(False)
    launches = profile.summary()

    print(f"\n== Case study: million-element wide registry "
          f"(max_bits={max_bits}, {L} limbs) ==")
    print(f"  elements {len(prime_of):,}   chains {n_chains:,} x {depth} "
          f"deep   composites {len(comps):,} ({len(wide):,} beyond "
          f"int64, widest {max_comp_bits} bits)")
    print(f"  verified {len(sample)} composites x {len(queries)} query "
          f"primes: scan hits {scan_hits}, false positives {false_pos}, "
          f"gcd pairs {len(gs)} ({gcd_nontrivial} nontrivial)")
    print(f"  walls: assign {assign_wall:.1f}s  register "
          f"{register_wall:.1f}s  scan {scan_wall:.2f}s  factorize "
          f"{factor_wall:.2f}s")

    emit("case_scale.n_elements", len(prime_of))
    emit("case_scale.n_composites", len(comps))
    emit("case_scale.n_wide_composites", len(wide))
    emit("case_scale.max_composite_bits", max_comp_bits)
    emit("case_scale.factor_false_positives", false_pos)
    out = dict(
        n_elements=len(prime_of), n_chains=n_chains, chain_depth=depth,
        registry_max_bits=max_bits, n_limbs=L,
        n_relationships=len(registry), n_composites=len(comps),
        n_wide_composites=len(wide), max_composite_bits=max_comp_bits,
        max_prime=max(prime_of),
        verify=dict(
            n_verified=len(sample), n_query_primes=len(queries),
            scan_hits=scan_hits, factor_false_positives=false_pos,
            residual_all_one=True, gcd_pairs=len(gs),
            gcd_nontrivial=gcd_nontrivial,
        ),
        assign_wall_s=assign_wall, register_wall_s=register_wall,
        scan_wall_s=scan_wall, factor_wall_s=factor_wall,
        # wall-clock-exempt reporting block (gate skips the whole
        # component — tools/check_bench_regression.py EXEMPT_COMPONENTS)
        obs=dict(registry_build=build_rate, kernel_launches=launches),
    )
    save_json("case_scale", out)
    save_bench("case_scale", out)
    return out


def case_serving(smoke: bool = False, shards=None):
    """Serving-layer load benchmark: continuous batching over the paged
    KV cache.

    Drives the null-model engine (pure page management — the serving
    hot path under test) with a shared-prefix request mix through three
    cache configurations:

      * ``pfcs_vec``    — vectorized array-state cache, table-driven
        bulk discovery (the production path; ZERO per-page registry
        scans on the touch path);
      * ``pfcs_scalar`` — the scalar oracle (one §4.2 divisibility scan
        per touched page) — bit-exact same placement, so the wall-clock
        delta isolates the discovery/representation cost;
      * ``lru``         — prefetch disabled: plain LRU paging, the
        baseline a statistical-prefetch-free server would run;

    plus a ``--shards`` sweep of ``pfcs_shard{N}`` configurations —
    the mesh-partitioned :class:`~repro.serving.kv_cache_sharded.
    ShardedPagedKVCache` (DESIGN.md §6) at N shards each (default sweep
    1/2/4; smoke runs 2 only).  Sharded runs use ``shard_map`` when the
    host exposes >= N devices (CI forces a 2-device CPU mesh via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=2``) and the
    bit-identical host loop otherwise; either way their counters must
    match the scalar oracle exactly.

    Reports throughput, mean TTFT, HBM hit rate, prefetch hit rate, and
    peak per-step concurrency; asserts counter parity between the vec /
    sharded and scalar runs and (non-smoke) >= 100 concurrent
    requests/step.
    """
    from repro.serving.engine import ServingEngine

    # HBM is sized BELOW the live working set (live slots x reread
    # window) on purpose: that is the regime where placement policy
    # decides everything — plain LRU collapses under the sequential
    # window re-reads (scan thrash) while chain prefetch pipelines the
    # next page just-in-time.  Capacity-rich configs make any policy
    # look perfect; see EXPERIMENTS.md for the sweep.
    if smoke:
        n_req, max_batch, max_new = 48, 16, 8
        hbm, shared_tok, window = 24, 64, 2
        shard_sweep = (2,) if shards is None else tuple(shards)
    else:
        n_req, max_batch, max_new = 256, 128, 32
        hbm, shared_tok, window = 384, 128, 4
        shard_sweep = (1, 2, 4) if shards is None else tuple(shards)

    def run(kv: str, budget: int, n_shards: int = 1):
        rng = np.random.default_rng(0)
        eng = ServingEngine(None, None, max_batch=max_batch, page_size=16,
                            hbm_pages=hbm, kv=kv, prefetch_budget=budget,
                            reread_window=window, shards=n_shards)
        groups = [list(rng.integers(0, 30_000, size=shared_tok))
                  for _ in range(max(1, n_req // 8))]
        for r in range(n_req):
            tail = list(rng.integers(0, 30_000,
                                     size=int(rng.integers(48, 129))))
            eng.submit(groups[r % len(groups)] + tail,
                       max_new_tokens=max_new)
        t0 = time.perf_counter()
        done = eng.run_until_idle()
        wall = time.perf_counter() - t0
        toks = sum(len(r.generated) for r in done)
        ttfts = [r.first_token_t - r.submit_t for r in done
                 if r.first_token_t is not None]
        st = eng.pages.stats
        out = dict(
            completed=len(done), wall_s=wall,
            tok_per_s=toks / max(wall, 1e-9),
            req_per_s=len(done) / max(wall, 1e-9),
            mean_ttft_s=float(np.mean(ttfts)) if ttfts else 0.0,
            peak_concurrency=eng.peak_live,
            hbm_hit_rate=st.hbm_hit_rate,
            prefetch_hit_rate=st.prefetch_hit_rate,
            registry_scans=st.registry_scans,
            bulk_refreshes=getattr(eng.pages, "bulk_refreshes", None),
            parity=st.parity_tuple(),
        )
        if kv == "sharded":
            scan = eng.pages.last_scan
            out.update(
                shards=n_shards, used_shard_map=scan.used_shard_map,
                local_composites=list(scan.local_composites),
                cross_composites=scan.cross_composites,
                queries_per_shard=list(scan.queries_per_shard),
                shard_load=eng.pages.shard_load(),
                shard_agg_parity=eng.pages.aggregate_shard_stats()
                                    .parity_tuple(),
            )
        return out

    res = {"pfcs_vec": run("vec", 4),
           "pfcs_scalar": run("scalar", 4),
           "lru": run("vec", 0)}
    for n in shard_sweep:
        res[f"pfcs_shard{n}"] = run("sharded", 4, n_shards=n)

    # the vectorized / sharded caches are implementations, not
    # estimators: their counters must match the scalar oracle exactly
    assert res["pfcs_vec"]["parity"] == res["pfcs_scalar"]["parity"], \
        "vectorized serving cache diverged from the scalar oracle"
    assert res["pfcs_vec"]["registry_scans"] == 0, \
        "vectorized touch path performed a per-page registry scan"
    for n in shard_sweep:
        r = res[f"pfcs_shard{n}"]
        assert r["parity"] == res["pfcs_scalar"]["parity"], \
            f"sharded cache ({n} shards) diverged from the scalar oracle"
        assert r["shard_agg_parity"] == r["parity"], \
            f"per-shard stats ({n} shards) do not aggregate to the total"
        assert r["registry_scans"] == 0, \
            "sharded touch path performed a per-page registry scan"
    if not smoke:
        assert res["pfcs_vec"]["peak_concurrency"] >= 100, \
            "load benchmark must sustain >= 100 concurrent requests/step"

    print("\n== Case study: serving load (paged KV, continuous batching, "
          f"{n_req} requests, {max_batch} slots) ==")
    hdr = (f"  {'config':<12} {'tok/s':>9} {'ttft_ms':>8} {'hbm_hit%':>9} "
           f"{'pf_hit%':>8} {'scans':>7} {'conc':>5}")
    print(hdr)
    for name, r in res.items():
        print(f"  {name:<12} {r['tok_per_s']:>9.0f} "
              f"{r['mean_ttft_s']*1e3:>8.1f} {r['hbm_hit_rate']*100:>9.1f} "
              f"{r['prefetch_hit_rate']*100:>8.1f} "
              f"{r['registry_scans']:>7d} {r['peak_concurrency']:>5d}")
    speedup = res["pfcs_scalar"]["wall_s"] / max(res["pfcs_vec"]["wall_s"],
                                                 1e-9)
    print(f"  vec vs scalar cache wall-clock: {speedup:.2f}x   "
          f"PFCS vs LRU hbm hit: "
          f"{res['pfcs_vec']['hbm_hit_rate']*100:.1f}% vs "
          f"{res['lru']['hbm_hit_rate']*100:.1f}%")
    for n in shard_sweep:
        r = res[f"pfcs_shard{n}"]
        peak_local = max(r["local_composites"]) if r["local_composites"] \
            else 0
        print(f"  shard{n}: shard_map={r['used_shard_map']} "
              f"per-shard local composites={r['local_composites']} "
              f"cross={r['cross_composites']} "
              f"(peak scan slice {peak_local} of "
              f"{sum(r['local_composites']) + r['cross_composites']})")
        emit(f"case_serving.shard{n}_tok_per_s", r["tok_per_s"])
        emit(f"case_serving.shard{n}_cross_composites",
             r["cross_composites"])
    emit("case_serving.vec_tok_per_s", res["pfcs_vec"]["tok_per_s"])
    emit("case_serving.vec_mean_ttft_ms",
         res["pfcs_vec"]["mean_ttft_s"] * 1e3)
    emit("case_serving.vec_hbm_hit_pct",
         res["pfcs_vec"]["hbm_hit_rate"] * 100)
    emit("case_serving.vec_vs_scalar_speedup", speedup)
    emit("case_serving.lru_hbm_hit_pct", res["lru"]["hbm_hit_rate"] * 100)
    out = {k: {kk: vv for kk, vv in v.items()
               if kk not in ("parity", "shard_agg_parity")}
           for k, v in res.items()}
    out["vec_vs_scalar_speedup"] = speedup
    save_json("case_serving", out)
    save_bench("case_serving", {
        "wall_s": {k: res[k]["wall_s"] for k in res},
        "tok_per_s": {k: res[k]["tok_per_s"] for k in res},
        "hbm_hit_rate": {k: res[k]["hbm_hit_rate"] for k in res},
        "prefetch_hit_rate": {k: res[k]["prefetch_hit_rate"] for k in res},
        "registry_scans": {k: res[k]["registry_scans"] for k in res},
        "vec_vs_scalar_speedup": speedup,
    })
    return out


def case_elastic(smoke: bool = False):
    """Elastic resharding + shard-loss recovery under serving load
    (DESIGN.md §9).

    Runs the IDENTICAL request stream twice through the null-model
    engine:

      * ``scalar``  — uninterrupted scalar-oracle run;
      * ``elastic`` — :class:`~repro.serving.elastic.
        ElasticShardedPagedKVCache` hit mid-serve by a resize storm
        (2 -> 4 -> 2 -> ...) plus a shard-loss schedule: periodic kills
        with measured recovery latency, and one deferred kill whose
        shard is rebuilt lazily by failover-on-demand at the next touch.

    Reports recovery latency, migrated bytes vs the naive full-rebuild
    baseline (a resize that re-registered every composite), and hit
    rates; asserts bit-exact parity between the two runs — every chaos
    event must be invisible to placement, tokens, and counters — and
    that the incremental migration moved strictly less than a rebuild.
    """
    from repro.serving.engine import ServingEngine

    if smoke:
        n_req, max_batch, max_new = 64, 16, 8
        hbm, shared_tok, window = 24, 64, 2
    else:
        n_req, max_batch, max_new = 192, 64, 16
        hbm, shared_tok, window = 128, 96, 3

    def build(kv: str) -> ServingEngine:
        rng = np.random.default_rng(0)
        eng = ServingEngine(None, None, max_batch=max_batch, page_size=8,
                            hbm_pages=hbm, kv=kv, prefetch_budget=4,
                            reread_window=window, shards=2)
        groups = [list(rng.integers(0, 30_000, size=shared_tok))
                  for _ in range(max(1, n_req // 8))]
        for r in range(n_req):
            tail = list(rng.integers(0, 30_000,
                                     size=int(rng.integers(48, 129))))
            eng.submit(groups[r % len(groups)] + tail,
                       max_new_tokens=max_new)
        return eng

    def drain(eng: ServingEngine, chaos: bool):
        done, step = [], 0
        recovery_s = []
        t0 = time.perf_counter()
        while eng.queue or any(s is not None for s in eng.slots):
            if chaos:
                if step % 3 == 2:               # resize storm: 2<->4
                    eng.resize(4 if eng.pages.n_shards == 2 else 2)
                if step % 4 == 1:               # kill + timed recovery
                    t1 = time.perf_counter()
                    eng.fail_shard(step % eng.pages.n_shards)
                    recovery_s.append(time.perf_counter() - t1)
                if step == 5:                   # failover-on-demand path
                    eng.fail_shard(0, recover=False)
            before = list(eng.slots)
            eng.step()
            done.extend(r for r in before
                        if r is not None and r.state == "done")
            step += 1
        return done, time.perf_counter() - t0, recovery_s

    oracle = build("scalar")
    done_o, wall_o, _ = drain(oracle, chaos=False)
    eng = build("elastic")
    done_e, wall_e, recovery_s = drain(eng, chaos=True)

    # chaos must be invisible: tokens, counters, LRU order, prefetch log
    key = lambda rs: [(r.req_id, tuple(r.generated))
                      for r in sorted(rs, key=lambda r: r.req_id)]
    assert key(done_e) == key(done_o), \
        "elastic chaos run diverged from the uninterrupted oracle"
    st_e, st_o = eng.pages.stats, oracle.pages.stats
    assert st_e.parity_tuple() == st_o.parity_tuple(), \
        "elastic counters diverged from the scalar oracle"
    assert list(eng.pages.hbm.items()) == list(oracle.pages.hbm.items())
    assert eng.pages.prefetch_log == oracle.pages.prefetch_log
    assert st_e.registry_scans == 0
    assert (eng.pages.aggregate_shard_stats().parity_tuple()
            == st_e.parity_tuple())

    plans = eng.pages.reshard_log
    migrated = sum(p.migrated_bytes for p in plans)
    full_rebuild = sum(p.full_rebuild_bytes for p in plans)
    moved = sum(len(p.moved) for p in plans)
    assert plans and moved > 0, \
        "resize storm never moved a block — workload too small"
    assert migrated < full_rebuild, \
        "incremental migration must beat the naive full rebuild"
    reports = eng.pages.recovery_log
    assert eng.pages.recoveries >= 2 and reports
    assert any(r.mode == "partial" for r in reports)

    out = dict(
        wall_s_oracle=wall_o, wall_s_elastic=wall_e,
        tok_per_s=sum(len(r.generated) for r in done_e)
        / max(wall_e, 1e-9),
        n_resizes=len(plans), n_recoveries=eng.pages.recoveries,
        moved_blocks=moved,
        migrated_bytes=migrated, full_rebuild_bytes=full_rebuild,
        migrated_ratio=migrated / max(full_rebuild, 1),
        recovery_latency_mean_s=float(np.mean(recovery_s)),
        recovery_latency_max_s=float(np.max(recovery_s)),
        refactorized=sum(r.refactorized for r in reports),
        rows_rebuilt=sum(r.rows_rebuilt for r in reports),
        hbm_hit_rate=st_e.hbm_hit_rate,
        prefetch_hit_rate=st_e.prefetch_hit_rate,
    )
    print("\n== Case study: elastic serving (resize storm + shard loss, "
          f"{n_req} requests, {len(plans)} resizes, "
          f"{eng.pages.recoveries} recoveries) ==")
    print(f"  parity with uninterrupted oracle: EXACT "
          f"(tiers/counters/LRU/prefetch-log)")
    print(f"  migrated {migrated} B over {moved} moved blocks vs "
          f"{full_rebuild} B naive full rebuild "
          f"({100 * out['migrated_ratio']:.1f}%)")
    print(f"  recovery latency mean {out['recovery_latency_mean_s']*1e3:.2f}"
          f" ms  max {out['recovery_latency_max_s']*1e3:.2f} ms  "
          f"({out['refactorized']} composites refactorized, "
          f"{out['rows_rebuilt']} rows rebuilt)")
    emit("case_elastic.migrated_bytes", migrated)
    emit("case_elastic.full_rebuild_bytes", full_rebuild)
    emit("case_elastic.migrated_ratio_pct", out["migrated_ratio"] * 100)
    emit("case_elastic.recovery_latency_ms",
         out["recovery_latency_mean_s"] * 1e3)
    emit("case_elastic.tok_per_s", out["tok_per_s"])
    save_json("case_elastic", out)
    save_bench("case_elastic", out)
    return out


def case_moe(smoke: bool = False, real_router: bool = None):
    """MoE expert-serving load benchmark: router-driven co-activation
    over the PFCS expert cache (DESIGN.md §7).

    Replays ONE deterministic router schedule through three cache
    configurations.  The expert universe models the stacked MoE layers
    of a real deployment (kimi-k2: 384 routed experts x 61 layers): a
    HOT cluster set the schedule draws from (specialized co-firing
    groups, the DeepSeek/Kimi expert-specialization picture) plus COLD
    clusters — other layers' accumulated co-activation structure that
    lives in the same registry but is rarely routed.  The cold
    structure is what separates the implementations: the scalar
    oracle's per-activation §4.2 scan pays O(total registry) while the
    table path pays O(row).  Weight use is staggered by the expert
    all-to-all schedule (head expert first, co-fired tail after), so
    head-triggered prefetch pipelines the tail host→HBM just-in-time.
    HBM is sized AT the per-step demand and far below the expert
    universe — the regime where placement policy decides everything:

      * ``pfcs_vec``    — :class:`~repro.serving.expert_cache_vec.
        VectorizedExpertCache`: array residency + table-driven bulk
        co-fire discovery (the production path; ZERO per-expert
        registry scans on the activation path);
      * ``pfcs_scalar`` — the scalar oracle (one §4.2 divisibility scan
        per activated expert) — bit-exact same placement, so the
        wall-clock delta isolates discovery/representation cost;
      * ``lru``         — prefetch disabled: plain LRU expert
        residency, the baseline a co-activation-blind server would run.

    Reports throughput (activations/s), demand-miss stalls, HBM hit
    rate, and prefetch precision; asserts counter AND prefetch-log
    parity between the vec and scalar runs.  A second block drives the
    continuous-batching engine end-to-end: the synthetic-router
    load-generator mode always, plus (``real_router``, default on for
    non-smoke) a real smoke-scale MoE model whose ``apply_moe`` top-k
    sets feed the cache through ``Model.decode_step_router``.
    """
    from repro.serving.engine import ServingEngine
    from repro.serving.expert_cache import ExpertCache
    from repro.serving.expert_cache_vec import VectorizedExpertCache

    if real_router is None:
        real_router = not smoke
    if smoke:
        E, hot_e, slots, topk, steps, B = 256, 64, 16, 4, 150, 4
        eng_req, eng_batch = 24, 8
    else:
        # 4096 experts ~ a few stacked MoE layers of a kimi-k2-class
        # deployment (384 routed experts x 61 layers = 23k total); the
        # 256-expert hot set is the layer group the schedule routes to
        E, hot_e, slots, topk, steps, B = 4096, 256, 64, 8, 1000, 8
        eng_req, eng_batch = 96, 32

    rng = np.random.default_rng(0)
    perm = rng.permutation(hot_e)
    hot = [tuple(int(e) for e in perm[i:i + topk])
           for i in range(0, hot_e - topk + 1, topk)]
    cold = [tuple(range(i, i + topk))
            for i in range(hot_e, E - topk + 1, topk)]
    schedule = [[hot[int(rng.integers(len(hot)))] for _ in range(B)]
                for _ in range(steps)]

    def run(cls, budget):
        ec = cls(E, hbm_slots=slots, prefetch_budget=budget)
        ec.observe_routing(cold)       # accumulated cross-layer structure
        t0 = time.perf_counter()
        for batch in schedule:
            ec.observe_routing(batch)
            # weight use is staggered by the expert all-to-all schedule:
            # the head expert's activation prefetches the co-fired tail
            # host->HBM before the tail's wave demands it
            ec.activate_batch([g[:1] for g in batch])
            ec.activate_batch([g[1:] for g in batch])
        wall = time.perf_counter() - t0
        s = ec.stats
        return dict(
            wall_s=wall,
            activations_per_s=steps * B * topk / max(wall, 1e-9),
            hbm_hit_rate=s.hit_rate,
            demand_misses=s.misses,
            prefetch_precision=s.prefetch_precision,
            registry_scans=s.registry_scans,
            parity=s.parity_tuple(),
            prefetch_log=tuple(ec.prefetch_log),
        )

    # budget = the full co-fired tail: one head activation pipelines the
    # whole group host->HBM ahead of the all-to-all
    res = {"pfcs_vec": run(VectorizedExpertCache, topk - 1),
           "pfcs_scalar": run(ExpertCache, topk - 1),
           "lru": run(VectorizedExpertCache, 0)}

    # the vectorized cache is an implementation, not an estimator: its
    # counters AND its (source, target) prefetch decisions must match
    # the scalar oracle exactly (Theorem 1 is a statement about exact
    # discovery, not aggregate rates)
    assert res["pfcs_vec"]["parity"] == res["pfcs_scalar"]["parity"], \
        "vectorized expert cache diverged from the scalar oracle"
    assert (res["pfcs_vec"]["prefetch_log"]
            == res["pfcs_scalar"]["prefetch_log"]), \
        "vectorized expert cache issued different prefetches"
    assert res["pfcs_vec"]["registry_scans"] == 0, \
        "vectorized activation path performed a per-expert registry scan"
    assert res["lru"]["prefetch_log"] == ()

    speedup = res["pfcs_scalar"]["wall_s"] / max(res["pfcs_vec"]["wall_s"],
                                                 1e-9)
    print("\n== Case study: MoE expert serving (router-driven "
          f"co-activation, {E} experts / {hot_e} hot, {slots} HBM slots, "
          f"top-{topk}, {steps}x{B} router sets, "
          f"{len(hot) + len(cold)} registered groups) ==")
    print(f"  {'config':<12} {'acts/s':>10} {'hbm_hit%':>9} {'misses':>8} "
          f"{'pf_prec%':>9} {'scans':>8}")
    for name, r in res.items():
        print(f"  {name:<12} {r['activations_per_s']:>10.0f} "
              f"{r['hbm_hit_rate']*100:>9.1f} {r['demand_misses']:>8d} "
              f"{r['prefetch_precision']*100:>9.1f} "
              f"{r['registry_scans']:>8d}")
    print(f"  vec vs scalar cache wall-clock: {speedup:.2f}x   "
          f"PFCS vs LRU hbm hit: "
          f"{res['pfcs_vec']['hbm_hit_rate']*100:.1f}% vs "
          f"{res['lru']['hbm_hit_rate']*100:.1f}%")

    # -- engine block: synthetic-router load generator ------------------ #
    eng = ServingEngine(None, None, max_batch=eng_batch, page_size=16,
                        hbm_pages=eng_batch * 3, moe="vec",
                        moe_experts=hot_e, moe_slots=slots, moe_topk=topk,
                        moe_groups=len(hot))
    rng = np.random.default_rng(1)
    for r in range(eng_req):
        eng.submit(list(rng.integers(0, 30_000,
                                     size=int(rng.integers(16, 64)))),
                   max_new_tokens=8)
    t0 = time.perf_counter()
    done = eng.run_until_idle()
    wall = time.perf_counter() - t0
    es = eng.experts.stats
    res["engine_loadgen"] = dict(
        completed=len(done),
        tok_per_s=sum(len(r.generated) for r in done) / max(wall, 1e-9),
        expert_hit_rate=es.hit_rate, expert_misses=es.misses,
        prefetch_precision=es.prefetch_precision,
        registry_scans=es.registry_scans)
    print(f"  engine loadgen: {res['engine_loadgen']['tok_per_s']:.0f} tok/s "
          f"expert hit {es.hit_rate*100:.1f}% misses {es.misses} "
          f"pf_prec {es.prefetch_precision*100:.1f}%")

    # -- engine block: real router (smoke-scale MoE model) --------------- #
    if real_router:
        import jax

        from repro.configs import get_smoke
        from repro.models import build_model

        cfg = get_smoke("kimi-k2-1t-a32b")
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        reng = ServingEngine(model, params, max_batch=2, max_seq=96,
                             page_size=8, moe="vec", moe_slots=4,
                             moe_prefetch_budget=4)
        for i in range(4):
            reng.submit(list(range(12)) + [20 + i], max_new_tokens=4)
        reng.run_until_idle()
        rs = reng.experts.stats
        false_pos = sum(1 for src, tgt in reng.experts.prefetch_log
                        if tgt not in reng.experts.coactivated(src))
        res["engine_real_router"] = dict(
            arch=cfg.name, n_experts=cfg.moe.n_experts,
            expert_hit_rate=rs.hit_rate, prefetches=rs.prefetches,
            prefetch_precision=rs.prefetch_precision,
            false_positive_prefetches=false_pos)
        assert false_pos == 0, "Theorem 1 violated on live router traffic"
        print(f"  engine real-router ({cfg.name}): expert hit "
              f"{rs.hit_rate*100:.1f}% prefetches {rs.prefetches} "
              f"false-positives {false_pos} (Theorem 1)")

    emit("case_moe.vec_acts_per_s", res["pfcs_vec"]["activations_per_s"])
    emit("case_moe.vec_hbm_hit_pct", res["pfcs_vec"]["hbm_hit_rate"] * 100)
    emit("case_moe.vec_vs_scalar_speedup", speedup)
    emit("case_moe.lru_hbm_hit_pct", res["lru"]["hbm_hit_rate"] * 100)
    emit("case_moe.vec_prefetch_precision_pct",
         res["pfcs_vec"]["prefetch_precision"] * 100)
    out = {k: {kk: vv for kk, vv in v.items()
               if kk not in ("parity", "prefetch_log")}
           for k, v in res.items()}
    out["vec_vs_scalar_speedup"] = speedup
    save_json("case_moe", out)
    cache_cfgs = ("pfcs_vec", "pfcs_scalar", "lru")
    save_bench("case_moe", {
        "hbm_hit_rate": {k: res[k]["hbm_hit_rate"] for k in cache_cfgs},
        "demand_misses": {k: res[k]["demand_misses"] for k in cache_cfgs},
        "prefetch_precision": {k: res[k]["prefetch_precision"]
                               for k in cache_cfgs},
        "registry_scans": {k: res[k]["registry_scans"]
                           for k in cache_cfgs},
        "engine_loadgen": {k: res["engine_loadgen"][k]
                           for k in ("completed", "expert_hit_rate",
                                     "expert_misses",
                                     "prefetch_precision",
                                     "registry_scans")},
        "vec_vs_scalar_speedup": speedup,
    })
    return out


def case_tenancy(smoke: bool = False):
    """Multi-tenant QoS load benchmark: mixed-tenant traffic over the
    coprime-namespace serving cache (DESIGN.md §8).

    One engine serves three tenant classes at once — the regime where a
    shared cache's placement is a fairness weapon:

      * **hot** (tenant 0) — zipf-popular shared prefixes, many short
        decodes: the tenant with cache-friendly structure to protect;
      * **cold** (tenants 1..T-2) — sparse unique traffic;
      * **scanner** (tenant T-1) — adversarial long-chain sweeps, the
        LRU-thrash pattern that evicts everyone in a shared cache.

    Every tenant submits the SAME total token demand, so the fairness
    ratio — max/min per-tenant COMPLETION rate, each tenant's tokens
    over its own first-submit -> last-completion span — reads
    directly: a starved tenant finishes late and its rate drops
    (tokens over total wall would be blind to starvation, since every
    request eventually completes).

    Asserts: tenanted vec == tenanted scalar bit-exact (global stats,
    per-tenant stats, prefetch logs), ZERO cross-tenant prefetches
    (the namespace isolation theorem, audited on the live log), the
    isolation checker over the final registry, and quota occupancy
    bounds.  Reports per-tenant hit rate / prefetch precision / TTFT,
    the fairness ratio, and a quota-vs-shared protection A/B: the hot
    tenant's hit rate with QoS quotas vs the same traffic through one
    shared (untenanted) cache the scanner is free to thrash.
    """
    from repro.serving.engine import ServingEngine
    from repro.serving.kv_cache_vec import VectorizedPagedKVCache
    from repro.tenancy import TenantQoSConfig, TenantedVectorizedPagedKVCache

    if smoke:
        n_cold, hbm, max_batch = 2, 32, 16
        hot_req, cold_req, scan_req = 12, 6, 8
        hot_new, cold_new, scan_new = 8, 16, 12
        scan_prompt, shared_tok = 192, 48
    else:
        n_cold, hbm, max_batch = 6, 128, 64
        hot_req, cold_req, scan_req = 48, 8, 16
        hot_new, cold_new, scan_new = 8, 48, 24
        scan_prompt, shared_tok = 512, 96
    T = n_cold + 2
    hot, scanner = 0, T - 1
    # hot tenant earns a weighted share; scanner gets the same share as
    # a cold tenant — QoS is the contract, not the workload's appetite
    cfg = TenantQoSConfig.weighted(hbm, [4] + [1] * n_cold + [1],
                                   prefetch_budget=4)

    def submit_all(eng):
        """Round-robin mixed-tenant submission (identical across runs);
        returns request -> tenant attribution."""
        rng = np.random.default_rng(0)
        groups = [list(rng.integers(0, 30_000, size=shared_tok))
                  for _ in range(4)]
        reqs = []
        for _ in range(hot_req):           # zipf-hot shared prefixes
            g = groups[min(int(rng.zipf(1.5)) - 1, 3)]
            tail = list(rng.integers(0, 30_000,
                                     size=int(rng.integers(16, 50))))
            reqs.append((hot, g + tail, hot_new))
        for t in range(1, 1 + n_cold):     # sparse unique traffic
            for _ in range(cold_req):
                reqs.append((t, list(rng.integers(0, 30_000,
                                                  size=int(rng.integers(
                                                      24, 80)))), cold_new))
        for i in range(scan_req):          # adversarial long chains
            base = 100_000 + i * scan_prompt
            reqs.append((scanner, list(range(base, base + scan_prompt)),
                         scan_new))
        # round-robin interleave by tenant so every class is always live
        by_t = {t: [r for r in reqs if r[0] == t] for t in range(T)}
        tenant_of_req = {}
        while any(by_t.values()):
            for t in range(T):
                if by_t[t]:
                    tt, prompt, new = by_t[t].pop(0)
                    rid = eng.submit(prompt, max_new_tokens=new, tenant=tt)
                    tenant_of_req[rid] = tt
        return tenant_of_req

    def run(kv: str):
        eng = ServingEngine(None, None, max_batch=max_batch, page_size=16,
                            hbm_pages=hbm, kv=kv, prefetch_budget=4,
                            reread_window=2, tenants=cfg)
        t_of = submit_all(eng)
        t0 = time.perf_counter()
        done = eng.run_until_idle()
        wall = time.perf_counter() - t0
        toks = [0] * T
        ttfts = [[] for _ in range(T)]
        span_lo = [float("inf")] * T     # first submit .. last completion:
        span_hi = [0.0] * T              # a starved tenant finishes LATE,
        #                                  so its completion rate drops —
        #                                  tokens/wall would be blind to
        #                                  starvation (everyone completes)
        for r in done:
            t = t_of[r.req_id]
            toks[t] += len(r.generated)
            span_lo[t] = min(span_lo[t], r.submit_t)
            span_hi[t] = max(span_hi[t], r.done_t or r.submit_t)
            if r.first_token_t is not None:
                ttfts[t].append(r.first_token_t - r.submit_t)
        q = eng.pages.qos
        return dict(
            wall_s=wall,
            completed=len(done),
            tenant_tok_per_s=[tk / max(hi - lo, 1e-9)
                              for tk, lo, hi in zip(toks, span_lo,
                                                    span_hi)],
            tenant_hit_rate=[s.hbm_hit_rate for s in q.tenant_stats],
            tenant_pf_precision=[s.prefetch_hit_rate
                                 for s in q.tenant_stats],
            tenant_mean_ttft_ms=[float(np.mean(tt)) * 1e3 if tt else 0.0
                                 for tt in ttfts],
            tenant_evictions=[s.evictions for s in q.tenant_stats],
            cross_tenant_prefetches=eng.pages.cross_tenant_prefetches(),
            occupancy_ok=bool((q.occupancy <= q.quota).all()),
            quota=[int(x) for x in q.quota],
            parity=eng.pages.stats.parity_tuple(),
            tenant_parity=[s.parity_tuple() for s in q.tenant_stats],
            prefetch_log=tuple(eng.pages.prefetch_log),
            registry_scans=eng.pages.stats.registry_scans,
            _pages=eng.pages,
        )

    res = {"pfcs_vec": run("vec"), "pfcs_scalar": run("scalar")}

    # tenanted vec is an implementation, not an estimator: bit-exact
    # against the scalar oracle, globally AND per tenant
    assert res["pfcs_vec"]["parity"] == res["pfcs_scalar"]["parity"], \
        "tenanted vectorized cache diverged from the scalar oracle"
    assert (res["pfcs_vec"]["tenant_parity"]
            == res["pfcs_scalar"]["tenant_parity"]), \
        "per-tenant stats diverged between vec and scalar"
    assert (res["pfcs_vec"]["prefetch_log"]
            == res["pfcs_scalar"]["prefetch_log"]), \
        "tenanted caches issued different prefetches"
    assert res["pfcs_vec"]["registry_scans"] == 0, \
        "tenanted vectorized touch path performed a registry scan"
    # the isolation theorem, on the live run: zero cross-tenant
    # prefetches, every composite inside one tenant's blocks
    for name in ("pfcs_vec", "pfcs_scalar"):
        assert res[name]["cross_tenant_prefetches"] == 0, \
            f"{name}: cross-tenant prefetch issued"
        assert res[name]["occupancy_ok"], f"{name}: quota exceeded"
    pages = res["pfcs_vec"].pop("_pages")
    res["pfcs_scalar"].pop("_pages")
    rep = pages.namespace.check_isolation(pages.registry,
                                          pairwise_gcd=smoke)
    assert rep.ok, f"isolation violated: {rep.violations}"

    # fairness: max/min per-tenant completion rate (tokens over the
    # tenant's first-submit -> last-completion span) under EQUAL token
    # demand — a starved tenant finishes late and drags its rate down
    rates = res["pfcs_vec"]["tenant_tok_per_s"]
    fairness = max(rates) / max(min(rates), 1e-9)

    # protection A/B: the hot working set vs the scanner, quota-confined
    # cache vs one shared (untenanted) cache — same traffic pattern
    def protection(tenanted: bool) -> float:
        if tenanted:
            kv = TenantedVectorizedPagedKVCache(
                hbm_pages=8, page_size=4, prefetch_budget=0,
                qos=TenantQoSConfig(2, (4, 4), (0, 0), (1, 1)))
            kv.register_request(0, list(range(16)), tenant=0)
            kv.register_request(1, list(range(100, 196)), tenant=1)
        else:
            kv = VectorizedPagedKVCache(hbm_pages=8, page_size=4,
                                        prefetch_budget=0)
            kv.register_request(0, list(range(16)))
            kv.register_request(1, list(range(100, 196)))
        hits = total = 0
        for i in range(30):
            hits += kv.touch(0, i % 4) == "hbm"
            total += 1
            kv.touch_batch([(1, j) for j in range(len(kv.chains[1]))])
        return hits / total

    hot_quota, hot_shared = protection(True), protection(False)

    v = res["pfcs_vec"]
    print("\n== Case study: multi-tenant QoS serving "
          f"({T} tenants: 1 hot / {n_cold} cold / 1 scanner, {hbm} HBM "
          f"pages, quotas {v['quota']}) ==")
    print(f"  {'tenant':<10} {'tok/s':>8} {'hbm_hit%':>9} {'pf_prec%':>9} "
          f"{'ttft_ms':>8} {'evicts':>7}")
    names = (["hot"] + [f"cold{i}" for i in range(1, 1 + n_cold)]
             + ["scanner"])
    for t, nm in enumerate(names):
        print(f"  {nm:<10} {v['tenant_tok_per_s'][t]:>8.0f} "
              f"{v['tenant_hit_rate'][t]*100:>9.1f} "
              f"{v['tenant_pf_precision'][t]*100:>9.1f} "
              f"{v['tenant_mean_ttft_ms'][t]:>8.1f} "
              f"{v['tenant_evictions'][t]:>7d}")
    print(f"  fairness (max/min tok/s): {fairness:.3f}   "
          f"cross-tenant prefetches: {v['cross_tenant_prefetches']}   "
          f"isolation: {rep.n_composites} composites, "
          f"{rep.coprime_pairs_checked} coprime pairs checked")
    print(f"  hot-tenant protection vs scanner: hit "
          f"{hot_quota*100:.1f}% under quotas vs {hot_shared*100:.1f}% "
          f"shared LRU")

    emit("case_tenancy.hot_hit_pct", v["tenant_hit_rate"][hot] * 100)
    emit("case_tenancy.scanner_hit_pct",
         v["tenant_hit_rate"][scanner] * 100)
    emit("case_tenancy.fairness_ratio", fairness)
    emit("case_tenancy.cross_tenant_prefetches",
         v["cross_tenant_prefetches"])
    emit("case_tenancy.protection_quota_hit_pct", hot_quota * 100)
    emit("case_tenancy.protection_shared_hit_pct", hot_shared * 100)
    out = {k: {kk: vv for kk, vv in r.items()
               if kk not in ("parity", "tenant_parity", "prefetch_log")}
           for k, r in res.items()}
    out.update(fairness_ratio=fairness, tenant_names=names,
               isolation_composites=rep.n_composites,
               coprime_pairs_checked=rep.coprime_pairs_checked,
               protection=dict(quota_hit=hot_quota, shared_hit=hot_shared))
    save_json("case_tenancy", out)
    save_bench("case_tenancy", {
        # deterministic placement counters only: the fairness ratio and
        # per-tenant tok/s are wall-clock-derived and would flake a gate
        "tenant_hit_rate": v["tenant_hit_rate"],
        "tenant_evictions": v["tenant_evictions"],
        "cross_tenant_prefetches": v["cross_tenant_prefetches"],
        "completed": v["completed"],
        "registry_scans": v["registry_scans"],
        "quota": v["quota"],
        "isolation_composites": rep.n_composites,
        "protection": dict(quota_hit=hot_quota, shared_hit=hot_shared),
    })
    return out


def case_dedup(smoke: bool = False):
    """Cross-tenant COW shared-prefix dedup benchmark (DESIGN.md §12).

    The real-traffic regime dedup exists for: every user of every
    tenant resends one of a handful of SYSTEM PROMPTS verbatim, plus a
    short unique suffix.  Without dedup the tenancy tier — correctly,
    by the isolation theorem — stores one private copy of the system
    prompt per tenant per request; with dedup the identical prefix is
    detected at admission (gcd-probed, Theorem 1), backed by refcounted
    read-only pages in the shared prime namespace, and copied-on-write
    at the first divergent block.

    Measures, dedup vs no-dedup over the SAME trace and slot engine:

      * **HBM pages/user** — refcount-weighted charged shares per
        tenant (each tenant pays its fraction of every resident shared
        page) vs plain per-tenant occupancy, plus nominal KV MB/user;
      * **TTFT** — the admission prefill skip over the already-resident
        shared run (tick percentiles from the slot machine's report);
      * total unique pages materialized (the allocator-level win).

    Asserts: the dedup slot machine (vec) is bit-exact vs the scalar
    dedup oracle on every DEDUP counter, tier log, and refcount map;
    zero cross-tenant prefetches; the isolation checker stays green
    over the final registry (shared pages legal, private crossings
    impossible); and dedup strictly reduces both mean charged
    HBM pages/user and mean TTFT.  Every reported metric except
    ``*_wall_s`` is deterministic, so the checked-in
    ``BENCH_case_dedup.json`` gates the dedup path end to end.
    """
    from repro.serving.dedup import DEDUP_COUNTERS
    from repro.serving.slots import SlotMachine, SlotOracle

    if smoke:
        T, req_per_tenant, hbm = 3, 4, 30
        sys_tok, max_new = 32, 6
    else:
        T, req_per_tenant, hbm = 6, 10, 84
        sys_tok, max_new = 64, 8
    page_size, n_prompts = 4, 2
    #: nominal KV bytes per page: page_size tokens x (K+V) x 4096
    #: hidden x fp16 — a fixed scale factor, not a measurement
    page_mb = page_size * 2 * 4096 * 2 / 2**20

    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, 30_000, size=sys_tok))
               for _ in range(n_prompts)]
    arrivals = []
    for i in range(T * req_per_tenant):
        t = i % T
        sysp = prompts[(i // T) % n_prompts]
        tail = list(rng.integers(0, 30_000,
                                 size=int(rng.integers(4, 13))))
        arrivals.append((i // T, sysp + tail, max_new, t))

    def run(cls, kv: str, dedup: bool):
        eng = cls(max_batch=8, page_size=page_size, hbm_pages=hbm,
                  prefetch_budget=2, reread_window=2, prefill_tokens=8,
                  kv=kv, tenants=T, dedup=dedup)
        for arrival, prompt, new, t in arrivals:
            eng.submit(list(prompt), max_new_tokens=new, tenant=t,
                       arrival=arrival)
        t0 = time.perf_counter()
        eng.run_until_idle()
        wall = time.perf_counter() - t0
        rep = eng.latency_report()
        pages = eng.pages
        if dedup:
            per_user = [float(x) for x in pages.charged_shares()]
        else:
            per_user = [float(x) for x in pages.qos.occupancy]
        out = dict(
            wall_s=wall,
            completed=rep["completed"],
            ticks=rep["ticks"],
            ttft_ticks=rep["ttft_ticks"],
            tpot_ticks=rep["tpot_ticks"],
            hbm_pages_per_user=per_user,
            hbm_mb_per_user=[p * page_mb for p in per_user],
            mean_pages_per_user=float(np.mean(per_user)),
            unique_pages=int(pages._next_page),
            counters={f: getattr(pages.stats, f)
                      for f in DEDUP_COUNTERS},
            cross_tenant_prefetches=pages.cross_tenant_prefetches(),
            tier_log=tuple(eng.tier_log),
            _pages=pages,
        )
        if dedup:
            out.update(dedup_state=pages.dedup_state(),
                       dedup_probes=int(pages.dedup_probes),
                       shared_occupancy=int(pages.qos.shared_occupancy))
        return out

    res = {
        "dedup_vec": run(SlotMachine, "vec", True),
        "dedup_scalar": run(SlotOracle, "scalar", True),
        "nodedup_vec": run(SlotMachine, "vec", False),
    }

    # the dedup machine is an implementation, not an estimator:
    # bit-exact vs the scalar dedup oracle under the same trace
    a, b = res["dedup_vec"], res["dedup_scalar"]
    assert a["counters"] == b["counters"], \
        "dedup slot machine diverged from the scalar dedup oracle"
    assert a["tier_log"] == b["tier_log"], "dedup tier logs diverged"
    assert a["dedup_state"] == b["dedup_state"], \
        "dedup refcount state diverged"
    assert a["counters"]["dedup_hits"] > 0
    assert a["counters"]["dedup_promotions"] > 0
    assert a["counters"]["cow_copies"] > 0
    for name in ("dedup_vec", "dedup_scalar", "nodedup_vec"):
        assert res[name]["cross_tenant_prefetches"] == 0, name
        assert res[name]["completed"] == len(arrivals), name
    pages = res["dedup_vec"]["_pages"]
    rep = pages.namespace.check_isolation(pages.registry,
                                          pairwise_gcd=smoke)
    assert rep.ok, f"isolation violated: {rep.violations}"
    assert rep.n_shared > 0, "dedup run must produce shared composites"
    for r in res.values():
        r.pop("_pages")
        r.pop("tier_log")

    nd = res["nodedup_vec"]
    hbm_saving = 1 - a["mean_pages_per_user"] / nd["mean_pages_per_user"]
    ttft_saving = 1 - a["ttft_ticks"][50] / max(nd["ttft_ticks"][50], 1e-9)
    # the headline claims, asserted: dedup strictly reduces both the
    # charged HBM footprint per user and the median TTFT
    assert a["mean_pages_per_user"] < nd["mean_pages_per_user"], \
        "dedup failed to reduce charged HBM pages per user"
    assert a["ttft_ticks"][50] < nd["ttft_ticks"][50], \
        "dedup failed to reduce median TTFT"

    print(f"\n== Case study: COW shared-prefix dedup ({T} tenants x "
          f"{req_per_tenant} requests, {n_prompts} system prompts of "
          f"{sys_tok} tokens, {hbm} HBM pages) ==")
    print(f"  {'':<14} {'pages/user':>11} {'MB/user':>9} "
          f"{'ttft p50':>9} {'ttft p99':>9} {'unique pages':>13}")
    for name, label in (("dedup_vec", "dedup"),
                        ("nodedup_vec", "no-dedup")):
        r = res[name]
        print(f"  {label:<14} {r['mean_pages_per_user']:>11.2f} "
              f"{r['mean_pages_per_user'] * page_mb:>9.2f} "
              f"{r['ttft_ticks'][50]:>9.1f} {r['ttft_ticks'][99]:>9.1f} "
              f"{r['unique_pages']:>13d}")
    c = a["counters"]
    print(f"  HBM/user -{hbm_saving * 100:.1f}%   TTFT p50 "
          f"-{ttft_saving * 100:.1f}%   dedup_hits {c['dedup_hits']}  "
          f"promotions {c['dedup_promotions']}  cow {c['cow_copies']}  "
          f"gcd probes {a['dedup_probes']}")
    print(f"  isolation: {rep.n_composites} composites "
          f"({rep.n_shared} shared), cross-tenant prefetches 0")

    emit("case_dedup.hbm_pages_per_user_dedup", a["mean_pages_per_user"])
    emit("case_dedup.hbm_pages_per_user_nodedup",
         nd["mean_pages_per_user"])
    emit("case_dedup.hbm_saving_pct", hbm_saving * 100)
    emit("case_dedup.ttft_p50_dedup", a["ttft_ticks"][50])
    emit("case_dedup.ttft_p50_nodedup", nd["ttft_ticks"][50])
    emit("case_dedup.dedup_hits", c["dedup_hits"])
    emit("case_dedup.cow_copies", c["cow_copies"])
    out = dict(res, hbm_saving=hbm_saving, ttft_saving=ttft_saving,
               n_shared_composites=rep.n_shared,
               page_mb=page_mb)
    save_json("case_dedup", out)
    save_bench("case_dedup", {
        # deterministic counters and tick timings only (wall_s exempt
        # by the gate anyway, but keep the contract obvious)
        "counters": c,
        "dedup_state_refs": a["dedup_state"]["refs"],
        "dedup_probes": a["dedup_probes"],
        "shared_occupancy": a["shared_occupancy"],
        "hbm_pages_per_user_dedup": a["hbm_pages_per_user"],
        "hbm_pages_per_user_nodedup": nd["hbm_pages_per_user"],
        "unique_pages": {"dedup": a["unique_pages"],
                         "nodedup": nd["unique_pages"]},
        "ttft_ticks": {"dedup": a["ttft_ticks"],
                       "nodedup": nd["ttft_ticks"]},
        "completed": a["completed"],
        "n_shared_composites": rep.n_shared,
    })
    return out


def case_batching(smoke: bool = False):
    """Continuous-batching load benchmark: open-loop Poisson arrivals
    through the slot machine (DESIGN.md §10).

    The paper's claims only matter under realistic ragged traffic
    (arrival-process shape, not mean load, dominates cache behavior),
    so this case drives 1k+ concurrent open-loop Poisson requests —
    a burst front plus a Poisson tail, ragged prompt lengths and decode
    demands, Sarathi-style chunked prefill — through four engines on
    the IDENTICAL arrival trace:

      * ``slot_vec``    — :class:`~repro.serving.slots.SlotMachine`:
        continuous admission + preemption/resume, vectorized int32 slot
        state over the vectorized cache (the production path);
      * ``slot_oracle`` — :class:`~repro.serving.slots.SlotOracle`:
        per-slot Python loops, same semantics — placement parity is
        asserted bit-exactly (counters, tiers, prefetch log, and every
        request's per-tick timings);
      * ``lockstep``    — the same machine behind the gang-scheduled
        admission gate (all slots drain before the next batch enters):
        the static-batching baseline the scheduling claim is against;
      * ``lru``         — continuous admission with prefetch disabled:
        what continuous batching buys WITHOUT factorization-recovered
        prefetch (isolates the PFCS contribution, incl. resume anchors).

    Reports TTFT/TPOT p50/p95/p99 (engine ticks), goodput (completed
    tokens per tick), preemption/resume counts, peak in-flight, and
    wall-clock throughput; asserts slot_vec == slot_oracle bit-exact,
    goodput(slot_vec) > goodput(lockstep) on the same trace, and 1k+
    peak concurrent in-flight requests.
    """
    from repro.serving.slots import SlotMachine, SlotOracle

    if smoke:
        n_req, max_batch, rate = 1200, 64, 24.0
        hbm, prefill_tok = 96, 256
    else:
        n_req, max_batch, rate = 4000, 128, 48.0
        hbm, prefill_tok = 256, 1024

    # one shared arrival trace: a 60% burst front (the 1k+ concurrent
    # regime) + a Poisson tail, shared prompt prefixes so chain
    # discovery and gcd sharing stay load-bearing
    rng = np.random.default_rng(0)
    from repro.serving.slots import poisson_arrival_ticks
    ticks = poisson_arrival_ticks(n_req, rate=rate, seed=0,
                                  burst_frac=0.6, silence_ticks=2)
    groups = [list(rng.integers(0, 30_000, size=48))
              for _ in range(max(1, n_req // 64))]
    arrivals = []
    for i, t in enumerate(ticks):
        tail = list(rng.integers(0, 30_000,
                                 size=int(rng.integers(8, 33))))
        arrivals.append((int(t), groups[i % len(groups)][:32] + tail,
                         int(rng.integers(4, 9))))

    def run(cls, policy: str, budget: int, preempt_wait):
        eng = cls(max_batch=max_batch, page_size=16, hbm_pages=hbm,
                  kv="vec", prefetch_budget=budget, reread_window=2,
                  prefill_tokens=prefill_tok, policy=policy,
                  preempt_wait=preempt_wait)
        for t, prompt, new in arrivals:
            eng.submit(prompt, max_new_tokens=new, arrival=t)
        t0 = time.perf_counter()
        eng.run_until_idle(max_ticks=1_000_000)
        wall = time.perf_counter() - t0
        rep = eng.latency_report()
        rep.update(
            wall_s=wall,
            tok_per_s=rep["tokens"] / max(wall, 1e-9),
            hbm_hit_rate=eng.pages.stats.hbm_hit_rate,
            prefetch_hit_rate=eng.pages.stats.prefetch_hit_rate,
            parity=eng.pages.stats.parity_tuple(),
            prefetch_log=tuple(eng.pages.prefetch_log),
            tier_log=eng.tier_log,
            timings=[(r.first_tick, r.done_tick, r.preemptions)
                     for r in eng.requests],
        )
        return rep

    res = {
        "slot_vec": run(SlotMachine, "continuous", 4, 6),
        "slot_oracle": run(SlotOracle, "continuous", 4, 6),
        "lockstep": run(SlotMachine, "lockstep", 4, None),
        "lru": run(SlotMachine, "continuous", 0, 6),
    }

    # the slot machine is an implementation, not an estimator: bit-exact
    # placement parity with the per-slot-loop oracle on the same trace
    v, o = res["slot_vec"], res["slot_oracle"]
    assert v["parity"] == o["parity"], \
        "slot machine diverged from the lockstep oracle"
    assert v["tier_log"] == o["tier_log"], \
        "slot machine touch tiers diverged from the oracle"
    assert v["prefetch_log"] == o["prefetch_log"], \
        "slot machine issued different prefetches than the oracle"
    assert v["timings"] == o["timings"], \
        "per-request tick timings diverged from the oracle"
    assert (v["ticks"], v["preemptions"], v["resumes"]) \
        == (o["ticks"], o["preemptions"], o["resumes"])
    # the scheduling claim itself, on the identical trace
    assert v["goodput_tok_per_tick"] > res["lockstep"][
        "goodput_tok_per_tick"], \
        "continuous batching must beat the lockstep gate on goodput"
    assert v["peak_in_flight"] >= 1000, \
        "load benchmark must reach 1k+ concurrent in-flight requests"

    print("\n== Case study: continuous batching (open-loop Poisson, "
          f"{n_req} requests, {max_batch} slots, peak in-flight "
          f"{v['peak_in_flight']}) ==")
    print(f"  {'config':<12} {'goodput':>8} {'ticks':>7} {'ttft_p50':>9} "
          f"{'ttft_p99':>9} {'tpot_p50':>9} {'tpot_p99':>9} "
          f"{'preempt':>8} {'tok/s':>10}")
    for name, r in res.items():
        print(f"  {name:<12} {r['goodput_tok_per_tick']:>8.2f} "
              f"{r['ticks']:>7d} {r['ttft_ticks'][50]:>9.1f} "
              f"{r['ttft_ticks'][99]:>9.1f} {r['tpot_ticks'][50]:>9.2f} "
              f"{r['tpot_ticks'][99]:>9.2f} {r['preemptions']:>8d} "
              f"{r['tok_per_s']:>10.0f}")
    gain = (v["goodput_tok_per_tick"]
            / max(res["lockstep"]["goodput_tok_per_tick"], 1e-9))
    print(f"  continuous vs lockstep goodput: {gain:.2f}x   "
          f"resumes {v['resumes']} (resume-prefetch: "
          f"pf_hit {v['prefetch_hit_rate']*100:.1f}% vs LRU "
          f"{res['lru']['prefetch_hit_rate']*100:.1f}%)")

    emit("case_batching.goodput_tok_per_tick", v["goodput_tok_per_tick"])
    emit("case_batching.goodput_vs_lockstep", gain)
    emit("case_batching.ttft_p99_ticks", v["ttft_ticks"][99])
    emit("case_batching.peak_in_flight", v["peak_in_flight"])
    emit("case_batching.resumes", v["resumes"])
    out = {k: {kk: vv for kk, vv in r.items()
               if kk not in ("parity", "prefetch_log", "tier_log",
                             "timings")}
           for k, r in res.items()}
    out["goodput_vs_lockstep"] = gain
    save_json("case_batching", out)
    save_bench("case_batching", {
        name: dict(
            completed=r["completed"], tokens=r["tokens"],
            ticks=r["ticks"],
            goodput_tok_per_tick=r["goodput_tok_per_tick"],
            ttft_ticks={str(q): x for q, x in r["ttft_ticks"].items()},
            tpot_ticks={str(q): x for q, x in r["tpot_ticks"].items()},
            preemptions=r["preemptions"], resumes=r["resumes"],
            peak_in_flight=r["peak_in_flight"],
            hbm_hit_rate=r["hbm_hit_rate"],
            prefetch_hit_rate=r["prefetch_hit_rate"],
            wall_s=r["wall_s"],
        ) for name, r in res.items()
    })
    return out


if __name__ == "__main__":
    case_db()
    case_ml()
    case_hft()
    case_serving()
    case_moe()
    case_tenancy()
    case_batching()
