"""§6.3 case studies: database joins, ML training, HFT market data.

Paper claims: DB hit 84.7% -> 97.8% with 43% fewer I/O ops; ML case
"623% faster gradient computation ... bandwidth -39%"; HFT sub-100ns
relationship discovery vs 2.3-7.8 us heuristics with 12.4% FP.
We reproduce the cache-level metrics that drive those numbers and report
the model-derived latency per discovery.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (DEFAULT_COSTS, db_join_trace, hft_trace,
                        ml_epoch_trace, simulate_baseline, simulate_pfcs,
                        simulate_semantic)
from repro.core.pfcs_cache import PFCSCache

from .common import emit, save_json


def case_db(seed: int = 0):
    caps = (("L1", 128), ("L2", 512), ("L3", 4096))
    tr = db_join_trace(n_orders=8000, n_customers=1000, n_items=2000,
                       n_queries=30000, seed=seed)
    lru = simulate_baseline("lru", tr, caps)
    pfcs = simulate_pfcs(tr, caps)
    io_reduction = 1.0 - pfcs.misses / max(1, lru.misses)
    print("\n== Case study: production database (paper: 84.7%->97.8% hit, "
          "-43% I/O) ==")
    print(f"  hit rate: {lru.hit_rate*100:.1f}% -> {pfcs.hit_rate*100:.1f}%")
    print(f"  backing-store I/O reduction: {io_reduction*100:.1f}%")
    emit("case_db.hit_lru_pct", lru.hit_rate * 100)
    emit("case_db.hit_pfcs_pct", pfcs.hit_rate * 100)
    emit("case_db.io_reduction_pct", io_reduction * 100)
    out = dict(lru_hit=lru.hit_rate, pfcs_hit=pfcs.hit_rate,
               io_reduction=io_reduction)
    save_json("case_db", out)
    return out


def case_ml(seed: int = 0):
    caps = (("L1", 128), ("L2", 512), ("L3", 2048))
    tr = ml_epoch_trace(n_samples=6000, n_feature_rows=1500, n_epochs=3,
                        seed=seed)
    lru = simulate_baseline("lru", tr, caps)
    pfcs = simulate_pfcs(tr, caps)
    # memory-bandwidth proxy: bytes moved from backing store
    bw = 1.0 - (pfcs.misses + max(0, pfcs.prefetches_issued
                                  - pfcs.prefetches_used)) / max(1, lru.misses)
    speedup = lru.avg_latency_ns() / pfcs.avg_latency_ns()
    print("\n== Case study: ML training data tier (paper: -39% bandwidth) ==")
    print(f"  hit rate: {lru.hit_rate*100:.1f}% -> {pfcs.hit_rate*100:.1f}%")
    print(f"  access speedup: {speedup:.2f}x   bandwidth delta: {bw*100:+.1f}%")
    emit("case_ml.speedup", speedup)
    emit("case_ml.bandwidth_delta_pct", bw * 100)
    out = dict(lru_hit=lru.hit_rate, pfcs_hit=pfcs.hit_rate, speedup=speedup,
               bandwidth_delta=bw)
    save_json("case_ml", out)
    return out


def case_hft(seed: int = 0):
    caps = (("L1", 256), ("L2", 1024), ("L3", 4096))
    tr = hft_trace(n_instruments=3000, n_corr_groups=400, n_events=30000,
                   seed=seed)
    pfcs = simulate_pfcs(tr, caps)
    sem = simulate_semantic(tr, caps, seed=seed)
    # model-derived relationship-discovery latency: weighted stage costs
    c = DEFAULT_COSTS
    ops = pfcs.factor_ops
    n_disc = max(1, sum(ops.values()))
    disc_ns = (ops.get("table", 0) * c.lat_factor_table
               + ops.get("cache", 0) * c.lat_factor_cache
               + ops.get("trial", 0) * c.lat_factor_trial
               + ops.get("rho", 0) * c.lat_factor_rho) / n_disc
    sem_ns = c.lat_embedding
    fp_rate = 1.0 - (sem.prefetch_precision or 1.0)
    print("\n== Case study: HFT market data (paper: <100ns vs 2.3-7.8us, "
          "0% vs 12.4% FP) ==")
    print(f"  PFCS discovery latency (model): {disc_ns:.0f} ns/op "
          f"(stages: {dict(ops)})")
    print(f"  semantic discovery latency (model): {sem_ns:.0f} ns/op, "
          f"false-positive rate {fp_rate*100:.1f}%")
    print(f"  PFCS false positives: "
          f"{(1.0 - (pfcs.prefetch_precision or 1.0))*100:.2f}% (Theorem 1)")
    emit("case_hft.pfcs_discovery_ns", disc_ns)
    emit("case_hft.semantic_fp_pct", fp_rate * 100)
    out = dict(discovery_ns=disc_ns, semantic_fp=fp_rate,
               pfcs_hit=pfcs.hit_rate, semantic_hit=sem.hit_rate)
    save_json("case_hft", out)
    return out


def case_serving():
    """PFCS paged-KV + expert-cache micro-case (the framework integration)."""
    from repro.serving.expert_cache import ExpertCache
    from repro.serving.kv_cache import PagedKVCache

    rng = np.random.default_rng(0)
    kv = PagedKVCache(hbm_pages=64, page_size=16, prefetch_budget=4)
    shared = list(rng.integers(0, 1000, size=64))
    for r in range(32):
        tail = list(rng.integers(0, 1000, size=32))
        kv.register_request(r, shared + tail)
    for r in range(32):
        for i in range(len(kv.chains[r])):
            kv.touch(r, i)
    print("\n== Case study: serving tier (PFCS pages + expert cache) ==")
    print(f"  KV pages: hbm_hit={kv.stats.hbm_hit_rate*100:.1f}% "
          f"prefetches={kv.stats.prefetches} "
          f"shared_prefix_pages={kv.stats.shared_prefix_pages}")

    E = 384
    ec = ExpertCache(E, hbm_slots=96, prefetch_budget=7)
    groups = [tuple(rng.choice(E, size=8, replace=False)) for _ in range(24)]
    ec.observe_routing(groups)
    for _ in range(2000):
        g = groups[int(rng.integers(len(groups)))]
        ec.activate([g[0]])
        ec.activate(list(g[1:]))
    print(f"  expert cache: hit={ec.stats.hit_rate*100:.1f}% "
          f"prefetch_hits={ec.stats.prefetch_hits}")
    emit("case_serving.kv_hbm_hit_pct", kv.stats.hbm_hit_rate * 100)
    emit("case_serving.expert_hit_pct", ec.stats.hit_rate * 100)
    out = dict(kv_hit=kv.stats.hbm_hit_rate, expert_hit=ec.stats.hit_rate,
               shared_pages=kv.stats.shared_prefix_pages)
    save_json("case_serving", out)
    return out


if __name__ == "__main__":
    case_db()
    case_ml()
    case_hft()
    case_serving()
