"""Pure-jnp oracles for the PFCS Pallas kernels.

These are the semantic ground truth the kernels are validated against
(tests sweep shapes/dtypes and assert exact equality — integer kernels,
no tolerance needed).
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

__all__ = ["divisibility_mask_ref", "factorize_squarefree_ref", "gcd_ref",
           "divisibility_mask_limbs_ref", "factorize_limbs_ref",
           "gcd_limbs_ref"]


def divisibility_mask_ref(composites: jnp.ndarray, primes: jnp.ndarray) -> jnp.ndarray:
    """mask[i, j] = primes[j] divides composites[i].

    composites: (N,) int32/int64, primes: (P,) same dtype -> (N, P) bool.
    Zero-padded primes never divide (pad-safe); composite 0/1 rows are all
    False for primes > 1.
    """
    c = composites[:, None]
    p = primes[None, :]
    safe_p = jnp.where(p <= 0, 1, p)
    mask = (c % safe_p) == 0
    return jnp.logical_and(mask, p > 1)


def factorize_squarefree_ref(composites: jnp.ndarray, primes: jnp.ndarray):
    """Squarefree factorization against a prime pool.

    PFCS composites are products of *distinct* primes (one per data
    element), so the divisibility mask IS the factorization.  Returns
    ``(mask, residual)`` where ``residual[i] = composites[i] / prod of
    dividing pool primes`` — 1 when the pool fully factors the composite,
    else the cofactor for the next (colder) pool / Pollard stage.
    """
    mask = divisibility_mask_ref(composites, primes)
    p = primes[None, :].astype(composites.dtype)
    factors = jnp.where(mask, p, jnp.ones_like(p))
    prod = jnp.prod(factors, axis=1)
    residual = jnp.where(prod > 0, composites // jnp.maximum(prod, 1), composites)
    return mask, residual


def gcd_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Elementwise gcd (Euclid), same shape/dtype in and out."""
    return jnp.gcd(a, b)


# ----------------------------------------------------------------------- #
# multi-limb oracles (DESIGN.md §11)                                      #
# ----------------------------------------------------------------------- #
# Ground truth for the limb kernels is arbitrary-precision Python-int
# arithmetic: unpack limbs -> exact int ops -> repack.  Deliberately NOT
# jnp — there is nothing to get subtly wrong here, which is the point of
# an oracle.

def _unpack(limbs: np.ndarray):
    from repro.core.composite import unpack_limbs
    return unpack_limbs(np.asarray(limbs))


def divisibility_mask_limbs_ref(limbs: np.ndarray, primes) -> np.ndarray:
    """mask[i, j] = primes[j] divides the composite encoded by limbs[i].

    limbs: (N, L) int64 little-endian 32-bit limbs -> (N, P) bool; pad
    primes <= 1 never divide (same contract as the flat kernel).
    """
    vals = _unpack(limbs)
    ps = [int(p) for p in np.asarray(primes)]
    return np.array([[p > 1 and v % p == 0 for p in ps] for v in vals],
                    dtype=bool).reshape(len(vals), len(ps))


def factorize_limbs_ref(limbs: np.ndarray, primes):
    """Wide squarefree factorization oracle: ``(mask, residual_limbs)``
    with the residual repacked at the input limb width."""
    from repro.core.composite import pack_limbs
    vals = _unpack(limbs)
    ps = [int(p) for p in np.asarray(primes)]
    mask = divisibility_mask_limbs_ref(limbs, primes)
    residuals = []
    for i, v in enumerate(vals):
        for j, p in enumerate(ps):
            if mask[i, j]:
                v //= p
        residuals.append(v)
    return mask, pack_limbs(residuals, np.asarray(limbs).shape[1])


def gcd_limbs_ref(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Elementwise exact gcd of limb-encoded pairs, repacked limbs.

    This is FULL math.gcd — it equals the kernel's pool-reconstruction
    gcd exactly when both sides are squarefree products of pool primes
    (the registry invariant the differential fuzz pins).
    """
    from repro.core.composite import pack_limbs
    va, vb = _unpack(a), _unpack(b)
    return pack_limbs([math.gcd(x, y) for x, y in zip(va, vb)],
                      np.asarray(a).shape[1])
