"""Pure-jnp oracles for the PFCS Pallas kernels.

These are the semantic ground truth the kernels are validated against
(tests sweep shapes/dtypes and assert exact equality — integer kernels,
no tolerance needed).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["divisibility_mask_ref", "factorize_squarefree_ref", "gcd_ref"]


def divisibility_mask_ref(composites: jnp.ndarray, primes: jnp.ndarray) -> jnp.ndarray:
    """mask[i, j] = primes[j] divides composites[i].

    composites: (N,) int32/int64, primes: (P,) same dtype -> (N, P) bool.
    Zero-padded primes never divide (pad-safe); composite 0/1 rows are all
    False for primes > 1.
    """
    c = composites[:, None]
    p = primes[None, :]
    safe_p = jnp.where(p <= 0, 1, p)
    mask = (c % safe_p) == 0
    return jnp.logical_and(mask, p > 1)


def factorize_squarefree_ref(composites: jnp.ndarray, primes: jnp.ndarray):
    """Squarefree factorization against a prime pool.

    PFCS composites are products of *distinct* primes (one per data
    element), so the divisibility mask IS the factorization.  Returns
    ``(mask, residual)`` where ``residual[i] = composites[i] / prod of
    dividing pool primes`` — 1 when the pool fully factors the composite,
    else the cofactor for the next (colder) pool / Pollard stage.
    """
    mask = divisibility_mask_ref(composites, primes)
    p = primes[None, :].astype(composites.dtype)
    factors = jnp.where(mask, p, jnp.ones_like(p))
    prod = jnp.prod(factors, axis=1)
    residual = jnp.where(prod > 0, composites // jnp.maximum(prod, 1), composites)
    return mask, residual


def gcd_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Elementwise gcd (Euclid), same shape/dtype in and out."""
    return jnp.gcd(a, b)
