"""Public jit'd wrappers around the PFCS Pallas kernels.

Handles the ragged real world: pads inputs to tile multiples, picks the
int32 fast path vs the int64 wide path per composite magnitude (DESIGN.md
§3 — TPUs have no fast 64-bit integer multiply, and PFCS routes hot data
to small primes precisely so the hot path stays narrow), and decides
interpret mode from the backend (compiled on TPU, interpreted on CPU).

Numpy in, numpy out — these are host-callable building blocks used by the
registry/prefetcher when batch sizes justify the device round trip.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .factorize import divisibility_mask_pallas, factorize_squarefree_pallas
from .gcd import gcd_pallas

__all__ = ["factorize_batch", "divisibility_scan", "gcd_batch",
           "INT32_SAFE_LIMIT"]

# composites below this fit the int32 fast path
INT32_SAFE_LIMIT = 2**31 - 1


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: np.ndarray, mult: int, fill) -> np.ndarray:
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])


def _pick_dtype(*arrays: np.ndarray):
    hi = max((int(a.max()) if a.size else 0) for a in arrays)
    return np.int32 if hi <= INT32_SAFE_LIMIT else np.int64


def factorize_batch(
    composites: Sequence[int],
    primes: Sequence[int],
    block_n: int = 256,
    block_p: int = 512,
    interpret: bool | None = None,
) -> Tuple[List[List[int]], np.ndarray]:
    """Factor each composite against the pool.

    Returns ``(factors, residuals)`` — per composite the dividing pool
    primes and the remaining cofactor (1 when fully factored).
    """
    if interpret is None:
        interpret = _interpret_default()
    comp = np.asarray(list(composites))
    pool = np.asarray(list(primes))
    if comp.size == 0:
        return [], np.empty(0, dtype=np.int64)
    dt = _pick_dtype(comp, pool)
    n, p = comp.shape[0], pool.shape[0]
    comp_p = _pad_to(comp.astype(dt), block_n, 1)
    pool_p = _pad_to(pool.astype(dt), block_p, 0)
    with enable_x64(True) if dt == np.int64 else _nullcontext():
        mask, residual = factorize_squarefree_pallas(
            jnp.asarray(comp_p), jnp.asarray(pool_p),
            block_n=block_n, block_p=block_p, interpret=interpret)
        mask = np.asarray(mask)[:n, :p]
        residual = np.asarray(residual)[:n]
    factors = [[int(pool[j]) for j in np.nonzero(mask[i])[0]] for i in range(n)]
    return factors, residual.astype(np.int64)


def divisibility_scan(
    registry: Sequence[int],
    query_primes: Sequence[int],
    block_n: int = 256,
    block_p: int = 512,
    interpret: bool | None = None,
) -> List[np.ndarray]:
    """For each query prime, indices of registry composites it divides.

    The §4.2 prefetch scan: host compacts the kernel's boolean mask into
    candidate index lists.
    """
    if interpret is None:
        interpret = _interpret_default()
    reg = np.asarray(list(registry))
    qs = np.asarray(list(query_primes))
    if reg.size == 0 or qs.size == 0:
        return [np.empty(0, dtype=np.int64) for _ in range(qs.size)]
    dt = _pick_dtype(reg, qs)
    n, q = reg.shape[0], qs.shape[0]
    reg_p = _pad_to(reg.astype(dt), block_n, 1)
    qs_p = _pad_to(qs.astype(dt), block_p, 0)
    with enable_x64(True) if dt == np.int64 else _nullcontext():
        mask = divisibility_mask_pallas(
            jnp.asarray(reg_p), jnp.asarray(qs_p),
            block_n=block_n, block_p=block_p, interpret=interpret)
        mask = np.asarray(mask)[:n, :q]
    return [np.nonzero(mask[:, j])[0] for j in range(q)]


def gcd_batch(
    a: Sequence[int],
    b: Sequence[int],
    block_n: int = 1024,
    interpret: bool | None = None,
) -> np.ndarray:
    """Elementwise gcd over pairs (shared-prefix composite discovery)."""
    if interpret is None:
        interpret = _interpret_default()
    aa = np.asarray(list(a))
    bb = np.asarray(list(b))
    assert aa.shape == bb.shape
    if aa.size == 0:
        return np.empty(0, dtype=np.int64)
    dt = _pick_dtype(aa, bb)
    n = aa.shape[0]
    ap = _pad_to(aa.astype(dt), block_n, 0)
    bp = _pad_to(bb.astype(dt), block_n, 0)
    with enable_x64(True) if dt == np.int64 else _nullcontext():
        g = gcd_pallas(jnp.asarray(ap), jnp.asarray(bp),
                       block_n=block_n, interpret=interpret)
        g = np.asarray(g)[:n]
    return g.astype(np.int64)


class _nullcontext:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
