"""Public jit'd wrappers around the PFCS Pallas kernels.

Handles the ragged real world: pads inputs to tile multiples, picks the
int32 fast path vs the int64 wide path per composite magnitude (DESIGN.md
§3 — TPUs have no fast 64-bit integer multiply, and PFCS routes hot data
to small primes precisely so the hot path stays narrow), and decides
interpret mode from the backend (compiled on TPU, interpreted on CPU).

Numpy in, numpy out — these are host-callable building blocks used by the
registry/prefetcher when batch sizes justify the device round trip.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.composite import (LIMB_BITS, limbs_to_int, n_limbs_for_bits,
                                  pack_limbs, unpack_limbs)
from repro.obs.profile import kernel_scope

from .factorize import (divisibility_mask_limbs_pallas,
                        divisibility_mask_pallas, factorize_limbs_pallas,
                        factorize_squarefree_pallas)
from .gcd import gcd_limbs_pallas, gcd_pallas

__all__ = ["factorize_batch", "divisibility_scan", "gcd_batch",
           "divisibility_scan_limbs", "factorize_batch_limbs",
           "gcd_batch_limbs", "factorize_batch_exact", "gcd_batch_exact",
           "INT32_SAFE_LIMIT", "INT64_SAFE_LIMIT"]

# composites below this fit the int32 fast path
INT32_SAFE_LIMIT = 2**31 - 1

# composites below this fit the flat int64 kernels; anything larger takes
# the multi-limb path (DESIGN.md §11)
INT64_SAFE_LIMIT = 2**63 - 1


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: np.ndarray, mult: int, fill) -> np.ndarray:
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])


def _pick_dtype(*arrays: np.ndarray):
    hi = max((int(a.max()) if a.size else 0) for a in arrays)
    return np.int32 if hi <= INT32_SAFE_LIMIT else np.int64


def factorize_batch(
    composites: Sequence[int],
    primes: Sequence[int],
    block_n: int = 256,
    block_p: int = 512,
    interpret: bool | None = None,
) -> Tuple[List[List[int]], np.ndarray]:
    """Factor each composite against the pool.

    Returns ``(factors, residuals)`` — per composite the dividing pool
    primes and the remaining cofactor (1 when fully factored).
    """
    if interpret is None:
        interpret = _interpret_default()
    comp = np.asarray(list(composites))
    pool = np.asarray(list(primes))
    if comp.size == 0:
        return [], np.empty(0, dtype=np.int64)
    dt = _pick_dtype(comp, pool)
    n, p = comp.shape[0], pool.shape[0]
    comp_p = _pad_to(comp.astype(dt), block_n, 1)
    pool_p = _pad_to(pool.astype(dt), block_p, 0)
    with enable_x64(True) if dt == np.int64 else _nullcontext():
        with kernel_scope("factorize_batch", items=n):
            mask, residual = factorize_squarefree_pallas(
                jnp.asarray(comp_p), jnp.asarray(pool_p),
                block_n=block_n, block_p=block_p, interpret=interpret)
            mask = np.asarray(mask)[:n, :p]
            residual = np.asarray(residual)[:n]
    factors = [[int(pool[j]) for j in np.nonzero(mask[i])[0]] for i in range(n)]
    return factors, residual.astype(np.int64)


def divisibility_scan(
    registry: Sequence[int],
    query_primes: Sequence[int],
    block_n: int = 256,
    block_p: int = 512,
    interpret: bool | None = None,
) -> List[np.ndarray]:
    """For each query prime, indices of registry composites it divides.

    The §4.2 prefetch scan: host compacts the kernel's boolean mask into
    candidate index lists.
    """
    if interpret is None:
        interpret = _interpret_default()
    reg = np.asarray(list(registry))
    qs = np.asarray(list(query_primes))
    if reg.size == 0 or qs.size == 0:
        return [np.empty(0, dtype=np.int64) for _ in range(qs.size)]
    dt = _pick_dtype(reg, qs)
    n, q = reg.shape[0], qs.shape[0]
    reg_p = _pad_to(reg.astype(dt), block_n, 1)
    qs_p = _pad_to(qs.astype(dt), block_p, 0)
    with enable_x64(True) if dt == np.int64 else _nullcontext():
        with kernel_scope("divisibility_scan", items=n):
            mask = divisibility_mask_pallas(
                jnp.asarray(reg_p), jnp.asarray(qs_p),
                block_n=block_n, block_p=block_p, interpret=interpret)
            mask = np.asarray(mask)[:n, :q]
    return [np.nonzero(mask[:, j])[0] for j in range(q)]


def gcd_batch(
    a: Sequence[int],
    b: Sequence[int],
    block_n: int = 1024,
    interpret: bool | None = None,
) -> np.ndarray:
    """Elementwise gcd over pairs (shared-prefix composite discovery)."""
    if interpret is None:
        interpret = _interpret_default()
    aa = np.asarray(list(a))
    bb = np.asarray(list(b))
    assert aa.shape == bb.shape
    if aa.size == 0:
        return np.empty(0, dtype=np.int64)
    dt = _pick_dtype(aa, bb)
    n = aa.shape[0]
    ap = _pad_to(aa.astype(dt), block_n, 0)
    bp = _pad_to(bb.astype(dt), block_n, 0)
    with enable_x64(True) if dt == np.int64 else _nullcontext():
        with kernel_scope("gcd_batch", items=n):
            g = gcd_pallas(jnp.asarray(ap), jnp.asarray(bp),
                           block_n=block_n, interpret=interpret)
            g = np.asarray(g)[:n]
    return g.astype(np.int64)


class _nullcontext:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


# --------------------------------------------------------------------------- #
# multi-limb wrappers + exact dispatchers (DESIGN.md §11)                      #
# --------------------------------------------------------------------------- #
# Python ints in, Python ints out: the wrappers pack arbitrary-precision
# composites into (N, L) 32-bit-limb int64 matrices for the limb kernels
# and unpack results exactly.  The ``*_exact`` dispatchers pick the flat
# int64 kernels when every value fits a machine word (bit-identical to
# the narrow path) and the limb kernels otherwise, so consumers stay
# mode-agnostic.

def _as_limbs(values, n_limbs: int | None) -> np.ndarray:
    """Values -> (N, L) limb matrix; passes (N, L) arrays through."""
    if isinstance(values, np.ndarray) and values.ndim == 2 \
            and values.dtype != object:
        assert n_limbs is None or values.shape[1] == n_limbs
        return values.astype(np.int64)
    vals = [int(v) for v in values]
    if n_limbs is None:
        n_limbs = max(1, n_limbs_for_bits(max(
            (v.bit_length() for v in vals), default=1)))
    return pack_limbs(vals, n_limbs)


def divisibility_scan_limbs(
    registry_limbs: np.ndarray,     # (N, L) limbs OR sequence of ints
    query_primes: Sequence[int],
    block_n: int = 256,
    block_p: int = 512,
    interpret: bool | None = None,
    n_limbs: int | None = None,
) -> List[np.ndarray]:
    """Wide §4.2 scan: per query prime, indices of dividing composites."""
    if interpret is None:
        interpret = _interpret_default()
    limbs = _as_limbs(registry_limbs, n_limbs)
    qs = np.asarray(list(query_primes), dtype=np.int64)
    n, q = limbs.shape[0], qs.shape[0]
    if n == 0 or q == 0:
        return [np.empty(0, dtype=np.int64) for _ in range(q)]
    limbs_p = np.concatenate(
        [limbs, _pad_rows_one(limbs.shape[1], (-n) % block_n)]) \
        if n % block_n else limbs
    qs_p = _pad_to(qs, block_p, 0)
    with enable_x64(True):
        with kernel_scope("divisibility_scan_limbs", items=n):
            mask = divisibility_mask_limbs_pallas(
                jnp.asarray(limbs_p), jnp.asarray(qs_p),
                block_n=block_n, block_p=block_p, interpret=interpret)
            mask = np.asarray(mask)[:n, :q]
    return [np.nonzero(mask[:, j])[0] for j in range(q)]


def _pad_rows_one(L: int, rows: int) -> np.ndarray:
    """Pad rows encoding composite value 1 (divides nothing)."""
    out = np.zeros((rows, L), dtype=np.int64)
    if rows:
        out[:, 0] = 1
    return out


def factorize_batch_limbs(
    composites,                     # sequence of ints OR (N, L) limbs
    primes: Sequence[int],
    block_n: int = 256,
    block_p: int = 512,
    interpret: bool | None = None,
    n_limbs: int | None = None,
) -> Tuple[List[List[int]], List[int]]:
    """Wide :func:`factorize_batch`: residuals come back as exact Python
    ints (1 when the pool fully factors the composite)."""
    if interpret is None:
        interpret = _interpret_default()
    limbs = _as_limbs(composites, n_limbs)
    pool = np.asarray(list(primes), dtype=np.int64)
    n, p = limbs.shape[0], pool.shape[0]
    if n == 0:
        return [], []
    limbs_p = np.concatenate(
        [limbs, _pad_rows_one(limbs.shape[1], (-n) % block_n)]) \
        if n % block_n else limbs
    pool_p = _pad_to(pool, block_p, 0)
    with enable_x64(True):
        with kernel_scope("factorize_batch_limbs", items=n):
            mask, residual = factorize_limbs_pallas(
                jnp.asarray(limbs_p), jnp.asarray(pool_p),
                block_n=block_n, block_p=block_p, interpret=interpret)
            mask = np.asarray(mask)[:n, :p]
            residual = np.asarray(residual)[:n]
    factors = [[int(pool[j]) for j in np.nonzero(mask[i])[0]]
               for i in range(n)]
    return factors, unpack_limbs(residual)


def gcd_batch_limbs(
    a, b,                           # sequences of ints OR (N, L) limbs
    pool: Sequence[int],
    block_n: int = 256,
    block_p: int = 512,
    interpret: bool | None = None,
    n_limbs: int | None = None,
) -> List[int]:
    """Wide elementwise gcd of squarefree composite pairs, exact Python
    ints out.  ``pool`` must cover the common member primes (either
    side's prime set suffices — see ``gcd_limbs_pallas``)."""
    if interpret is None:
        interpret = _interpret_default()
    if n_limbs is None and not (isinstance(a, np.ndarray) and a.ndim == 2):
        hi = max((int(v).bit_length() for v in [*a, *b]), default=1)
        n_limbs = max(1, n_limbs_for_bits(hi))
    aa = _as_limbs(a, n_limbs)
    bb = _as_limbs(b, n_limbs if n_limbs is not None else aa.shape[1])
    assert aa.shape == bb.shape, (aa.shape, bb.shape)
    pl_ = np.asarray(list(pool), dtype=np.int64)
    n = aa.shape[0]
    if n == 0:
        return []
    pad = (-n) % block_n
    if pad:
        aa = np.concatenate([aa, _pad_rows_one(aa.shape[1], pad)])
        bb = np.concatenate([bb, _pad_rows_one(bb.shape[1], pad)])
    pool_p = _pad_to(pl_, block_p, 0)
    with enable_x64(True):
        with kernel_scope("gcd_batch_limbs", items=n):
            g = gcd_limbs_pallas(jnp.asarray(aa), jnp.asarray(bb),
                                 jnp.asarray(pool_p), block_n=block_n,
                                 block_p=block_p, interpret=interpret)
            g = np.asarray(g)[:n]
    return unpack_limbs(g)


def factorize_batch_exact(
    composites: Sequence[int],
    primes: Sequence[int],
    **kw,
) -> Tuple[List[List[int]], List[int]]:
    """Width-agnostic factorize: flat int64 kernels when every composite
    fits, limb kernels otherwise.  Residuals are Python ints either way."""
    vals = [int(c) for c in composites]
    if not vals:
        return [], []
    if max(vals) <= INT64_SAFE_LIMIT:
        facs, residual = factorize_batch(vals, primes, **kw)
        return facs, [int(r) for r in residual]
    return factorize_batch_limbs(vals, primes, **kw)


def gcd_batch_exact(
    a: Sequence[int],
    b: Sequence[int],
    pool: Sequence[int],
    **kw,
) -> List[int]:
    """Width-agnostic elementwise gcd (see :func:`gcd_batch_limbs` for
    the squarefree/pool contract of the wide path)."""
    va = [int(x) for x in a]
    vb = [int(x) for x in b]
    if not va:
        return []
    if max(max(va), max(vb)) <= INT64_SAFE_LIMIT:
        return [int(g) for g in gcd_batch(va, vb, **{
            k: v for k, v in kw.items() if k in ("block_n", "interpret")})]
    return gcd_batch_limbs(va, vb, pool, **kw)
