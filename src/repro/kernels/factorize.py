"""Pallas TPU kernel: batched squarefree factorization by trial division.

PFCS Algorithm 2 stage 1 (trial division against a prime pool), adapted
from the paper's per-access scalar loop to a TPU-native *batched* kernel:
the registry refresh / bulk relationship-discovery path factorizes many
composites against a whole pool at once.

Layout (all VMEM):
    composites tile  (BN, 1)  int32/int64  — one composite per sublane row
    primes tile      (1, BP)  int32/int64  — prime pool along lanes
    mask out tile    (BN, BP) bool         — mask[i,j] = p_j | c_i
    residual out     (BN, 1)               — c_i / prod(dividing p_j)

Grid: (N/BN, P/BP).  The prime axis (j) is the innermost, sequentially
executed grid dimension on TPU, so the residual tile accumulates the
running cofactor across prime tiles: initialized to the composite at
j == 0, divided by every dividing prime as tiles stream through.  This is
the standard TPU accumulator pattern (same shape as a matmul K-loop).

Default tile sizes keep the working set well under VMEM (BN=256, BP=512
int32 ≈ 0.5 MB including the bool tile) and lane-align BP to 128.

TPU int width note (DESIGN.md §3): the int32 fast path covers L1xL1 and
L1xL2 composites (the hot path by construction — hot data gets small
primes).  The int64 variant is validated in interpret mode and is the
reference semantics for hardware with emulated 64-bit integer ops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["factorize_squarefree_pallas", "divisibility_mask_pallas",
           "divisibility_mask_limbs_pallas", "factorize_limbs_pallas"]

# ----------------------------------------------------------------------- #
# multi-limb variants (DESIGN.md §11)                                     #
# ----------------------------------------------------------------------- #
# Composites wider than 63 bits arrive as (N, L) little-endian 32-bit
# limbs in int64 lanes.  All arithmetic is exact integer:
#
#   Horner mod      r = (r * 2**32 + limb) % p      r < p < 2**31
#                   => r * 2**32 + limb < p * 2**32 <= 2**63        OK
#   short division  cur = carry * 2**32 + limb; q, carry = divmod(cur, p)
#                   carry < p < 2**31 => cur < 2**63                OK
#
# so every intermediate fits a signed int64 as long as primes fit 31 bits
# (MAX_PRIME_BITS in core.composite — the pools never mint larger).  The
# limb count L is static (baked into the traced program), tiles are
# (BN, L) composites x (1, BP) primes exactly like the flat kernels.

_LIMB_BITS = 32
_LIMB_BASE = 1 << _LIMB_BITS


def _horner_mod(limbs, p):
    """Remainder of an (BN, L)-limb composite modulo (1, BP) primes.

    Little-endian limbs evaluated most-significant-first (Horner);
    returns (BN, BP) remainders.  ``p`` must be sanitized > 0.
    """
    bn, L = limbs.shape
    r = jnp.zeros((bn, p.shape[1]), dtype=jnp.int64)
    for k in reversed(range(L)):
        r = (r * _LIMB_BASE + limbs[:, k:k + 1]) % p
    return r


def _short_div(limbs, p):
    """Exact division of (BN, L) limbs by a scalar prime p (int64).

    Most-significant-first schoolbook short division; returns the
    quotient limbs.  Caller guarantees divisibility (squarefree exact
    path) — the final carry is the remainder and is discarded.
    """
    bn, L = limbs.shape
    carry = jnp.zeros((bn,), dtype=jnp.int64)
    out = [None] * L
    for k in reversed(range(L)):
        cur = carry * _LIMB_BASE + limbs[:, k]
        out[k] = cur // p
        carry = cur % p
    return jnp.stack(out, axis=1)


def _divmask_limbs_kernel(c_ref, p_ref, mask_ref):
    limbs = c_ref[...]                       # (BN, L)
    p = p_ref[...]                           # (1, BP)
    safe_p = jnp.where(p <= 1, jnp.ones_like(p), p)
    mask_ref[...] = jnp.logical_and(_horner_mod(limbs, safe_p) == 0, p > 1)


@functools.partial(jax.jit, static_argnames=("block_n", "block_p", "interpret"))
def divisibility_mask_limbs_pallas(
    limbs: jnp.ndarray,        # (N, L) int64 32-bit limbs, N % block_n == 0
    primes: jnp.ndarray,       # (P,)  int64, P % block_p == 0
    *,
    block_n: int = 256,
    block_p: int = 512,
    interpret: bool = True,
):
    """Wide §4.2 prefetch scan: mask[i, j] = primes[j] | composite(limbs[i]).

    Limb rows of all-zero / value-1 composites (padding) match nothing;
    zero-padded primes never divide (same pad contract as the flat
    kernel).
    """
    n, L = limbs.shape
    p = primes.shape[0]
    assert n % block_n == 0 and p % block_p == 0, (n, p, block_n, block_p)
    grid = (n // block_n, p // block_p)
    return pl.pallas_call(
        _divmask_limbs_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, L), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_p), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_p), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.bool_),
        interpret=interpret,
    )(limbs, primes.reshape(1, p))


def _factorize_limbs_kernel(c_ref, p_ref, mask_ref, res_ref, *, block_p: int):
    j = pl.program_id(1)
    limbs = c_ref[...]                       # (BN, L)
    p = p_ref[...]                           # (1, BP)
    safe_p = jnp.where(p <= 1, jnp.ones_like(p), p)
    divides = jnp.logical_and(_horner_mod(limbs, safe_p) == 0, p > 1)
    mask_ref[...] = divides

    @pl.when(j == 0)
    def _init():
        res_ref[...] = limbs

    # peel off every dividing prime of this tile sequentially: short
    # division is inherently most-significant-first, so unlike the flat
    # kernel there is no one-shot tile-product divide — but the body is
    # traced ONCE (fori_loop) and each trip is L exact int64 ops/lane.
    def body(jj, res):
        pj = lax.dynamic_index_in_dim(safe_p[0], jj, keepdims=False)
        div = lax.dynamic_index_in_dim(divides, jj, axis=1, keepdims=False)
        return jnp.where(div[:, None], _short_div(res, pj), res)

    res_ref[...] = lax.fori_loop(0, block_p, body, res_ref[...])


@functools.partial(jax.jit, static_argnames=("block_n", "block_p", "interpret"))
def factorize_limbs_pallas(
    limbs: jnp.ndarray,        # (N, L) int64 32-bit limbs, N % block_n == 0
    primes: jnp.ndarray,       # (P,)  int64, P % block_p == 0
    *,
    block_n: int = 256,
    block_p: int = 512,
    interpret: bool = True,
):
    """Wide squarefree factorization: ``(mask (N, P) bool, residual
    (N, L))`` where the residual limbs hold the cofactor after dividing
    out every dividing pool prime (limb value 1 when fully factored).
    Same grid/accumulator shape as :func:`factorize_squarefree_pallas`
    with the residual tile carrying L limbs instead of one word.
    """
    n, L = limbs.shape
    p = primes.shape[0]
    assert n % block_n == 0 and p % block_p == 0, (n, p, block_n, block_p)
    grid = (n // block_n, p // block_p)
    mask, residual = pl.pallas_call(
        functools.partial(_factorize_limbs_kernel, block_p=block_p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, L), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_p), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, block_p), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, L), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, p), jnp.bool_),
            jax.ShapeDtypeStruct((n, L), jnp.int64),
        ],
        interpret=interpret,
    )(limbs, primes.reshape(1, p))
    return mask, residual


def _factorize_kernel(c_ref, p_ref, mask_ref, res_ref):
    """One (BN, BP) tile: divisibility mask + residual accumulation."""
    j = pl.program_id(1)
    c = c_ref[...]          # (BN, 1)
    p = p_ref[...]          # (1, BP)
    safe_p = jnp.where(p <= 1, jnp.ones_like(p), p)
    divides = jnp.logical_and((c % safe_p) == 0, p > 1)   # (BN, BP)
    mask_ref[...] = divides

    # residual accumulator: init with the composite on the first prime tile
    @pl.when(j == 0)
    def _init():
        res_ref[...] = c

    # divide out every dividing prime in this tile (squarefree: each prime
    # appears at most once, so a single exact division per prime is exact).
    factor = jnp.where(divides, safe_p, jnp.ones_like(safe_p))
    tile_prod = jnp.prod(factor, axis=1, keepdims=True)   # (BN, 1)
    res_ref[...] = res_ref[...] // jnp.maximum(tile_prod, 1)


@functools.partial(jax.jit, static_argnames=("block_n", "block_p", "interpret"))
def factorize_squarefree_pallas(
    composites: jnp.ndarray,   # (N,) int32/int64, N % block_n == 0
    primes: jnp.ndarray,       # (P,) same dtype, P % block_p == 0
    *,
    block_n: int = 256,
    block_p: int = 512,
    interpret: bool = True,
):
    """Returns ``(mask (N, P) bool, residual (N,))`` — see ref.py oracle."""
    n, p = composites.shape[0], primes.shape[0]
    assert n % block_n == 0 and p % block_p == 0, (n, p, block_n, block_p)
    c2 = composites.reshape(n, 1)
    p2 = primes.reshape(1, p)
    grid = (n // block_n, p // block_p)

    mask, residual = pl.pallas_call(
        _factorize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_p), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, block_p), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, p), jnp.bool_),
            jax.ShapeDtypeStruct((n, 1), composites.dtype),
        ],
        interpret=interpret,
    )(c2, p2)
    return mask, residual.reshape(n)


def _divmask_kernel(c_ref, p_ref, mask_ref):
    c = c_ref[...]
    p = p_ref[...]
    safe_p = jnp.where(p <= 1, jnp.ones_like(p), p)
    mask_ref[...] = jnp.logical_and((c % safe_p) == 0, p > 1)


@functools.partial(jax.jit, static_argnames=("block_n", "block_p", "interpret"))
def divisibility_mask_pallas(
    composites: jnp.ndarray,   # (N,) — the registry
    primes: jnp.ndarray,       # (P,) — query primes (recently accessed)
    *,
    block_n: int = 256,
    block_p: int = 512,
    interpret: bool = True,
):
    """Prefetch candidate scan (§4.2): mask[i, j] = primes[j] | composites[i].

    Mask-only variant of the factorize kernel for the serving-path hot
    loop: the host compacts per-query candidate lists from the mask and
    hands pairwise cofactors to the O(1) primality fast path.
    """
    n, p = composites.shape[0], primes.shape[0]
    assert n % block_n == 0 and p % block_p == 0, (n, p, block_n, block_p)
    grid = (n // block_n, p // block_p)
    return pl.pallas_call(
        _divmask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_p), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_p), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.bool_),
        interpret=interpret,
    )(composites.reshape(n, 1), primes.reshape(1, p))
