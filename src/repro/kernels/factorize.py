"""Pallas TPU kernel: batched squarefree factorization by trial division.

PFCS Algorithm 2 stage 1 (trial division against a prime pool), adapted
from the paper's per-access scalar loop to a TPU-native *batched* kernel:
the registry refresh / bulk relationship-discovery path factorizes many
composites against a whole pool at once.

Layout (all VMEM):
    composites tile  (BN, 1)  int32/int64  — one composite per sublane row
    primes tile      (1, BP)  int32/int64  — prime pool along lanes
    mask out tile    (BN, BP) bool         — mask[i,j] = p_j | c_i
    residual out     (BN, 1)               — c_i / prod(dividing p_j)

Grid: (N/BN, P/BP).  The prime axis (j) is the innermost, sequentially
executed grid dimension on TPU, so the residual tile accumulates the
running cofactor across prime tiles: initialized to the composite at
j == 0, divided by every dividing prime as tiles stream through.  This is
the standard TPU accumulator pattern (same shape as a matmul K-loop).

Default tile sizes keep the working set well under VMEM (BN=256, BP=512
int32 ≈ 0.5 MB including the bool tile) and lane-align BP to 128.

TPU int width note (DESIGN.md §3): the int32 fast path covers L1xL1 and
L1xL2 composites (the hot path by construction — hot data gets small
primes).  The int64 variant is validated in interpret mode and is the
reference semantics for hardware with emulated 64-bit integer ops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["factorize_squarefree_pallas", "divisibility_mask_pallas"]


def _factorize_kernel(c_ref, p_ref, mask_ref, res_ref):
    """One (BN, BP) tile: divisibility mask + residual accumulation."""
    j = pl.program_id(1)
    c = c_ref[...]          # (BN, 1)
    p = p_ref[...]          # (1, BP)
    safe_p = jnp.where(p <= 1, jnp.ones_like(p), p)
    divides = jnp.logical_and((c % safe_p) == 0, p > 1)   # (BN, BP)
    mask_ref[...] = divides

    # residual accumulator: init with the composite on the first prime tile
    @pl.when(j == 0)
    def _init():
        res_ref[...] = c

    # divide out every dividing prime in this tile (squarefree: each prime
    # appears at most once, so a single exact division per prime is exact).
    factor = jnp.where(divides, safe_p, jnp.ones_like(safe_p))
    tile_prod = jnp.prod(factor, axis=1, keepdims=True)   # (BN, 1)
    res_ref[...] = res_ref[...] // jnp.maximum(tile_prod, 1)


@functools.partial(jax.jit, static_argnames=("block_n", "block_p", "interpret"))
def factorize_squarefree_pallas(
    composites: jnp.ndarray,   # (N,) int32/int64, N % block_n == 0
    primes: jnp.ndarray,       # (P,) same dtype, P % block_p == 0
    *,
    block_n: int = 256,
    block_p: int = 512,
    interpret: bool = True,
):
    """Returns ``(mask (N, P) bool, residual (N,))`` — see ref.py oracle."""
    n, p = composites.shape[0], primes.shape[0]
    assert n % block_n == 0 and p % block_p == 0, (n, p, block_n, block_p)
    c2 = composites.reshape(n, 1)
    p2 = primes.reshape(1, p)
    grid = (n // block_n, p // block_p)

    mask, residual = pl.pallas_call(
        _factorize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_p), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, block_p), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, p), jnp.bool_),
            jax.ShapeDtypeStruct((n, 1), composites.dtype),
        ],
        interpret=interpret,
    )(c2, p2)
    return mask, residual.reshape(n)


def _divmask_kernel(c_ref, p_ref, mask_ref):
    c = c_ref[...]
    p = p_ref[...]
    safe_p = jnp.where(p <= 1, jnp.ones_like(p), p)
    mask_ref[...] = jnp.logical_and((c % safe_p) == 0, p > 1)


@functools.partial(jax.jit, static_argnames=("block_n", "block_p", "interpret"))
def divisibility_mask_pallas(
    composites: jnp.ndarray,   # (N,) — the registry
    primes: jnp.ndarray,       # (P,) — query primes (recently accessed)
    *,
    block_n: int = 256,
    block_p: int = 512,
    interpret: bool = True,
):
    """Prefetch candidate scan (§4.2): mask[i, j] = primes[j] | composites[i].

    Mask-only variant of the factorize kernel for the serving-path hot
    loop: the host compacts per-query candidate lists from the mask and
    hands pairwise cofactors to the O(1) primality fast path.
    """
    n, p = composites.shape[0], primes.shape[0]
    assert n % block_n == 0 and p % block_p == 0, (n, p, block_n, block_p)
    grid = (n // block_n, p // block_p)
    return pl.pallas_call(
        _divmask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_p), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_p), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.bool_),
        interpret=interpret,
    )(composites.reshape(n, 1), primes.reshape(1, p))
