"""Pallas TPU kernel: batched elementwise GCD (Euclid, fixed trip count).

Used by the serving tier for deterministic shared-prefix discovery:
``gcd(chain_composite_a, chain_composite_b)`` is the composite of the
shared pages (PFCS relationship intersection — exact, zero false
positives by unique factorization).

Vectorization note: binary GCD needs count-trailing-zeros, which does not
vectorize cleanly on the VPU; the Euclidean form ``(a, b) -> (b, a mod b)``
is branch-free with a ``b == 0`` guard and converges in <= 47 iterations
for int32 (Fibonacci worst case), <= 92 for int64.  A fixed-trip
``lax.fori_loop`` keeps the kernel shape static.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["gcd_pallas"]

_TRIPS = {jnp.dtype(jnp.int32): 48, jnp.dtype(jnp.int64): 96}


def _gcd_kernel(a_ref, b_ref, o_ref, *, trips: int):
    a = a_ref[...]
    b = b_ref[...]

    def body(_, ab):
        a, b = ab
        safe_b = jnp.where(b == 0, jnp.ones_like(b), b)
        r = jnp.where(b == 0, jnp.zeros_like(a), a % safe_b)
        new_a = jnp.where(b == 0, a, b)
        return new_a, r

    a, b = lax.fori_loop(0, trips, body, (a, b))
    o_ref[...] = a


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gcd_pallas(
    a: jnp.ndarray,   # (N,) int32/int64, N % block_n == 0
    b: jnp.ndarray,   # (N,) same
    *,
    block_n: int = 1024,
    interpret: bool = True,
):
    """Elementwise gcd(a, b) — matches ``jnp.gcd`` (incl. gcd(x, 0) = |x|;
    PFCS composites are positive so the abs path never triggers)."""
    n = a.shape[0]
    assert n % block_n == 0, (n, block_n)
    trips = _TRIPS[jnp.dtype(a.dtype)]
    # lanes-last layout: (rows, 128)
    lanes = 128
    assert block_n % lanes == 0
    rows = block_n // lanes
    a2 = a.reshape(n // lanes, lanes)
    b2 = b.reshape(n // lanes, lanes)
    out = pl.pallas_call(
        functools.partial(_gcd_kernel, trips=trips),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((rows, lanes), lambda i: (i, 0)),
            pl.BlockSpec((rows, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n // lanes, lanes), a.dtype),
        interpret=interpret,
    )(a2, b2)
    return out.reshape(n)
