"""Pallas TPU kernel: batched elementwise GCD (Euclid, fixed trip count).

Used by the serving tier for deterministic shared-prefix discovery:
``gcd(chain_composite_a, chain_composite_b)`` is the composite of the
shared pages (PFCS relationship intersection — exact, zero false
positives by unique factorization).

Vectorization note: binary GCD needs count-trailing-zeros, which does not
vectorize cleanly on the VPU; the Euclidean form ``(a, b) -> (b, a mod b)``
is branch-free with a ``b == 0`` guard and converges in <= 47 iterations
for int32 (Fibonacci worst case), <= 92 for int64.  A fixed-trip
``lax.fori_loop`` keeps the kernel shape static.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["gcd_pallas", "gcd_limbs_pallas"]

_TRIPS = {jnp.dtype(jnp.int32): 48, jnp.dtype(jnp.int64): 96}


def _gcd_kernel(a_ref, b_ref, o_ref, *, trips: int):
    a = a_ref[...]
    b = b_ref[...]

    def body(_, ab):
        a, b = ab
        safe_b = jnp.where(b == 0, jnp.ones_like(b), b)
        r = jnp.where(b == 0, jnp.zeros_like(a), a % safe_b)
        new_a = jnp.where(b == 0, a, b)
        return new_a, r

    a, b = lax.fori_loop(0, trips, body, (a, b))
    o_ref[...] = a


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gcd_pallas(
    a: jnp.ndarray,   # (N,) int32/int64, N % block_n == 0
    b: jnp.ndarray,   # (N,) same
    *,
    block_n: int = 1024,
    interpret: bool = True,
):
    """Elementwise gcd(a, b) — matches ``jnp.gcd`` (incl. gcd(x, 0) = |x|;
    PFCS composites are positive so the abs path never triggers)."""
    n = a.shape[0]
    assert n % block_n == 0, (n, block_n)
    trips = _TRIPS[jnp.dtype(a.dtype)]
    # lanes-last layout: (rows, 128)
    lanes = 128
    assert block_n % lanes == 0
    rows = block_n // lanes
    a2 = a.reshape(n // lanes, lanes)
    b2 = b.reshape(n // lanes, lanes)
    out = pl.pallas_call(
        functools.partial(_gcd_kernel, trips=trips),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((rows, lanes), lambda i: (i, 0)),
            pl.BlockSpec((rows, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n // lanes, lanes), a.dtype),
        interpret=interpret,
    )(a2, b2)
    return out.reshape(n)


# ----------------------------------------------------------------------- #
# multi-limb variant (DESIGN.md §11)                                      #
# ----------------------------------------------------------------------- #
# Multi-limb Euclid needs long division with normalization — hostile to
# the VPU.  PFCS composites let us sidestep it: chunk values are
# SQUAREFREE products of pool primes, so
#
#     gcd(a, b) = prod { p in pool : p | a  and  p | b }
#
# exactly (unique factorization — Theorem 1).  The kernel computes both
# divisibility masks with the Horner-mod ladder and rebuilds the gcd by
# masked schoolbook scalar multiplication into a limb accumulator:
#
#     t = g_limb * p + carry     g_limb < 2**32, p < 2**31, carry < 2**31
#                                => t < 2**63                         OK
#
# The caller supplies the prime pool covering the common factors (any
# superset of either side's member primes works — common primes are a
# subset of both).

_LIMB_BITS = 32
_LIMB_BASE = 1 << _LIMB_BITS
_LIMB_MASK = _LIMB_BASE - 1


def _horner_mod_g(limbs, p):
    r = jnp.zeros((limbs.shape[0], p.shape[1]), dtype=jnp.int64)
    for k in reversed(range(limbs.shape[1])):
        r = (r * _LIMB_BASE + limbs[:, k:k + 1]) % p
    return r


def _gcd_limbs_kernel(a_ref, b_ref, p_ref, o_ref, *, block_p: int):
    j = pl.program_id(1)
    a = a_ref[...]                           # (BN, L)
    b = b_ref[...]                           # (BN, L)
    p = p_ref[...]                           # (1, BP)
    L = a.shape[1]
    safe_p = jnp.where(p <= 1, jnp.ones_like(p), p)
    common = jnp.logical_and(
        jnp.logical_and(_horner_mod_g(a, safe_p) == 0,
                        _horner_mod_g(b, safe_p) == 0),
        p > 1)                               # (BN, BP)

    # accumulator: limb value 1 on the first prime tile
    @pl.when(j == 0)
    def _init():
        one = jnp.zeros_like(a)
        o_ref[...] = one.at[:, 0].set(1)

    def body(jj, g):
        pj = lax.dynamic_index_in_dim(safe_p[0], jj, keepdims=False)
        take = lax.dynamic_index_in_dim(common, jj, axis=1, keepdims=False)
        carry = jnp.zeros((g.shape[0],), dtype=jnp.int64)
        out = []
        for k in range(L):
            t = g[:, k] * pj + carry
            out.append(t & _LIMB_MASK)
            carry = t >> _LIMB_BITS
        mul = jnp.stack(out, axis=1)
        return jnp.where(take[:, None], mul, g)

    o_ref[...] = lax.fori_loop(0, block_p, body, o_ref[...])


@functools.partial(jax.jit, static_argnames=("block_n", "block_p", "interpret"))
def gcd_limbs_pallas(
    a: jnp.ndarray,            # (N, L) int64 32-bit limbs, N % block_n == 0
    b: jnp.ndarray,            # (N, L) same
    pool: jnp.ndarray,         # (P,)  int64 primes covering common factors
    *,
    block_n: int = 256,
    block_p: int = 512,
    interpret: bool = True,
):
    """Elementwise gcd of squarefree multi-limb composite pairs.

    Exact for chunk values that are products of distinct ``pool`` primes
    (the registry invariant).  Pad rows (limb value 0 or 1) and
    zero-padded pool primes yield gcd 1 — callers slice to the live
    prefix, matching the flat kernel's contract.
    """
    n, L = a.shape
    assert a.shape == b.shape, (a.shape, b.shape)
    p = pool.shape[0]
    assert n % block_n == 0 and p % block_p == 0, (n, p, block_n, block_p)
    grid = (n // block_n, p // block_p)
    return pl.pallas_call(
        functools.partial(_gcd_limbs_kernel, block_p=block_p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, L), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, L), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_p), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, L), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, L), jnp.int64),
        interpret=interpret,
    )(a, b, pool.reshape(1, p))
