"""Pallas TPU kernels for the PFCS factorization hot paths.

``factorize.py``  — batched squarefree trial-division factorization
                    (VMEM-tiled composites x prime-pool grid)
``gcd.py``        — batched Euclidean gcd (chain-composite intersection)
``ops.py``        — host-facing jit'd wrappers (padding, int32/int64 path)
``ref.py``        — pure-jnp oracles the kernels are tested against

Validated in interpret mode on CPU; compiled path targets TPU (see
DESIGN.md §3 for the int-width adaptation notes).
"""

from .ops import (INT32_SAFE_LIMIT, INT64_SAFE_LIMIT, divisibility_scan,
                  divisibility_scan_limbs, factorize_batch,
                  factorize_batch_exact, factorize_batch_limbs, gcd_batch,
                  gcd_batch_exact, gcd_batch_limbs)

__all__ = ["INT32_SAFE_LIMIT", "INT64_SAFE_LIMIT", "divisibility_scan",
           "divisibility_scan_limbs", "factorize_batch",
           "factorize_batch_exact", "factorize_batch_limbs", "gcd_batch",
           "gcd_batch_exact", "gcd_batch_limbs"]
