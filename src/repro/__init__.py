"""repro — PFCS (Prime Factorization Cache System) as a multi-pod JAX
training/serving framework.

Subpackages: ``core`` (the paper's contribution), ``kernels`` (Pallas),
``models`` / ``configs`` (the 10 assigned architectures), ``sharding`` /
``launch`` (distribution + dry-run), ``training`` / ``serving`` / ``data``
(substrates).  See README.md.
"""

__version__ = "1.0.0"
