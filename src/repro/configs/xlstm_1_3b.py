"""xlstm-1.3b — sLSTM + mLSTM block stack.

[arXiv:2405.04517; unverified]  48L d_model=2048 4H d_ff=0 (projection
factor lives inside the xLSTM blocks) vocab=50304.  Every 8th block is an
sLSTM (scalar memory, true recurrence); the rest are mLSTM (matrix
memory, parallelizable).  Fully recurrent -> long_500k runs.
"""

from .base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50_304,
    act="gelu",
    xlstm=XLSTMConfig(slstm_every=8, proj_factor_mlstm=2.0,
                      proj_factor_slstm=1.333, conv_kernel=4),
    subquadratic=True,
    remat="full",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        name="xlstm-smoke",
        n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        vocab_size=512,
        xlstm=XLSTMConfig(slstm_every=2, proj_factor_mlstm=2.0,
                          proj_factor_slstm=1.333, conv_kernel=4),
        dtype="float32", remat="none", attn_chunk=64,
    )
