"""qwen3-32b — dense GQA decoder with qk-norm.

[hf:Qwen/Qwen3-8B (family); hf]  64L d_model=5120 64H (GQA kv=8)
d_ff=25600 vocab=151936, qk_norm, head_dim=128, RoPE theta 1e6, SwiGLU.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25_600,
    vocab_size=151_936,
    act="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    subquadratic=False,
    use_fsdp=True,
    optimizer="adamw",
    remat="full",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        name="qwen3-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, use_fsdp=False,
        dtype="float32", remat="none", attn_chunk=64,
    )
