"""phi-3-vision-4.2b — VLM: phi3-mini backbone + CLIP patch stub.

[hf:microsoft/Phi-3-vision-128k-instruct; hf]  32L d_model=3072 32H
(GQA kv=32) d_ff=8192 vocab=32064.  The CLIP-L/14 vision tower is a STUB
per the assignment: ``input_specs()`` provides precomputed patch
embeddings (576 patches x 1024 features); the backbone consumes them via
a learned projector.
"""

from .base import ArchConfig, FrontendConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32_064,
    act="swiglu",
    rope_theta=10_000.0,
    frontend=FrontendConfig(kind="vision", feature_dim=1024, n_positions=576),
    subquadratic=False,
    remat="full",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        name="phi3-vision-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        frontend=FrontendConfig(kind="vision", feature_dim=32, n_positions=16),
        dtype="float32", remat="none", attn_chunk=64,
    )
