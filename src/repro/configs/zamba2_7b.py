"""zamba2-7b — hybrid: Mamba-2 backbone + shared attention blocks.

[arXiv:2411.15242; unverified]  81L d_model=3584 32H (GQA kv=32)
d_ff=14336 vocab=32000, ssm_state=64.  Two shared transformer blocks are
applied (alternating) every 6 Mamba layers — the Zamba2 weight-sharing
scheme.  Simplifications vs the released model (documented in DESIGN.md):
additive residual instead of the embedding-concat re-injection, no LoRA
adapters on the shared blocks.
"""

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,                   # Mamba-2 layers
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14_336,                   # shared block FFN
    vocab_size=32_000,
    act="swiglu",
    rope_theta=10_000.0,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                  n_groups=2, chunk_size=256),
    shared_attn_every=6,
    n_shared_attn_blocks=2,
    subquadratic=True,             # Mamba backbone -> long_500k runs
    remat="full",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        name="zamba2-smoke",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk_size=32),
        shared_attn_every=2, n_shared_attn_blocks=2,
        dtype="float32", remat="none", attn_chunk=64,
    )
