"""kimi-k2-1t-a32b — trillion-parameter MoE (384 experts, top-8).

[arXiv:2501.kimi2; unverified, paper-table]  61L d_model=7168 64H
(GQA kv=8) expert d_ff=2048 vocab=163840, MoE 384e top-8, 1 shared
expert, first layer dense (d_ff=18432).  The assigned table specifies
GQA (not MLA); we follow the assignment.  FSDP over the data axis +
expert parallelism over the model axis; Adafactor keeps optimizer state
factored (a 1T-param AdamW would need ~8 TB of moments).
"""

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=18_432,                  # dense (first) layer FFN
    vocab_size=163_840,
    act="swiglu",
    rope_theta=50_000.0,
    moe=MoEConfig(
        n_experts=384,
        top_k=8,
        d_ff_expert=2048,
        n_shared_experts=1,
        d_ff_shared=2048,
        capacity_factor=1.25,
        first_dense_layers=1,
    ),
    subquadratic=False,
    use_fsdp=True,
    optimizer="adafactor",
    remat="full",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        name="kimi-k2-smoke",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      n_shared_experts=1, d_ff_shared=32,
                      first_dense_layers=1),
        use_fsdp=False, optimizer="adamw",
        dtype="float32", remat="none", attn_chunk=64,
    )
