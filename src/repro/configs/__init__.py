"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke(arch_id)``.

One module per assigned architecture; ids match the assignment table.
"""

from __future__ import annotations

from typing import Callable, Dict, List

from .base import ArchConfig, ShapeSpec, SHAPES

from . import (
    seamless_m4t_large_v2,
    qwen3_32b,
    phi3_medium_14b,
    gemma_2b,
    qwen2_5_3b,
    kimi_k2_1t_a32b,
    deepseek_v2_236b,
    zamba2_7b,
    xlstm_1_3b,
    phi3_vision_4_2b,
)

_MODULES = {
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "qwen3-32b": qwen3_32b,
    "phi3-medium-14b": phi3_medium_14b,
    "gemma-2b": gemma_2b,
    "qwen2.5-3b": qwen2_5_3b,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "zamba2-7b": zamba2_7b,
    "xlstm-1.3b": xlstm_1_3b,
    "phi-3-vision-4.2b": phi3_vision_4_2b,
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    try:
        return _MODULES[arch_id].CONFIG
    except KeyError:
        raise ValueError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")


def get_smoke(arch_id: str) -> ArchConfig:
    try:
        return _MODULES[arch_id].smoke()
    except KeyError:
        raise ValueError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")


def shape_applies(cfg: ArchConfig, shape: ShapeSpec) -> bool:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False
    return True


def cells(include_inapplicable: bool = False):
    """All (arch_id, shape) evaluation cells per the assignment."""
    out = []
    for aid in ARCH_IDS:
        cfg = get_config(aid)
        for shp in SHAPES:
            if include_inapplicable or shape_applies(cfg, shp):
                out.append((aid, shp))
    return out


__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "ARCH_IDS", "get_config",
           "get_smoke", "shape_applies", "cells"]
