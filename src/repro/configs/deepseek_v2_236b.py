"""deepseek-v2-236b — MoE with Multi-head Latent Attention (MLA).

[arXiv:2405.04434; hf]  60L d_model=5120 128H d_ff=1536 (expert)
vocab=102400, MLA kv_lora_rank=512 q_lora_rank=1536 (rope 64 / nope 128 /
v 128), MoE: 2 shared + 160 routed top-6, first layer dense (d_ff=12288).
"""

from .base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,               # MLA: per-head KV from the shared latent
    head_dim=128,
    d_ff=12_288,                  # dense (first) layer FFN
    vocab_size=102_400,
    act="swiglu",
    rope_theta=10_000.0,
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=160,
        top_k=6,
        d_ff_expert=1536,
        n_shared_experts=2,
        d_ff_shared=1536,
        capacity_factor=1.25,
        first_dense_layers=1,
    ),
    subquadratic=False,
    use_fsdp=True,
    optimizer="adafactor",
    remat="full",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        name="deepseek-v2-smoke",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        mla=MLAConfig(kv_lora_rank=16, q_lora_rank=32,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      n_shared_experts=2, d_ff_shared=32,
                      first_dense_layers=1),
        use_fsdp=False, optimizer="adamw",
        dtype="float32", remat="none", attn_chunk=64,
    )
