"""gemma-2b — dense MQA decoder with GeGLU and wide heads.

[arXiv:2403.08295; hf]  18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=256000, GeGLU, head_dim=256, tied embeddings.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab_size=256_000,
    act="geglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    subquadratic=False,
    remat="full",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        name="gemma-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=128, vocab_size=512,
        dtype="float32", remat="none", attn_chunk=64,
    )
