"""qwen2.5-3b — dense GQA decoder with QKV bias.

[hf:Qwen/Qwen2.5-0.5B (family); hf]  36L d_model=2048 16H (GQA kv=2)
d_ff=11008 vocab=151936, QKV bias, SwiGLU, head_dim=128.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11_008,
    vocab_size=151_936,
    act="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    subquadratic=False,
    remat="full",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        name="qwen2.5-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        dtype="float32", remat="none", attn_chunk=64,
    )
