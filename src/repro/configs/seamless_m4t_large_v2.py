"""seamless-m4t-large-v2 — enc-dec multimodal (audio) backbone.

[arXiv:2308.11596; hf]  24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206.  The speech frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (80-dim fbank x2
stacked = 160 features/frame); the transformer backbone is what we build.
"""

from .base import ArchConfig, EncDecConfig, FrontendConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,                   # per stack; see encdec
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    act="gelu",                    # classic (non-gated) transformer FFN
    rope_theta=10_000.0,
    encdec=EncDecConfig(n_encoder_layers=24, n_decoder_layers=24),
    frontend=FrontendConfig(kind="audio", feature_dim=160, n_positions=0),
    subquadratic=False,            # full attention -> long_500k skipped
    remat="full",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        name="seamless-smoke",
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        vocab_size=512,
        encdec=EncDecConfig(n_encoder_layers=2, n_decoder_layers=2),
        frontend=FrontendConfig(kind="audio", feature_dim=20, n_positions=0),
        dtype="float32", remat="none", attn_chunk=64,
    )
