"""phi3-medium-14b — dense GQA decoder.

[arXiv:2404.14219; unverified]  40L d_model=5120 40H (GQA kv=10)
d_ff=17920 vocab=100352, RoPE SwiGLU GQA, head_dim=128.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    head_dim=128,
    d_ff=17_920,
    vocab_size=100_352,
    act="swiglu",
    rope_theta=10_000.0,
    subquadratic=False,
    use_fsdp=True,
    optimizer="adamw",
    remat="full",
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        name="phi3-medium-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, use_fsdp=False,
        dtype="float32", remat="none", attn_chunk=64,
    )
