"""Architecture config schema for the assigned model pool.

Every architecture in ``repro.configs`` instantiates :class:`ArchConfig`
with its exact published dimensions, plus a ``smoke()`` reduced variant of
the same family for CPU tests.  The model zoo (``repro.models``) builds
parameter trees and step functions purely from this schema.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

__all__ = ["MoEConfig", "MLAConfig", "SSMConfig", "XLSTMConfig",
           "EncDecConfig", "FrontendConfig", "ArchConfig", "SHAPES",
           "ShapeSpec"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0           # per shared expert
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    first_dense_layers: int = 1    # leading dense layers (DeepSeek/Kimi style)


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block parameters."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block stack: mLSTM blocks with sLSTM blocks interleaved."""
    slstm_every: int = 8           # every k-th block is sLSTM (rest mLSTM)
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.333
    conv_kernel: int = 4


@dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 24
    n_decoder_layers: int = 24


@dataclass(frozen=True)
class FrontendConfig:
    kind: str = "none"             # "audio" | "vision" | "none"
    feature_dim: int = 0           # precomputed frame/patch embedding dim
    n_positions: int = 0           # patches per image / frames per clip


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    act: str = "swiglu"            # swiglu|geglu|gelu
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    subquadratic: bool = False     # eligible for long_500k
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    # hybrid (zamba2-style): shared attention block applied every k ssm layers
    shared_attn_every: int = 0
    n_shared_attn_blocks: int = 0
    # distribution hints
    use_fsdp: bool = False         # shard weights over the data axis too
    optimizer: str = "adamw"       # adamw|adafactor|sgdm
    remat: str = "full"            # full|dots|none
    attn_chunk: int = 1024         # query-chunked attention block (train/prefill)
    unroll: bool = False           # unroll all scans (dry-run cost probes only)
    # -- perf-variant knobs (EXPERIMENTS.md §Perf A/B) ------------------- #
    moe_combine: str = "scatter"   # "scatter" (baseline) | "gather" (opt)
    shard_moe_dispatch: bool = False  # d-shard dispatch buf (avoids weight
    #                                   all-gather under FSDP at decode)
    accum_steps: int = 1           # microbatch accumulation for train_step
    kv_cache_dtype: str = "model"  # "model" | "int8" (quantized decode cache)
    dp_only: bool = False          # small models: FSDP over data, no TP

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # -- approximate parameter counts (roofline MODEL_FLOPS) --------------- #

    def param_count(self) -> int:
        """Total parameters (embedding included once)."""
        from repro.models.model_zoo import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model_zoo import count_params_analytic
        return count_params_analytic(self, active_only=True)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train|prefill|decode


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)
