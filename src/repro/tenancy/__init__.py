"""Multi-tenant QoS serving subsystem (DESIGN.md §8).

PFCS makes tenant isolation a *theorem* instead of a policy: every
tenant draws its primes from a disjoint family of contiguous value
blocks (:class:`~repro.tenancy.namespace.TenantNamespace`), so the gcd
of any two tenants' composites is identically 1 and no composite can
ever encode a cross-tenant relationship — discovery, and therefore
prefetch, cannot leak across tenants by construction.

On top of the namespace layer, :mod:`repro.tenancy.qos` enforces
per-tenant HBM-page and prefetch-budget quotas as int32 array state
inside the serving caches (scalar oracle twin kept bit-exact), and
``ServingEngine(tenants=...)`` threads per-request tenant ids through
the continuous-batching loop.
"""

from .namespace import (IsolationReport, StripedPrimePool, TenantAssigner,
                        TenantNamespace)
from .qos import (QuotaState, TenantQoSConfig, TenantedExpertCache,
                  TenantedPagedKVCache, TenantedShardedPagedKVCache,
                  TenantedVectorizedExpertCache,
                  TenantedVectorizedPagedKVCache, weighted_quotas)

__all__ = [
    "TenantNamespace", "TenantAssigner", "StripedPrimePool",
    "IsolationReport",
    "TenantQoSConfig", "QuotaState", "weighted_quotas",
    "TenantedPagedKVCache", "TenantedVectorizedPagedKVCache",
    "TenantedShardedPagedKVCache",
    "TenantedExpertCache", "TenantedVectorizedExpertCache",
]
