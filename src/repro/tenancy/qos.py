"""Vectorized per-tenant admission control and weighted eviction.

The tenanted serving caches layer three QoS mechanisms over the
existing scalar/vec/sharded paged-KV and expert caches (DESIGN.md §8.3):

  * **Weighted HBM quotas.**  Each tenant holds at most ``hbm_quota[t]``
    resident pages (slots), with quotas derived from integer priority
    weights (``weighted_quotas`` — largest-remainder apportionment)
    and ``sum(quota) <= capacity`` enforced at construction.  Quota
    state is int32 array state (``quota`` / ``occupancy`` / ``priority``
    arrays alongside the HBM slot arrays).
  * **Confined eviction.**  A tenant at quota evicts its OWN least-
    recently-used page — one masked ``argmin`` over the stamp array in
    the vectorized cache, the first own-tenant entry of the
    ``OrderedDict`` in the scalar oracle (stamp order == dict order, so
    the two victims coincide exactly).  No insert, demand or prefetch,
    can ever displace another tenant's page: a scanner tenant thrashes
    only its own allotment.
  * **Per-tenant prefetch budgets.**  The §4.2 successor prefetch loop
    runs under ``prefetch_budget[t]`` of the *touching* page's tenant;
    every issued prefetch lands in the per-tenant prefetch log.  Cross-
    tenant prefetches are impossible by the namespace isolation theorem
    (``repro.tenancy.namespace``) and audited by
    ``cross_tenant_prefetches()``.

The scalar twins are the bit-exact oracles: every ``PARITY_COUNTERS``
entry, every per-touch tier, the exact HBM LRU order, per-tenant stats,
and the prefetch logs must match between the tenanted scalar and
vectorized caches under any interleaving, at any tenant count, and
composed with the mesh-sharded cache — the established differential-
fuzz recipe, extended in ``tests/test_tenancy.py``.

Entry points, documented with runnable examples in docs/api.md:
:class:`~repro.tenancy.qos.TenantQoSConfig`,
:class:`~repro.tenancy.qos.TenantedVectorizedPagedKVCache`, and
:class:`~repro.tenancy.qos.TenantedVectorizedExpertCache`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.primes import CacheLevel
from repro.serving.expert_cache import ExpertCache
from repro.serving.expert_cache_vec import VectorizedExpertCache
from repro.serving.elastic import ElasticShardedPagedKVCache
from repro.serving.kv_cache import PARITY_COUNTERS, PagedKVCache, PageStats
from repro.serving.kv_cache_sharded import ShardedPagedKVCache
from repro.serving.kv_cache_vec import EMPTY, VectorizedPagedKVCache

from .namespace import TenantAssigner, TenantNamespace

__all__ = [
    "weighted_quotas", "refcount_weighted_shares", "TenantQoSConfig",
    "QuotaState",
    "TenantedPagedKVCache", "TenantedVectorizedPagedKVCache",
    "TenantedShardedPagedKVCache", "TenantedElasticShardedPagedKVCache",
    "TenantedExpertCache", "TenantedVectorizedExpertCache",
]

_STAMP_MAX = np.iinfo(np.int64).max


def _audit_prefetch_log(log, assigner, namespace,
                        tenant_of_element) -> int:
    """Theorem-level audit shared by both cache tiers: count prefetch
    pairs whose source and target element primes fall in different
    tenants' block families (pure value ownership — the §8.2 corollary
    says this must be 0).  Elements whose prime was since recycled
    audit by ``tenant_of_element`` (the recorded binding) instead."""
    bad = 0
    for src, tgt in log:
        ps, pt = assigner.prime_of(src), assigner.prime_of(tgt)
        if ps is not None and pt is not None:
            if (namespace.tenant_of_value(ps)
                    != namespace.tenant_of_value(pt)):
                bad += 1
        elif tenant_of_element(src) != tenant_of_element(tgt):
            bad += 1
    return bad


def weighted_quotas(capacity: int, priorities: Sequence[int]) -> List[int]:
    """Apportion ``capacity`` HBM pages over tenants by integer priority
    weight: every tenant gets at least 1, the remainder is split
    proportionally (largest-remainder method, ties to the lower tenant
    id — fully deterministic)."""
    pri = [int(p) for p in priorities]
    n = len(pri)
    if n < 1:
        raise ValueError("need at least one tenant")
    if any(p < 1 for p in pri):
        raise ValueError("priorities must be >= 1")
    if capacity < n:
        raise ValueError(f"capacity {capacity} cannot give {n} tenants "
                         f"one page each")
    extra = capacity - n
    total = sum(pri)
    raw = [extra * p / total for p in pri]
    out = [1 + int(r) for r in raw]
    rem = capacity - sum(out)
    order = sorted(range(n), key=lambda i: (-(raw[i] - int(raw[i])), i))
    for i in order[:rem]:
        out[i] += 1
    return out


@dataclass(frozen=True)
class TenantQoSConfig:
    """Per-tenant QoS contract: HBM quota, prefetch budget, priority.

    ``shared_quota`` (default 0) reserves HBM slots for the shared
    dedup namespace's read-only pages (``repro.serving.dedup``,
    DESIGN.md §12); it participates in the quota-partition inequality
    — ``sum(hbm_quota) + shared_quota <= capacity`` — so shared pages
    can never displace (or be displaced by) a tenant's private pages."""

    n_tenants: int
    hbm_quota: Tuple[int, ...]
    prefetch_budget: Tuple[int, ...]
    priority: Tuple[int, ...]
    shared_quota: int = 0

    def validate(self, capacity: int) -> None:
        T = self.n_tenants
        if T < 1:
            raise ValueError("n_tenants must be >= 1")
        for name, v in (("hbm_quota", self.hbm_quota),
                        ("prefetch_budget", self.prefetch_budget),
                        ("priority", self.priority)):
            if len(v) != T:
                raise ValueError(f"{name} has {len(v)} entries for "
                                 f"{T} tenants")
        if any(q < 1 for q in self.hbm_quota):
            raise ValueError("every tenant needs hbm_quota >= 1")
        if self.shared_quota < 0:
            raise ValueError("shared_quota must be >= 0")
        if sum(self.hbm_quota) + self.shared_quota > capacity:
            raise ValueError(
                f"sum(hbm_quota)={sum(self.hbm_quota)} + "
                f"shared_quota={self.shared_quota} exceeds HBM "
                f"capacity {capacity} — quotas must partition HBM "
                f"(that inequality IS the confinement guarantee)")
        if any(b < 0 for b in self.prefetch_budget):
            raise ValueError("prefetch budgets must be >= 0")

    @classmethod
    def even(cls, n_tenants: int, capacity: int,
             prefetch_budget: int = 4) -> "TenantQoSConfig":
        """Equal-priority split of the whole HBM capacity."""
        return cls.weighted(capacity, [1] * n_tenants, prefetch_budget)

    @classmethod
    def weighted(cls, capacity: int, priorities: Sequence[int],
                 prefetch_budget: int = 4) -> "TenantQoSConfig":
        """Priority-weighted split of the whole HBM capacity."""
        q = weighted_quotas(capacity, priorities)
        n = len(q)
        return cls(n_tenants=n, hbm_quota=tuple(q),
                   prefetch_budget=(int(prefetch_budget),) * n,
                   priority=tuple(int(p) for p in priorities))

    @classmethod
    def normalize(cls, qos: Union[int, "TenantQoSConfig"], capacity: int,
                  default_budget: int) -> "TenantQoSConfig":
        if isinstance(qos, int):
            qos = cls.even(qos, capacity, prefetch_budget=default_budget)
        qos.validate(capacity)
        return qos


class QuotaState:
    """The QoS array state: int32 quota / occupancy / priority /
    prefetch-budget vectors plus per-tenant prefetch logs, and — when
    the cache charges them (the paged-KV tier's ``_charge_touch``) —
    per-tenant stats.  ``stats_factory=None`` leaves ``tenant_stats``
    as ``None`` instead of planting counters nothing ever increments
    (the expert tier: per-tenant accounting there is the logs,
    ``occupancy``, and the per-expert tiers ``activate`` returns)."""

    def __init__(self, cfg: TenantQoSConfig, stats_factory=None):
        T = cfg.n_tenants
        self.quota = np.asarray(cfg.hbm_quota, dtype=np.int32)
        self.pf_budget = np.asarray(cfg.prefetch_budget, dtype=np.int32)
        self.priority = np.asarray(cfg.priority, dtype=np.int32)
        self.occupancy = np.zeros((T,), dtype=np.int32)
        # shared dedup namespace residency (repro.serving.dedup):
        # tracked as a scalar alongside the per-tenant arrays so the
        # partition inequality stays checkable at runtime
        self.shared_quota = int(getattr(cfg, "shared_quota", 0))
        self.shared_occupancy = 0
        self.tenant_stats = None if stats_factory is None \
            else [stats_factory() for _ in range(T)]
        self.tenant_logs: List[List[Tuple[int, int]]] = [[] for _ in range(T)]


def refcount_weighted_shares(occupancy: Sequence[int],
                             shared_refs: Sequence[Dict[int, int]]
                             ) -> np.ndarray:
    """Refcount-weighted HBM accounting (DESIGN.md §12): each tenant is
    charged its private occupancy plus, for every HBM-resident shared
    page, the fraction of that page's references it holds —
    ``occupancy[t] + Σ_pages ref_t(page) / ref(page)``.  The column sum
    equals total resident pages, so dedup's HBM-bytes/user win shows up
    as each tenant's charged share dropping below its no-dedup
    footprint (``benchmarks.cases.case_dedup``)."""
    out = np.asarray(occupancy, dtype=np.float64).copy()
    for refs in shared_refs:
        total = sum(refs.values())
        if total <= 0:
            continue
        for t, r in refs.items():
            out[t] += r / total
    return out


# --------------------------------------------------------------------------- #
# paged-KV tenancy                                                            #
# --------------------------------------------------------------------------- #

class _TenantedKVBase:
    """Identity + accounting layer shared by every tenanted KV cache:
    tenant-scoped content addressing, namespace-routed prime assignment,
    per-tenant stats/log charging.  Placement enforcement lives in the
    scalar / vec placement subclasses below."""

    def _setup_tenancy(self, qos, namespace, capacity: int,
                       default_budget: int) -> None:
        cfg = TenantQoSConfig.normalize(qos, capacity, default_budget)
        if namespace is None:
            namespace = TenantNamespace(cfg.n_tenants)
        if namespace.n_tenants != cfg.n_tenants:
            raise ValueError(f"namespace has {namespace.n_tenants} tenants, "
                             f"qos config {cfg.n_tenants}")
        self.qos_config = cfg
        self.namespace = namespace
        self.qos = QuotaState(cfg, PageStats)
        self._tenant_of_req: Dict[int, int] = {}
        self._current_tenant = 0

    # -- identity hooks (see PagedKVCache._init_identity) ------------------

    def _make_assigner(self):
        return TenantAssigner(self.namespace, self.registry)

    def _content_key(self, token_block):
        # tenant-scoped content addressing: identical tokens, different
        # tenants -> different pages (no cross-tenant relationships)
        return (self._current_tenant,) + tuple(token_block)

    def _assign_page(self, pid: int) -> None:
        self.assigner.bind(pid, self._current_tenant)
        self.assigner.assign(pid, CacheLevel.L2)

    def tenant_of_page(self, pid: int) -> int:
        t = self.assigner.tenant_of(pid)
        return 0 if t is None else int(t)

    def tenant_of_request(self, req_id: int) -> int:
        return self._tenant_of_req.get(req_id, 0)

    # -- request lifecycle -------------------------------------------------

    def register_request(self, req_id: int, tokens, tenant: int = 0):
        t = int(tenant)
        if not 0 <= t < self.qos_config.n_tenants:
            raise ValueError(f"tenant {t} out of range "
                             f"[0, {self.qos_config.n_tenants})")
        self._tenant_of_req[req_id] = t
        self._current_tenant = t
        before = self.stats.shared_prefix_pages
        pages = super().register_request(req_id, tokens)
        self.qos.tenant_stats[t].shared_prefix_pages += \
            self.stats.shared_prefix_pages - before
        return pages

    def release_request(self, req_id: int) -> None:
        self._tenant_of_req.pop(req_id, None)
        super().release_request(req_id)

    # -- accounting --------------------------------------------------------

    def _charge_touch(self, t: int, before: Tuple[int, ...],
                      n_log: int) -> None:
        """Charge every counter delta (and prefetch-log slice) one touch
        produced to the touching tenant — confinement means every
        affected page is the tenant's own, so the attribution is exact
        (same delta-diff recipe as the sharded cache's shard stats)."""
        ts = self.qos.tenant_stats[t]
        for f, b, a in zip(PARITY_COUNTERS, before, self.stats.parity_tuple()):
            if a != b:
                setattr(ts, f, getattr(ts, f) + (a - b))
        if len(self.prefetch_log) > n_log:
            self.qos.tenant_logs[t].extend(self.prefetch_log[n_log:])

    def cross_tenant_prefetches(self) -> int:
        """Prefetch-log entries spanning tenant namespaces — must be 0
        (asserted by ``case_tenancy`` and the fuzz suite); see
        ``_audit_prefetch_log``."""
        return _audit_prefetch_log(self.prefetch_log, self.assigner,
                                   self.namespace, self.tenant_of_page)

    def tenant_hit_rates(self) -> List[float]:
        return [ts.hbm_hit_rate for ts in self.qos.tenant_stats]


class TenantedPagedKVCache(_TenantedKVBase, PagedKVCache):
    """Scalar oracle with per-tenant quotas — the bit-exact reference
    for the vectorized and sharded tenanted caches."""

    def __init__(self, hbm_pages: int = 1024, page_size: int = 16,
                 prefetch_budget: int = 4, qos: Union[int, TenantQoSConfig] = 2,
                 namespace: Optional[TenantNamespace] = None,
                 max_bits: int = 62):
        self._setup_tenancy(qos, namespace, hbm_pages, prefetch_budget)
        super().__init__(hbm_pages=hbm_pages, page_size=page_size,
                         prefetch_budget=prefetch_budget, max_bits=max_bits)

    def _insert_hbm(self, pid: int, prefetched: bool) -> None:
        t = self.tenant_of_page(pid)
        q = self.qos
        if q.occupancy[t] >= q.quota[t]:
            # confined eviction: the tenant's own LRU page (first own
            # entry of the OrderedDict == oldest stamp)
            victim = next(x for x in self.hbm if self.tenant_of_page(x) == t)
            del self.hbm[victim]
            self.host.add(victim)
            self.stats.evictions += 1
            self._note_evict(victim)
            q.occupancy[t] -= 1
        super()._insert_hbm(pid, prefetched)   # base evict loop: no-op
        q.occupancy[t] += 1

    def touch(self, req_id: int, page_idx: int) -> str:
        pid = self.chains[req_id][page_idx]
        t = self.tenant_of_page(pid)
        self.prefetch_budget = int(self.qos.pf_budget[t])
        before = self.stats.parity_tuple()
        n_log = len(self.prefetch_log)
        tier = super().touch(req_id, page_idx)
        self._charge_touch(t, before, n_log)
        return tier


class _TenantedVecPlacement(_TenantedKVBase):
    """Array-state quota enforcement shared by the vectorized and the
    mesh-sharded tenanted caches."""

    def _init_slot_tenant(self) -> None:
        #: per-slot tenant id (-1 empty) — the mask the confined
        #: eviction argmin runs over
        self.slot_tenant = np.full((self.hbm_capacity,), -1, dtype=np.int32)

    def _insert(self, pid: int, prefetched: bool) -> None:
        t = self.tenant_of_page(pid)
        q = self.qos
        if q.occupancy[t] >= q.quota[t]:
            # confined eviction: oldest stamp among the tenant's own
            # slots (one masked argmin — unique stamps make it exactly
            # the scalar oracle's first-own-entry victim)
            n = self._n_occupied
            stamps = np.where(self.slot_tenant[:n] == t,
                              self.slot_t[:n], _STAMP_MAX)
            s = int(np.argmin(stamps))
            victim = int(self.slot_page[s])
            self.slot_of[victim] = EMPTY
            self.in_host[victim] = True
            self.stats.evictions += 1
            self._note_evict(victim)
            q.occupancy[t] -= 1
            self.in_host[pid] = False
            self.slot_page[s] = pid
            self.slot_of[pid] = s
            self.slot_t[s] = self._tick()
            self.slot_pf[s] = prefetched       # slot_tenant[s] stays t
        else:
            # below quota: sum(quota) <= capacity guarantees a free slot
            assert self._n_occupied < self.hbm_capacity, \
                "quota invariant broken: HBM full with a tenant under quota"
            super()._insert(pid, prefetched)
            self.slot_tenant[self.slot_of[pid]] = t
        q.occupancy[t] += 1

    def _touch_one(self, pid: int) -> str:
        t = self.tenant_of_page(pid)
        self.prefetch_budget = int(self.qos.pf_budget[t])
        before = self.stats.parity_tuple()
        n_log = len(self.prefetch_log)
        tier = super()._touch_one(pid)
        self._charge_touch(t, before, n_log)
        return tier


class TenantedVectorizedPagedKVCache(_TenantedVecPlacement,
                                     VectorizedPagedKVCache):
    """Drop-in :class:`~repro.serving.kv_cache_vec.VectorizedPagedKVCache`
    with coprime tenant namespaces and array-state quota enforcement —
    bit-exact against ``TenantedPagedKVCache``."""

    def __init__(self, hbm_pages: int = 1024, page_size: int = 16,
                 prefetch_budget: int = 4, discover: str = "incremental",
                 qos: Union[int, TenantQoSConfig] = 2,
                 namespace: Optional[TenantNamespace] = None,
                 max_bits: int = 62):
        self._setup_tenancy(qos, namespace, hbm_pages, prefetch_budget)
        super().__init__(hbm_pages=hbm_pages, page_size=page_size,
                         prefetch_budget=prefetch_budget, discover=discover,
                         max_bits=max_bits)
        self._init_slot_tenant()


class TenantedShardedPagedKVCache(_TenantedVecPlacement,
                                  ShardedPagedKVCache):
    """Tenant namespaces composed with the mesh-sharded cache: prime
    ownership stripes over SHARDS for discovery work (DESIGN.md §6) and
    over TENANTS for isolation/quotas (§8) — two independent pure
    functions of the same prime value, so the per-shard bulk rebuild
    and the collective gcd exchange run unchanged over the tenanted
    prime space."""

    def __init__(self, hbm_pages: int = 1024, page_size: int = 16,
                 prefetch_budget: int = 4, n_shards: int = 2,
                 mesh="auto", stripes_per_shard: int = 8,
                 qos: Union[int, TenantQoSConfig] = 2,
                 namespace: Optional[TenantNamespace] = None,
                 max_bits: int = 62):
        self._setup_tenancy(qos, namespace, hbm_pages, prefetch_budget)
        super().__init__(hbm_pages=hbm_pages, page_size=page_size,
                         prefetch_budget=prefetch_budget, n_shards=n_shards,
                         mesh=mesh, stripes_per_shard=stripes_per_shard,
                         max_bits=max_bits)
        self._init_slot_tenant()


class TenantedElasticShardedPagedKVCache(_TenantedVecPlacement,
                                         ElasticShardedPagedKVCache):
    """Tenant namespaces composed with the ELASTIC sharded cache
    (DESIGN.md §9): ``resize``/``fail_shard``/``recover_shard`` operate
    purely on the shard striping of the prime space, while tenant
    isolation/quotas stripe the SAME prime values over tenants — two
    independent pure ownership functions, so no elastic event can move
    a page across a tenant boundary.  The chaos fuzz asserts the
    namespace isolation checker after every recovery
    (``tests/test_elastic.py``)."""

    def __init__(self, hbm_pages: int = 1024, page_size: int = 16,
                 prefetch_budget: int = 4, n_shards: int = 2,
                 mesh="auto", stripes_per_shard: int = 8,
                 qos: Union[int, TenantQoSConfig] = 2,
                 namespace: Optional[TenantNamespace] = None,
                 max_bits: int = 62):
        self._setup_tenancy(qos, namespace, hbm_pages, prefetch_budget)
        super().__init__(hbm_pages=hbm_pages, page_size=page_size,
                         prefetch_budget=prefetch_budget, n_shards=n_shards,
                         mesh=mesh, stripes_per_shard=stripes_per_shard,
                         max_bits=max_bits)
        self._init_slot_tenant()


# --------------------------------------------------------------------------- #
# MoE expert tenancy                                                          #
# --------------------------------------------------------------------------- #

class _TenantedExpertBase:
    """Identity + QoS layer shared by the tenanted expert caches."""

    def _setup_expert_tenancy(self, qos, namespace, hbm_slots: int,
                              default_budget: int, n_experts: int,
                              tenant_of_expert) -> None:
        cfg = TenantQoSConfig.normalize(qos, hbm_slots, default_budget)
        if namespace is None:
            namespace = TenantNamespace(cfg.n_tenants)
        if namespace.n_tenants != cfg.n_tenants:
            raise ValueError(f"namespace has {namespace.n_tenants} tenants, "
                             f"qos config {cfg.n_tenants}")
        self.qos_config = cfg
        self.namespace = namespace
        self.qos = QuotaState(cfg)       # stats: logs/occupancy/tiers only
        if tenant_of_expert is None:
            # default: contiguous equal expert blocks per tenant
            tenant_of_expert = (np.arange(n_experts, dtype=np.int64)
                                * cfg.n_tenants) // max(1, n_experts)
        self.tenant_of_expert = np.asarray(tenant_of_expert, dtype=np.int32)
        if self.tenant_of_expert.shape != (n_experts,):
            raise ValueError("tenant_of_expert must map every expert")
        if (self.tenant_of_expert.min(initial=0) < 0
                or self.tenant_of_expert.max(initial=0) >= cfg.n_tenants):
            raise ValueError("tenant_of_expert entries out of range")
        #: router sets that spanned tenants and were split before
        #: registration (isolation by construction)
        self.cross_tenant_groups = 0

    # -- identity hooks ----------------------------------------------------

    def _make_assigner(self):
        return TenantAssigner(self.namespace, self.registry)

    def _assign_expert(self, e: int) -> None:
        self.assigner.bind(e, int(self.tenant_of_expert[e]))
        self.assigner.assign(e, CacheLevel.L2)

    # -- co-activation registration (split by tenant) ----------------------

    def observe_routing(self, expert_sets):
        """Split every router set by tenant before registration: a
        co-activation group spanning tenants would be a cross-tenant
        composite — exactly what the namespace forbids — so each
        tenant's sub-group registers separately (sub-groups keep the
        set's expert order; counted in ``cross_tenant_groups``)."""
        split = []
        for s in expert_sets:
            groups: Dict[int, List[int]] = {}
            for e in s:
                groups.setdefault(int(self.tenant_of_expert[int(e)]),
                                  []).append(int(e))
            if len(groups) > 1:
                self.cross_tenant_groups += 1
            split.extend(tuple(g) for g in groups.values())
        return super().observe_routing(split)

    def cross_tenant_prefetches(self) -> int:
        """Prefetch-log entries spanning tenant namespaces — must be 0;
        see ``_audit_prefetch_log``."""
        return _audit_prefetch_log(self.prefetch_log, self.assigner,
                                   self.namespace,
                                   lambda e: int(self.tenant_of_expert[e]))


class TenantedExpertCache(_TenantedExpertBase, ExpertCache):
    """Scalar oracle: per-tenant HBM-slot quotas and prefetch budgets
    over the MoE expert cache."""

    def __init__(self, n_experts: int, hbm_slots: int,
                 prefetch_budget: int = 4, max_group: int = 8,
                 qos: Union[int, TenantQoSConfig] = 2,
                 namespace: Optional[TenantNamespace] = None,
                 tenant_of_expert=None):
        self._setup_expert_tenancy(qos, namespace, hbm_slots,
                                   prefetch_budget, n_experts,
                                   tenant_of_expert)
        super().__init__(n_experts, hbm_slots, prefetch_budget, max_group)

    def _insert(self, e: int, prefetched: bool) -> None:
        t = int(self.tenant_of_expert[e])
        q = self.qos
        if q.occupancy[t] >= q.quota[t]:
            victim = next(x for x in self.hbm
                          if self.tenant_of_expert[x] == t)
            del self.hbm[victim]
            self.stats.evictions += 1
            q.occupancy[t] -= 1
        super()._insert(e, prefetched)         # base evict loop: no-op
        q.occupancy[t] += 1

    def _prefetch_coactivated(self, e: int) -> None:
        t = int(self.tenant_of_expert[e])
        self.prefetch_budget = int(self.qos.pf_budget[t])
        n_log = len(self.prefetch_log)
        super()._prefetch_coactivated(e)
        if len(self.prefetch_log) > n_log:
            self.qos.tenant_logs[t].extend(self.prefetch_log[n_log:])


class TenantedVectorizedExpertCache(_TenantedExpertBase,
                                    VectorizedExpertCache):
    """Drop-in :class:`~repro.serving.expert_cache_vec.
    VectorizedExpertCache` with coprime tenant namespaces and
    array-state quota enforcement — bit-exact against
    ``TenantedExpertCache``."""

    def __init__(self, n_experts: int, hbm_slots: int,
                 prefetch_budget: int = 4, max_group: int = 8,
                 discover: str = "incremental",
                 qos: Union[int, TenantQoSConfig] = 2,
                 namespace: Optional[TenantNamespace] = None,
                 tenant_of_expert=None):
        self._setup_expert_tenancy(qos, namespace, hbm_slots,
                                   prefetch_budget, n_experts,
                                   tenant_of_expert)
        super().__init__(n_experts, hbm_slots, prefetch_budget, max_group,
                         discover)
        self.slot_tenant = np.full((hbm_slots,), -1, dtype=np.int32)

    def _insert(self, e: int, prefetched: bool) -> None:
        t = int(self.tenant_of_expert[e])
        q = self.qos
        if q.occupancy[t] >= q.quota[t]:
            n = self._n_occupied
            stamps = np.where(self.slot_tenant[:n] == t,
                              self.slot_t[:n], _STAMP_MAX)
            s = int(np.argmin(stamps))
            victim = int(self.slot_expert[s])
            self.slot_of[victim] = EMPTY
            self.stats.evictions += 1
            q.occupancy[t] -= 1
            self.slot_expert[s] = e
            self.slot_of[e] = s
            self.slot_t[s] = self._tick()
            self.slot_pf[s] = prefetched       # slot_tenant[s] stays t
        else:
            assert self._n_occupied < self.hbm_slots, \
                "quota invariant broken: HBM full with a tenant under quota"
            super()._insert(e, prefetched)
            self.slot_tenant[self.slot_of[e]] = t
        q.occupancy[t] += 1

    def _prefetch_row(self, e: int) -> None:
        t = int(self.tenant_of_expert[e])
        self.prefetch_budget = int(self.qos.pf_budget[t])
        n_log = len(self.prefetch_log)
        super()._prefetch_row(e)
        if len(self.prefetch_log) > n_log:
            self.qos.tenant_logs[t].extend(self.prefetch_log[n_log:])
