"""Coprime tenant namespaces: disjoint prime-value blocks per tenant.

Every tenant draws its primes from its own family of contiguous value
blocks, dealt round-robin by the shared striping partitioner
(``repro.sharding.stripes.BlockStripes`` — the same machinery the
mesh-sharded discovery layer stripes shards with, DESIGN.md §6.1/§8.1).
Disjoint blocks mean disjoint prime sets, and by unique factorization
the gcd of composites built from disjoint prime sets is identically 1:

    **Isolation theorem** (DESIGN.md §8.2).  For tenants s != t, every
    composite of tenant s is coprime to every composite of tenant t,
    and no live composite factors across two tenants' blocks.  Hence a
    §4.2 divisibility scan or gcd discovery issued with tenant t's
    primes can only ever surface tenant t's relationships — cross-tenant
    prefetch traffic is impossible by construction, not by policy.

``TenantNamespace.check_isolation`` is that theorem as an
executable check: it re-*factorizes* every live registry composite
(Algorithm 2, not a reverse index) and verifies the recovered member
primes map into a single tenant's block family; the optional pairwise
mode additionally verifies ``gcd == 1`` across every cross-tenant
composite pair.

Entry points, documented with runnable examples in docs/api.md:
:class:`~repro.tenancy.namespace.TenantNamespace` (block layout,
vectorized membership, the isolation checker) and
:class:`~repro.tenancy.namespace.TenantAssigner` (per-tenant Algorithm-1
assigners over one shared registry, with per-namespace prime recycling).
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.assignment import PrimeAssigner
from repro.core.primes import (CacheLevel, HierarchicalPrimeAllocator,
                               LEVEL_PRIME_RANGES, PrimePool, segmented_sieve)
from repro.sharding.stripes import BlockStripes

__all__ = ["TenantNamespace", "TenantAssigner", "StripedPrimePool",
           "IsolationReport"]


@functools.lru_cache(maxsize=256)
def _sieve_cached(lo: int, hi: int) -> Tuple[int, ...]:
    """Memoized sieve segment — tenant pools re-filter the same level
    ranges and lazy MEM segments per tenant and per cache construction;
    sieving each segment once per process keeps namespace construction
    at numpy-filter cost."""
    return tuple(int(p) for p in segmented_sieve(lo, hi))


@dataclass
class StripedPrimePool(PrimePool):
    """A ``repro.core.primes.PrimePool`` restricted to the blocks
    one tenant owns: sieved primes are filtered through the namespace's
    vectorized ownership test, so two tenants' pools over the SAME level
    range can never hand out the same prime.  Allocation order within
    the tenant stays ascending (Algorithm 1's cheapest-factorization
    discipline), it just skips foreign blocks."""

    stripes: Optional[BlockStripes] = None
    part: int = 0

    def _owned(self, primes: Sequence[int]) -> List[int]:
        ps = np.asarray(primes, dtype=np.int64)
        if ps.size == 0:
            return []
        return [int(p) for p in ps[self.stripes.owners(ps) == self.part]]

    def __post_init__(self) -> None:
        assert self.stripes is not None
        if self.hi is not None:
            self._primes = self._owned(_sieve_cached(self.lo, self.hi + 1))
        else:
            self._lazy_cursor = self.lo
            self._extend(self.initial_capacity)

    def _extend(self, at_least: int) -> None:
        if self.hi is not None:
            return
        got = 0
        seg = 1 << 16
        while got < at_least:
            new = self._owned(_sieve_cached(self._lazy_cursor,
                                            self._lazy_cursor + seg))
            self._primes.extend(new)
            got += len(new)
            self._lazy_cursor += seg
            seg = min(seg * 2, 1 << 22)


@dataclass
class IsolationReport:
    """Result of ``TenantNamespace.check_isolation``."""

    ok: bool = True
    n_relationships: int = 0
    n_composites: int = 0
    per_tenant: List[int] = field(default_factory=list)
    #: (composite, tenant ids its factors span) for every violation
    violations: List[Tuple[int, Tuple[int, ...]]] = field(
        default_factory=list)
    #: cross-tenant composite pairs gcd-verified coprime (pairwise mode)
    coprime_pairs_checked: int = 0
    #: composites touching the shared dedup namespace (``shared=True``
    #: namespaces only): wholly-shared chain edges plus mixed
    #: shared↔private COW-boundary edges — legal by construction,
    #: excluded from the pairwise coprimality sweep (DESIGN.md §12)
    n_shared: int = 0


class TenantNamespace:
    """Disjoint contiguous prime-value blocks per tenant.

    Ownership is pure O(1) arithmetic on the prime value
    (``BlockStripes``), so membership tests
    vectorize over whole registries and any holder of a prime can
    classify it without coordination.  ``n_tenants == 1`` degenerates to
    the untenanted prime space: tenant 0 owns every block, and a
    1-tenant namespace allocator is value-for-value identical to the
    global ``HierarchicalPrimeAllocator``.
    """

    def __init__(self, n_tenants: int, stripes_per_tenant: int = 8,
                 ranges: Optional[Dict[int, Tuple[int, Optional[int]]]] = None,
                 mem_initial_capacity: int = 1024, shared: bool = False):
        if n_tenants < 1:
            raise ValueError("n_tenants must be >= 1")
        self.ranges = dict(ranges or LEVEL_PRIME_RANGES)
        # shared=True reserves ONE extra block family — the dedup
        # namespace (DESIGN.md §12): part id ``n_tenants`` in the same
        # BlockStripes deal, so shared blocks are disjoint from (hence
        # shared primes coprime to) every tenant's blocks by the same
        # construction that separates tenants from each other.
        n_parts = n_tenants + 1 if shared else n_tenants
        self.stripes = BlockStripes(n_parts, self.ranges,
                                    stripes_per_part=stripes_per_tenant)
        self.n_tenants = int(n_tenants)
        self.n_parts = self.stripes.n_parts
        self.shared_part: Optional[int] = n_tenants if shared else None
        self.mem_initial_capacity = mem_initial_capacity

    # ------------------------------------------------------------------ #
    # membership                                                          #
    # ------------------------------------------------------------------ #

    def tenant_of_value(self, p: int) -> int:
        """Tenant owning prime value ``p`` — pure function, O(1)."""
        return self.stripes.owner(p)

    def tenant_of_values(self, values: Sequence[int]) -> np.ndarray:
        """Vectorized membership: int array of values -> int32 tenant
        ids (one arithmetic pass per cache level, no per-value loop)."""
        return self.stripes.owners(values)

    def is_member(self, tenant: int, values: Sequence[int]) -> np.ndarray:
        """Bool mask: which of ``values`` fall inside ``tenant``'s
        blocks."""
        return self.tenant_of_values(values) == int(tenant)

    # ------------------------------------------------------------------ #
    # allocation                                                          #
    # ------------------------------------------------------------------ #

    def make_allocator(self, tenant: int) -> HierarchicalPrimeAllocator:
        """A level-pool façade whose every pool is restricted to the
        tenant's blocks (disjoint from every other tenant's by
        construction).  In a ``shared=True`` namespace, part id
        ``shared_part`` (== ``n_tenants``) is a valid target too — the
        dedup namespace's own allocator."""
        if not 0 <= int(tenant) < self.n_parts:
            raise ValueError(f"tenant {tenant} out of range "
                             f"[0, {self.n_parts})")
        alloc = HierarchicalPrimeAllocator.__new__(HierarchicalPrimeAllocator)
        alloc.pools = {
            lvl: StripedPrimePool(level=lvl, lo=lo, hi=hi,
                                  initial_capacity=self.mem_initial_capacity,
                                  stripes=self.stripes, part=int(tenant))
            for lvl, (lo, hi) in self.ranges.items()}
        return alloc

    # ------------------------------------------------------------------ #
    # the isolation theorem, as an executable check                       #
    # ------------------------------------------------------------------ #

    def check_isolation(self, registry,
                        pairwise_gcd: bool = False) -> IsolationReport:
        """Prove every live composite factors inside ONE tenant's block
        family.

        Each composite is re-factorized through the registry's
        factorizer (``registry.decode`` — Algorithm 2, the same decode
        path discovery uses), and the recovered primes are mapped
        through the vectorized membership test.  ``pairwise_gcd=True``
        additionally gcd-checks every cross-tenant composite pair
        against 1 — the coprimality statement of the theorem verified
        literally (quadratic; meant for tests and smoke benchmarks).

        In a ``shared=True`` namespace the theorem statement weakens
        exactly as DESIGN.md §12 proves it must: shared-part primes are
        *deliberately* common, so a composite is a violation only when
        its factors span two distinct **non-shared** tenants.  Wholly-
        shared and mixed shared↔private composites are counted in
        ``n_shared`` and excluded from the pairwise sweep (two tenants
        diverging off the same shared page legitimately share that
        page's prime across their COW-boundary edges).
        """
        arr = registry.composites_view()
        rep = IsolationReport(per_tenant=[0] * self.n_tenants,
                              n_relationships=len(registry),
                              n_composites=int(arr.size))
        tenant_of_comp: List[int] = []
        for c in arr:
            primes = registry.decode(int(c))
            parts = self.tenant_of_values(np.asarray(primes, dtype=np.int64))
            if self.shared_part is not None:
                shared_mask = parts == self.shared_part
                has_shared = bool(shared_mask.any())
                ts = np.unique(parts[~shared_mask])
            else:
                has_shared = False
                ts = np.unique(parts)
            if ts.size == 0:              # wholly shared-namespace edge
                rep.n_shared += 1
                tenant_of_comp.append(-2)
            elif ts.size == 1:
                t = int(ts[0])
                rep.per_tenant[t] += 1
                if has_shared:            # mixed COW-boundary edge
                    rep.n_shared += 1
                    tenant_of_comp.append(-2)
                else:
                    tenant_of_comp.append(t)
            else:
                rep.ok = False
                rep.violations.append((int(c), tuple(int(t) for t in ts)))
                tenant_of_comp.append(-1)
        if pairwise_gcd:
            for i in range(arr.size):
                for j in range(i + 1, arr.size):
                    if (tenant_of_comp[i] == tenant_of_comp[j]
                            or tenant_of_comp[i] < 0
                            or tenant_of_comp[j] < 0):
                        continue
                    rep.coprime_pairs_checked += 1
                    if math.gcd(int(arr[i]), int(arr[j])) != 1:
                        rep.ok = False
                        rep.violations.append(
                            (int(arr[i]),
                             (tenant_of_comp[i], tenant_of_comp[j])))
        return rep

    def assert_isolated(self, registry) -> None:
        """Raise ``AssertionError`` with the violation list if any live
        composite spans tenants (test/fuzz invariant hook)."""
        rep = self.check_isolation(registry)
        assert rep.ok, f"tenant isolation violated: {rep.violations}"

    def describe(self) -> str:
        return (f"TenantNamespace(n_tenants={self.n_tenants}, "
                f"{self.stripes.describe()})")


class TenantAssigner:
    """Per-tenant Algorithm-1 assigners over ONE shared registry.

    Each tenant gets its own ``PrimeAssigner`` — its own namespace-restricted pools and its own
    access tracker — so pool-exhaustion recycling is *per namespace*: a
    noisy tenant churning through its prime blocks recycles only its own
    LRU elements and can never stall (or purge composites of) another
    tenant.  The registry is shared, so the §4.2 divisibility scan, the
    successor tables, and the sharded discovery path all run unchanged
    over the union — isolation comes from the namespace math, not from
    splitting the registry.

    The façade speaks the ``PrimeAssigner`` vocabulary the serving
    caches use (``prime_of`` / ``data_of`` / ``assign`` / ``release``);
    routing is by the data element's recorded tenant binding on the data
    side and by pure value-ownership on the prime side.
    """

    def __init__(self, namespace: TenantNamespace, registry,
                 recycle_fraction: float = 0.1):
        self.namespace = namespace
        self.registry = registry
        # one assigner per part — includes the shared dedup part when
        # the namespace was built with shared=True (DESIGN.md §12)
        self.per_tenant: List[PrimeAssigner] = [
            PrimeAssigner(namespace.make_allocator(t), registry,
                          recycle_fraction=recycle_fraction)
            for t in range(namespace.n_parts)]
        self._tenant_of_data: Dict[Hashable, int] = {}

    # -- tenant binding ----------------------------------------------------

    def bind(self, d: Hashable, tenant: int) -> None:
        self._tenant_of_data[d] = int(tenant)

    def tenant_of(self, d: Hashable) -> Optional[int]:
        return self._tenant_of_data.get(d)

    # -- PrimeAssigner vocabulary (routed) ---------------------------------

    @property
    def epoch(self) -> int:
        """Aggregate release epoch (see ``PrimeAssigner.epoch``)."""
        return sum(a.epoch for a in self.per_tenant)

    def assign(self, d: Hashable, level: int) -> int:
        t = self._tenant_of_data.get(d)
        if t is None:
            raise KeyError(f"data element {d!r} has no tenant binding "
                           f"(call bind(d, tenant) first)")
        return self.per_tenant[t].assign(d, level)

    def prime_of(self, d: Hashable) -> Optional[int]:
        t = self._tenant_of_data.get(d)
        return None if t is None else self.per_tenant[t].prime_of(d)

    def data_of(self, p: int) -> Optional[Hashable]:
        # prime side routes by VALUE ownership — pure namespace math
        return self.per_tenant[self.namespace.tenant_of_value(p)].data_of(p)

    def release(self, d: Hashable, level: int) -> None:
        t = self._tenant_of_data.get(d)
        if t is not None:
            self.per_tenant[t].release(d, level)
