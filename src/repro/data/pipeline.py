"""Data pipeline: synthetic corpus, byte tokenizer, deterministic sharded
loader, and a PFCS-cached storage tier.

The loader is host-count aware (``shard_index`` / ``shard_count``): every
host reads only its slice, deterministically from (seed, step) — so a
restarted or re-sharded (elastic) job reproduces the exact global batch
stream from any step, which together with the checkpoint manager gives
bit-identical resume.

The storage tier models a shard-file cache: mixture sampling makes shard
co-access structured (a mixture 'domain' pulls a correlated set of
shards); PFCS registers domain->shard relationships and prefetches the
shards a sampled domain is about to read.  ``ml_epoch_trace`` in
``core.traces`` is the micro version of this workload; here it is wired
to the real loader.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pfcs_cache import PFCSCache

__all__ = ["ByteTokenizer", "SyntheticCorpus", "ShardedLoader"]


class ByteTokenizer:
    """Byte-level tokenizer with a few special tokens."""

    PAD, BOS, EOS = 256, 257, 258
    vocab_size = 259

    def encode(self, text: str, add_bos: bool = True) -> List[int]:
        ids = list(text.encode("utf-8"))
        return ([self.BOS] if add_bos else []) + ids

    def decode(self, ids: Sequence[int]) -> str:
        return bytes(i for i in ids if i < 256).decode("utf-8", "replace")


@dataclass
class SyntheticCorpus:
    """Deterministic mixture-of-domains token stream.

    Each domain d has a distinct unigram distribution (so training on it
    is learnable) and owns a set of shard files; sampling a sequence from
    d touches ~3 of its shards (the relationship structure PFCS caches).
    """

    vocab_size: int = 259
    n_domains: int = 8
    shards_per_domain: int = 16
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.domain_logits = rng.normal(size=(self.n_domains, self.vocab_size))
        self.domain_shards = [
            list(range(d * self.shards_per_domain,
                       (d + 1) * self.shards_per_domain))
            for d in range(self.n_domains)
        ]

    @property
    def n_shards(self) -> int:
        return self.n_domains * self.shards_per_domain

    def sample_sequence(self, rng: np.random.Generator, seq_len: int
                        ) -> Tuple[np.ndarray, int, List[int]]:
        """Returns (tokens, domain, shards_touched)."""
        d = int(rng.integers(self.n_domains))
        logits = self.domain_logits[d]
        p = np.exp(logits - logits.max())
        p /= p.sum()
        toks = rng.choice(self.vocab_size, size=seq_len, p=p).astype(np.int32)
        shards = list(rng.choice(self.domain_shards[d], size=3, replace=False))
        return toks, d, [int(s) for s in shards]


class ShardedLoader:
    """Deterministic, restartable, host-sharded batch iterator."""

    def __init__(self, corpus: SyntheticCorpus, global_batch: int,
                 seq_len: int, shard_index: int = 0, shard_count: int = 1,
                 seed: int = 0, pfcs_cache: Optional[PFCSCache] = None):
        assert global_batch % shard_count == 0
        self.corpus = corpus
        self.global_batch = global_batch
        self.local_batch = global_batch // shard_count
        self.seq_len = seq_len
        self.shard_index = shard_index
        self.shard_count = shard_count
        self.seed = seed
        self.cache = pfcs_cache
        if self.cache is not None:
            # register domain -> shard relationships (the catalog)
            for d, shards in enumerate(corpus.domain_shards):
                self.cache.register_relationship(
                    [("domain", d)] + [("shard", s) for s in shards],
                    kind="dataset")

    def _rng_for(self, step: int, sample: int) -> np.random.Generator:
        key = hashlib.sha256(
            f"{self.seed}:{step}:{self.shard_index}:{sample}".encode()
        ).digest()
        return np.random.default_rng(int.from_bytes(key[:8], "little"))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The exact local batch for ``step`` (same result on every call)."""
        toks = np.empty((self.local_batch, self.seq_len), np.int32)
        for i in range(self.local_batch):
            rng = self._rng_for(step, i)
            seq, domain, shards = self.corpus.sample_sequence(rng, self.seq_len)
            toks[i] = seq
            if self.cache is not None:
                self.cache.access(("domain", domain))
                for s in shards:
                    self.cache.access(("shard", s))
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
