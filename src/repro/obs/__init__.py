"""PFCS observability layer: event tracing, serving telemetry, kernel
profiling (DESIGN.md §13) — disabled by default, provably inert when
off.

Every serving cache, slot front-end, and engine carries an ``obs``
attribute that defaults to ``None``; every hook in the hot paths is
guarded by ``if self.obs is not None``.  Attaching an
:class:`Observability` turns on event recording and telemetry
snapshots without touching a single placement decision — the
tracing-off parity sweep in ``tests/test_obs.py`` pins that the
counters, tier logs, LRU orders, and prefetch logs of every backend
are bit-identical with ``obs=None``, with a zero-capacity tracer, and
with a live tracer attached.

Documented with runnable examples in docs/api.md:
:class:`~repro.obs.Observability` (the façade),
:class:`~repro.obs.trace.EventTracer` (the int32 event ring),
:func:`~repro.obs.trace.trace_diff` (the differential-trace axis),
:class:`~repro.obs.telemetry.Telemetry` (gauges + histograms),
:class:`~repro.obs.telemetry.Progress` (host-side rate reporting), and
:func:`~repro.obs.profile.kernel_scope` (named-scope + launch-ledger
profiling).
"""

from __future__ import annotations

import json
from typing import Optional

from . import profile
from .telemetry import Progress, StreamingHist, Telemetry
from .trace import (EVENT_FIELDS, EVENT_NAMES, EV_ADMIT, EV_AGE_OUT,
                    EV_COMPLETE, EV_COW, EV_DEDUP_HIT, EV_DEDUP_PROMOTE,
                    EV_EVICT, EV_GCD_EXCHANGE, EV_PREEMPT, EV_PREFETCH,
                    EV_PREFILL_CHUNK, EV_RECOVERY, EV_RESUME_PREFETCH,
                    EventTracer, TraceEvent, trace_diff)

__all__ = [
    "Observability", "EventTracer", "TraceEvent", "trace_diff",
    "Telemetry", "StreamingHist", "Progress", "profile",
    "EVENT_FIELDS", "EVENT_NAMES",
    "EV_ADMIT", "EV_PREFILL_CHUNK", "EV_PREEMPT", "EV_RESUME_PREFETCH",
    "EV_COMPLETE", "EV_EVICT", "EV_PREFETCH", "EV_DEDUP_HIT",
    "EV_DEDUP_PROMOTE", "EV_COW", "EV_AGE_OUT", "EV_GCD_EXCHANGE",
    "EV_RECOVERY",
]


class Observability:
    """The attachable observability façade: one event tracer + one
    telemetry sink, carried by caches / slot machines / engines as
    their ``obs`` attribute.

    ``trace_capacity=0`` keeps the tracer attached but recording
    nothing (pure counter bumps); ``telemetry=False`` drops the
    telemetry sink entirely.  The kernel profiling ledger is
    process-global (``repro.obs.profile``) and merely *reported* here.
    """

    def __init__(self, trace_capacity: int = 4096,
                 telemetry: bool = True,
                 telemetry_capacity: int = 4096):
        self.trace = EventTracer(trace_capacity)
        self.telemetry: Optional[Telemetry] = (
            Telemetry(telemetry_capacity) if telemetry else None)

    # hot-path hook: one guarded call in the instrumented sites
    def emit(self, kind: int, **lanes) -> None:
        self.trace.emit(kind, **lanes)

    def export(self) -> dict:
        """Everything observed, as one JSON-ready payload (the input
        format of ``tools/trace_view.py``)."""
        return {
            "schema": {str(k): v for k, v in EVENT_NAMES.items()},
            "trace": self.trace.export(),
            "telemetry": (self.telemetry.export()
                          if self.telemetry is not None else None),
            "kernel_launches": profile.summary(),
        }

    def export_json(self, path) -> None:
        with open(path, "w") as fh:
            json.dump(self.export(), fh, indent=1, sort_keys=True)
            fh.write("\n")


def attach(target, obs: Optional[Observability]) -> Optional[Observability]:
    """Attach ``obs`` to an engine / slot front-end and its cache
    tiers (``pages`` and, when present, ``experts``).  Returns ``obs``
    for chaining; ``attach(target, None)`` detaches."""
    target.obs = obs
    for attr in ("pages", "experts"):
        tier = getattr(target, attr, None)
        if tier is not None:
            tier.obs = obs
    return obs
