"""Serving telemetry: per-tick gauges, streaming histograms, and
progress/rate reporting (DESIGN.md §13).

Everything here is **read-only over serving state**: a snapshot pulls
queue depth, slot occupancy, phase mix, counter deltas, per-tenant
charged HBM (refcount-weighted when the dedup tier is active), and
per-shard scan-slice peaks out of an engine or slot machine, and stores
them in bounded rings.  No snapshot ever writes back into the object it
observes, which is the whole inertness argument: with telemetry
attached, the serving stack computes byte-for-byte the same placement
it computes without it.

Histograms are power-of-two bucketed (``value.bit_length()``), so they
are deterministic for the integer quantities they record (tick
latencies, queue depths) — percentile *estimates* come from bucket
upper bounds, exact min/max/mean come from exact accumulators.
"""

from __future__ import annotations

import sys
import time
from typing import Dict, List, Optional

__all__ = ["StreamingHist", "Telemetry", "Progress"]


class StreamingHist:
    """Streaming histogram over non-negative integers with power-of-two
    buckets: bucket ``k`` holds values with ``bit_length() == k``
    (i.e. ``[2^(k-1), 2^k)``; bucket 0 holds the zeros)."""

    def __init__(self) -> None:
        self.counts: Dict[int, int] = {}
        self.n = 0
        self.total = 0
        self.min: Optional[int] = None
        self.max: Optional[int] = None

    def add(self, value: int) -> None:
        v = int(value)
        if v < 0:
            v = 0
        b = v.bit_length()
        self.counts[b] = self.counts.get(b, 0) + 1
        self.n += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def quantile(self, q: float) -> int:
        """Upper-bound estimate of the ``q``-quantile from the bucket
        boundaries (exact for values 0 and 1, within 2x above)."""
        if not self.n:
            return 0
        want = max(1, int(q * self.n + 0.999999))
        seen = 0
        for b in sorted(self.counts):
            seen += self.counts[b]
            if seen >= want:
                return (1 << b) - 1 if b else 0
        return self.max or 0

    def summary(self) -> dict:
        return {
            "count": self.n,
            "sum": self.total,
            "mean": self.total / self.n if self.n else 0.0,
            "min": self.min or 0,
            "max": self.max or 0,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
            "buckets": {str(k): v for k, v in sorted(self.counts.items())},
        }


class Telemetry:
    """Bounded per-tick gauge rings + named streaming histograms.

    ``gauge(name, value, tick)`` appends to a ring of the last
    ``capacity`` samples per name; ``observe(name, value)`` feeds the
    named histogram.  ``tick_slots``/``tick_engine`` are the canonical
    snapshot points wired into ``SlotMachine``/``SlotOracle`` ticks and
    ``ServingEngine.step()``.
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self.gauges: Dict[str, List[List[float]]] = {}
        self.hists: Dict[str, StreamingHist] = {}
        self.ticks_seen = 0

    # -- primitives -------------------------------------------------------- #

    def gauge(self, name: str, value, tick: int = -1) -> None:
        ring = self.gauges.setdefault(name, [])
        ring.append([int(tick), float(value)])
        if len(ring) > self.capacity:
            del ring[:len(ring) - self.capacity]

    def observe(self, name: str, value: int) -> None:
        self.hists.setdefault(name, StreamingHist()).add(value)

    # -- canonical snapshot points ----------------------------------------- #

    def tick_slots(self, m) -> None:
        """Per-tick gauges from a slot front-end (machine or oracle):
        queue depth, phase mix, live occupancy — all via the shared
        ``obs_slot_mix()`` accessor so both twins report identically."""
        tick = int(m.now)
        free, prefill, decode = m.obs_slot_mix()
        self.gauge("queue_depth", len(m.waiting), tick)
        self.gauge("slots_free", free, tick)
        self.gauge("slots_prefill", prefill, tick)
        self.gauge("slots_decode", decode, tick)
        self.gauge("live", prefill + decode, tick)
        self.observe("queue_depth", len(m.waiting))
        self._snap_pages(m.pages, tick)
        self.ticks_seen += 1

    def tick_engine(self, eng) -> None:
        """Per-step gauges from a ``ServingEngine``: queue depth, live
        slots, cache counters, per-tenant charged HBM, shard scan
        slices."""
        tick = int(getattr(eng, "steps", self.ticks_seen))
        live = sum(1 for s in eng.slots if s is not None)
        self.gauge("queue_depth", len(eng.queue), tick)
        self.gauge("live", live, tick)
        self.observe("queue_depth", len(eng.queue))
        self._snap_pages(eng.pages, tick)
        self.ticks_seen += 1

    def _snap_pages(self, pages, tick: int) -> None:
        st = pages.stats
        self.gauge("hbm_hits", st.hbm_hits, tick)
        self.gauge("misses", st.misses, tick)
        self.gauge("prefetches", st.prefetches, tick)
        self.gauge("evictions", st.evictions, tick)
        self.gauge("prefetch_hit_rate", st.prefetch_hit_rate, tick)
        # per-tenant charged HBM: refcount-weighted under dedup, plain
        # quota occupancy under tenancy, absent otherwise
        if hasattr(pages, "charged_shares"):
            for t, v in enumerate(pages.charged_shares()):
                self.gauge(f"tenant{t}_charged_pages", float(v), tick)
        elif hasattr(pages, "qos"):
            for t, v in enumerate(pages.qos.occupancy):
                self.gauge(f"tenant{t}_charged_pages", int(v), tick)
        # per-shard scan-slice peaks (sharded/elastic backends)
        scan = getattr(pages, "last_scan", None)
        if scan is not None and scan.local_composites:
            self.gauge("scan_slice_peak", max(scan.local_composites),
                       tick)
            self.gauge("scan_cross_composites", scan.cross_composites,
                       tick)

    def complete(self, ttft_ticks: int, tpot_milliticks: int) -> None:
        """Request-completion latency observations (engine ticks; TPOT
        scaled x1000 so sub-tick decode rates survive integer
        buckets)."""
        self.observe("ttft_ticks", ttft_ticks)
        self.observe("tpot_milliticks", tpot_milliticks)

    # -- export ------------------------------------------------------------- #

    def export(self) -> dict:
        return {
            "capacity": self.capacity,
            "ticks_seen": self.ticks_seen,
            "gauges": {k: [list(s) for s in v]
                       for k, v in sorted(self.gauges.items())},
            "hists": {k: h.summary()
                      for k, h in sorted(self.hists.items())},
        }


class Progress:
    """Host-side progress/rate reporter for long deterministic builds
    (the ``case_scale`` 1M-element registry loop).

    Rate accounting always runs (the totals feed the benchmark ``obs``
    block); *printing* is throttled to ``interval_s`` and suppressed
    entirely under ``quiet=True`` — the CI default, where 20 seconds of
    progress lines would only bloat logs.
    """

    def __init__(self, total: int, label: str = "", quiet: bool = False,
                 interval_s: float = 2.0, stream=None):
        self.total = int(total)
        self.label = label
        self.quiet = bool(quiet)
        self.interval_s = float(interval_s)
        self.stream = stream if stream is not None else sys.stderr
        self.done_n = 0
        self.t0 = time.perf_counter()
        self._last_print = self.t0

    def advance(self, n: int = 1) -> None:
        self.done_n += int(n)
        if self.quiet:
            return
        now = time.perf_counter()
        if (now - self._last_print) >= self.interval_s \
                and self.done_n < self.total:
            self._last_print = now
            self._print(now)

    def _print(self, now: float) -> None:
        rate = self.done_n / max(now - self.t0, 1e-9)
        pct = 100.0 * self.done_n / max(self.total, 1)
        print(f"  {self.label}: {self.done_n:,}/{self.total:,} "
              f"({pct:.1f}%)  {rate:,.0f}/s", file=self.stream)

    @property
    def rate(self) -> float:
        return self.done_n / max(time.perf_counter() - self.t0, 1e-9)

    def finish(self) -> dict:
        """Close out (prints a final line unless quiet) and return the
        rate summary for the benchmark ``obs`` block."""
        wall = time.perf_counter() - self.t0
        if not self.quiet:
            print(f"  {self.label}: {self.done_n:,}/{self.total:,} "
                  f"done in {wall:.1f}s "
                  f"({self.done_n / max(wall, 1e-9):,.0f}/s)",
                  file=self.stream)
        return {"label": self.label, "n": self.done_n,
                "wall_s": wall,
                "per_s": self.done_n / max(wall, 1e-9)}
