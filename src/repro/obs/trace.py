"""Factorization-event tracing: a fixed-capacity int32 ring buffer
(DESIGN.md §13).

Every typed runtime event — slot admission, chunked-prefill step,
preemption, resume-prefetch, completion, eviction (victim + tenant),
prefetch issue, dedup hit / promotion / COW divergence, shared-page
age-out, shard gcd-exchange, recovery refactorization — is one row of
eight ``int32`` lanes in a preallocated ring:

    (kind, tick, slot, req, page, tenant, shard, arg)

The buffer is plain array state, exactly like the slot machine's
``phase``/``age`` arrays it rides along with: emitting an event is one
row write at ``total % capacity`` plus a counter increment.  Nothing is
read back on the hot path, no allocation happens after construction,
and ``capacity=0`` degrades every ``emit`` to a bare counter bump — so
tracing can be carried by both the scalar oracles and the vectorized
twins without perturbing a single placement decision (the inertness
contract tests/test_obs.py pins).

Because the oracle and the vec twin emit at semantically identical
points, a **trace diff** (:func:`trace_diff`) is a differential-testing
axis one level finer than ``PARITY_COUNTERS``: two backends that agree
on every counter but disagree on the *order* of events diverge here
first.
"""

from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import numpy as np

__all__ = [
    "EVENT_FIELDS", "EVENT_NAMES", "TraceEvent", "EventTracer",
    "trace_diff",
    "EV_ADMIT", "EV_PREFILL_CHUNK", "EV_PREEMPT", "EV_RESUME_PREFETCH",
    "EV_COMPLETE", "EV_EVICT", "EV_PREFETCH", "EV_DEDUP_HIT",
    "EV_DEDUP_PROMOTE", "EV_COW", "EV_AGE_OUT", "EV_GCD_EXCHANGE",
    "EV_RECOVERY",
]

#: int32 lanes of one ring row, in storage order.  Unused lanes hold -1.
EVENT_FIELDS = ("kind", "tick", "slot", "req", "page", "tenant",
                "shard", "arg")

# -- typed event kinds (DESIGN.md §13 event schema) ------------------------- #
EV_ADMIT = 1            #: request admitted to a slot (slot, req)
EV_PREFILL_CHUNK = 2    #: chunked-prefill step (slot, req, arg=tokens)
EV_PREEMPT = 3          #: decode slot preempted (slot, req)
EV_RESUME_PREFETCH = 4  #: resume anchor touched (req, page=anchor idx)
EV_COMPLETE = 5         #: request finished (slot, req, arg=ttft ticks)
EV_EVICT = 6            #: HBM eviction (page=victim, tenant)
EV_PREFETCH = 7         #: prefetch issued (page=source, arg=target)
EV_DEDUP_HIT = 8        #: admission hit an existing shared page (page)
EV_DEDUP_PROMOTE = 9    #: private content promoted to a shared page
EV_COW = 10             #: copy-on-write divergence (page=fresh private)
EV_AGE_OUT = 11         #: zero-ref shared page aged out, prime recycled
EV_GCD_EXCHANGE = 12    #: sharded collective gcd exchange (shard, arg)
EV_RECOVERY = 13        #: shard recovery refactorization (shard, arg)

EVENT_NAMES = {
    EV_ADMIT: "admit",
    EV_PREFILL_CHUNK: "prefill_chunk",
    EV_PREEMPT: "preempt",
    EV_RESUME_PREFETCH: "resume_prefetch",
    EV_COMPLETE: "complete",
    EV_EVICT: "evict",
    EV_PREFETCH: "prefetch",
    EV_DEDUP_HIT: "dedup_hit",
    EV_DEDUP_PROMOTE: "dedup_promote",
    EV_COW: "cow",
    EV_AGE_OUT: "age_out",
    EV_GCD_EXCHANGE: "gcd_exchange",
    EV_RECOVERY: "recovery",
}


class TraceEvent(NamedTuple):
    kind: int
    tick: int
    slot: int
    req: int
    page: int
    tenant: int
    shard: int
    arg: int

    @property
    def name(self) -> str:
        return EVENT_NAMES.get(self.kind, f"kind{self.kind}")


class EventTracer:
    """Fixed-capacity int32 event ring.

    ``capacity`` rows are allocated once; ``emit`` writes row
    ``total % capacity`` and bumps ``total``.  When the ring wraps, the
    oldest events are overwritten (``dropped`` counts them).  A
    ``capacity=0`` tracer accepts every emit as a pure counter bump —
    the cheapest possible "tracing attached but recording nothing"
    configuration, used by the inertness parity sweep.
    """

    def __init__(self, capacity: int = 4096):
        if capacity < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity = int(capacity)
        self.buf = np.full((self.capacity, len(EVENT_FIELDS)), -1,
                           dtype=np.int32)
        self.total = 0

    def emit(self, kind: int, tick: int = -1, slot: int = -1,
             req: int = -1, page: int = -1, tenant: int = -1,
             shard: int = -1, arg: int = -1) -> None:
        if self.capacity:
            self.buf[self.total % self.capacity] = (
                kind, tick, slot, req, page, tenant, shard, arg)
        self.total += 1

    @property
    def dropped(self) -> int:
        """Events overwritten by ring wraparound (or uncaptured at
        capacity 0)."""
        return max(0, self.total - self.capacity)

    def __len__(self) -> int:
        return min(self.total, self.capacity)

    def as_array(self) -> np.ndarray:
        """Retained events, oldest first, as an ``(n, 8)`` int32 view."""
        n = len(self)
        if n < self.capacity or n == 0:
            return self.buf[:n].copy()
        head = self.total % self.capacity
        return np.concatenate([self.buf[head:], self.buf[:head]])

    def events(self) -> List[TraceEvent]:
        """Retained events, oldest first, as typed tuples."""
        return [TraceEvent(*(int(x) for x in row))
                for row in self.as_array()]

    def clear(self) -> None:
        self.buf.fill(-1)
        self.total = 0

    def export(self) -> dict:
        return {
            "capacity": self.capacity,
            "total": self.total,
            "dropped": self.dropped,
            "fields": list(EVENT_FIELDS),
            "events": [list(row) for row in self.as_array().tolist()],
        }


def trace_diff(a: "EventTracer", b: "EventTracer"
               ) -> Optional[Tuple[int, Optional[TraceEvent],
                                   Optional[TraceEvent]]]:
    """First divergence between two event streams, or ``None`` if they
    are bit-identical (counts, order, and every lane).

    Returns ``(index, event_a, event_b)``; a missing side is ``None``
    when one stream is a strict prefix of the other.
    """
    ea, eb = a.events(), b.events()
    for i, (x, y) in enumerate(zip(ea, eb)):
        if x != y:
            return (i, x, y)
    if len(ea) != len(eb):
        i = min(len(ea), len(eb))
        return (i, ea[i] if i < len(ea) else None,
                eb[i] if i < len(eb) else None)
    if a.total != b.total:
        return (len(ea), None, None)
    return None
