"""Kernel profiling hooks: named scopes + a wall-clock launch ledger
(DESIGN.md §13).

Every Pallas launch wrapper in ``repro.kernels.ops`` (and the shard_map
gcd exchange in ``repro.core.engine.shard``) runs its body under
:func:`kernel_scope`, which does two things:

  * always annotates the region with ``jax.named_scope`` — a pure
    metadata tag visible to ``jax.profiler`` traces and XLA HLO dumps,
    with zero numeric effect;
  * when profiling is **enabled** (off by default), times the region
    with ``time.perf_counter`` and accumulates a per-name launch ledger
    ``{calls, items, wall_s}``.

The ledger is process-global on purpose: kernel launches happen deep
under cache internals where threading a handle through every call
would be pure noise, and wall clocks are only ever *reported* (into
the wall-clock-exempt ``obs`` block of ``BENCH_*.json``), never gated.
Disabled, the only residue is one module-level boolean check per
launch.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict

import jax

__all__ = ["kernel_scope", "enable", "enabled", "reset", "summary",
           "profiling"]

_enabled = False
_ledger: Dict[str, Dict[str, float]] = {}


def enable(on: bool = True) -> None:
    """Turn the wall-clock launch ledger on/off (named scopes are
    always applied)."""
    global _enabled
    _enabled = bool(on)


def enabled() -> bool:
    return _enabled


def reset() -> None:
    """Drop all accumulated ledger entries."""
    _ledger.clear()


def summary() -> Dict[str, Dict[str, float]]:
    """Per-kernel launch ledger: ``{name: {calls, items, wall_s}}``."""
    return {name: dict(rec) for name, rec in sorted(_ledger.items())}


@contextmanager
def kernel_scope(name: str, items: int = 0):
    """Annotate (always) and, when enabled, time one kernel launch.

    ``items`` is the batch size the launch processed (composites,
    query primes, gcd pairs, ...) so the ledger can report per-item
    rates alongside raw walls.
    """
    with jax.named_scope(name):
        if not _enabled:
            yield
            return
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            rec = _ledger.setdefault(
                name, {"calls": 0, "items": 0, "wall_s": 0.0})
            rec["calls"] += 1
            rec["items"] += int(items)
            rec["wall_s"] += dt


@contextmanager
def profiling():
    """Scoped enable: ledger is reset and collected for the duration.

    Yields the live ledger dict so callers can snapshot it on exit::

        with profiling():
            run_benchmark()
            obs_block = {"kernel_launches": summary()}
    """
    prev = _enabled
    reset()
    enable(True)
    try:
        yield _ledger
    finally:
        enable(prev)
