"""Baseline cache replacement policies (PFCS Table 1 comparison set).

Exact host-side reference implementations of every system the paper
compares against:

  * LRU          — least recently used (paper "Traditional LRU")
  * FIFO         — first in first out (extra baseline)
  * 2Q           — Johnson & Shasha, VLDB'94 [paper ref 13]
  * ARC          — Megiddo & Modha, FAST'03 [paper ref 2]
  * LIRS         — Jiang & Zhang, SIGMETRICS'02 [paper ref 3]

All policies implement :class:`CachePolicy`: unit-sized entries,
``access(key) -> hit?`` with internal insertion on miss, plus an explicit
``insert``/``contains`` split so the simulator can model prefetching
(inserts that are not demand accesses).
"""

from __future__ import annotations

from collections import OrderedDict, deque
from typing import Deque, Dict, Hashable, Optional, Set

__all__ = ["CachePolicy", "LRUCachePolicy", "FIFOCachePolicy", "TwoQCachePolicy",
           "ARCCachePolicy", "LIRSCachePolicy", "make_policy", "POLICY_FACTORIES"]

Key = Hashable


class CachePolicy:
    """Interface: a fixed-capacity, unit-entry cache."""

    name = "base"

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity

    # -- required -----------------------------------------------------------
    def access(self, key: Key) -> bool:
        """Demand access. Returns True on hit; on miss the key is admitted."""
        raise NotImplementedError

    def contains(self, key: Key) -> bool:
        raise NotImplementedError

    def insert(self, key: Key) -> None:
        """Admit ``key`` without counting it as a demand access (prefetch)."""
        raise NotImplementedError

    def evict_key(self, key: Key) -> None:
        """Force-remove (invalidation); default no-op if absent."""
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError


# ---------------------------------------------------------------------------


class LRUCachePolicy(CachePolicy):
    name = "lru"

    def __init__(self, capacity: int):
        super().__init__(capacity)
        self._d: "OrderedDict[Key, None]" = OrderedDict()

    def access(self, key: Key) -> bool:
        if key in self._d:
            self._d.move_to_end(key)
            return True
        self.insert(key)
        return False

    def contains(self, key: Key) -> bool:
        return key in self._d

    def insert(self, key: Key) -> None:
        if key in self._d:
            self._d.move_to_end(key)
            return
        self._d[key] = None
        if len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def evict_key(self, key: Key) -> None:
        self._d.pop(key, None)

    def __len__(self) -> int:
        return len(self._d)


class FIFOCachePolicy(CachePolicy):
    name = "fifo"

    def __init__(self, capacity: int):
        super().__init__(capacity)
        self._q: Deque[Key] = deque()
        self._s: Set[Key] = set()

    def access(self, key: Key) -> bool:
        if key in self._s:
            return True
        self.insert(key)
        return False

    def contains(self, key: Key) -> bool:
        return key in self._s

    def insert(self, key: Key) -> None:
        if key in self._s:
            return
        self._q.append(key)
        self._s.add(key)
        if len(self._q) > self.capacity:
            self._s.discard(self._q.popleft())

    def evict_key(self, key: Key) -> None:
        if key in self._s:
            self._s.discard(key)
            try:
                self._q.remove(key)
            except ValueError:
                pass

    def __len__(self) -> int:
        return len(self._s)


class TwoQCachePolicy(CachePolicy):
    """Simplified 2Q (Johnson & Shasha '94): A1in FIFO (Kin), ghost A1out
    (Kout), main Am LRU."""

    name = "2q"

    def __init__(self, capacity: int, kin_frac: float = 0.25, kout_frac: float = 0.5):
        super().__init__(capacity)
        self.kin = max(1, int(capacity * kin_frac))
        self.kout = max(1, int(capacity * kout_frac))
        self.km = max(1, capacity - self.kin)
        self._a1in: "OrderedDict[Key, None]" = OrderedDict()
        self._a1out: "OrderedDict[Key, None]" = OrderedDict()  # ghosts (no data)
        self._am: "OrderedDict[Key, None]" = OrderedDict()

    def access(self, key: Key) -> bool:
        if key in self._am:
            self._am.move_to_end(key)
            return True
        if key in self._a1in:
            return True  # stays in A1in until evicted (classic 2Q)
        self.insert(key)
        return False

    def contains(self, key: Key) -> bool:
        return key in self._am or key in self._a1in

    def insert(self, key: Key) -> None:
        if self.contains(key):
            return
        if key in self._a1out:  # second touch within window -> hot
            self._a1out.pop(key)
            self._am[key] = None
            if len(self._am) > self.km:
                self._am.popitem(last=False)
            return
        self._a1in[key] = None
        if len(self._a1in) > self.kin:
            old, _ = self._a1in.popitem(last=False)
            self._a1out[old] = None
            if len(self._a1out) > self.kout:
                self._a1out.popitem(last=False)

    def evict_key(self, key: Key) -> None:
        self._a1in.pop(key, None)
        self._am.pop(key, None)

    def __len__(self) -> int:
        return len(self._a1in) + len(self._am)


class ARCCachePolicy(CachePolicy):
    """ARC (Megiddo & Modha, FAST'03) — faithful to the published pseudocode.

    T1/T2 resident lists, B1/B2 ghost lists, adaptive target ``p``.
    """

    name = "arc"

    def __init__(self, capacity: int):
        super().__init__(capacity)
        self.p = 0.0
        self.t1: "OrderedDict[Key, None]" = OrderedDict()
        self.t2: "OrderedDict[Key, None]" = OrderedDict()
        self.b1: "OrderedDict[Key, None]" = OrderedDict()
        self.b2: "OrderedDict[Key, None]" = OrderedDict()

    # LRU = first item; MRU = last item.
    def _replace(self, in_b2: bool) -> None:
        if self.t1 and ((in_b2 and len(self.t1) == int(self.p)) or len(self.t1) > int(self.p)):
            k, _ = self.t1.popitem(last=False)
            self.b1[k] = None
        elif self.t2:
            k, _ = self.t2.popitem(last=False)
            self.b2[k] = None
        elif self.t1:
            k, _ = self.t1.popitem(last=False)
            self.b1[k] = None

    def access(self, key: Key) -> bool:
        c = self.capacity
        if key in self.t1:  # Case I
            self.t1.pop(key)
            self.t2[key] = None
            return True
        if key in self.t2:
            self.t2.move_to_end(key)
            return True
        if key in self.b1:  # Case II
            self.p = min(float(c), self.p + max(1.0, len(self.b2) / max(1, len(self.b1))))
            self._replace(False)
            self.b1.pop(key)
            self.t2[key] = None
            return False
        if key in self.b2:  # Case III
            self.p = max(0.0, self.p - max(1.0, len(self.b1) / max(1, len(self.b2))))
            self._replace(True)
            self.b2.pop(key)
            self.t2[key] = None
            return False
        # Case IV: complete miss
        l1 = len(self.t1) + len(self.b1)
        if l1 == c:
            if len(self.t1) < c:
                self.b1.popitem(last=False)
                self._replace(False)
            else:
                self.t1.popitem(last=False)
        else:
            total = l1 + len(self.t2) + len(self.b2)
            if total >= c:
                if total == 2 * c:
                    self.b2.popitem(last=False)
                self._replace(False)
        self.t1[key] = None
        return False

    def contains(self, key: Key) -> bool:
        return key in self.t1 or key in self.t2

    def insert(self, key: Key) -> None:
        if not self.contains(key):
            # prefetch path: same as a miss access, minus the hit return
            self.access(key)
            # undo the "recency" boost a demand access would legitimately get
            # (prefetched entries enter T1 cold, which access() already does)

    def evict_key(self, key: Key) -> None:
        for lst in (self.t1, self.t2, self.b1, self.b2):
            lst.pop(key, None)

    def __len__(self) -> int:
        return len(self.t1) + len(self.t2)


class LIRSCachePolicy(CachePolicy):
    """LIRS (Jiang & Zhang, SIGMETRICS'02).

    Stack S tracks recency (LIR + HIR + non-resident HIR); queue Q tracks
    resident HIR blocks. ``hir_frac`` of capacity is the HIR partition
    (1% in the paper; bumped for small caches).
    """

    name = "lirs"

    _LIR, _HIR = 0, 1

    def __init__(self, capacity: int, hir_frac: float = 0.05):
        super().__init__(capacity)
        self.lhirs = max(1, int(capacity * hir_frac))
        self.llirs = max(1, capacity - self.lhirs)
        self.s: "OrderedDict[Key, None]" = OrderedDict()   # recency stack
        self.q: "OrderedDict[Key, None]" = OrderedDict()   # resident HIR queue
        self.status: Dict[Key, int] = {}                   # key -> LIR/HIR
        self.resident: Set[Key] = set()
        self.n_lir = 0

    def _stack_prune(self) -> None:
        while self.s:
            k = next(iter(self.s))
            if self.status.get(k) == self._LIR:
                break
            self.s.pop(k)
            if k not in self.resident:
                self.status.pop(k, None)

    def _evict_resident_hir(self) -> None:
        if self.q:
            k, _ = self.q.popitem(last=False)
            self.resident.discard(k)  # becomes non-resident HIR (ghost in S)
            if k not in self.s:
                self.status.pop(k, None)

    def _demote_bottom_lir(self) -> None:
        if not self.s:
            return
        k = next(iter(self.s))
        if self.status.get(k) == self._LIR:
            self.s.pop(k)
            self.status[k] = self._HIR
            self.n_lir -= 1
            if k in self.resident:
                self.q[k] = None
            self._stack_prune()

    def access(self, key: Key) -> bool:
        hit = key in self.resident
        self._touch(key, demand=True)
        return hit

    def _touch(self, key: Key, demand: bool) -> None:
        st = self.status.get(key)
        if st == self._LIR:  # hit on LIR
            was_bottom = next(iter(self.s)) == key if self.s else False
            self.s.pop(key, None)
            self.s[key] = None
            if was_bottom:
                self._stack_prune()
            return
        if key in self.resident:  # resident HIR
            in_stack = key in self.s
            if in_stack:
                self.s.pop(key)
                self.s[key] = None
                self.status[key] = self._LIR
                self.n_lir += 1
                self.q.pop(key, None)
                if self.n_lir > self.llirs:
                    self._demote_bottom_lir()
            else:
                self.s[key] = None
                self.status[key] = self._HIR
                self.q.pop(key, None)
                self.q[key] = None  # move to queue end
            return
        # miss ---------------------------------------------------------------
        if len(self.resident) >= self.capacity:
            self._evict_resident_hir()
            if len(self.resident) >= self.capacity:  # all-LIR corner case
                self._demote_bottom_lir()
                self._evict_resident_hir()
        self.resident.add(key)
        if self.n_lir < self.llirs and key not in self.s:
            # cold start: fill LIR partition first
            self.status[key] = self._LIR
            self.n_lir += 1
            self.s[key] = None
            return
        if key in self.s:  # non-resident HIR with recency -> promote to LIR
            self.s.pop(key)
            self.s[key] = None
            self.status[key] = self._LIR
            self.n_lir += 1
            if self.n_lir > self.llirs:
                self._demote_bottom_lir()
        else:
            self.s[key] = None
            self.status[key] = self._HIR
            self.q[key] = None

    def contains(self, key: Key) -> bool:
        return key in self.resident

    def insert(self, key: Key) -> None:
        if key not in self.resident:
            self._touch(key, demand=False)

    def evict_key(self, key: Key) -> None:
        self.resident.discard(key)
        self.q.pop(key, None)

    def __len__(self) -> int:
        return len(self.resident)


POLICY_FACTORIES = {
    "lru": LRUCachePolicy,
    "fifo": FIFOCachePolicy,
    "2q": TwoQCachePolicy,
    "arc": ARCCachePolicy,
    "lirs": LIRSCachePolicy,
}


def make_policy(name: str, capacity: int) -> CachePolicy:
    try:
        return POLICY_FACTORIES[name](capacity)
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; have {sorted(POLICY_FACTORIES)}")
