"""Latency / power / speedup models and result containers (PFCS Table 1).

The container is CPU-only, so wall-clock numbers for a cache *hierarchy*
cannot be measured directly; hit rates and relationship accuracy are
measured exactly by simulation, while latency and energy are derived from
per-tier constants.  Constants follow standard published figures
(Hennessy & Patterson 6e [paper ref 1]; DRAM/IO energies from Horowitz,
ISSCC'14 keynote) and are explicit model parameters — change them here
and every benchmark re-derives.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

__all__ = ["TierCosts", "DEFAULT_COSTS", "AccessStats", "derive_table1_row"]


@dataclass(frozen=True)
class TierCosts:
    """Per-access latency (ns) and energy (nJ) for each tier + overheads."""

    # hit service latencies, ns
    lat_l1: float = 1.0
    lat_l2: float = 4.0
    lat_l3: float = 20.0
    lat_mem: float = 100.0
    lat_backing: float = 10_000.0  # storage / remote node on full miss

    # energy per access, nJ
    en_l1: float = 0.5
    en_l2: float = 1.2
    en_l3: float = 5.0
    en_mem: float = 20.0
    en_backing: float = 1_000.0

    # PFCS factorization-stage costs, ns (paper §4.1 staging)
    lat_factor_table: float = 2.0      # precomputed SPF lookup
    lat_factor_cache: float = 3.0      # factorization-cache hit
    lat_factor_trial: float = 60.0     # vectorized trial division
    lat_factor_rho: float = 900.0      # Pollard rho tail
    en_factor: float = 0.05            # nJ per factorization op

    # semantic-cache embedding overhead, ns per discovery (paper §2.1:
    # "15-23% CPU utilization for embedding generation")
    lat_embedding: float = 450.0
    en_embedding: float = 8.0


DEFAULT_COSTS = TierCosts()


@dataclass
class AccessStats:
    """Counters produced by one simulation run."""

    name: str = ""
    demand_accesses: int = 0
    hits_per_level: Dict[str, int] = field(default_factory=dict)  # L1/L2/L3/MEM
    misses: int = 0  # served by backing store

    prefetches_issued: int = 0
    prefetches_used: int = 0      # prefetched entry later demanded while resident
    prefetches_true: int = 0      # prefetch target truly related (ground truth)

    factor_ops: Dict[str, int] = field(default_factory=dict)  # stage -> count
    embedding_ops: int = 0
    extra_backing_fetches: int = 0  # prefetch traffic to backing store

    # ------------------------------------------------------------------ #

    @property
    def hits(self) -> int:
        return sum(self.hits_per_level.values())

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.demand_accesses)

    @property
    def prefetch_precision(self) -> Optional[float]:
        """'Relationship accuracy' in Table 1: fraction of prefetch
        decisions whose target was truly related to the trigger."""
        if self.prefetches_issued == 0:
            return None
        return self.prefetches_true / self.prefetches_issued

    # -- derived latency / energy ----------------------------------------- #

    def total_latency_ns(self, costs: TierCosts = DEFAULT_COSTS) -> float:
        lat = {
            "L1": costs.lat_l1,
            "L2": costs.lat_l2,
            "L3": costs.lat_l3,
            "MEM": costs.lat_mem,
        }
        t = sum(self.hits_per_level.get(k, 0) * v for k, v in lat.items())
        t += self.misses * costs.lat_backing
        t += self.factor_ops.get("table", 0) * costs.lat_factor_table
        t += self.factor_ops.get("cache", 0) * costs.lat_factor_cache
        t += self.factor_ops.get("trial", 0) * costs.lat_factor_trial
        t += self.factor_ops.get("rho", 0) * costs.lat_factor_rho
        t += self.embedding_ops * costs.lat_embedding
        return t

    def avg_latency_ns(self, costs: TierCosts = DEFAULT_COSTS) -> float:
        return self.total_latency_ns(costs) / max(1, self.demand_accesses)

    def total_energy_nj(self, costs: TierCosts = DEFAULT_COSTS) -> float:
        en = {
            "L1": costs.en_l1,
            "L2": costs.en_l2,
            "L3": costs.en_l3,
            "MEM": costs.en_mem,
        }
        e = sum(self.hits_per_level.get(k, 0) * v for k, v in en.items())
        e += self.misses * costs.en_backing
        # Prefetch traffic: a *used* prefetch replaces the demand fetch that
        # would otherwise have happened (net-zero energy, off critical
        # path); only wasted prefetches burn extra backing-store energy.
        wasted = max(0, self.prefetches_issued - self.prefetches_used)
        e += wasted * costs.en_backing
        e += sum(self.factor_ops.values()) * costs.en_factor
        e += self.embedding_ops * costs.en_embedding
        return e

    def as_dict(self) -> Dict:
        return {
            "name": self.name,
            "demand_accesses": self.demand_accesses,
            "hit_rate": self.hit_rate,
            "hits_per_level": dict(self.hits_per_level),
            "misses": self.misses,
            "avg_latency_ns": self.avg_latency_ns(),
            "total_energy_nj": self.total_energy_nj(),
            "prefetch_precision": self.prefetch_precision,
            "prefetches_issued": self.prefetches_issued,
            "prefetches_used": self.prefetches_used,
        }


def derive_table1_row(stats: AccessStats, baseline: AccessStats,
                      costs: TierCosts = DEFAULT_COSTS) -> Dict:
    """Produce one Table-1-style row relative to a baseline system."""
    lat_s, lat_b = stats.avg_latency_ns(costs), baseline.avg_latency_ns(costs)
    en_s, en_b = stats.total_energy_nj(costs), baseline.total_energy_nj(costs)
    acc = stats.prefetch_precision
    return {
        "system": stats.name,
        "hit_rate_pct": 100.0 * stats.hit_rate,
        "latency_reduction_pct": 100.0 * (1.0 - lat_s / lat_b) if lat_b else 0.0,
        "power_reduction_pct": 100.0 * (1.0 - en_s / en_b) if en_b else 0.0,
        "relationship_accuracy_pct": None if acc is None else 100.0 * acc,
        "speedup": lat_b / lat_s if lat_s else float("inf"),
    }
