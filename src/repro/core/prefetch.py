"""Intelligent prefetching engine (PFCS §4.2).

On access of element d with prime p, scan the composite registry for
multiples of p, factorize the hits, and prefetch the recovered related
elements.  Every prefetch target is *mathematically proven* related
(Theorem 1) — zero false-positive prefetch traffic.

Related-set computation is memoized against the registry version so the
scan + factorization cost is paid once per (prime, registry state), which
is also how the TPU deployment behaves (the Pallas divisibility kernel
refreshes candidate masks in batch when the registry changes, cf.
``repro.kernels.divisibility``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Hashable, List, Optional, Set, Tuple

from .assignment import PrimeAssigner
from .composite import CompositeRegistry

__all__ = ["PrefetchDecision", "IntelligentPrefetcher"]

DataID = Hashable


@dataclass(frozen=True)
class PrefetchDecision:
    target: DataID
    trigger: DataID
    weight: float  # relationship weight x predicted access probability


class IntelligentPrefetcher:
    """Deterministic relationship-driven prefetcher."""

    def __init__(
        self,
        assigner: PrimeAssigner,
        budget_per_access: int = 8,
        min_weight: float = 0.0,
    ):
        self.assigner = assigner
        self.registry: CompositeRegistry = assigner.registry
        self.budget = budget_per_access
        self.min_weight = min_weight
        self._memo: Dict[int, Tuple[int, List[Tuple[DataID, float]]]] = {}

    def related_elements(self, d: DataID) -> List[Tuple[DataID, float]]:
        """All elements related to d with weights, via factorization."""
        p = self.assigner.prime_of(d)
        if p is None:
            return []
        ver = self.registry.version
        memo = self._memo.get(p)
        if memo is not None and memo[0] == ver:
            return memo[1]
        out: Dict[DataID, float] = {}
        for rel in self.registry.containing(p):
            for q in rel.primes:
                if q == p:
                    continue
                target = self.assigner.data_of(q)
                if target is not None:
                    out[target] = max(out.get(target, 0.0), rel.weight)
        ranked = sorted(out.items(), key=lambda kv: -kv[1])
        self._memo[p] = (ver, ranked)
        return ranked

    def decide(self, d: DataID) -> List[PrefetchDecision]:
        """Ranked, budget-limited prefetch decisions for an access to d."""
        decisions: List[PrefetchDecision] = []
        for target, w in self.related_elements(d):
            pw = w * (0.5 + 0.5 * self.assigner.tracker.predicted_frequency(target))
            if pw >= self.min_weight:
                decisions.append(PrefetchDecision(target, d, pw))
            if len(decisions) >= self.budget:
                break
        return decisions
