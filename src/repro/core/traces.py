"""Workload trace generators (PFCS §6.1 workload diversity).

Each generator returns a :class:`Trace`: an access sequence over integer
keys plus the *ground-truth relationship groups* that exist in the
workload (FK edges, co-accessed feature rows, correlated instruments).
PFCS registers these relationships when they are established (the
database knows its FK constraints; the trainer knows its batch
composition; the trading system knows its correlation graph) and must
*re-discover* them deterministically at access time via factorization.
Baselines see only the raw access stream; the semantic baseline sees a
noisy approximation of the relationship graph.

Generators (mapped to the paper's §6 workloads):

  * ``db_join_trace``    — TPC-C/H-like order->customer->item FK joins
  * ``ml_epoch_trace``   — minibatch training epochs with shared feature rows
  * ``hft_trace``        — correlated-instrument market data bursts
  * ``zipf_trace``       — skewed key-value (web/CDN) traffic, no relationships
  * ``scan_trace``       — sequential scans (worst case for LRU)
  * ``graph_walk_trace`` — tunable relationship density (Fig. 2a x-axis)

All generators are deterministic given ``seed``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = [
    "Trace", "db_join_trace", "ml_epoch_trace", "hft_trace",
    "zipf_trace", "scan_trace", "graph_walk_trace", "TRACES",
]


@dataclass
class Trace:
    name: str
    accesses: np.ndarray                     # (T,) int64 key per demand access
    relationships: List[Tuple[int, ...]]     # ground-truth related key groups
    n_keys: int
    meta: Dict = field(default_factory=dict)

    def __post_init__(self):
        self.accesses = np.asarray(self.accesses, dtype=np.int64)

    @property
    def length(self) -> int:
        return int(self.accesses.shape[0])

    def related_map(self) -> Dict[int, set]:
        """key -> set of truly-related keys (for prefetch precision)."""
        m: Dict[int, set] = {}
        for grp in self.relationships:
            for k in grp:
                m.setdefault(int(k), set()).update(int(g) for g in grp if g != k)
        return m


# --------------------------------------------------------------------------- #
# Database joins                                                              #
# --------------------------------------------------------------------------- #

def db_join_trace(
    n_orders: int = 8_000,
    n_customers: int = 1_000,
    n_items: int = 2_000,
    n_queries: int = 25_000,
    point_query_frac: float = 0.25,
    seed: int = 0,
) -> Trace:
    """OLTP-style trace: ``SELECT * FROM orders JOIN customers ...``.

    Key space: orders [0, n_orders), customers [n_orders, +n_customers),
    items (order lines) after that.  A join query touches an order row,
    then its customer row, then 1-3 item rows — the FK relationships the
    paper's motivating example (§2.1) wants discovered.
    """
    rng = np.random.default_rng(seed)
    cust_base = n_orders
    item_base = n_orders + n_customers

    order_customer = rng.integers(0, n_customers, size=n_orders)
    order_items = [
        rng.integers(0, n_items, size=rng.integers(1, 4)) for _ in range(n_orders)
    ]

    relationships: List[Tuple[int, ...]] = []
    for o in range(n_orders):
        grp = (o, cust_base + int(order_customer[o]),
               *(item_base + int(i) for i in order_items[o]))
        relationships.append(tuple(dict.fromkeys(grp)))

    # order popularity is zipfian (hot accounts)
    ranks = np.arange(1, n_orders + 1, dtype=np.float64)
    pop = 1.0 / ranks**0.9
    pop /= pop.sum()

    accesses: List[int] = []
    while len(accesses) < n_queries:
        o = int(rng.choice(n_orders, p=pop))
        if rng.random() < point_query_frac:
            accesses.append(o)  # point query: order only
            continue
        accesses.append(o)
        accesses.append(cust_base + int(order_customer[o]))
        for i in order_items[o]:
            accesses.append(item_base + int(i))
    n_keys = n_orders + n_customers + n_items
    return Trace("db_join", np.array(accesses[:n_queries]), relationships, n_keys,
                 meta=dict(kind="database", point_query_frac=point_query_frac))


# --------------------------------------------------------------------------- #
# ML training                                                                 #
# --------------------------------------------------------------------------- #

def ml_epoch_trace(
    n_samples: int = 6_000,
    n_feature_rows: int = 1_500,
    feats_per_sample: int = 3,
    batch_size: int = 32,
    n_epochs: int = 3,
    seed: int = 0,
) -> Trace:
    """Training epochs: shuffled sample order; each sample drags in its
    (sparse) feature-table rows — e.g. embedding rows shared across
    samples.  The sample->features map is the relationship set ("PFCS
    identified feature relationships", §6.3)."""
    rng = np.random.default_rng(seed)
    feat_base = n_samples
    sample_feats = rng.integers(0, n_feature_rows, size=(n_samples, feats_per_sample))

    relationships = [
        tuple(dict.fromkeys((s, *(feat_base + int(f) for f in sample_feats[s]))))
        for s in range(n_samples)
    ]

    accesses: List[int] = []
    for _ in range(n_epochs):
        order = rng.permutation(n_samples)
        for s in order:
            accesses.append(int(s))
            for f in sample_feats[s]:
                accesses.append(feat_base + int(f))
    return Trace("ml_epoch", np.array(accesses), relationships,
                 n_samples + n_feature_rows,
                 meta=dict(kind="ml", batch_size=batch_size))


# --------------------------------------------------------------------------- #
# High-frequency trading                                                      #
# --------------------------------------------------------------------------- #

def hft_trace(
    n_instruments: int = 3_000,
    n_corr_groups: int = 400,
    group_size: int = 5,
    n_events: int = 40_000,
    burst_prob: float = 0.85,
    seed: int = 0,
) -> Trace:
    """Market-data bursts: a tick on instrument i triggers reads of its
    correlated instruments (sector/ETF basket) — the §6.3 HFT case."""
    rng = np.random.default_rng(seed)
    groups = [tuple(int(x) for x in rng.choice(n_instruments, size=group_size,
                                               replace=False))
              for _ in range(n_corr_groups)]
    member_of: Dict[int, List[int]] = {}
    for gi, g in enumerate(groups):
        for k in g:
            member_of.setdefault(k, []).append(gi)

    # instrument popularity: heavy-tailed
    ranks = np.arange(1, n_instruments + 1, dtype=np.float64)
    pop = 1.0 / ranks**1.1
    pop /= pop.sum()

    accesses: List[int] = []
    while len(accesses) < n_events:
        i = int(rng.choice(n_instruments, p=pop))
        accesses.append(i)
        gids = member_of.get(i)
        if gids and rng.random() < burst_prob:
            g = groups[int(rng.choice(gids))]
            for k in g:
                if k != i:
                    accesses.append(k)
    return Trace("hft", np.array(accesses[:n_events]), groups, n_instruments,
                 meta=dict(kind="hft", burst_prob=burst_prob))


# --------------------------------------------------------------------------- #
# Relationship-free baselines                                                 #
# --------------------------------------------------------------------------- #

def zipf_trace(n_keys: int = 20_000, n_accesses: int = 40_000,
               alpha: float = 0.99, seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    p = 1.0 / ranks**alpha
    p /= p.sum()
    acc = rng.choice(n_keys, size=n_accesses, p=p)
    return Trace("zipf", acc, [], n_keys, meta=dict(kind="kv", alpha=alpha))


def scan_trace(n_keys: int = 10_000, n_passes: int = 4, seed: int = 0) -> Trace:
    acc = np.tile(np.arange(n_keys, dtype=np.int64), n_passes)
    return Trace("scan", acc, [], n_keys, meta=dict(kind="scan"))


# --------------------------------------------------------------------------- #
# Tunable relationship density (Fig. 2a)                                      #
# --------------------------------------------------------------------------- #

def graph_walk_trace(
    n_keys: int = 10_000,
    relationship_density: float = 0.5,   # 0 = none, 1 = dense groups
    n_accesses: int = 40_000,
    max_group: int = 8,
    seed: int = 0,
) -> Trace:
    """Random walk over a relationship graph whose density is the Fig. 2a
    'workload complexity' axis.

    Keys are PARTITIONED into disjoint groups (each key belongs to at
    most one group — FK-like structure); ``relationship_density``
    controls (a) the fraction of the key space that is grouped, (b) the
    group size (2 -> max_group), and (c) how deterministically an access
    to a group member drags in the rest of the group.  Higher density =
    more of each access's future is relationship-determined = more a
    deterministic-discovery system can exploit (the paper's
    'relationship-heavy workloads').
    """
    rng = np.random.default_rng(seed)
    gsz = 2 + int(round(relationship_density * (max_group - 2)))
    covered = int(relationship_density * n_keys)
    perm = rng.permutation(n_keys)
    groups = [tuple(int(x) for x in perm[i:i + gsz])
              for i in range(0, max(0, covered - gsz), gsz)]
    member_of: Dict[int, int] = {}
    for gi, g in enumerate(groups):
        for k in g:
            member_of[k] = gi

    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    pop = 1.0 / ranks**0.8
    pop /= pop.sum()
    pop = pop[np.argsort(perm)]  # decouple popularity from group layout
    burst_p = 0.5 + 0.5 * relationship_density

    accesses: List[int] = []
    while len(accesses) < n_accesses:
        k = int(rng.choice(n_keys, p=pop))
        accesses.append(k)
        gi = member_of.get(k)
        if gi is not None and rng.random() < burst_p:
            for q in groups[gi]:
                if q != k:
                    accesses.append(q)
    return Trace(f"graph_walk_d{relationship_density:.2f}",
                 np.array(accesses[:n_accesses]), groups, n_keys,
                 meta=dict(kind="graph", density=relationship_density))


TRACES = {
    "db_join": db_join_trace,
    "ml_epoch": ml_epoch_trace,
    "hft": hft_trace,
    "zipf": zipf_trace,
    "scan": scan_trace,
    "graph_walk": graph_walk_trace,
}
