"""Multi-stage factorization engine (PFCS Algorithm 2).

Stage 0: precomputed SPF table for composites <= PRECOMPUTED_LIMIT (O(1)).
Stage 1: factorization cache lookup (LRU).
Stage 2: time-budgeted trial division with small primes (<= 70% of budget).
Stage 3: Pollard's rho (Brent variant) for the remaining cofactor.

The engine records per-stage counters so benchmarks can attribute latency
(the paper's Table 1 latency model charges each stage differently).

Host path uses exact Python integers (arbitrary precision); the batched
TPU path (int32/int64 arrays, VMEM-tiled) lives in ``repro.kernels``.
"""

from __future__ import annotations

import math
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .primes import is_prime, sieve_primes, spf_table

__all__ = ["FactorizationStats", "Factorizer", "PRECOMPUTED_LIMIT"]

# Paper Algorithm 2 line 1: composites <= 10**6 hit the precomputed table.
PRECOMPUTED_LIMIT = 1_000_000


@dataclass
class FactorizationStats:
    """Per-stage hit counters (drives the latency/power models)."""

    table_hits: int = 0
    cache_hits: int = 0
    trial_division: int = 0
    pollard_rho: int = 0
    budget_exceeded: int = 0
    total: int = 0

    def as_dict(self) -> Dict[str, int]:
        return dict(
            table_hits=self.table_hits,
            cache_hits=self.cache_hits,
            trial_division=self.trial_division,
            pollard_rho=self.pollard_rho,
            budget_exceeded=self.budget_exceeded,
            total=self.total,
        )


class _LRUFactorCache:
    """LRU cache: composite -> sorted tuple of prime factors (w/ multiplicity)."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._d: "OrderedDict[int, Tuple[int, ...]]" = OrderedDict()

    def get(self, c: int) -> Optional[Tuple[int, ...]]:
        v = self._d.get(c)
        if v is not None:
            self._d.move_to_end(c)
        return v

    def put(self, c: int, factors: Tuple[int, ...]) -> None:
        if c in self._d:
            self._d.move_to_end(c)
        self._d[c] = factors
        if len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def __contains__(self, c: int) -> bool:
        return c in self._d

    def __len__(self) -> int:
        return len(self._d)


class Factorizer:
    """PFCS Algorithm 2: hierarchical relationship discovery.

    Parameters
    ----------
    precomputed_limit:
        Upper bound of the SPF table (paper: 10**6).
    cache_capacity:
        Entries in the factorization LRU cache.
    trial_prime_limit:
        Largest prime used in stage-2 trial division (paper: 1000, i.e.
        ``SmallPrimes[2, min(1000, sqrt(c))]``).
    """

    def __init__(
        self,
        precomputed_limit: int = PRECOMPUTED_LIMIT,
        cache_capacity: int = 1 << 16,
        trial_prime_limit: int = 1000,
    ):
        self.precomputed_limit = precomputed_limit
        self._spf = spf_table(precomputed_limit)
        self._small_primes = [int(p) for p in sieve_primes(trial_prime_limit)]
        self.cache = _LRUFactorCache(cache_capacity)
        self.stats = FactorizationStats()

    # ------------------------------------------------------------------ #
    # public API                                                         #
    # ------------------------------------------------------------------ #

    def factorize(self, c: int, time_budget_s: float = 0.05) -> Tuple[int, ...]:
        """Full prime factorization of ``c`` (sorted, with multiplicity).

        Deterministic and exact for any 64-bit composite; the time budget
        applies the paper's staged split (70% trial division, remainder
        Pollard rho).  On budget exhaustion the partial factorization is
        returned with the unfactored cofactor appended if it is prime,
        else factored best-effort (counted in ``budget_exceeded``).
        """
        self.stats.total += 1
        if c <= 1:
            return ()
        # Stage 0: precomputed SPF table ------------------------------------
        if c <= self.precomputed_limit:
            self.stats.table_hits += 1
            return self._factor_spf(c)
        # Stage 1: factorization cache --------------------------------------
        cached = self.cache.get(c)
        if cached is not None:
            self.stats.cache_hits += 1
            return cached
        # Stage 2: bounded trial division ------------------------------------
        t0 = time.perf_counter()
        factors: List[int] = []
        remaining = c
        trial_deadline = t0 + 0.7 * time_budget_s
        used_trial = False
        sqrt_c = math.isqrt(remaining)
        for p in self._small_primes:
            if p > sqrt_c or remaining == 1:
                break
            if remaining % p == 0:
                used_trial = True
                while remaining % p == 0:
                    factors.append(p)
                    remaining //= p
                sqrt_c = math.isqrt(remaining)
            if time.perf_counter() > trial_deadline:
                break
        if used_trial:
            self.stats.trial_division += 1
        # Stage 3: Pollard rho on the cofactor --------------------------------
        if remaining > 1:
            if remaining <= self.precomputed_limit:
                factors.extend(self._factor_spf(remaining))
            elif is_prime(remaining):
                factors.append(remaining)
            else:
                self.stats.pollard_rho += 1
                deadline = t0 + time_budget_s
                ok = self._pollard_recurse(remaining, factors, deadline)
                if not ok:
                    self.stats.budget_exceeded += 1
                    # graceful degradation result: do NOT cache — a partial
                    # factorization in the cache would later violate the
                    # zero-false-positive contract (Theorem 1) when served
                    # for a composite whose factors are known to a caller.
                    return tuple(sorted(factors))
        out = tuple(sorted(factors))
        self.cache.put(c, out)
        return out

    def factorize_batch(self, cs: Sequence[int], time_budget_s: float = 0.05) -> List[Tuple[int, ...]]:
        return [self.factorize(int(c), time_budget_s) for c in cs]

    def distinct_factors(self, c: int, **kw) -> Tuple[int, ...]:
        return tuple(sorted(set(self.factorize(c, **kw))))

    # ------------------------------------------------------------------ #
    # stages                                                              #
    # ------------------------------------------------------------------ #

    def _factor_spf(self, c: int) -> Tuple[int, ...]:
        out: List[int] = []
        spf = self._spf
        while c > 1:
            p = int(spf[c])
            out.append(p)
            c //= p
        return tuple(out)

    @staticmethod
    def _pollard_brent(n: int, seed: int = 1) -> int:
        """One non-trivial factor of composite n (Brent's improvement of
        Pollard's rho, Pollard 1975 [paper ref 5]). Deterministic seeds."""
        if n % 2 == 0:
            return 2
        # deterministic sequence of (y, c) trials
        for c in range(seed, seed + 64):
            y, m, g, r, q = 2 + c, 128, 1, 1, 1
            x = ys = y
            while g == 1:
                x = y
                for _ in range(r):
                    y = (y * y + c) % n
                k = 0
                while k < r and g == 1:
                    ys = y
                    for _ in range(min(m, r - k)):
                        y = (y * y + c) % n
                        q = q * abs(x - y) % n
                    g = math.gcd(q, n)
                    k += m
                r <<= 1
            if g == n:
                g = 1
                while g == 1:
                    ys = (ys * ys + c) % n
                    g = math.gcd(abs(x - ys), n)
            if g != n:
                return g
        raise ArithmeticError(f"pollard_brent failed for {n}")

    def _pollard_recurse(self, n: int, out: List[int], deadline: float) -> bool:
        """Fully factor n into ``out``. Returns False if budget ran out
        (best-effort factors still appended)."""
        stack = [n]
        ok = True
        while stack:
            m = stack.pop()
            if m == 1:
                continue
            if m <= self.precomputed_limit:
                out.extend(self._factor_spf(m))
                continue
            if is_prime(m):
                out.append(m)
                continue
            if time.perf_counter() > deadline:
                # graceful degradation (paper §7.2): keep composite as-is
                out.append(m)
                ok = False
                continue
            d = self._pollard_brent(m)
            stack.append(d)
            stack.append(m // d)
        return ok
