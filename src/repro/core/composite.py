"""Composite relationship encoding & registry (PFCS §3.1, §4.2).

A relationship over data elements {d1..dk} with primes {p1..pk} is stored
as the composite c = Π pi.  The Fundamental Theorem of Arithmetic makes the
decoding (factorization) unique — Theorem 1's zero-false-positive
guarantee, which the test-suite checks as a machine property.

64-bit overflow management
--------------------------
The paper implicitly assumes composites fit machine words ("systems with
10**12 elements require primes within 64-bit ranges", §7.1).  Products of
many primes overflow regardless, so the registry *chunks* a k-ary
relationship into composites that each fit ``max_bits`` (default 62, so
int64 device kernels stay exact); all chunks share a relationship id.
Pairwise relationships — the dominant case in the paper's workloads
(FK pairs, feature pairs, instrument pairs) — always fit.

The registry also maintains the flat numpy array view of live composites
that the TPU divisibility-scan kernel (``repro.kernels.divisibility``)
consumes directly.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from .factorization import Factorizer

__all__ = ["encode_relationship", "CompositeRegistry", "Relationship"]


def encode_relationship(primes: Sequence[int], max_bits: int = 62) -> List[int]:
    """Chunk a multiset of primes into composites, each < 2**max_bits.

    Greedy first-fit keeps chunk count minimal for sorted input. Raises if
    any single prime alone exceeds the bound (cannot be represented).
    """
    limit = 1 << max_bits
    chunks: List[int] = []
    cur = 1
    for p in sorted(primes):
        if p <= 1:
            raise ValueError(f"not a prime: {p}")
        if p >= limit:
            raise ValueError(f"prime {p} exceeds {max_bits}-bit composite budget")
        if cur * p >= limit:
            chunks.append(cur)
            cur = p
        else:
            cur *= p
    if cur > 1:
        chunks.append(cur)
    return chunks


@dataclass(frozen=True)
class Relationship:
    """One registered relationship (e.g. an FK edge or co-access group)."""

    rel_id: int
    primes: FrozenSet[int]
    composites: Tuple[int, ...]
    kind: str = "generic"
    weight: float = 1.0


class CompositeRegistry:
    """Live store of relationship composites with divisibility scanning.

    API mirrors the paper's use:
      * ``register(primes)``       — establish a relationship (composite(s))
      * ``related_to(p)``          — §4.2 intelligent prefetch: all primes
                                     co-occurring with p in any composite,
                                     recovered *by factorization*.
      * ``composites_array()``     — int64 view for the Pallas scan kernel.
    """

    def __init__(self, factorizer: Optional[Factorizer] = None, max_bits: int = 62):
        if not 1 < max_bits <= 63:
            # a chunk in [2**63, 2**64) would register fine and then wrap
            # (or raise) only later, when composites_array() materializes
            # the int64 kernel view — reject the misconfiguration at
            # construction so deep-chain registration can never corrupt
            raise ValueError(
                f"max_bits must be in (1, 63] so every composite chunk "
                f"fits a signed int64 kernel word, got {max_bits}")
        self.factorizer = factorizer or Factorizer()
        self.max_bits = max_bits
        self._next_id = 0
        self._by_id: Dict[int, Relationship] = {}
        self._by_composite: Dict[int, int] = {}  # composite -> rel_id
        self._prime_degree: Dict[int, int] = {}  # prime -> #relationships
        self._dirty = True
        self._arr: np.ndarray = np.empty(0, dtype=np.int64)
        self.version = 0  # bumped on every mutation (memoization key)

    # -- registration -------------------------------------------------------

    def register(self, primes: Iterable[int], kind: str = "generic", weight: float = 1.0) -> Relationship:
        pset = frozenset(int(p) for p in primes)
        if len(pset) < 2:
            raise ValueError("a relationship needs >= 2 distinct elements")
        comps = tuple(encode_relationship(sorted(pset), self.max_bits))
        rel = Relationship(self._next_id, pset, comps, kind, weight)
        self._next_id += 1
        self._by_id[rel.rel_id] = rel
        for c in comps:
            self._by_composite[c] = rel.rel_id
        for p in pset:
            self._prime_degree[p] = self._prime_degree.get(p, 0) + 1
        self._dirty = True
        self.version += 1
        return rel

    def unregister(self, rel_id: int) -> None:
        rel = self._by_id.pop(rel_id, None)
        if rel is None:
            return
        for c in rel.composites:
            self._by_composite.pop(c, None)
        for p in rel.primes:
            d = self._prime_degree.get(p, 0) - 1
            if d <= 0:
                self._prime_degree.pop(p, None)
            else:
                self._prime_degree[p] = d
        self._dirty = True
        self.version += 1

    def drop_prime(self, p: int) -> List[int]:
        """Remove every relationship involving prime p (prime recycling
        must purge stale composites or factorization would resurrect a
        recycled element — paper §7.2 'prime space management')."""
        doomed = [r.rel_id for r in self._by_id.values() if p in r.primes]
        for rid in doomed:
            self.unregister(rid)
        return doomed

    # -- queries -------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._by_id)

    @property
    def n_composites(self) -> int:
        return len(self._by_composite)

    def degree(self, p: int) -> int:
        return self._prime_degree.get(p, 0)

    def primes_array(self) -> np.ndarray:
        """Sorted int64 array of every live member prime — the trial-
        division pool for the batched factorize kernel (engine bulk
        discovery, DESIGN.md §3)."""
        return np.fromiter(sorted(self._prime_degree), dtype=np.int64,
                           count=len(self._prime_degree))

    def composites_array(self) -> np.ndarray:
        """Flat int64 array of all live composites (kernel input)."""
        if self._dirty:
            self._arr = np.fromiter(self._by_composite.keys(), dtype=np.int64,
                                    count=len(self._by_composite))
            self._dirty = False
        return self._arr

    def relationship_of_composite(self, c: int) -> Optional[Relationship]:
        rid = self._by_composite.get(c)
        return self._by_id.get(rid) if rid is not None else None

    def containing(self, p: int) -> List[Relationship]:
        """All relationships whose composite is divisible by p.

        This is the paper's §4.2 scan: divisibility test over the registry,
        then *factorization* of the matching composites recovers the exact
        member set (not a reverse-index lookup — the correctness of the
        factorization path is the claim under test, and the scan is what
        the TPU kernel accelerates).
        """
        arr = self.composites_array()
        if arr.size == 0:
            return []
        hits = arr[arr % p == 0]
        out: List[Relationship] = []
        seen: Set[int] = set()
        for c in hits:
            c = int(c)
            factors = self._factor_with_hint(c, p)
            assert p in factors, "divisibility hit must contain p (Theorem 1)"
            rid = self._by_composite[c]
            if rid not in seen:
                seen.add(rid)
                out.append(self._by_id[rid])
        return out

    def _factor_with_hint(self, c: int, p: int) -> Tuple[int, ...]:
        """Factor c given the known factor p from the divisibility scan.

        The scan *is* trial division by pool primes (Algorithm 2 stage 1):
        once p is known, the cofactor c//p is either 1, prime (pairwise
        relationship — the dominant case), or recursed through the full
        multi-stage factorizer.  Stage stats are charged accordingly.
        """
        from .primes import is_prime  # local import avoids cycle at module load

        cached = self.factorizer.cache.get(c)
        if cached is not None and p in cached:
            self.factorizer.stats.cache_hits += 1
            self.factorizer.stats.total += 1
            return tuple(sorted(set(cached)))
        q, r = divmod(c, p)
        assert r == 0
        self.factorizer.stats.total += 1
        self.factorizer.stats.trial_division += 1
        if q == 1:
            out = (p,)
        elif is_prime(q):
            out = (p, q)
        else:
            # generous budget: registry hits must decode exactly (partial
            # factorizations are never cached — see Factorizer.factorize)
            out = tuple(sorted({p, *self.factorizer.factorize(
                q, time_budget_s=1.0)}))
        self.factorizer.cache.put(c, out)
        return out

    def related_primes(self, p: int) -> Set[int]:
        """All primes deterministically related to p (excluding p)."""
        rel: Set[int] = set()
        for r in self.containing(p):
            for c in r.composites:
                for q in self.factorizer.distinct_factors(int(c)):
                    if q != p:
                        rel.add(q)
            # multi-chunk relationships: all member primes are related
            rel |= set(r.primes) - {p}
        return rel

    def decode(self, c: int) -> Tuple[int, ...]:
        """Factorize an arbitrary composite back to its member primes."""
        return self.factorizer.distinct_factors(int(c))
