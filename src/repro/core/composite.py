"""Composite relationship encoding & registry (PFCS §3.1, §4.2).

A relationship over data elements {d1..dk} with primes {p1..pk} is stored
as the composite c = Π pi.  The Fundamental Theorem of Arithmetic makes the
decoding (factorization) unique — Theorem 1's zero-false-positive
guarantee, which the test-suite checks as a machine property.

64-bit overflow management
--------------------------
The paper implicitly assumes composites fit machine words ("systems with
10**12 elements require primes within 64-bit ranges", §7.1).  Products of
many primes overflow regardless, so the registry *chunks* a k-ary
relationship into composites that each fit ``max_bits`` (default 62, so
int64 device kernels stay exact); all chunks share a relationship id.
Pairwise relationships — the dominant case in the paper's workloads
(FK pairs, feature pairs, instrument pairs) — always fit.

Multi-limb wide mode (DESIGN.md §11)
------------------------------------
``max_bits > 63`` switches the registry to the :class:`LimbComposite`
encoding: each chunk is stored exactly as ``ceil(max_bits / 32)``
little-endian 32-bit limbs, so a single chunk can hold a 100+-deep chain
composite without overflow and the former PR 6 "detect, never silent"
overflow guard becomes "represent, never raise".  Member primes must fit
``MAX_PRIME_BITS`` (31) bits so every limb x prime product in the Pallas
kernels stays inside a signed int64 word — a bound no pool prime ever
approaches (the 10**6-th prime is ~2**24).  Arithmetic stays exact
integer everywhere; Theorem 1's zero-false-positive guarantee is
untouched because chunk values are the same products of distinct primes,
merely re-encoded.

The registry also maintains the flat numpy array view of live composites
that the TPU divisibility-scan kernel (``repro.kernels.divisibility``)
consumes directly, and — in wide mode — the ``(N, L)`` int64 limb matrix
the limb kernels consume (``limbs_array``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from .factorization import Factorizer

__all__ = ["encode_relationship", "CompositeRegistry", "Relationship",
           "LimbComposite", "LIMB_BITS", "LIMB_BASE", "MAX_PRIME_BITS",
           "MAX_COMPOSITE_BITS", "n_limbs_for_bits", "int_to_limbs",
           "limbs_to_int", "pack_limbs", "unpack_limbs"]

#: limb word width: 32-bit limbs held in int64 lanes keep every kernel
#: intermediate (limb * prime + carry, Horner-mod partial remainders)
#: provably inside a signed int64 — no float paths, no wraparound.
LIMB_BITS = 32
LIMB_BASE = 1 << LIMB_BITS
LIMB_MASK = LIMB_BASE - 1

#: primes must fit 31 bits so ``limb * p`` < 2**63 (see DESIGN.md §11);
#: the prime pools never mint anything close (10**6-th prime ~ 2**24).
MAX_PRIME_BITS = 31
MAX_PRIME_LIMIT = 1 << MAX_PRIME_BITS

#: sanity cap on chunk width (128 limbs) — wide enough for 150+-deep
#: chains of MEM-level primes in ONE chunk, small enough that a
#: misconfigured budget cannot allocate absurd limb matrices.
MAX_COMPOSITE_BITS = 4096


def n_limbs_for_bits(max_bits: int) -> int:
    """Limbs needed to hold any value < 2**max_bits."""
    return -(-int(max_bits) // LIMB_BITS)


def int_to_limbs(x: int, n_limbs: int) -> List[int]:
    """Little-endian 32-bit limb decomposition of a non-negative int."""
    x = int(x)
    if x < 0:
        raise ValueError(f"composites are positive, got {x}")
    out = []
    for _ in range(n_limbs):
        out.append(x & LIMB_MASK)
        x >>= LIMB_BITS
    if x:
        raise OverflowError(
            f"value needs more than {n_limbs} limbs ({n_limbs * LIMB_BITS} bits)")
    return out


def limbs_to_int(limbs: Sequence[int]) -> int:
    """Inverse of :func:`int_to_limbs` (exact Python int)."""
    x = 0
    for limb in reversed(list(limbs)):
        x = (x << LIMB_BITS) | (int(limb) & LIMB_MASK)
    return x


def pack_limbs(values: Sequence[int], n_limbs: int) -> np.ndarray:
    """Pack Python-int composites into the ``(N, L)`` int64 kernel matrix."""
    out = np.zeros((len(values), n_limbs), dtype=np.int64)
    for i, v in enumerate(values):
        out[i, :] = int_to_limbs(v, n_limbs)
    return out


def unpack_limbs(arr: np.ndarray) -> List[int]:
    """Exact Python ints back out of an ``(N, L)`` limb matrix."""
    return [limbs_to_int(row) for row in np.asarray(arr)]


@dataclass(frozen=True)
class LimbComposite:
    """One composite as fixed-width little-endian 32-bit limbs.

    The scalar unit of the wide registry encoding: ``encode`` splits an
    exact Python-int chunk value into limbs, ``value`` reassembles it
    bit-exactly.  The registry's ``limbs_array()`` is the batched (N, L)
    form of this for the Pallas limb kernels.
    """

    limbs: Tuple[int, ...]

    @classmethod
    def encode(cls, value: int, n_limbs: int) -> "LimbComposite":
        return cls(tuple(int_to_limbs(value, n_limbs)))

    @property
    def value(self) -> int:
        return limbs_to_int(self.limbs)

    def __int__(self) -> int:
        return self.value

    @property
    def n_limbs(self) -> int:
        return len(self.limbs)


def encode_relationship(primes: Sequence[int], max_bits: int = 62) -> List[int]:
    """Chunk a multiset of primes into composites, each < 2**max_bits.

    This is the ONE canonical chunking point: the input multiset is
    sorted here (and only here), so the same multiset produces the same
    chunk tuple regardless of caller order — including duplicate-prime
    multisets, where ``sorted`` keeps every occurrence.  Callers must NOT
    pre-sort (``CompositeRegistry.register`` passes its frozenset
    straight through).

    Greedy first-fit keeps chunk count minimal for sorted input.  The
    boundary is inclusive on the value side and exclusive on the budget:
    a chunk product of exactly ``2**max_bits - 1`` is accepted, a prime
    of exactly ``2**max_bits`` is rejected.  Raises if any single prime
    alone exceeds the bound (cannot be represented), or — in wide
    (``max_bits > 63``) mode — exceeds the 31-bit kernel limb word (no
    pool prime ever does; see DESIGN.md §11).
    """
    limit = 1 << max_bits
    wide = max_bits > 63
    chunks: List[int] = []
    cur = 1
    for p in sorted(primes):
        if p <= 1:
            raise ValueError(f"not a prime: {p}")
        if p >= limit:
            raise ValueError(f"prime {p} exceeds {max_bits}-bit composite budget")
        if wide and p >= MAX_PRIME_LIMIT:
            raise ValueError(
                f"prime {p} exceeds the {MAX_PRIME_BITS}-bit kernel limb "
                f"word (limb arithmetic would overflow int64)")
        if cur * p >= limit:
            chunks.append(cur)
            cur = p
        else:
            cur *= p
    if cur > 1:
        chunks.append(cur)
    return chunks


@dataclass(frozen=True)
class Relationship:
    """One registered relationship (e.g. an FK edge or co-access group)."""

    rel_id: int
    primes: FrozenSet[int]
    composites: Tuple[int, ...]
    kind: str = "generic"
    weight: float = 1.0


class CompositeRegistry:
    """Live store of relationship composites with divisibility scanning.

    API mirrors the paper's use:
      * ``register(primes)``       — establish a relationship (composite(s))
      * ``related_to(p)``          — §4.2 intelligent prefetch: all primes
                                     co-occurring with p in any composite,
                                     recovered *by factorization*.
      * ``composites_array()``     — int64 view for the Pallas scan kernel.
    """

    def __init__(self, factorizer: Optional[Factorizer] = None, max_bits: int = 62):
        if not 1 < max_bits <= MAX_COMPOSITE_BITS:
            # max_bits <= 63 keeps every chunk inside one signed int64
            # kernel word (the flat composites_array() view); anything
            # wider flips the registry into multi-limb mode, where chunks
            # are exact (N, n_limbs) 32-bit-limb rows (limbs_array()) and
            # the cap only guards against absurd limb matrices.
            raise ValueError(
                f"max_bits must be in (1, {MAX_COMPOSITE_BITS}], "
                f"got {max_bits}")
        self.factorizer = factorizer or Factorizer()
        self.max_bits = max_bits
        #: wide mode: chunks may exceed int64 — consumers must use the
        #: limb matrix (limbs_array) or exact Python ints
        #: (composites_list / composites_view), never composites_array.
        self.wide = max_bits > 63
        #: limb rows wide enough for any value < 2**max_bits (also
        #: meaningful in narrow mode: the limb kernels are differential-
        #: fuzzed against the int64 path at every width)
        self.n_limbs = n_limbs_for_bits(max_bits)
        self._next_id = 0
        self._by_id: Dict[int, Relationship] = {}
        self._by_composite: Dict[int, int] = {}  # composite -> rel_id
        self._prime_degree: Dict[int, int] = {}  # prime -> #relationships
        self._dirty = True
        self._arr: np.ndarray = np.empty(0, dtype=np.int64)
        self._limbs: np.ndarray = np.empty((0, self.n_limbs), dtype=np.int64)
        self._limbs_version = -1
        self.version = 0  # bumped on every mutation (memoization key)

    # -- registration -------------------------------------------------------

    def register(self, primes: Iterable[int], kind: str = "generic", weight: float = 1.0) -> Relationship:
        pset = frozenset(int(p) for p in primes)
        if len(pset) < 2:
            raise ValueError("a relationship needs >= 2 distinct elements")
        # canonical chunking happens INSIDE encode_relationship (the one
        # sort) — passing the frozenset unsorted is deliberate.
        comps = tuple(encode_relationship(pset, self.max_bits))
        rel = Relationship(self._next_id, pset, comps, kind, weight)
        self._next_id += 1
        self._by_id[rel.rel_id] = rel
        for c in comps:
            self._by_composite[c] = rel.rel_id
        for p in pset:
            self._prime_degree[p] = self._prime_degree.get(p, 0) + 1
        self._dirty = True
        self.version += 1
        return rel

    def register_many(self, groups: Iterable[Iterable[int]],
                      kind: str = "generic",
                      weight: float = 1.0) -> List[Relationship]:
        """Batched :meth:`register`, bit-identical to the per-element loop.

        Same validation, same canonical chunking, same id sequence, and
        the same final ``version`` (bumped once per registration, so
        version-keyed memoizers observe the same epoch).  The speedup
        comes from hoisting the dict attribute lookups out of the hot
        loop and deferring the ``_next_id`` / ``version`` writebacks —
        the streamed-build path for million-composite registries
        (``benchmarks.cases.case_scale``).  If a group fails validation
        mid-batch, the completed prefix stays registered exactly as the
        scalar loop would leave it.
        """
        by_id = self._by_id
        by_comp = self._by_composite
        deg = self._prime_degree
        max_bits = self.max_bits
        limit = 1 << max_bits
        wide = self.wide
        rid = self._next_id
        out: List[Relationship] = []
        try:
            for primes in groups:
                pset = frozenset(map(int, primes))
                if len(pset) < 2:
                    raise ValueError(
                        "a relationship needs >= 2 distinct elements")
                if len(pset) == 2:
                    # pairwise fast path — the dominant case (FK pairs,
                    # chain edges): inline the two-prime chunking;
                    # identical chunk tuple, with invalid pairs deferred
                    # to the canonical encoder for the canonical error
                    a, b = pset
                    if a > b:
                        a, b = b, a
                    if a <= 1 or b >= limit or (wide
                                                and b >= MAX_PRIME_LIMIT):
                        encode_relationship(pset, max_bits)  # raises
                        raise AssertionError("unreachable")
                    ab = a * b
                    comps = (ab,) if ab < limit else (a, b)
                else:
                    comps = tuple(encode_relationship(pset, max_bits))
                rel = Relationship(rid, pset, comps, kind, weight)
                rid += 1
                by_id[rel.rel_id] = rel
                for c in comps:
                    by_comp[c] = rel.rel_id
                for p in pset:
                    deg[p] = deg.get(p, 0) + 1
                out.append(rel)
        finally:
            self._next_id = rid
            if out:
                self._dirty = True
                self.version += len(out)
        return out

    def unregister(self, rel_id: int) -> None:
        rel = self._by_id.pop(rel_id, None)
        if rel is None:
            return
        for c in rel.composites:
            self._by_composite.pop(c, None)
        for p in rel.primes:
            d = self._prime_degree.get(p, 0) - 1
            if d <= 0:
                self._prime_degree.pop(p, None)
            else:
                self._prime_degree[p] = d
        self._dirty = True
        self.version += 1

    def drop_prime(self, p: int) -> List[int]:
        """Remove every relationship involving prime p (prime recycling
        must purge stale composites or factorization would resurrect a
        recycled element — paper §7.2 'prime space management')."""
        doomed = [r.rel_id for r in self._by_id.values() if p in r.primes]
        for rid in doomed:
            self.unregister(rid)
        return doomed

    # -- queries -------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._by_id)

    @property
    def n_composites(self) -> int:
        return len(self._by_composite)

    def degree(self, p: int) -> int:
        return self._prime_degree.get(p, 0)

    def primes_array(self) -> np.ndarray:
        """Sorted int64 array of every live member prime — the trial-
        division pool for the batched factorize kernel (engine bulk
        discovery, DESIGN.md §3)."""
        return np.fromiter(sorted(self._prime_degree), dtype=np.int64,
                           count=len(self._prime_degree))

    def composites_array(self) -> np.ndarray:
        """Flat int64 array of all live composites (kernel input).

        Narrow mode only — wide (multi-limb) chunks cannot fit int64;
        use :meth:`limbs_array` (kernels) or :meth:`composites_view` /
        :meth:`composites_list` (host) there.
        """
        if self.wide:
            raise OverflowError(
                "composites exceed int64 in wide (multi-limb) mode; use "
                "limbs_array() / composites_view() / composites_list()")
        if self._dirty:
            self._arr = np.fromiter(self._by_composite.keys(), dtype=np.int64,
                                    count=len(self._by_composite))
            self._dirty = False
        return self._arr

    def composites_list(self) -> List[int]:
        """All live composites as exact Python ints, registry order."""
        return [int(c) for c in self._by_composite]

    def composites_view(self) -> np.ndarray:
        """Registry-order composite array at whatever dtype is exact:
        the int64 kernel view in narrow mode, an object array of Python
        ints in wide mode.  Host-side consumers that only index / compare
        / take ``%`` (resharding, isolation audit) stay mode-agnostic."""
        if not self.wide:
            return self.composites_array()
        out = np.empty(len(self._by_composite), dtype=object)
        for i, c in enumerate(self._by_composite):
            out[i] = int(c)
        return out

    def limbs_array(self) -> np.ndarray:
        """``(N, n_limbs)`` int64 little-endian 32-bit-limb matrix of all
        live composites, registry (row) order matching
        :meth:`composites_view` — the wide-mode kernel input."""
        if self._limbs_version != self.version:
            self._limbs = pack_limbs(list(self._by_composite), self.n_limbs)
            self._limbs_version = self.version
        return self._limbs

    def relationship_of_composite(self, c: int) -> Optional[Relationship]:
        rid = self._by_composite.get(c)
        return self._by_id.get(rid) if rid is not None else None

    def containing(self, p: int) -> List[Relationship]:
        """All relationships whose composite is divisible by p.

        This is the paper's §4.2 scan: divisibility test over the registry,
        then *factorization* of the matching composites recovers the exact
        member set (not a reverse-index lookup — the correctness of the
        factorization path is the claim under test, and the scan is what
        the TPU kernel accelerates).
        """
        if self.wide:
            # exact Python-int modular scan (dict insertion order == the
            # registry order the narrow numpy path iterates in)
            hits: Sequence[int] = [c for c in self._by_composite if c % p == 0]
        else:
            arr = self.composites_array()
            if arr.size == 0:
                return []
            hits = arr[arr % p == 0]
        out: List[Relationship] = []
        seen: Set[int] = set()
        for c in hits:
            c = int(c)
            factors = self._factor_with_hint(c, p)
            assert p in factors, "divisibility hit must contain p (Theorem 1)"
            rid = self._by_composite[c]
            if rid not in seen:
                seen.add(rid)
                out.append(self._by_id[rid])
        return out

    def _factor_with_hint(self, c: int, p: int) -> Tuple[int, ...]:
        """Factor c given the known factor p from the divisibility scan.

        The scan *is* trial division by pool primes (Algorithm 2 stage 1):
        once p is known, the cofactor c//p is either 1, prime (pairwise
        relationship — the dominant case), or recursed through the full
        multi-stage factorizer.  Stage stats are charged accordingly.
        """
        from .primes import is_prime  # local import avoids cycle at module load

        cached = self.factorizer.cache.get(c)
        if cached is not None and p in cached:
            self.factorizer.stats.cache_hits += 1
            self.factorizer.stats.total += 1
            return tuple(sorted(set(cached)))
        q, r = divmod(c, p)
        assert r == 0
        self.factorizer.stats.total += 1
        self.factorizer.stats.trial_division += 1
        if q == 1:
            out = (p,)
        elif is_prime(q):
            out = (p, q)
        else:
            # generous budget: registry hits must decode exactly (partial
            # factorizations are never cached — see Factorizer.factorize)
            out = tuple(sorted({p, *self.factorizer.factorize(
                q, time_budget_s=1.0)}))
        self.factorizer.cache.put(c, out)
        return out

    def related_primes(self, p: int) -> Set[int]:
        """All primes deterministically related to p (excluding p)."""
        rel: Set[int] = set()
        for r in self.containing(p):
            for c in r.composites:
                for q in self.factorizer.distinct_factors(int(c)):
                    if q != p:
                        rel.add(q)
            # multi-chunk relationships: all member primes are related
            rel |= set(r.primes) - {p}
        return rel

    def limb_composite(self, c: int) -> LimbComposite:
        """The registry-width :class:`LimbComposite` encoding of one
        composite (a single row of :meth:`limbs_array`)."""
        return LimbComposite.encode(int(c), self.n_limbs)

    def decode(self, c: int) -> Tuple[int, ...]:
        """Factorize an arbitrary composite back to its member primes."""
        return self.factorizer.distinct_factors(int(c))
