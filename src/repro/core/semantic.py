"""Semantic-cache baseline (PFCS §2.1 / Table 1 'Semantic Cache').

Embedding-similarity relationship discovery: each key gets a random-
projection embedding of its true relationship neighborhood plus noise;
neighbor queries return cosine-similar keys.  This reproduces the
published failure modes the paper attributes to such systems:

  * false positives (2.3-15.7% in the paper) — similar-but-unrelated keys
    get prefetched, wasting cache space and backing-store bandwidth;
  * false negatives — some true relationships fall below the similarity
    threshold and are never prefetched;
  * per-discovery embedding compute charged by the latency model
    (paper: 15-23% CPU overhead for embedding generation).

The implementation is deterministic given ``seed``.
"""

from __future__ import annotations

from typing import Dict, Hashable, List, Sequence, Set, Tuple

import numpy as np

__all__ = ["SemanticRelationshipModel"]

DataID = Hashable


class SemanticRelationshipModel:
    """Approximate relationship oracle with tunable FP/FN rates."""

    def __init__(
        self,
        relationships: Sequence[Tuple[int, ...]],
        n_keys: int,
        embed_dim: int = 32,
        fp_rate: float = 0.12,   # fraction of returned neighbors that are false
        fn_rate: float = 0.10,   # fraction of true neighbors dropped
        seed: int = 0,
    ):
        self.rng = np.random.default_rng(seed)
        self.n_keys = n_keys
        self.fp_rate = fp_rate
        self.fn_rate = fn_rate
        self.embed_dim = embed_dim

        # true adjacency
        self._adj: Dict[int, Set[int]] = {}
        for grp in relationships:
            for k in grp:
                self._adj.setdefault(int(k), set()).update(
                    int(g) for g in grp if g != k)

        # random-projection embeddings: related keys pull together, noise
        # keeps similarity imperfect (the source of FP/FN behaviour).
        self._emb = self.rng.normal(size=(n_keys, embed_dim)).astype(np.float32)
        for k, nbrs in self._adj.items():
            if nbrs:
                centroid = self._emb[list(nbrs)].mean(axis=0)
                self._emb[k] = 0.6 * self._emb[k] + 0.4 * centroid
        norms = np.linalg.norm(self._emb, axis=1, keepdims=True)
        self._emb /= np.maximum(norms, 1e-6)

        self._memo: Dict[int, List[int]] = {}
        self.discovery_ops = 0  # embedding computations (charged by metrics)

    def neighbors(self, k: int, budget: int = 8) -> List[int]:
        """Approximate related keys: true neighbors minus FN, plus FP."""
        k = int(k)
        if k in self._memo:
            self.discovery_ops += 1  # similarity search still runs per query
            return self._memo[k]
        self.discovery_ops += 1
        true_nbrs = list(self._adj.get(k, ()))
        kept = [n for n in true_nbrs if self.rng.random() >= self.fn_rate]
        # false positives: cosine-similar but unrelated keys
        n_fp = int(np.ceil(len(kept) * self.fp_rate / max(1e-9, 1 - self.fp_rate)))
        if not kept and self._adj.get(k):
            n_fp = max(n_fp, 1)
        fps: List[int] = []
        if n_fp > 0:
            sims = self._emb @ self._emb[k]
            sims[k] = -np.inf
            for n in true_nbrs:
                sims[n] = -np.inf
            order = np.argpartition(-sims, min(n_fp, self.n_keys - 1))[: n_fp]
            fps = [int(x) for x in order]
        out = (kept + fps)[:budget]
        self._memo[k] = out
        return out

    def is_truly_related(self, a: int, b: int) -> bool:
        return int(b) in self._adj.get(int(a), set())
