"""Adaptive prime assignment (PFCS Algorithm 1).

Maps data elements to primes, level by level:

    1. GetCachedPrime(d, L)            — bidirectional map lookup
    2. PredictAccessFrequency(d, A)    — EWMA over the access history
    3. EstimateRelationshipCount(d, A) — registry degree + pattern hints
    4. ComputeFactorizationBudget(L)   — per-level time budget
    5. SelectOptimalPrimeRange(...)    — hot/low-degree data -> small primes
    6. AllocateFromPool(range, L)      — ascending allocation
    7. RecycleLRUPrimes(L, 0.1*pool)   — pool-exhaustion recycling

Recycling frees the primes of the least-recently-used elements *and*
purges their composites from the registry (otherwise factorization would
resurrect recycled identities — see composite.drop_prime).
"""

from __future__ import annotations

import itertools
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

from .composite import CompositeRegistry
from .primes import CacheLevel, HierarchicalPrimeAllocator

__all__ = ["AccessTracker", "PrimeAssigner", "AssignmentStats"]

DataID = Hashable


class AccessTracker:
    """EWMA access-frequency predictor + LRU ordering of elements."""

    def __init__(self, alpha: float = 0.25):
        self.alpha = alpha
        self._freq: Dict[DataID, float] = {}
        self._lru: "OrderedDict[DataID, int]" = OrderedDict()
        self._clock = 0

    def record(self, d: DataID) -> None:
        self._clock += 1
        f = self._freq.get(d, 0.0)
        self._freq[d] = f + self.alpha * (1.0 - f)
        if d in self._lru:
            self._lru.move_to_end(d)
        self._lru[d] = self._clock

    def decay_tick(self) -> None:
        """Periodic decay so stale elements cool down (called by the cache)."""
        for k in self._freq:
            self._freq[k] *= 1.0 - self.alpha * 0.1

    def predicted_frequency(self, d: DataID) -> float:
        return self._freq.get(d, 0.0)

    def lru_order(self) -> List[DataID]:
        return list(self._lru.keys())  # oldest first

    def forget(self, d: DataID) -> None:
        self._freq.pop(d, None)
        self._lru.pop(d, None)


@dataclass
class AssignmentStats:
    assigned: int = 0
    reused: int = 0
    recycle_events: int = 0
    recycled_primes: int = 0


class PrimeAssigner:
    """Algorithm 1 — adaptive prime assignment with predictive allocation."""

    # per-level factorization time budgets (seconds) — §3.2's
    # "progressively larger prime spaces, accepting higher factorization
    # costs": L1 must be near-instant, MEM can afford real work.
    LEVEL_BUDGETS = {
        CacheLevel.L1: 1e-6,
        CacheLevel.L2: 1e-4,
        CacheLevel.L3: 1e-3,
        CacheLevel.MEM: 5e-2,
    }

    def __init__(
        self,
        allocator: Optional[HierarchicalPrimeAllocator] = None,
        registry: Optional[CompositeRegistry] = None,
        tracker: Optional[AccessTracker] = None,
        recycle_fraction: float = 0.1,  # paper line 9: 0.1 * PoolSize[L]
    ):
        # NB: `x if x is not None else ...` — CompositeRegistry defines
        # __len__, so an *empty* registry is falsy and `or` would silently
        # replace it with a fresh one.
        self.allocator = allocator if allocator is not None else HierarchicalPrimeAllocator()
        self.registry = registry if registry is not None else CompositeRegistry()
        self.tracker = tracker if tracker is not None else AccessTracker()
        self.recycle_fraction = recycle_fraction
        self.stats = AssignmentStats()
        #: bumped whenever a data->prime binding is destroyed (release /
        #: recycling) — consumers caching prime-derived state (e.g. the
        #: vectorized cache's chain-composite chunks) key on this to
        #: notice that a cached prime may since have been recycled and
        #: reassigned to a different element
        self.epoch = 0
        # bidirectional maps, per level (Listing 1 data_to_prime/prime_to_data)
        self._data_to_prime: Dict[int, Dict[DataID, int]] = {l: {} for l in CacheLevel.ALL}
        self._prime_to_data: Dict[int, Dict[int, DataID]] = {l: {} for l in CacheLevel.ALL}

    # ------------------------------------------------------------------ #

    def get_cached_prime(self, d: DataID, level: int) -> Optional[int]:
        return self._data_to_prime[level].get(d)

    def prime_of(self, d: DataID) -> Optional[int]:
        """Prime of d at any level (hot levels searched first)."""
        for lvl in CacheLevel.ALL:
            p = self._data_to_prime[lvl].get(d)
            if p is not None:
                return p
        return None

    def data_of(self, p: int) -> Optional[DataID]:
        for lvl in CacheLevel.ALL:
            d = self._prime_to_data[lvl].get(p)
            if d is not None:
                return d
        return None

    def factorization_budget(self, level: int) -> float:
        return self.LEVEL_BUDGETS[level]

    def _select_range(self, freq: float, degree: int, level: int) -> int:
        """SelectOptimalPrimeRange: hot/high-degree data earns a *hotter*
        level's pool than its resident level, because its prime appears in
        many composites and must be cheap to factor out."""
        score = freq + 0.1 * min(degree, 10)
        if score > 0.75 and level > CacheLevel.L1:
            return level - 1  # promote one level hotter
        return level

    # ------------------------------------------------------------------ #

    def assign(self, d: DataID, level: int) -> int:
        """Algorithm 1 main entry: returns the prime for element d."""
        p = self.get_cached_prime(d, level)
        if p is not None:
            self.stats.reused += 1
            return p
        freq = self.tracker.predicted_frequency(d)
        degree = 0
        existing = self.prime_of(d)
        if existing is not None:
            degree = self.registry.degree(existing)
        rng_level = self._select_range(freq, degree, level)
        p = self.allocator.allocate(rng_level)
        if p is None and freq > 0.3:
            # pool exhaustion for genuinely *hot* data -> recycle 10% and
            # retry (paper lines 8-11). Cold data spills to a colder pool
            # instead — recycling an in-use hot prime for a cold element
            # would destroy more prefetch value than it creates.
            self._recycle(rng_level)
            p = self.allocator.allocate(rng_level)
        while p is None and rng_level < CacheLevel.MEM:
            rng_level += 1
            p = self.allocator.allocate(rng_level)
        assert p is not None, "MEM pool is unbounded; allocation cannot fail"
        self._data_to_prime[level][d] = p
        self._prime_to_data[level][p] = d
        self.stats.assigned += 1
        return p

    def assign_many(self, ds: Sequence[DataID], level: int) -> List[int]:
        """Batched :meth:`assign`, bit-identical to the per-element loop.

        Runs of *fresh, cold* elements (no prime at any level, zero
        predicted frequency — for those ``_select_range`` provably
        returns ``level`` and :meth:`assign` reduces to a pure pool
        allocation) are allocated in one :meth:`PrimePool.allocate_many`
        slice and bulk-inserted into the bidirectional maps.  Anything
        else — cached primes, warm elements, duplicates within the batch
        — flushes the pending run and falls back to scalar :meth:`assign`
        at its original position, so allocation order (and therefore
        every prime handed out) matches the scalar loop exactly.  This
        is the streamed-build fast path for million-element registries.
        """
        out: List[int] = []
        run: List[DataID] = []
        run_set: set = set()
        pool = self.allocator.pools[level]

        def flush() -> None:
            if not run:
                return
            ps = pool.allocate_many(len(run))
            d2p = self._data_to_prime[level]
            p2d = self._prime_to_data[level]
            for d, p in zip(run, ps):
                d2p[d] = p
                p2d[p] = d
            self.stats.assigned += len(ps)
            out.extend(ps)
            if len(ps) < len(run):
                # bounded pool ran dry mid-run: the scalar path would
                # spill the remainder level by level — defer to it
                for d in run[len(ps):]:
                    out.append(self.assign(d, level))
            run.clear()
            run_set.clear()

        for d in ds:
            if (d not in run_set
                    and self.tracker.predicted_frequency(d) == 0.0
                    and self.prime_of(d) is None):
                run.append(d)
                run_set.add(d)
            else:
                flush()
                out.append(self.assign(d, level))
        flush()
        return out

    def release(self, d: DataID, level: int) -> None:
        """Return d's prime at `level` to its pool and purge composites."""
        p = self._data_to_prime[level].pop(d, None)
        if p is None:
            return
        self.epoch += 1
        self._prime_to_data[level].pop(p, None)
        self.registry.drop_prime(p)
        self.allocator.free(self.allocator.level_of_prime(p), p)

    def _recycle(self, level: int) -> None:
        """RecycleLRUPrimes(L, 0.1 * PoolSize[L])."""
        pool = self.allocator.pool(level)
        want = max(1, int(self.recycle_fraction * max(pool.size, 1)))
        victims: List[Tuple[DataID, int]] = []
        mapped = self._data_to_prime[level]
        for d in self.tracker.lru_order():
            if d in mapped:
                victims.append((d, mapped[d]))
                if len(victims) >= want:
                    break
        if not victims:  # no tracked victims: recycle arbitrary mappings
            victims = list(itertools.islice(mapped.items(), want))
        for d, p in victims:
            self.release(d, level)
            self.tracker.forget(d)
        self.stats.recycle_events += 1
        self.stats.recycled_primes += len(victims)

    def migrate(self, d: DataID, src: int, dst: int) -> int:
        """Move an element between levels (cache promotion/demotion).

        The element gets a prime from the destination pool; its
        relationships are re-encoded so composites track level residency.
        """
        old = self._data_to_prime[src].get(d)
        related: List[frozenset] = []
        if old is not None:
            rels = self.registry.containing(old)
            related = [r.primes for r in rels]
        self.release(d, src)
        p = self.assign(d, dst)
        # re-register relationships with the new prime
        for primes in related:
            new_primes = {p if q == old else q for q in primes}
            if len(new_primes) >= 2:
                self.registry.register(new_primes)
        return p
