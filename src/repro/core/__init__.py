"""PFCS core — the paper's primary contribution.

Prime-factorization-based deterministic data-relationship discovery for
cache systems (Le, CS.DB 2025): unique prime assignment (Algorithm 1),
composite relationship encoding, multi-stage factorization (Algorithm 2),
intelligent prefetching (§4.2), hierarchical cache integration (§3.2),
plus every baseline the paper compares against and the trace-driven
evaluation harness behind Table 1 / Fig. 2.
"""

from .primes import (CacheLevel, HierarchicalPrimeAllocator, PrimePool,
                     is_prime, segmented_sieve, sieve_primes, spf_table)
from .factorization import Factorizer, FactorizationStats, PRECOMPUTED_LIMIT
from .composite import CompositeRegistry, Relationship, encode_relationship
from .assignment import AccessTracker, PrimeAssigner
from .prefetch import IntelligentPrefetcher, PrefetchDecision
from .pfcs_cache import PFCSCache
from .policies import (ARCCachePolicy, CachePolicy, FIFOCachePolicy,
                       LIRSCachePolicy, LRUCachePolicy, TwoQCachePolicy,
                       make_policy)
from .semantic import SemanticRelationshipModel
from .metrics import AccessStats, TierCosts, DEFAULT_COSTS, derive_table1_row
from .traces import (Trace, db_join_trace, graph_walk_trace, hft_trace,
                     ml_epoch_trace, scan_trace, zipf_trace)
from .simulator import (DEFAULT_LEVELS, fast_lru_hit_rate, run_all_systems,
                        simulate_baseline, simulate_pfcs, simulate_semantic)


def __getattr__(name):
    # lazy: the vectorized engine pulls in jax at import time; callers that
    # only need the host-side core shouldn't pay for it (PEP 562)
    if name == "engine":
        from . import engine
        return engine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "CacheLevel", "HierarchicalPrimeAllocator", "PrimePool", "is_prime",
    "segmented_sieve", "sieve_primes", "spf_table",
    "Factorizer", "FactorizationStats", "PRECOMPUTED_LIMIT",
    "CompositeRegistry", "Relationship", "encode_relationship",
    "AccessTracker", "PrimeAssigner",
    "IntelligentPrefetcher", "PrefetchDecision", "PFCSCache",
    "ARCCachePolicy", "CachePolicy", "FIFOCachePolicy", "LIRSCachePolicy",
    "LRUCachePolicy", "TwoQCachePolicy", "make_policy",
    "SemanticRelationshipModel",
    "AccessStats", "TierCosts", "DEFAULT_COSTS", "derive_table1_row",
    "Trace", "db_join_trace", "graph_walk_trace", "hft_trace",
    "ml_epoch_trace", "scan_trace", "zipf_trace",
    "DEFAULT_LEVELS", "fast_lru_hit_rate", "run_all_systems",
    "simulate_baseline", "simulate_pfcs", "simulate_semantic",
]
