"""Trace-driven multi-level cache simulation harness (PFCS §6).

Runs a trace through (a) baseline policy hierarchies (LRU/FIFO/2Q/ARC/
LIRS), (b) the semantic-prefetch system, and (c) PFCS, producing
:class:`~repro.core.metrics.AccessStats` for the Table 1 / Fig. 2
benchmarks.

All hierarchies share the same level capacities and the same inclusive
promote-on-hit / demote-on-evict discipline so the only degrees of
freedom are replacement policy and relationship discovery — exactly the
comparison the paper draws.

A jitted array-based LRU fast path (``fast_lru_hit_rate``) backs the
large cache-size sweeps; it was the seed of — and is now subsumed by —
the vectorized batch engine (:mod:`repro.core.engine`), which carries
every system's state through ``lax.scan`` and batches traces with
``vmap``.  ``run_all_systems`` dispatches to the engine by default; the
scalar loops in this module remain the cross-check oracle the engine is
tested against bit-for-bit (DESIGN.md §4, tests/test_engine.py).
"""

from __future__ import annotations

import functools
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from .metrics import AccessStats
from .pfcs_cache import PFCSCache
from .policies import CachePolicy, make_policy
from .semantic import SemanticRelationshipModel
from .traces import Trace

__all__ = [
    "DEFAULT_LEVELS", "simulate_baseline", "simulate_semantic",
    "simulate_pfcs", "run_all_systems", "fast_lru_hit_rate",
]

DEFAULT_LEVELS: Tuple[Tuple[str, int], ...] = (("L1", 64), ("L2", 512), ("L3", 4096))

_LEVEL_NAMES = ("L1", "L2", "L3", "MEM")


class _BaselineHierarchy:
    """Baseline system: ONE policy cache of total capacity + recency shadows.

    Composing stateful policies (ARC/LIRS) as literal stacked levels
    corrupts their internal recency/ghost state on promotion/demotion, so
    residency is decided by a single policy instance over the summed
    capacity — the policy's published behaviour.  Tier *attribution* for
    the latency/energy model uses policy-independent recency shadows:
    nested exact-LRU sets of sizes c1 < c1+c2 < ... ; a hit is served by
    the smallest shadow containing the key (the hierarchy keeps the most
    recent data closest).  Resident keys outside every shadow (prefetched
    or retained-cold, e.g. LIRS LIR blocks) are charged the MEM tier.
    """

    def __init__(self, policy: str, capacities: Sequence[Tuple[str, int]]):
        self.names = [name for name, _ in capacities]
        total = sum(cap for _, cap in capacities)
        self.policy = make_policy(policy, total)
        cum = 0
        self.shadows: List[Tuple[str, int, "OrderedDict"]] = []
        from collections import OrderedDict as _OD
        for name, cap in capacities:
            cum += cap
            self.shadows.append((name, cum, _OD()))
        self.prefetched: set = set()  # keys resident due to prefetch only

    def _touch_shadows(self, key) -> None:
        for _, cap, sh in self.shadows:
            if key in sh:
                sh.move_to_end(key)
            else:
                sh[key] = None
            while len(sh) > cap:
                sh.popitem(last=False)

    def _tier_of(self, key) -> str:
        for name, _, sh in self.shadows:
            if key in sh:
                return name
        return "MEM"

    def access(self, key) -> Tuple[bool, Optional[str], bool]:
        was_pf = key in self.prefetched
        self.prefetched.discard(key)
        resident = self.policy.contains(key)
        tier = self._tier_of(key) if resident else None
        self._touch_shadows(key)
        self.policy.access(key)  # updates policy state; admits on miss
        return resident, tier, was_pf

    def insert_prefetch(self, key, level_idx: int) -> None:
        if not self.policy.contains(key):
            self.policy.insert(key)
            self.prefetched.add(key)

    def contains(self, key) -> bool:
        return self.policy.contains(key)


def _finalize(stats: AccessStats, related: Dict[int, set],
              prefetch_pairs: List[Tuple[int, int]]) -> AccessStats:
    stats.prefetches_true = sum(
        1 for trig, tgt in prefetch_pairs if int(tgt) in related.get(int(trig), set())
    )
    return stats


# --------------------------------------------------------------------------- #
# baseline systems                                                            #
# --------------------------------------------------------------------------- #

def simulate_baseline(policy: str, trace: Trace,
                      capacities: Sequence[Tuple[str, int]] = DEFAULT_LEVELS
                      ) -> AccessStats:
    """Classic replacement policy, no relationship awareness."""
    h = _BaselineHierarchy(policy, capacities)
    stats = AccessStats(name=policy.upper())
    stats.hits_per_level = {n: 0 for n, _ in capacities}
    stats.hits_per_level["MEM"] = 0
    for key in trace.accesses:
        key = int(key)
        stats.demand_accesses += 1
        hit, lvl, _ = h.access(key)
        if hit:
            stats.hits_per_level[lvl] += 1
        else:
            stats.misses += 1
    return stats


def simulate_semantic(trace: Trace,
                      capacities: Sequence[Tuple[str, int]] = DEFAULT_LEVELS,
                      fp_rate: float = 0.12, fn_rate: float = 0.10,
                      prefetch_budget: int = 4, seed: int = 0,
                      prefetch_trigger: str = "miss") -> AccessStats:
    """LRU hierarchy + embedding-similarity prefetch (Table 1 row 4)."""
    h = _BaselineHierarchy("lru", capacities)
    model = SemanticRelationshipModel(
        trace.relationships, trace.n_keys, fp_rate=fp_rate, fn_rate=fn_rate,
        seed=seed)
    stats = AccessStats(name="SEMANTIC")
    stats.hits_per_level = {n: 0 for n, _ in capacities}
    stats.hits_per_level["MEM"] = 0
    related = trace.related_map()
    pf_level = max(0, len(capacities) - 2)
    pairs: List[Tuple[int, int]] = []
    for key in trace.accesses:
        key = int(key)
        stats.demand_accesses += 1
        hit, lvl, was_pf = h.access(key)
        if hit:
            stats.hits_per_level[lvl] += 1
            if was_pf:
                stats.prefetches_used += 1
        else:
            stats.misses += 1
        if prefetch_trigger != "always" and hit and not was_pf:
            continue
        for tgt in model.neighbors(key, budget=prefetch_budget):
            if not h.contains(tgt):
                h.insert_prefetch(tgt, pf_level)
                stats.prefetches_issued += 1
                stats.extra_backing_fetches += 1
                pairs.append((key, tgt))
    stats.embedding_ops = model.discovery_ops
    return _finalize(stats, related, pairs)


# --------------------------------------------------------------------------- #
# PFCS                                                                        #
# --------------------------------------------------------------------------- #

def simulate_pfcs(trace: Trace,
                  capacities: Sequence[Tuple[str, int]] = DEFAULT_LEVELS,
                  prefetch_budget: int = 4,
                  enable_prefetch: bool = True,
                  victim_window: int = 8,
                  prefetch_trigger: str = "miss") -> AccessStats:
    cache = PFCSCache(capacities, prefetch_budget=prefetch_budget,
                      enable_prefetch=enable_prefetch,
                      victim_window=victim_window,
                      prefetch_trigger=prefetch_trigger)
    for grp in trace.relationships:
        cache.register_relationship(grp, kind=trace.meta.get("kind", "generic"))

    stats = AccessStats(name="PFCS")
    stats.hits_per_level = {n: 0 for n, _ in capacities}
    related = trace.related_map()
    f0 = cache.factorizer.stats
    base = (f0.table_hits, f0.cache_hits, f0.trial_division, f0.pollard_rho)
    for key in trace.accesses:
        key = int(key)
        stats.demand_accesses += 1
        hit, lvl, was_pf = cache.access(key)
        if hit:
            stats.hits_per_level[lvl] += 1
            if was_pf:
                stats.prefetches_used += 1
        else:
            stats.misses += 1
    stats.prefetches_issued = cache.prefetches_issued
    stats.extra_backing_fetches = cache.prefetches_issued
    f1 = cache.factorizer.stats
    stats.factor_ops = {
        "table": f1.table_hits - base[0],
        "cache": f1.cache_hits - base[1],
        "trial": f1.trial_division - base[2],
        "rho": f1.pollard_rho - base[3],
    }
    return _finalize(stats, related, cache.prefetch_targets)


# --------------------------------------------------------------------------- #
# orchestration                                                               #
# --------------------------------------------------------------------------- #

def run_all_systems(trace: Trace,
                    capacities: Sequence[Tuple[str, int]] = DEFAULT_LEVELS,
                    systems: Sequence[str] = ("lru", "arc", "lirs", "semantic", "pfcs"),
                    seed: int = 0,
                    engine: str = "auto") -> Dict[str, AccessStats]:
    """Run every requested system over one trace.

    ``engine`` selects the simulation backend:

      * ``"auto"`` (default) — the vectorized array engine
        (:mod:`repro.core.engine`, a ``lax.scan`` state machine per
        system) for every system it supports; the scalar reference
        loops otherwise.  The engine is bit-identical to the scalar
        oracles (tests/test_engine.py), so results do not depend on the
        backend — only wall-clock does.
      * ``"vectorized"`` — require the engine; raise for systems it
        cannot run (the semantic baseline consumes its noise RNG in
        miss order, which is inherently serial).
      * ``"scalar"`` — force the reference loops (the oracle path).
    """
    if engine not in ("auto", "vectorized", "scalar"):
        raise ValueError(f"engine must be auto|vectorized|scalar, got {engine!r}")
    out: Dict[str, AccessStats] = {}
    vec_systems: List[str] = []
    for s in systems:
        if engine != "scalar":
            from .engine import VECTORIZED_SYSTEMS
            if s in VECTORIZED_SYSTEMS:
                vec_systems.append(s)
                continue
            if engine == "vectorized":
                raise ValueError(f"engine cannot simulate {s!r}")
        if s == "pfcs":
            out[s] = simulate_pfcs(trace, capacities)
        elif s == "semantic":
            out[s] = simulate_semantic(trace, capacities, seed=seed)
        else:
            out[s] = simulate_baseline(s, trace, capacities)
    if vec_systems:
        from .engine import simulate_trace as _vec_simulate
        for s in vec_systems:
            out[s] = _vec_simulate(trace, s, capacities)
    return out


# --------------------------------------------------------------------------- #
# jitted array LRU (TPU-native simulator fast path)                           #
# --------------------------------------------------------------------------- #

@functools.lru_cache(maxsize=None)
def _lru_scan_fn(capacity: int):
    import jax
    import jax.numpy as jnp

    def step(state, key):
        keys, ages = state  # (C,) int32 resident keys, (C,) int32 ages
        match = keys == key
        hit = jnp.any(match)
        ages = ages + 1
        # hit: zero the age of the matching slot
        ages = jnp.where(match, 0, ages)
        # miss: replace the oldest slot
        victim = jnp.argmax(ages)
        keys = jnp.where(hit, keys, keys.at[victim].set(key))
        ages = jnp.where(hit, ages, ages.at[victim].set(0))
        return (keys, ages), hit

    @jax.jit
    def run(accesses):
        keys0 = jnp.full((capacity,), -1, dtype=jnp.int32)
        ages0 = jnp.arange(capacity, dtype=jnp.int32)
        (_, _), hits = jax.lax.scan(step, (keys0, ages0), accesses)
        return hits.sum()

    return run


def fast_lru_hit_rate(accesses: np.ndarray, capacity: int) -> float:
    """Exact LRU hit rate via a jitted ``lax.scan`` state machine."""
    import jax.numpy as jnp

    run = _lru_scan_fn(int(capacity))
    acc = jnp.asarray(np.asarray(accesses, dtype=np.int32))
    hits = int(run(acc))
    return hits / max(1, len(accesses))
