"""PFCS multi-level cache front-end (PFCS §3, §5 Listing 1).

Combines the subsystems:

  * :class:`~repro.core.assignment.PrimeAssigner`   — Algorithm 1
  * :class:`~repro.core.composite.CompositeRegistry`— relationship store
  * :class:`~repro.core.factorization.Factorizer`   — Algorithm 2
  * :class:`~repro.core.prefetch.IntelligentPrefetcher` — §4.2

into a demand-access cache hierarchy with:

  * inclusive promote-on-hit / demote-on-evict level cascade,
  * relationship-aware replacement (victims are the coldest entries with
    the fewest live relationships — high-degree entries anchor prefetch
    value, so they are worth keeping),
  * deterministic relationship prefetch into a configurable level.

The class exposes the same ``access(key) -> (hit, level_name)`` contract
the simulator uses for the baselines, so Table 1 compares like for like.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Hashable, Iterable, List, Optional, Sequence, Tuple

from .assignment import PrimeAssigner
from .composite import CompositeRegistry
from .factorization import Factorizer
from .prefetch import IntelligentPrefetcher
from .primes import CacheLevel, HierarchicalPrimeAllocator

__all__ = ["PFCSCache"]

DataID = Hashable


class _Level:
    """One cache level: recency-ordered resident set."""

    def __init__(self, name: str, capacity: int):
        self.name = name
        self.capacity = capacity
        self.entries: "OrderedDict[DataID, bool]" = OrderedDict()  # val=prefetched?

    def __contains__(self, k: DataID) -> bool:
        return k in self.entries

    def __len__(self) -> int:
        return len(self.entries)

    def touch(self, k: DataID) -> None:
        self.entries.move_to_end(k)

    def add(self, k: DataID, prefetched: bool = False) -> None:
        self.entries[k] = prefetched
        self.entries.move_to_end(k)

    def pop(self, k: DataID) -> Optional[bool]:
        return self.entries.pop(k, None)


class PFCSCache:
    """The paper's cache system, end to end."""

    def __init__(
        self,
        capacities: Sequence[Tuple[str, int]] = (("L1", 64), ("L2", 512), ("L3", 4096)),
        prefetch_budget: int = 8,
        prefetch_level: str = "auto",   # "auto": largest (last) level
        victim_window: int = 8,
        factorizer: Optional[Factorizer] = None,
        enable_prefetch: bool = True,
        prefetch_trigger: str = "miss",   # "miss" | "always"
    ):
        self.factorizer = factorizer or Factorizer()
        self.registry = CompositeRegistry(self.factorizer)
        self.assigner = PrimeAssigner(
            HierarchicalPrimeAllocator(), self.registry)
        self.prefetcher = IntelligentPrefetcher(self.assigner, prefetch_budget)
        self.levels: List[_Level] = [_Level(n, c) for n, c in capacities]
        self._level_idx = {lv.name: i for i, lv in enumerate(self.levels)}
        if prefetch_level == "auto":
            prefetch_level = self.levels[-1].name
        self.prefetch_level = prefetch_level
        self.victim_window = victim_window
        self.enable_prefetch = enable_prefetch
        self.prefetch_trigger = prefetch_trigger

        # stats hooks read by the simulator
        self.prefetches_issued = 0
        self.prefetch_targets: List[Tuple[DataID, DataID]] = []  # (trigger, target)

    # ------------------------------------------------------------------ #
    # relationship establishment (schema/catalog time)                    #
    # ------------------------------------------------------------------ #

    def register_relationship(self, keys: Iterable[DataID], kind: str = "generic",
                              weight: float = 1.0,
                              hint_level: int = CacheLevel.L3) -> None:
        """Establish a relationship: assign primes (Algorithm 1) and store
        the composite (§3.1).  ``hint_level`` picks the prime pool for
        first-seen elements; catalog-time registrations default to the
        large L3 range — Algorithm 1 promotes elements to hotter (smaller)
        primes once their observed access frequency warrants it."""
        primes = [self._prime_for(k, hint_level) for k in keys]
        uniq = set(primes)
        if len(uniq) >= 2:
            self.registry.register(uniq, kind=kind, weight=weight)

    def _prime_for(self, k: DataID, hint_level: int) -> int:
        p = self.assigner.prime_of(k)
        if p is None:
            p = self.assigner.assign(k, hint_level)
        return p

    # ------------------------------------------------------------------ #
    # demand path (Listing 1 lookup())                                    #
    # ------------------------------------------------------------------ #

    def access(self, key: DataID) -> Tuple[bool, Optional[str], bool]:
        """Demand access.

        Returns ``(hit, level_name, was_prefetched)`` where
        ``was_prefetched`` flags a hit on an entry a prefetch brought in
        that had not been demanded yet (prefetch usefulness accounting).
        """
        self.assigner.tracker.record(key)
        hit_level: Optional[str] = None
        was_prefetched = False
        for i, lv in enumerate(self.levels):
            if key in lv:
                hit_level = lv.name
                was_prefetched = bool(lv.entries[key])
                lv.entries[key] = False  # demanded now
                if i == 0:
                    lv.touch(key)
                else:  # promote to L1, cascading demotions
                    lv.pop(key)
                    self._insert(0, key, prefetched=False)
                break
        hit = hit_level is not None
        if not hit:
            self._insert(0, key, prefetched=False)
        # Prefetch throttle: 'miss' issues relationship prefetch only on
        # demand misses (standard prefetcher discipline — hits mean the
        # working set is already resident; re-prefetching on every hit
        # floods the backing store with soon-evicted lines).  'always' is
        # the paper's literal §4.2 wording; Table 1 reports 'miss'.
        if self.enable_prefetch and (
                self.prefetch_trigger == "always" or not hit
                or was_prefetched):
            self._prefetch_related(key)
        return hit, hit_level, was_prefetched

    # ------------------------------------------------------------------ #

    def _insert(self, level_idx: int, key: DataID, prefetched: bool) -> None:
        """Insert into level, demoting cascade victims down the hierarchy."""
        if level_idx >= len(self.levels):
            return  # fell out of the hierarchy
        lv = self.levels[level_idx]
        if key in lv:
            lv.touch(key)
            lv.entries[key] = lv.entries[key] and prefetched
            return
        lv.add(key, prefetched)
        while len(lv) > lv.capacity:
            victim, was_pf = self._select_victim(lv)
            self._insert(level_idx + 1, victim, was_pf)

    def _select_victim(self, lv: _Level) -> Tuple[DataID, bool]:
        """Relationship-aware replacement: among the ``victim_window``
        least-recent entries, evict the one with the lowest live
        relationship degree (ties -> older).  Pure LRU when window=1."""
        it = iter(lv.entries.items())
        window = []
        for _ in range(min(self.victim_window, len(lv.entries))):
            window.append(next(it))
        best_key, best_pf, best_deg = None, False, None
        for k, pf in window:
            p = self.assigner.prime_of(k)
            deg = self.registry.degree(p) if p is not None else 0
            if best_deg is None or deg < best_deg:
                best_key, best_pf, best_deg = k, pf, deg
        lv.pop(best_key)
        return best_key, best_pf

    def _prefetch_related(self, key: DataID) -> None:
        for dec in self.prefetcher.decide(key):
            if any(dec.target in lv for lv in self.levels):
                continue
            self.prefetches_issued += 1
            self.prefetch_targets.append((key, dec.target))
            self._insert(self._level_idx[self.prefetch_level], dec.target,
                         prefetched=True)

    # ------------------------------------------------------------------ #
    # introspection                                                       #
    # ------------------------------------------------------------------ #

    def resident_anywhere(self, key: DataID) -> bool:
        return any(key in lv for lv in self.levels)

    def level_of(self, key: DataID) -> Optional[str]:
        for lv in self.levels:
            if key in lv:
                return lv.name
        return None

    @property
    def factor_stats(self):
        return self.factorizer.stats
