"""Prime generation and per-cache-level prime pools (PFCS §3.2–3.3).

The paper assigns each cache level a prime *range* trading factorization
cost against relationship expressiveness:

    L1   : small primes 2..997          (sub-ns factor-out; precomputed tables)
    L2   : medium primes 1_009..99_991
    L3   : large primes 100_003..999_983
    MEM  : primes >= 1_000_003          (generated lazily, segmented sieve)

``PrimePool`` hands out primes in ascending order (small primes are the
scarce, valuable resource — Algorithm 1 routes hot data here) and supports
the paper's LRU *recycling* path: on exhaustion, ``RecycleLRUPrimes``
reclaims the primes of the least-recently-used data elements
(10% of the pool per the pseudocode).

Everything here is exact host-side integer math (numpy sieves); the
batched/TPU paths live in ``repro.kernels``.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "sieve_primes",
    "spf_table",
    "segmented_sieve",
    "is_prime",
    "CacheLevel",
    "LEVEL_PRIME_RANGES",
    "PrimePool",
    "HierarchicalPrimeAllocator",
]


# --------------------------------------------------------------------------
# Sieves
# --------------------------------------------------------------------------

def sieve_primes(limit: int) -> np.ndarray:
    """All primes <= limit (inclusive), via the sieve of Eratosthenes.

    Returns int64 array. O(limit log log limit); limit=10**7 takes ~0.1 s.
    """
    if limit < 2:
        return np.empty(0, dtype=np.int64)
    mask = np.ones(limit + 1, dtype=bool)
    mask[:2] = False
    for p in range(2, int(limit**0.5) + 1):
        if mask[p]:
            mask[p * p :: p] = False
    return np.nonzero(mask)[0].astype(np.int64)


def spf_table(limit: int) -> np.ndarray:
    """Smallest-prime-factor table for 0..limit.

    ``spf[n]`` is the smallest prime dividing n (spf[0]=spf[1]=0).  This is
    the paper's "precomputed factorization table" for composites <= 10**6
    (Algorithm 2, stage 0): repeated division by spf recovers the full
    factorization in O(log n).
    """
    spf = np.zeros(limit + 1, dtype=np.int64)
    if limit >= 2:
        # every even number's smallest factor is 2
        spf[2::2] = 2
        for p in range(3, int(limit**0.5) + 1, 2):
            if spf[p] == 0:  # p is prime
                sl = spf[p * p :: 2 * p]  # odd multiples only
                sl[sl == 0] = p
                spf[p * p :: 2 * p] = sl
        # remaining zeros (odd primes themselves)
        odd = np.arange(3, limit + 1, 2)
        rem = odd[spf[odd] == 0]
        spf[rem] = rem
    return spf


def segmented_sieve(lo: int, hi: int, base_primes: Optional[np.ndarray] = None) -> np.ndarray:
    """Primes in [lo, hi) via a segmented sieve (lazy MEM-level extension)."""
    if hi <= lo:
        return np.empty(0, dtype=np.int64)
    if base_primes is None:
        base_primes = sieve_primes(int(hi**0.5) + 1)
    mask = np.ones(hi - lo, dtype=bool)
    if lo == 0:
        mask[: min(2, hi - lo)] = False
    elif lo == 1:
        mask[0] = False
    for p in base_primes:
        p = int(p)
        if p * p >= hi:
            break
        start = max(p * p, ((lo + p - 1) // p) * p)
        mask[start - lo :: p] = False
        if lo <= p < hi:  # the prime itself stays prime
            mask[p - lo] = True
    return (np.nonzero(mask)[0] + lo).astype(np.int64)


_SMALL_PRIMES_FOR_MR = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)


def is_prime(n: int) -> bool:
    """Deterministic Miller–Rabin, exact for all n < 3.3 * 10**24."""
    if n < 2:
        return False
    for p in _SMALL_PRIMES_FOR_MR:
        if n % p == 0:
            return n == p
    d, s = n - 1, 0
    while d % 2 == 0:
        d //= 2
        s += 1
    for a in _SMALL_PRIMES_FOR_MR:
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(s - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


# --------------------------------------------------------------------------
# Cache levels and pools
# --------------------------------------------------------------------------

class CacheLevel:
    """Symbolic cache-level ids, ordered hot -> cold (paper Fig. 1)."""

    L1 = 0
    L2 = 1
    L3 = 2
    MEM = 3

    ALL = (L1, L2, L3, MEM)
    NAMES = {L1: "L1", L2: "L2", L3: "L3", MEM: "MEM"}


# Paper §3.2 prime ranges per level. MEM is open-ended (lazy segments).
LEVEL_PRIME_RANGES: Dict[int, Tuple[int, Optional[int]]] = {
    CacheLevel.L1: (2, 997),
    CacheLevel.L2: (1_009, 99_991),
    CacheLevel.L3: (100_003, 999_983),
    CacheLevel.MEM: (1_000_003, None),
}


@dataclass
class PrimePool:
    """A pool of primes for one cache level (paper Algorithm 1, lines 7-11).

    Primes are allocated ascending (cheapest factorization first).  Freed
    primes return to a free-list and are re-used before fresh ones.  The
    pool can be lazily extended (MEM level) with a segmented sieve.
    """

    level: int
    lo: int
    hi: Optional[int]  # None => unbounded (lazy extension)
    initial_capacity: int = 4096

    _primes: List[int] = field(default_factory=list, repr=False)
    _next_idx: int = 0
    _free: List[int] = field(default_factory=list, repr=False)
    _allocated: set = field(default_factory=set, repr=False)
    _lazy_cursor: int = 0  # next sieve segment start (MEM level)

    def __post_init__(self) -> None:
        if self.hi is not None:
            self._primes = [int(p) for p in segmented_sieve(self.lo, self.hi + 1)]
        else:
            self._lazy_cursor = self.lo
            self._extend(self.initial_capacity)

    # -- internals ---------------------------------------------------------
    def _extend(self, at_least: int) -> None:
        """Lazily sieve more primes (MEM level only)."""
        if self.hi is not None:
            return
        got = 0
        seg = 1 << 16
        while got < at_least:
            new = segmented_sieve(self._lazy_cursor, self._lazy_cursor + seg)
            self._primes.extend(int(p) for p in new)
            got += len(new)
            self._lazy_cursor += seg
            seg = min(seg * 2, 1 << 22)

    # -- public API ---------------------------------------------------------
    @property
    def size(self) -> int:
        return len(self._primes)

    @property
    def n_allocated(self) -> int:
        return len(self._allocated)

    @property
    def n_available(self) -> int:
        avail = len(self._free) + (len(self._primes) - self._next_idx)
        return avail if self.hi is not None else int(1e18)

    def allocate(self) -> Optional[int]:
        """Next free prime, ascending; ``None`` when a bounded pool is dry."""
        if self._free:
            # smallest freed prime first — keeps hot-range density high
            p = min(self._free)
            self._free.remove(p)
            self._allocated.add(p)
            return p
        if self._next_idx >= len(self._primes):
            if self.hi is None:
                self._extend(self.initial_capacity)
            else:
                return None
        p = self._primes[self._next_idx]
        self._next_idx += 1
        self._allocated.add(p)
        return p

    def allocate_many(self, n: int) -> List[int]:
        """Batched :meth:`allocate`: the primes that ``n`` successive
        ``allocate()`` calls would return, in the same order (bounded
        pools return fewer when dry), with the same final allocation
        state.  The free-list is consumed smallest-first exactly as the
        scalar path does, then fresh primes come off the ascending
        cursor in one slice — this is the streamed-build fast path for
        million-element registries (``benchmarks.cases.case_scale``).
        """
        if n <= 0:
            return []
        out: List[int] = []
        if self._free:
            take = sorted(self._free)[:n]
            if len(take) == len(self._free):
                self._free.clear()
            else:
                for p in take:
                    self._free.remove(p)
            out.extend(take)
        want = n - len(out)
        if want > 0:
            if self.hi is None and len(self._primes) - self._next_idx < want:
                self._extend(want - (len(self._primes) - self._next_idx))
            fresh = self._primes[self._next_idx : self._next_idx + want]
            self._next_idx += len(fresh)
            out.extend(fresh)
        self._allocated.update(out)
        return out

    def free(self, p: int) -> None:
        """Return ``p`` to the free-list.  Double-frees and *foreign*
        primes (out of this pool's value range, or never allocated from
        it — e.g. another tenant namespace's prime) are no-ops: the
        ``_allocated`` guard is what keeps a double-free from planting
        the same prime on the free-list twice and handing it to two
        data elements (pinned in tests/test_pfcs_core.py)."""
        if not self.contains_range(p):
            return
        if p in self._allocated:
            self._allocated.remove(p)
            self._free.append(p)

    def contains_range(self, p: int) -> bool:
        return p >= self.lo and (self.hi is None or p <= self.hi)


class HierarchicalPrimeAllocator:
    """All four level pools behind one façade (paper Fig. 1)."""

    def __init__(self, ranges: Optional[Dict[int, Tuple[int, Optional[int]]]] = None):
        ranges = ranges or LEVEL_PRIME_RANGES
        self.pools: Dict[int, PrimePool] = {
            lvl: PrimePool(level=lvl, lo=lo, hi=hi) for lvl, (lo, hi) in ranges.items()
        }

    def pool(self, level: int) -> PrimePool:
        return self.pools[level]

    def allocate(self, level: int) -> Optional[int]:
        return self.pools[level].allocate()

    def allocate_many(self, level: int, n: int) -> List[int]:
        return self.pools[level].allocate_many(n)

    def free(self, level: int, p: int) -> None:
        """Free ``p``, routed to the pool whose range actually contains
        it.  Trusting a wrong ``level`` used to leak the prime silently
        (the range guard in ``PrimePool.free`` made the mis-routed call
        a no-op, so the prime was never reusable again) — audited and
        pinned in tests/test_pfcs_core.py."""
        owner = self.level_of_prime(p)
        self.pools[owner if owner in self.pools else level].free(p)

    def level_of_prime(self, p: int) -> int:
        for lvl, pool in self.pools.items():
            if pool.contains_range(p):
                return lvl
        return CacheLevel.MEM
