"""Shared array-state layout conventions and helpers (DESIGN.md §4.1).

Every engine structure is one of two fixed-shape layouts:

**Slot arrays** (bounded structures: policy lists, cache levels, the
recency shadows).  A structure of capacity ``C`` is a pair/triple of
``(C,)`` (or ``(C+1,)`` where a one-slot overflow reserve is needed)
arrays::

    keys : int32, ``EMPTY`` (= -1) marks a free slot
    t    : int32 recency/insertion stamp; stale values in free slots are
           ignored (occupancy is defined by ``keys != EMPTY`` alone)

Free slots are initialized with distinct *negative* stamps so that
"replace the LRU slot" (``argmin`` over stamps) naturally fills empty
slots first — exactly an ``OrderedDict`` that evicts its front.  Real
stamps are >= 0 and strictly increase, so ordering ties cannot occur
between live entries.

**Per-key arrays** (unbounded structures: the LIRS stack, PFCS residency
index).  Shape ``(K,)`` over the trace's key universe; a value of -1
means "not present".  This trades O(K) memory for O(1) scatter/gather
per event, which is the right trade on an accelerator and is what makes
``vmap`` batching trivial.

Timestamps are int32 *micro-op* counters: each trace step consumes a
fixed number ``M`` of ticks (one per potential ordered mutation within
the step) so that multi-insert steps (PFCS demote cascades + prefetch
bursts) keep the exact within-level ordering of the scalar oracle's
``OrderedDict``s.  int32 bounds the engine to ``2**31 / M`` steps —
~134M accesses at PFCS's largest ``M`` of 16 — checked at build time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["EMPTY", "I32MAX", "occupied", "count", "masked_argmin",
           "first_empty", "tree_where", "init_stamps"]

EMPTY = -1                                  # free-slot key sentinel
I32MAX = jnp.iinfo(jnp.int32).max


def occupied(keys: jnp.ndarray) -> jnp.ndarray:
    """Boolean occupancy mask of a slot array."""
    return keys != EMPTY


def count(keys: jnp.ndarray) -> jnp.ndarray:
    """Number of live entries (int32)."""
    return jnp.sum(occupied(keys)).astype(jnp.int32)


def masked_argmin(values: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Index of the smallest ``values[i]`` with ``mask[i]``; ties and the
    all-masked case resolve to the lowest index (callers guard on
    emptiness where the oracle does)."""
    return jnp.argmin(jnp.where(mask, values, I32MAX))


def first_empty(keys: jnp.ndarray) -> jnp.ndarray:
    """Index of the first free slot (callers guarantee one exists)."""
    return jnp.argmax(keys == EMPTY)


def init_stamps(n: int) -> jnp.ndarray:
    """Distinct negative stamps so empties fill in slot order first."""
    return jnp.arange(-n, 0, dtype=jnp.int32)


def tree_where(pred, if_true, if_false):
    """Leafwise ``jnp.where`` over two identical pytrees (step gating for
    padded/ragged batch entries)."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(pred, a, b), if_true, if_false)
