"""Static discovery tables for the vectorized PFCS engine.

PFCS relationships are registered at schema/catalog time (the database
knows its FK constraints, the trainer its batch composition) and are
immutable while a trace replays.  Everything the oracle's
``IntelligentPrefetcher.decide`` computes per access is therefore a pure
function of the key, and collapses to three arrays:

    targets : (K, budget) int32 — weight-ranked prefetch targets, -1 pad
    truth   : (K, budget) bool  — target truly related (ground truth)
    degree  : (K,) int32        — live relationship degree (victim policy)

Two discovery backends build the SAME target table:

  * ``discover="host"``   — replays ``IntelligentPrefetcher.decide`` per
    distinct accessed key.  Charges the host factorizer's stage mix
    (table/cache/trial/rho) exactly as the scalar simulation would, so
    engine ``AccessStats.factor_ops`` match the oracle's.
  * ``discover="kernel"`` — bulk path through the Pallas kernels
    (:func:`repro.kernels.ops.divisibility_scan` for the §4.2 registry
    scan, :func:`repro.kernels.ops.factorize_batch` for Algorithm 2
    stage 1 decode).  This is the TPU registry-refresh deployment; the
    decoded factorizations seed the host factorization cache and the
    stage mix reflects the kernel doing the work (trial for each first
    decode, cache thereafter — the rho tail is subsumed by the kernel).

Both backends produce bit-identical target ORDER: candidates are
deduplicated in registry (composite-array) order and ranked by weight
with a stable sort — the exact iteration order of the oracle
(``tests/test_engine.py::test_kernel_and_host_tables_agree``).
"""

from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from ..pfcs_cache import PFCSCache
from ..traces import Trace

__all__ = ["PFCSTables", "pfcs_tables", "related_bulk", "make_pfcs_cache",
           "successor_table"]


class PFCSTables(NamedTuple):
    """Precomputed engine inputs for one (trace, PFCS config) pair."""

    targets: np.ndarray          # (K, budget) int32, -1 padded
    truth: np.ndarray            # (K, budget) bool
    degree: np.ndarray           # (K,) int32
    factor_ops: Dict[str, int]   # stage -> op count (latency model input)
    cache: PFCSCache             # the registered host cache (introspection)


def make_pfcs_cache(trace: Trace,
                    capacities: Sequence[Tuple[str, int]],
                    prefetch_budget: int = 4,
                    victim_window: int = 8,
                    enable_prefetch: bool = True,
                    prefetch_trigger: str = "miss") -> PFCSCache:
    """Host cache with the trace's relationships registered — the same
    schema-time setup ``simulate_pfcs`` performs (prime assignment order
    and therefore every composite is identical)."""
    cache = PFCSCache(capacities, prefetch_budget=prefetch_budget,
                      enable_prefetch=enable_prefetch,
                      victim_window=victim_window,
                      prefetch_trigger=prefetch_trigger)
    for grp in trace.relationships:
        cache.register_relationship(grp, kind=trace.meta.get("kind", "generic"))
    return cache


def related_bulk(cache: PFCSCache, keys: Sequence[int],
                 chunk: int = 1024) -> Dict[int, List[Tuple[int, float]]]:
    """Bulk relationship discovery through the Pallas kernels.

    For every key with an assigned prime: divisibility-scan the live
    composite registry (§4.2), decode each matching composite with the
    batched trial-division kernel, and return the weight-ranked related
    elements — the device twin of
    ``IntelligentPrefetcher.related_elements``, with identical ordering.
    """
    from repro.kernels.ops import divisibility_scan, factorize_batch

    registry = cache.registry
    assigner = cache.assigner
    arr = registry.composites_array()
    keyed = [(int(k), p) for k in keys
             if (p := assigner.prime_of(int(k))) is not None]
    if arr.size == 0 or not keyed:
        return {}

    # kernel pass 1: registry divisibility scan, chunked over query primes
    primes = np.asarray([p for _, p in keyed], dtype=np.int64)
    cand: List[np.ndarray] = []
    for lo in range(0, len(primes), chunk):
        cand.extend(divisibility_scan(arr, primes[lo:lo + chunk]))

    # kernel pass 2: decode every candidate composite once
    needed = sorted({int(i) for idxs in cand for i in idxs})
    factors_of: Dict[int, set] = {}
    if needed:
        comps = arr[np.asarray(needed)]
        pool = registry.primes_array()
        facs, residual = factorize_batch(comps, pool)
        assert np.all(residual == 1), "registry composite escaped its pool"
        stats = cache.factorizer.stats
        for c, fs in zip(comps, facs):
            factors_of[int(c)] = set(fs)
            cache.factorizer.cache.put(int(c), tuple(sorted(fs)))
        # stage accounting: the kernel's trial division decodes each
        # composite once; every further (prime, composite) incidence is a
        # factorization-cache hit (DESIGN.md §3)
        incidences = sum(len(idxs) for idxs in cand)
        stats.trial_division += len(needed)
        stats.cache_hits += incidences - len(needed)
        stats.total += incidences

    out: Dict[int, List[Tuple[int, float]]] = {}
    for (k, p), idxs in zip(keyed, cand):
        ranked: Dict[int, float] = {}
        seen = set()
        for i in idxs:
            c = int(arr[int(i)])
            assert p in factors_of[c], "divisibility hit must contain p"
            rel = registry.relationship_of_composite(c)
            if rel is None or rel.rel_id in seen:
                continue
            seen.add(rel.rel_id)
            for q in rel.primes:     # same frozenset order as the oracle
                if q == p:
                    continue
                tgt = assigner.data_of(q)
                if tgt is not None:
                    ranked[tgt] = max(ranked.get(tgt, 0.0), rel.weight)
        out[k] = sorted(ranked.items(), key=lambda kv: -kv[1])
    return out


def successor_table(registry, assigner, data_ids: Sequence[int],
                    discover: str = "host",
                    chunk: int = 1024) -> Dict[int, List[int]]:
    """Bulk successor-discovery table for chain-style registries.

    The serving paged-KV cache's prefetch loop
    (``repro.serving.kv_cache.PagedKVCache._prefetch_successors``)
    walks, per touched page, every relationship containing the page's
    prime and collects the *other* members as prefetch candidates.  The
    candidate ORDER is the oracle's exact iteration order — composite
    registry (registration) order, deduplicated by relationship, then
    ``rel.primes`` iteration — and the list is deliberately NOT
    deduplicated by target: the dynamic residency check at touch time
    is what skips repeats, so repeats must survive into the table.

    Two backends build the SAME table:

      * ``discover="host"``   — replays ``registry.containing`` per id
        (charging the host factorizer exactly as the scalar cache does);
      * ``discover="kernel"`` — one bulk pass through the Pallas
        ``divisibility_scan`` / ``factorize_batch`` kernels, the TPU
        registry-refresh deployment (mirrors :func:`related_bulk`).

    Returns ``{data_id: [successor data_id, ...]}`` for every id that
    has an assigned prime (ids without one discover nothing — exactly
    the oracle's early return).
    """
    keyed = [(int(d), p) for d in data_ids
             if (p := assigner.prime_of(int(d))) is not None]
    if discover == "host":
        out: Dict[int, List[int]] = {}
        for d, p in keyed:
            row: List[int] = []
            for rel in registry.containing(p):
                for q in rel.primes:
                    if q == p:
                        continue
                    succ = assigner.data_of(q)
                    if succ is not None:
                        row.append(succ)
            out[d] = row
        return out
    if discover != "kernel":
        raise ValueError(f"discover must be 'host' or 'kernel', "
                         f"got {discover!r}")

    from repro.kernels.ops import (divisibility_scan,
                                   divisibility_scan_limbs, factorize_batch,
                                   factorize_batch_exact)

    wide = getattr(registry, "wide", False)
    arr = registry.composites_view() if wide else registry.composites_array()
    if arr.size == 0 or not keyed:
        return {d: [] for d, _ in keyed}

    # kernel pass 1: registry divisibility scan, chunked over query primes
    # (wide registries route through the multi-limb kernels — same mask
    # semantics, DESIGN.md §11)
    primes = np.asarray([p for _, p in keyed], dtype=np.int64)
    scan_input = registry.limbs_array() if wide else arr
    scan = divisibility_scan_limbs if wide else divisibility_scan
    cand: List[np.ndarray] = []
    for lo in range(0, len(primes), chunk):
        cand.extend(scan(scan_input, primes[lo:lo + chunk]))

    # kernel pass 2: decode every candidate composite once (Theorem 1
    # check: the decoded factors must contain the query prime)
    needed = sorted({int(i) for idxs in cand for i in idxs})
    factors_of: Dict[int, set] = {}
    if needed:
        comps = arr[np.asarray(needed)]
        facs, residual = factorize_batch_exact(comps, registry.primes_array())
        assert all(int(r) == 1 for r in residual), \
            "registry composite escaped its pool"
        for c, fs in zip(comps, facs):
            factors_of[int(c)] = set(fs)

    out = {}
    for (d, p), idxs in zip(keyed, cand):
        row = []
        seen: set = set()
        for i in idxs:                        # ascending == registry order
            c = int(arr[int(i)])
            assert p in factors_of[c], "divisibility hit must contain p"
            rel = registry.relationship_of_composite(c)
            if rel is None or rel.rel_id in seen:
                continue
            seen.add(rel.rel_id)
            for q in rel.primes:              # oracle's frozenset order
                if q == p:
                    continue
                succ = assigner.data_of(q)
                if succ is not None:
                    row.append(succ)
        out[d] = row
    return out


def pfcs_tables(trace: Trace,
                capacities: Sequence[Tuple[str, int]],
                prefetch_budget: int = 4,
                victim_window: int = 8,
                enable_prefetch: bool = True,
                prefetch_trigger: str = "miss",
                discover: str = "host",
                n_keys: Optional[int] = None) -> PFCSTables:
    """Build the engine's discovery tables for one trace."""
    cache = make_pfcs_cache(trace, capacities, prefetch_budget,
                            victim_window, enable_prefetch, prefetch_trigger)
    K = int(n_keys if n_keys is not None else
            max(trace.n_keys, int(trace.accesses.max(initial=0)) + 1))
    B = max(1, int(prefetch_budget))
    targets = np.full((K, B), -1, dtype=np.int32)
    truth = np.zeros((K, B), dtype=bool)
    related = trace.related_map()

    f = cache.factorizer.stats
    base = (f.table_hits, f.cache_hits, f.trial_division, f.pollard_rho)

    if enable_prefetch:
        # first-occurrence order: the host factorizer's cofactor cache is
        # order-sensitive when composites share cofactors, and the scalar
        # oracle pays each key's discovery cost at its FIRST access
        acc = np.asarray(trace.accesses)
        _, first = np.unique(acc, return_index=True)
        distinct = [int(k) for k in acc[np.sort(first)]]
        if discover == "kernel":
            ranked_map = related_bulk(cache, distinct)
            per_key = {k: [t for t, _ in ranked_map.get(k, [])][:B]
                       for k in distinct}
        elif discover == "host":
            per_key = {k: [d.target for d in cache.prefetcher.decide(k)][:B]
                       for k in distinct}
        else:
            raise ValueError(f"discover must be 'host' or 'kernel', "
                             f"got {discover!r}")
        for k, tgts in per_key.items():
            rel_k = related.get(k, ())
            for j, tgt in enumerate(tgts):
                targets[k, j] = int(tgt)
                truth[k, j] = int(tgt) in rel_k

    degree = np.zeros((K,), dtype=np.int32)
    for k in range(K):
        p = cache.assigner.prime_of(k)
        if p is not None:
            degree[k] = cache.registry.degree(p)

    f = cache.factorizer.stats
    factor_ops = {
        "table": f.table_hits - base[0],
        "cache": f.cache_hits - base[1],
        "trial": f.trial_division - base[2],
        "rho": f.pollard_rho - base[3],
    }
    return PFCSTables(targets, truth, degree, factor_ops, cache)
