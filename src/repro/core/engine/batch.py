"""Engine drivers: ``lax.scan`` over a trace, ``vmap`` over a batch.

One compiled function serves every trace of the same (length, key-space,
capacity-config) signature; builders are memoized on those static
parameters.  Batching stacks traces on a leading axis and ``vmap``s the
whole scan — per-trace PFCS tables ride along as batched inputs, and
shorter traces are padded with key ``-1`` (an exact no-op step), so
ragged batches lose nothing.

The drivers run under ``jax.enable_x64``: all state is explicitly int32
(DESIGN.md §3) except ARC's float64 adaptive target, which must match
the CPython float arithmetic of the oracle bit-for-bit.

``AccessStats`` assembly mirrors the scalar simulators field-for-field,
so callers (benchmarks, Table 1 derivations) cannot tell which engine
produced a result — except by wall clock.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..metrics import AccessStats
from ..traces import Trace
from .layout import tree_where
from .pfcs_vec import build_pfcs
from .policies_vec import POLICY_TICKS
from .tables import PFCSTables, pfcs_tables

__all__ = ["simulate_trace", "simulate_batch", "sweep", "VECTORIZED_SYSTEMS"]

#: systems the engine can simulate (the semantic baseline stays scalar —
#: its RNG noise is consumed in miss order, which is inherently serial)
VECTORIZED_SYSTEMS = ("lru", "fifo", "2q", "arc", "lirs", "pfcs")

_DEFAULT_LEVELS = (("L1", 64), ("L2", 512), ("L3", 4096))


# --------------------------------------------------------------------------- #
# compiled cores (memoized per static signature)                              #
# --------------------------------------------------------------------------- #

@functools.lru_cache(maxsize=None)
def _baseline_core(policy: str, caps: Tuple[Tuple[str, int], ...],
                   n_keys: int, length: int, batched: bool):
    import jax
    import jax.numpy as jnp

    from .hierarchy import build_hierarchy

    n_levels = len(caps)

    def run(accesses):
        state, step = build_hierarchy(policy, caps, n_keys)

        def body(carry, inp):
            s, hits, miss, demand = carry
            key, t = inp
            valid = key >= 0
            s2, (hit, tier) = step(s, jnp.maximum(key, 0),
                                   t * POLICY_TICKS)
            s2 = tree_where(valid, s2, s)
            hit = hit & valid
            onehot = (jnp.arange(n_levels + 1, dtype=jnp.int32) == tier) & hit
            return (s2, hits + onehot, miss + (valid & ~hit),
                    demand + valid), ()

        init = (state,
                jnp.zeros((n_levels + 1,), jnp.int32),
                jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
        ts = jnp.arange(length, dtype=jnp.int32)
        (_, hits, miss, demand), _ = jax.lax.scan(body, init, (accesses, ts))
        return {"hits": hits, "miss": miss, "demand": demand}

    return jax.jit(jax.vmap(run) if batched else run)


@functools.lru_cache(maxsize=None)
def _pfcs_core(caps: Tuple[Tuple[str, int], ...], n_keys: int,
               budget: int, window: int, enable_pf: bool, always: bool,
               length: int, batched: bool):
    import jax
    import jax.numpy as jnp

    def run(accesses, tgt, truth, deg):
        state, micro, step = build_pfcs(caps, n_keys, budget, window,
                                        enable_pf, always)

        def body(s, inp):
            key, t = inp
            return step(s, key, t * micro, tgt, truth, deg), ()

        ts = jnp.arange(length, dtype=jnp.int32)
        s, _ = jax.lax.scan(body, state, (accesses, ts))
        return s["stats"]

    return jax.jit(jax.vmap(run) if batched else run)


# --------------------------------------------------------------------------- #
# AccessStats assembly                                                        #
# --------------------------------------------------------------------------- #

def _baseline_stats(policy: str, caps, out, i: Optional[int]) -> AccessStats:
    pick = (lambda x: np.asarray(x)[i]) if i is not None else np.asarray
    hits = pick(out["hits"])
    st = AccessStats(name=policy.upper())
    st.hits_per_level = {name: int(h) for (name, _), h in zip(caps, hits)}
    st.hits_per_level["MEM"] = int(hits[len(caps)])
    st.misses = int(pick(out["miss"]))
    st.demand_accesses = int(pick(out["demand"]))
    return st


def _pfcs_stats(caps, out, tables: PFCSTables, i: Optional[int]) -> AccessStats:
    pick = (lambda x: np.asarray(x)[i]) if i is not None else np.asarray
    hits = pick(out["hits"])
    st = AccessStats(name="PFCS")
    st.hits_per_level = {name: int(h) for (name, _), h in zip(caps, hits)}
    st.misses = int(pick(out["miss"]))
    st.demand_accesses = int(pick(out["demand"]))
    st.prefetches_issued = int(pick(out["issued"]))
    st.prefetches_used = int(pick(out["used"]))
    st.prefetches_true = int(pick(out["true"]))
    st.extra_backing_fetches = st.prefetches_issued
    st.factor_ops = dict(tables.factor_ops)
    return st


# --------------------------------------------------------------------------- #
# public drivers                                                              #
# --------------------------------------------------------------------------- #

def _key_space(traces: Sequence[Trace]) -> int:
    return max(max(tr.n_keys, int(tr.accesses.max(initial=0)) + 1)
               for tr in traces)


def simulate_trace(trace: Trace, system: str,
                   capacities: Sequence[Tuple[str, int]] = _DEFAULT_LEVELS,
                   *, prefetch_budget: int = 4, victim_window: int = 8,
                   enable_prefetch: bool = True,
                   prefetch_trigger: str = "miss",
                   discover: str = "host",
                   tables: Optional[PFCSTables] = None) -> AccessStats:
    """Simulate ONE trace on the vectorized engine -> AccessStats.

    Bit-identical to ``simulate_baseline(system, trace, capacities)`` /
    ``simulate_pfcs(trace, capacities, ...)`` on every counter the
    scalar oracles produce (see tests/test_engine.py).
    """
    return simulate_batch([trace], system, capacities,
                          prefetch_budget=prefetch_budget,
                          victim_window=victim_window,
                          enable_prefetch=enable_prefetch,
                          prefetch_trigger=prefetch_trigger,
                          discover=discover,
                          tables=[tables] if tables is not None else None)[0]


def simulate_batch(traces: Sequence[Trace], system: str,
                   capacities: Sequence[Tuple[str, int]] = _DEFAULT_LEVELS,
                   *, prefetch_budget: int = 4, victim_window: int = 8,
                   enable_prefetch: bool = True,
                   prefetch_trigger: str = "miss",
                   discover: str = "host",
                   tables: Optional[Sequence[PFCSTables]] = None,
                   ) -> List[AccessStats]:
    """Simulate a batch of traces in ONE ``vmap``-batched scan.

    Traces may have ragged lengths (padded with no-op steps) and ragged
    key spaces (state sized to the largest).  Returns one
    ``AccessStats`` per trace, in order.
    """
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    system = system.lower()
    if system not in VECTORIZED_SYSTEMS:
        raise ValueError(f"engine cannot simulate {system!r}; "
                         f"supported: {VECTORIZED_SYSTEMS}")
    caps = tuple((str(n), int(c)) for n, c in capacities)
    n = len(traces)
    length = max(tr.length for tr in traces)
    n_keys = _key_space(traces)
    # int32 stamp bound (layout.py): each access consumes a fixed stride
    # of micro-op ticks; past 2**31 stamps would wrap into the negative
    # init-stamp range and silently corrupt recency order — fail instead
    ticks = (len(caps) + max(1, int(prefetch_budget))
             if system == "pfcs" else POLICY_TICKS)
    if length * ticks >= 2**31:
        raise ValueError(
            f"trace length {length} x {ticks} stamp ticks/access exceeds "
            f"the engine's int32 stamp space ({2**31 - 1}); split the "
            f"trace into <= {(2**31 - 1) // ticks}-access segments")
    acc = np.full((n, length), -1, dtype=np.int32)
    for i, tr in enumerate(traces):
        acc[i, :tr.length] = np.asarray(tr.accesses, dtype=np.int32)
    batched = n > 1

    with enable_x64(True):
        if system == "pfcs":
            budget_cols = max(1, int(prefetch_budget))
            if tables is not None:
                # caller-built tables define the key universe (targets may
                # index keys the residency array must be able to hold)
                sizes = {tb.targets.shape[0] for tb in tables}
                if len(sizes) > 1:
                    raise ValueError(f"tables disagree on key-space size: "
                                     f"{sorted(sizes)}")
                if max(sizes) < n_keys:
                    raise ValueError(
                        f"tables cover {max(sizes)} keys but the traces "
                        f"reach key {n_keys - 1}; rebuild with n_keys>="
                        f"{n_keys}")
                n_keys = max(sizes)
                if any(tb.targets.shape[1] != budget_cols for tb in tables):
                    raise ValueError(
                        f"tables built for budget "
                        f"{tables[0].targets.shape[1]}, run requested "
                        f"{budget_cols}; rebuild with matching "
                        f"prefetch_budget")
            if tables is None:
                tables = [pfcs_tables(tr, caps, prefetch_budget,
                                      victim_window, enable_prefetch,
                                      prefetch_trigger, discover,
                                      n_keys=n_keys)
                          for tr in traces]
            budget = max(1, int(prefetch_budget))
            tgt = np.stack([tb.targets for tb in tables])
            truth = np.stack([tb.truth for tb in tables])
            deg = np.stack([tb.degree for tb in tables])
            if not batched:
                tgt, truth, deg = tgt[0], truth[0], deg[0]
            fn = _pfcs_core(caps, n_keys, budget, int(victim_window),
                            bool(enable_prefetch),
                            prefetch_trigger == "always", length, batched)
            out = fn(jnp.asarray(acc if batched else acc[0]),
                     jnp.asarray(tgt), jnp.asarray(truth), jnp.asarray(deg))
            return [_pfcs_stats(caps, out, tables[i],
                                i if batched else None) for i in range(n)]

        # only LIRS carries per-key state; every other policy's compiled
        # core is key-space independent — normalize the cache key so one
        # compile serves traces of any key universe
        pol_keys = n_keys if system == "lirs" else 0
        fn = _baseline_core(system, caps, pol_keys, length, batched)
        out = fn(jnp.asarray(acc if batched else acc[0]))
        return [_baseline_stats(system, caps, out, i if batched else None)
                for i in range(n)]


def sweep(traces: Sequence[Trace], systems: Sequence[str],
          capacity_configs: Sequence[Sequence[Tuple[str, int]]],
          **kw) -> Dict[Tuple[str, int], List[AccessStats]]:
    """Systems x capacity-configs x traces sweep.

    Returns ``{(system, config_index): [AccessStats per trace]}``.  Each
    (system, config) cell is one vmap-batched run over all traces —
    capacity configs compile separately (shapes differ), traces batch.
    """
    out: Dict[Tuple[str, int], List[AccessStats]] = {}
    for ci, caps in enumerate(capacity_configs):
        for system in systems:
            out[(system, ci)] = simulate_batch(traces, system, caps, **kw)
    return out
