"""Vectorized baseline hierarchy: policy residency + recency-shadow tiers.

Array twin of ``simulator._BaselineHierarchy`` (see its docstring for the
modelling rationale): residency is decided by ONE policy instance over
the summed level capacity, while tier *attribution* for the latency /
energy model uses policy-independent nested exact-LRU shadows of sizes
``c1 < c1+c2 < ... < Ctot``.

Because the nested shadows see the identical touch stream, the LRU sets
are nested, and a single slot array of size ``Ctot`` (the largest
shadow) represents all of them at once: a key is in shadow ``i`` iff its
*recency rank* — one plus the number of tracked keys touched more
recently — is ``<= cum_i``.  A resident key absent from every shadow is
charged the MEM tier, exactly like the oracle.

Per access, in oracle order:

    1. tier  := shadow rank of the key (BEFORE the touch)
    2. hit   := policy residency       (BEFORE the policy update)
    3. touch the shadow, step the policy

The step emits ``(hit, tier_idx)`` with ``tier_idx in [0, L]`` where
``L`` is the MEM bin.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp

from .layout import EMPTY, init_stamps, occupied
from .policies_vec import VEC_POLICIES

__all__ = ["build_hierarchy"]


def build_hierarchy(policy: str, capacities: Sequence[Tuple[str, int]],
                    n_keys: int):
    """Returns ``(state, step)`` for one baseline system.

    ``step(state, key, now) -> (state, (hit, tier_idx))``; ``now`` must
    advance by ``POLICY_TICKS`` per access (the shadow shares the
    policy's stamp space but writes a disjoint array, so one stamp per
    access is enough for both).
    """
    caps = [int(c) for _, c in capacities]
    cums = jnp.asarray(jnp.cumsum(jnp.asarray(caps, jnp.int32)), jnp.int32)
    total = int(sum(caps))
    n_levels = len(caps)

    pol_state, pol_step = VEC_POLICIES[policy](total, n_keys)
    state = {
        "pol": pol_state,
        "shk": jnp.full((total,), EMPTY, jnp.int32),
        "sht": init_stamps(total),
    }

    def step(s, key, now):
        shk, sht = s["shk"], s["sht"]
        match = shk == key
        in_shadow = jnp.any(match)
        t_key = jnp.max(jnp.where(match, sht, -jnp.iinfo(jnp.int32).max))
        rank = 1 + jnp.sum(occupied(shk) & (sht > t_key))
        tier = jnp.where(in_shadow, jnp.sum(rank > cums), n_levels)

        # shadow touch == LRU update over the largest shadow
        victim = jnp.argmin(sht)
        shk2 = jnp.where(in_shadow, shk, shk.at[victim].set(key))
        sht2 = jnp.where(match, now, sht)
        sht2 = jnp.where(in_shadow, sht2, sht2.at[victim].set(now))

        pol, hit = pol_step(s["pol"], key, now)
        return {"pol": pol, "shk": shk2, "sht": sht2}, (hit, tier)

    return state, step
