"""Vectorized batch trace-simulation engine (TPU-native simulator).

This package generalizes the jitted array-LRU fast path that used to be
the only ``lax.scan`` state machine in the simulator
(:func:`repro.core.simulator.fast_lru_hit_rate`) to the *full* system
zoo the paper compares — LRU / FIFO / 2Q / ARC / LIRS baselines and PFCS
itself — as fixed-shape array state carried through ``jax.lax.scan`` and
``jax.vmap``-batched across traces.

Design contract (see DESIGN.md §4 for the full state-layout spec):

  * **Bit-exact oracle parity.**  Every engine system reproduces the hit
    counts of its scalar oracle (``simulate_baseline`` /
    ``simulate_pfcs``) exactly — not approximately.  The scalar
    implementations stay in the tree as the cross-check oracle; the
    equivalence is enforced by ``tests/test_engine.py``.
  * **Fixed shapes.**  All per-step state is fixed-shape int32/bool
    arrays (slot arrays for bounded structures, per-key arrays for
    unbounded ones such as the LIRS recency stack), so one compiled
    ``scan`` serves any trace of the same length and any batch via
    ``vmap``.  Empty slots are ``key == -1``; recency is a monotonically
    increasing int32 micro-op counter, never a pointer structure.
  * **int32 hot path.**  Keys, timestamps, and degrees are int32
    (DESIGN.md §3); the only wider state is ARC's adaptive float64
    target ``p``, matching CPython float semantics of the oracle.
  * **Kernel-backed discovery.**  PFCS relationship discovery is a
    *precomputed table* (relationships are static during a trace — the
    registry is written at schema time), built either on the host or in
    bulk through the existing Pallas ``divisibility_scan`` /
    ``factorize_batch`` kernels (:mod:`repro.kernels.ops`).

Public entry points (documented with runnable examples in docs/api.md):

  * :func:`simulate_trace`  — one trace, one system -> AccessStats
  * :func:`simulate_batch`  — stacked traces, vmap-batched -> [AccessStats]
  * :func:`sweep`           — systems x capacity configs x traces
  * :func:`pfcs_tables`     — precomputed PFCS discovery tables
  * :func:`related_bulk`    — bulk Pallas-kernel relationship discovery
  * :func:`successor_table` — bulk chain-successor discovery (the serving
    paged-KV cache's table-refresh path, DESIGN.md §5)
  * :func:`sharded_successor_table` — the mesh-partitioned twin:
    per-shard Pallas scans under ``shard_map`` + the cross-shard gcd
    exchange, bit-identical rows (DESIGN.md §6), with
    :class:`PrimeSpacePartition` as the ownership rule
"""

from .batch import VECTORIZED_SYSTEMS, simulate_batch, simulate_trace, sweep
from .shard import (PrimeSpacePartition, shard_mesh,
                    sharded_successor_table)
from .tables import (PFCSTables, pfcs_tables, related_bulk,
                     successor_table)

__all__ = [
    "simulate_trace", "simulate_batch", "sweep", "VECTORIZED_SYSTEMS",
    "PFCSTables", "pfcs_tables", "related_bulk", "successor_table",
    "PrimeSpacePartition", "shard_mesh", "sharded_successor_table",
]
