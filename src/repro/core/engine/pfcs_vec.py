"""Vectorized PFCS hierarchy: array twin of ``pfcs_cache.PFCSCache``.

State layout (DESIGN.md §4.2)
-----------------------------
Each level of capacity ``C`` is four ``(C+1,)`` arrays — ``keys``,
``t`` (recency stamp), ``pf`` (brought in by prefetch, not yet
demanded), ``deg`` (live relationship degree, snapshotted at insert from
the static degree table).  The extra slot absorbs the oracle's
add-then-evict transient, so an eviction always runs over a *full*
``C+1``-slot window and ``top_k`` sizes stay static.

``where_of`` is a per-key int32 array mapping key -> resident level (or
-1): O(1) hit detection and the residency check that guards prefetch
admission, updated by scatter on every move.

Relationship discovery is *table-driven*: relationships are registered
at schema time and immutable during a trace, so the oracle's
``IntelligentPrefetcher.decide`` collapses to a static ``(K, budget)``
target table plus a ``(K,)`` degree table (built in ``tables.py``,
optionally through the Pallas divisibility/factorize kernels).  The
weight-ranked target ORDER is preserved in the table, which is what
makes the engine's prefetch admissions bit-identical to the oracle's.

Stamp discipline: each access consumes ``M = L (+ budget)`` micro-op
ticks — tick ``base+i`` for the level-``i`` insert of the demand /
demote cascade, tick ``base+L+j`` for the ``j``-th prefetch insert —
reproducing the oracle's ``OrderedDict`` within-level ordering exactly.

Victim selection replicates ``PFCSCache._select_victim``: among the
``min(victim_window, C+1)`` least-recent entries, evict the lowest
relationship degree, ties to the older entry (strict-``<`` scan order in
the oracle == lexicographic ``(deg, stamp)`` argmin here, since stamps
are unique).
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp

from .layout import EMPTY, I32MAX, count, first_empty, init_stamps, occupied

__all__ = ["build_pfcs"]


def _safe(idx):
    """Clamp a possibly-EMPTY key for gather/scatter; callers mask."""
    return jnp.maximum(idx, 0)


def _level_init(cap: int):
    n = cap + 1
    return {"keys": jnp.full((n,), EMPTY, jnp.int32),
            "t": init_stamps(n),
            "pf": jnp.zeros((n,), jnp.bool_),
            "deg": jnp.zeros((n,), jnp.int32)}


def _add(lv, k, tick, pf, dg, do):
    e = first_empty(lv["keys"])
    return {"keys": jnp.where(do, lv["keys"].at[e].set(k), lv["keys"]),
            "t": jnp.where(do, lv["t"].at[e].set(tick), lv["t"]),
            "pf": jnp.where(do, lv["pf"].at[e].set(pf), lv["pf"]),
            "deg": jnp.where(do, lv["deg"].at[e].set(dg), lv["deg"])}


def _evict(lv, cap: int, window: int, do):
    """Relationship-aware replacement over a full C+1-slot level.

    The ``w`` least-recent slots are peeled off with ``w`` chained
    masked argmins rather than ``lax.top_k`` — inside a CPU scan body
    top_k lowers to a full sort (~140x slower at w=8; measured in
    benchmarks/kernel_bench.py), while chained argmins are w cheap
    vector reductions and stay exact because stamps are unique.
    """
    w = min(window, cap + 1)
    wt = jnp.where(occupied(lv["keys"]), lv["t"], I32MAX)
    best = jnp.zeros((), jnp.int32)          # winning slot so far
    best_deg = jnp.full((), I32MAX, jnp.int32)
    cur = wt
    for _ in range(w):                       # oldest -> newest window scan
        i = jnp.argmin(cur)
        take = lv["deg"][i] < best_deg       # strict <: ties keep the older
        best = jnp.where(take, i, best)
        best_deg = jnp.where(take, lv["deg"][i], best_deg)
        cur = cur.at[i].set(I32MAX)
    v = best
    vk, vpf, vdeg = lv["keys"][v], lv["pf"][v], lv["deg"][v]
    lv = {**lv, "keys": jnp.where(do, lv["keys"].at[v].set(EMPTY),
                                  lv["keys"])}
    return lv, vk, vpf, vdeg


def build_pfcs(capacities: Sequence[Tuple[str, int]], n_keys: int,
               prefetch_budget: int, victim_window: int,
               enable_prefetch: bool, trigger_always: bool):
    """Returns ``(state, micro_ticks, step)``.

    ``step(state, key, base, tgt_tbl, truth_tbl, deg_tbl) -> state`` where
    ``base`` advances by ``micro_ticks`` per access; counters live inside
    ``state["stats"]``.  ``key < 0`` marks a padded (no-op) step, which
    is what makes ragged vmap batches exact.
    """
    caps = [int(c) for _, c in capacities]
    L = len(caps)
    budget = prefetch_budget if enable_prefetch else 0
    micro = L + budget

    state = {
        "levels": tuple(_level_init(c) for c in caps),
        "where": jnp.full((n_keys,), -1, jnp.int32),
        "stats": {"hits": jnp.zeros((L,), jnp.int32),
                  "miss": jnp.zeros((), jnp.int32),
                  "demand": jnp.zeros((), jnp.int32),
                  "issued": jnp.zeros((), jnp.int32),
                  "used": jnp.zeros((), jnp.int32),
                  "true": jnp.zeros((), jnp.int32)},
    }

    def step(s, key, base, tgt_tbl, truth_tbl, deg_tbl):
        levels = list(s["levels"])
        where_of = s["where"]
        st = s["stats"]
        valid = key >= 0
        k = _safe(key)

        lvl = where_of[k]
        hit = valid & (lvl >= 0)
        was_pf = jnp.zeros((), jnp.bool_)
        for i in range(L):
            m = levels[i]["keys"] == k
            was_pf = was_pf | (hit & (lvl == i) & jnp.any(m & levels[i]["pf"]))

        # L0 hit: touch in place, clear the prefetched flag
        hit0 = hit & (lvl == 0)
        m0 = (levels[0]["keys"] == k) & hit0
        levels[0] = {**levels[0],
                     "t": jnp.where(m0, base, levels[0]["t"]),
                     "pf": jnp.where(m0, False, levels[0]["pf"])}

        # deeper hit: remove, then re-insert at L0 through the cascade
        for i in range(1, L):
            m = (levels[i]["keys"] == k) & hit & (lvl == i)
            levels[i] = {**levels[i],
                         "keys": jnp.where(m, EMPTY, levels[i]["keys"])}

        # demand insert + demote cascade
        pend_k, pend_pf = k, jnp.zeros((), jnp.bool_)
        pend_deg = deg_tbl[k]
        pend_do = valid & ~hit0
        for i in range(L):
            levels[i] = _add(levels[i], pend_k, base + i, pend_pf, pend_deg,
                             pend_do)
            where_of = jnp.where(pend_do,
                                 where_of.at[_safe(pend_k)].set(i), where_of)
            over = pend_do & (count(levels[i]["keys"]) > caps[i])
            levels[i], vk, vpf, vdeg = _evict(levels[i], caps[i],
                                              victim_window, over)
            pend_k, pend_pf, pend_deg, pend_do = vk, vpf, vdeg, over
        where_of = jnp.where(pend_do,
                             where_of.at[_safe(pend_k)].set(-1), where_of)

        # deterministic relationship prefetch into the last level
        issued = jnp.zeros((), jnp.int32)
        true_cnt = jnp.zeros((), jnp.int32)
        if enable_prefetch:
            trigger = valid & (jnp.bool_(trigger_always) | ~hit | was_pf)
            tgts = tgt_tbl[k]
            truths = truth_tbl[k]
            last = L - 1
            for j in range(budget):
                tgt = tgts[j]
                resident = where_of[_safe(tgt)] >= 0
                do = trigger & (tgt >= 0) & ~resident
                issued = issued + do
                true_cnt = true_cnt + (do & truths[j])
                levels[last] = _add(levels[last], tgt, base + L + j,
                                    jnp.ones((), jnp.bool_),
                                    deg_tbl[_safe(tgt)], do)
                where_of = jnp.where(do, where_of.at[_safe(tgt)].set(last),
                                     where_of)
                over = do & (count(levels[last]["keys"]) > caps[last])
                levels[last], vk, _, _ = _evict(levels[last], caps[last],
                                                victim_window, over)
                where_of = jnp.where(over, where_of.at[_safe(vk)].set(-1),
                                     where_of)

        onehot = (jnp.arange(L, dtype=jnp.int32) == lvl) & hit
        stats = {"hits": st["hits"] + onehot,
                 "miss": st["miss"] + (valid & ~hit),
                 "demand": st["demand"] + valid,
                 "issued": st["issued"] + issued,
                 "used": st["used"] + (hit & was_pf),
                 "true": st["true"] + true_cnt}
        return {"levels": tuple(levels), "where": where_of, "stats": stats}

    return state, micro, step
