"""Mesh-sharded PFCS discovery: partitioned prime spaces + shard_map scans.

PR 1-2 vectorized the simulator and the serving cache on ONE device;
this module distributes the *PFCS state itself* — the prime space and
the composite registry — across a ``("data", "model")`` device mesh so
bulk relationship discovery scales with shard count (DESIGN.md §6).

**Prime-space partition.**  :class:`PrimeSpacePartition` carves every
cache level's prime range (``core.primes.LEVEL_PRIME_RANGES``) into
contiguous value blocks dealt round-robin to shards: each shard owns a
striped family of contiguous prime ranges.  Contiguity keeps each
block's factorization locality (neighbouring chain pages get
neighbouring primes under Algorithm 1's ascending allocation); striping
keeps ownership balanced even though allocation is ascending.  Ownership
is a pure O(1) function of the prime value — no directory, no
coordination — so every shard can classify any composite locally.

**Sharded registry classification.**  A relationship whose member
primes all fall in one shard's ranges is *shard-local*: its composite
chunks live only in that shard's registry slice and are scanned only
there.  A relationship straddling prime ranges (a chain edge whose two
page primes have different owners) is *cross-shard*: its chunks go to
the exchanged slice that every shard scans.  Classification preserves
the global registry (registration) order — the candidate-order contract
the serving cache's parity tests pin down.

**Per-shard bulk discovery under shard_map.**  Successor rows are
rebuilt per shard through the SAME Pallas kernels the single-device
path uses (``divisibility_mask_pallas`` for the §4.2 scan), mapped over
the mesh with ``shard_map``: every shard scans its own registry slice
against its own query primes.  Cross-shard relationships are resolved
by a **collective batched-gcd exchange**: each shard contributes its
slice of the cross-shard composites, ``lax.all_gather`` replicates them
along the mesh, and each shard computes ``gcd_pallas`` of its *query
chunk products* (its owned query primes packed into < 2**62 composites)
against every gathered composite.  A gcd > 1 decodes — exactly, by
unique factorization — to the member primes the shard owns, so no
per-query modulo scan ever crosses shard boundaries.

When the host exposes fewer devices than shards (the common laptop
case), the same math runs as a per-shard host loop over the identical
kernels — bit-identical tables, no mesh required.  CI exercises the
real ``shard_map`` path on a forced multi-device CPU mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=2``).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.profile import kernel_scope
from repro.sharding.stripes import BlockStripes

from ..composite import encode_relationship
from ..primes import CacheLevel, LEVEL_PRIME_RANGES

__all__ = ["PrimeSpacePartition", "shard_mesh", "sharded_successor_table",
           "ShardScanReport"]


class PrimeSpacePartition:
    """Deterministic owner function: prime value -> shard id.

    Each bounded level range ``(lo, hi)`` is split into contiguous value
    blocks of width ``min((hi - lo + 1) // (n_shards * stripes_per_shard),
    cap)``; block ``k`` belongs to shard ``k % n_shards``.  The unbounded
    MEM range uses the fixed cap width.  ``n_shards == 1`` degenerates to
    "shard 0 owns everything" (the single-device mesh case).

    The block machinery itself — contiguous value blocks, round-robin
    striping, per-level width caps, vectorized ownership — is the shared
    :class:`repro.sharding.stripes.BlockStripes` partitioner (the tenant
    namespace layer stripes the same prime space over tenants with it).
    """

    def __init__(self, n_shards: int, stripes_per_shard: int = 8):
        self.stripes = BlockStripes(n_shards, LEVEL_PRIME_RANGES,
                                    stripes_per_part=stripes_per_shard)
        self.n_shards = self.stripes.n_parts
        self.stripes_per_shard = self.stripes.stripes_per_part
        self._blocks: Dict[int, Tuple[int, int]] = self.stripes._blocks

    def _level_of(self, p: int) -> int:
        return self.stripes.level_of(p)

    def owner(self, p: int) -> int:
        """Shard owning prime ``p`` — pure function, O(1), no state."""
        return self.stripes.owner(p)

    def owners(self, primes: Sequence[int]) -> np.ndarray:
        return self.stripes.owners(primes)

    def classify(self, registry) -> Tuple[List[List[int]], List[int]]:
        """Split the live registry into per-shard-local and cross-shard
        composite *positions* (indices into ``registry.composites_array()``
        — global registration order, which both scan paths preserve).

        A relationship is local to shard ``s`` iff every member prime is
        owned by ``s``; otherwise every chunk of it is cross-shard.
        """
        arr = registry.composites_view()
        local: List[List[int]] = [[] for _ in range(self.n_shards)]
        cross: List[int] = []
        for pos in range(arr.size):
            rel = registry.relationship_of_composite(int(arr[pos]))
            if rel is None:                       # pragma: no cover - defensive
                continue
            owners = {self.owner(q) for q in rel.primes}
            if len(owners) == 1:
                local[owners.pop()].append(pos)
            else:
                cross.append(pos)
        return local, cross

    def describe(self) -> str:
        parts = [f"{CacheLevel.NAMES[lvl]}:block={w}"
                 for lvl, (_, w) in sorted(self._blocks.items())]
        return (f"PrimeSpacePartition(n_shards={self.n_shards}, "
                f"stripes={self.stripes_per_shard}, {', '.join(parts)})")


def shard_mesh(n_shards: int):
    """A ``("data", "model")`` mesh with ``data * model == n_shards`` over
    the locally visible devices, or ``None`` when the host does not expose
    enough devices (callers then use the bit-identical host loop).

    The model axis takes the largest divisor of ``n_shards`` that is
    <= sqrt(n_shards) — 1 shard -> (1, 1), 2 -> (2, 1), 4 -> (2, 2) —
    mirroring ``launch.mesh.make_production_mesh``'s square-ish layout.
    """
    import jax

    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    if len(jax.devices()) < n_shards:
        return None
    model = 1
    for m in range(int(n_shards ** 0.5), 0, -1):
        if n_shards % m == 0:
            model = m
            break
    return jax.make_mesh((n_shards // model, model), ("data", "model"))


def _pad_rows(rows: Sequence[np.ndarray], mult: int, fill: int,
              dtype=np.int64) -> np.ndarray:
    """Stack ragged 1-D arrays into (S, W), W bucketed to ``mult * 2**k``
    — power-of-two buckets bound the number of distinct compiled shapes
    as tables grow across refreshes."""
    need = max([r.shape[0] for r in rows] + [1])
    width = mult
    while width < need:
        width *= 2
    out = np.full((len(rows), width), fill, dtype=dtype)
    for i, r in enumerate(rows):
        out[i, :r.shape[0]] = r
    return out


@dataclass
class ShardScanReport:
    """Per-refresh work split (benchmark / introspection output)."""

    n_shards: int = 0
    used_shard_map: bool = False
    local_composites: List[int] = field(default_factory=list)
    cross_composites: int = 0
    queries_per_shard: List[int] = field(default_factory=list)
    gcd_pairs: int = 0


def _one_shard_scan(lc, qs, ck, gathered_cross, *, n_chunks: int,
                    interpret: bool):
    """One shard's kernel work: local divisibility mask + cross gcds."""
    import jax.numpy as jnp

    from repro.kernels.factorize import divisibility_mask_pallas
    from repro.kernels.gcd import gcd_pallas

    gcd_block = 256
    mask = divisibility_mask_pallas(lc, qs, interpret=interpret)
    # batched-gcd exchange: every query chunk x every cross composite
    x = gathered_cross.shape[0]
    a = jnp.repeat(ck, x)
    b = jnp.tile(gathered_cross, n_chunks)
    pad = (-a.shape[0]) % gcd_block
    a = jnp.concatenate([a, jnp.ones((pad,), a.dtype)])
    b = jnp.concatenate([b, jnp.ones((pad,), b.dtype)])
    g = gcd_pallas(a, b, block_n=gcd_block, interpret=interpret)
    return mask, g[:n_chunks * x].reshape(n_chunks, x)


@functools.lru_cache(maxsize=64)
def _shard_map_scan(mesh, shapes: Tuple[int, ...], interpret: bool):
    """Compiled shard_map scan, memoized per (mesh, bucketed shapes)."""
    import jax
    from jax.experimental.shard_map import shard_map

    from repro.sharding.partition import shard_stack_spec

    axes = tuple(mesh.axis_names)
    spec = shard_stack_spec(mesh)       # leading shard axis over data x model
    _, _, K, _ = shapes

    def body(lc, qs, ck, xc):
        gathered = jax.lax.all_gather(xc[0], axes, tiled=True)
        mask, g = _one_shard_scan(lc[0], qs[0], ck[0], gathered,
                                  n_chunks=K, interpret=interpret)
        return mask[None], g[None]

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(spec, spec, spec, spec),
                             out_specs=(spec, spec), check_rep=False))


def _scan_sharded(local_c: np.ndarray, queries: np.ndarray,
                  chunks: np.ndarray, cross_c: np.ndarray,
                  mesh) -> Tuple[np.ndarray, np.ndarray]:
    """The per-shard kernel work: local divisibility masks + cross gcds.

    Inputs are (S, *) padded stacks; returns ``(local_mask (S, C, Q),
    gcds (S, K, X))``.  With a mesh of exactly S devices the work runs
    under ``shard_map`` (one shard per device, cross composites
    replicated by ``lax.all_gather`` — the collective exchange);
    otherwise a host loop runs the identical kernels per shard.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    interpret = jax.default_backend() != "tpu"
    S, C = local_c.shape
    Q = queries.shape[1]
    K = chunks.shape[1]

    with enable_x64(True), kernel_scope("sharded_gcd_exchange", items=S * C):
        if mesh is not None and mesh.size == S:
            fn = _shard_map_scan(mesh, (C, Q, K, cross_c.shape[1]),
                                 interpret)
            mask, g = fn(jnp.asarray(local_c), jnp.asarray(queries),
                         jnp.asarray(chunks), jnp.asarray(cross_c))
        else:                           # host loop, same kernels, same math
            gathered = jnp.asarray(cross_c.reshape(-1))
            masks, gs = [], []
            for s in range(S):
                m, g = _one_shard_scan(jnp.asarray(local_c[s]),
                                       jnp.asarray(queries[s]),
                                       jnp.asarray(chunks[s]), gathered,
                                       n_chunks=K, interpret=interpret)
                masks.append(m)
                gs.append(g)
            mask, g = jnp.stack(masks), jnp.stack(gs)
        return np.asarray(mask), np.asarray(g)


# --------------------------------------------------------------------------- #
# multi-limb twin of the shard scan (wide registries, DESIGN.md §11)          #
# --------------------------------------------------------------------------- #

def _pad_limb_stack(rows: Sequence[np.ndarray], mult: int, L: int,
                    width: Optional[int] = None) -> np.ndarray:
    """Stack ragged (n_i, L) limb matrices into (S, W, L); pad rows encode
    composite value 1 (match nothing) and W is bucketed to ``mult * 2**k``
    like :func:`_pad_rows`."""
    need = max([r.shape[0] for r in rows] + [1])
    if width is None:
        width = mult
        while width < need:
            width *= 2
    out = np.zeros((len(rows), width, L), dtype=np.int64)
    out[:, :, 0] = 1
    for i, r in enumerate(rows):
        if r.shape[0]:
            out[i, :r.shape[0], :] = r
    return out


def _one_shard_scan_limbs(lc, qs, ck, pool, gathered_cross, *, n_chunks: int,
                          interpret: bool):
    """One shard's limb-kernel work: local divisibility mask + cross gcds.

    Same collective recipe as :func:`_one_shard_scan` with (.., L) limb
    rows instead of int64 words; the gcd pool is the shard's own
    (deduplicated, zero-padded) query primes — chunk products are
    products of exactly those primes, so the pool covers every possible
    common factor.
    """
    import jax.numpy as jnp

    from repro.kernels.factorize import divisibility_mask_limbs_pallas
    from repro.kernels.gcd import gcd_limbs_pallas

    gcd_block = 256
    mask = divisibility_mask_limbs_pallas(lc, qs, interpret=interpret)
    x, L = gathered_cross.shape
    a = jnp.repeat(ck, x, axis=0)                       # (K*X, L)
    b = jnp.tile(gathered_cross, (n_chunks, 1))
    pad = (-a.shape[0]) % gcd_block
    one = jnp.zeros((pad, L), a.dtype).at[:, 0].set(1)
    a = jnp.concatenate([a, one])
    b = jnp.concatenate([b, one])
    g = gcd_limbs_pallas(a, b, pool, block_n=gcd_block, interpret=interpret)
    return mask, g[:n_chunks * x].reshape(n_chunks, x, L)


@functools.lru_cache(maxsize=64)
def _shard_map_scan_limbs(mesh, shapes: Tuple[int, ...], interpret: bool):
    """Compiled wide shard_map scan, memoized per (mesh, bucketed shapes)."""
    import jax
    from jax.experimental.shard_map import shard_map

    from repro.sharding.partition import shard_stack_spec

    axes = tuple(mesh.axis_names)
    spec = shard_stack_spec(mesh)
    _, _, K, _, _ = shapes

    def body(lc, qs, ck, pool, xc):
        gathered = jax.lax.all_gather(xc[0], axes, tiled=True)
        mask, g = _one_shard_scan_limbs(lc[0], qs[0], ck[0], pool[0],
                                        gathered, n_chunks=K,
                                        interpret=interpret)
        return mask[None], g[None]

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(spec, spec, spec, spec, spec),
                             out_specs=(spec, spec), check_rep=False))


def _scan_sharded_limbs(local_c: np.ndarray, queries: np.ndarray,
                        chunks: np.ndarray, pools: np.ndarray,
                        cross_c: np.ndarray,
                        mesh) -> Tuple[np.ndarray, np.ndarray]:
    """Wide twin of :func:`_scan_sharded`: (S, C, L) local limb stacks,
    (S, K, L) query-chunk limbs, (S, X, L) cross slices; returns
    ``(local_mask (S, C, Q), gcd limbs (S, K, X, L))``."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    interpret = jax.default_backend() != "tpu"
    S, C, L = local_c.shape
    Q = queries.shape[1]
    K = chunks.shape[1]

    with enable_x64(True), kernel_scope("sharded_gcd_exchange_limbs",
                                        items=S * C):
        if mesh is not None and mesh.size == S:
            fn = _shard_map_scan_limbs(
                mesh, (C, Q, K, pools.shape[1], cross_c.shape[1]), interpret)
            mask, g = fn(jnp.asarray(local_c), jnp.asarray(queries),
                         jnp.asarray(chunks), jnp.asarray(pools),
                         jnp.asarray(cross_c))
        else:                           # host loop, same kernels, same math
            gathered = jnp.asarray(cross_c.reshape(-1, L))
            masks, gs = [], []
            for s in range(S):
                m, g = _one_shard_scan_limbs(
                    jnp.asarray(local_c[s]), jnp.asarray(queries[s]),
                    jnp.asarray(chunks[s]), jnp.asarray(pools[s]), gathered,
                    n_chunks=K, interpret=interpret)
                masks.append(m)
                gs.append(g)
            mask, g = jnp.stack(masks), jnp.stack(gs)
        return np.asarray(mask), np.asarray(g)


def sharded_successor_table(registry, assigner, data_ids: Sequence[int],
                            partition: PrimeSpacePartition,
                            mesh=None,
                            report: Optional[ShardScanReport] = None,
                            precomputed: Optional[Tuple[List[List[int]],
                                                        List[int]]] = None,
                            ) -> Dict[int, List[int]]:
    """Mesh-partitioned twin of :func:`repro.core.engine.successor_table`.

    Produces BIT-IDENTICAL rows (same candidates, same order — global
    registry order, deduplicated by relationship, expanded in
    ``rel.primes`` order) while splitting the scan work by prime
    ownership: each shard's Pallas divisibility scan touches only its
    local registry slice, and only cross-shard relationships ride the
    collective gcd exchange.

    ``precomputed`` optionally supplies the ``(local_pos, cross_pos)``
    registry split (e.g. the maintained
    :class:`repro.sharding.reshard.ShardSlices` index) instead of the
    O(registry) :meth:`PrimeSpacePartition.classify` walk.  Any split
    that routes each position to a shard owning one of its chunk's
    primes yields identical rows — a prime's hits can only come from the
    chunk containing it.
    """
    from repro.kernels.ops import factorize_batch_exact

    from ..composite import limbs_to_int, pack_limbs

    S = partition.n_shards
    wide = getattr(registry, "wide", False)
    keyed = [(int(d), p) for d in data_ids
             if (p := assigner.prime_of(int(d))) is not None]
    arr = registry.composites_view()
    if arr.size == 0 or not keyed:
        return {d: [] for d, _ in keyed}

    # ---- partition state: registry slices and query routing ------------- #
    if precomputed is not None:
        local_pos, cross_pos = precomputed
    else:
        local_pos, cross_pos = partition.classify(registry)
    by_shard: List[List[Tuple[int, int]]] = [[] for _ in range(S)]
    for d, p in keyed:
        by_shard[partition.owner(p)].append((d, p))

    queries = _pad_rows([np.asarray([p for _, p in sh], dtype=np.int64)
                         for sh in by_shard], 512, 0)
    # query chunk products: each shard's owned query primes packed into
    # < 2**max_bits composites — the gcd exchange payload (one wide limb
    # chunk usually covers the whole shard's query set)
    chunk_bits = registry.max_bits if wide else 62
    chunk_vals: List[List[int]] = []
    for sh in by_shard:
        ps = {p for _, p in sh}
        chunk_vals.append(encode_relationship(ps, chunk_bits) if ps else [])
    # per-shard cross-slice width bucketed to powers of two, like every
    # other stack: an exact ceil(cross/S) width would change the compiled
    # shard_map shape on nearly every registry growth
    need = -(-max(len(cross_pos), 1) // S)
    per = 8
    while per < need:
        per *= 2

    if wide:
        limbs = registry.limbs_array()
        Lw = registry.n_limbs
        local_c = _pad_limb_stack(
            [limbs[np.asarray(pos, dtype=np.int64)]
             if pos else np.empty((0, Lw), np.int64)
             for pos in local_pos], 256, Lw)
        chunks = _pad_limb_stack([pack_limbs(cv, Lw) for cv in chunk_vals],
                                 1, Lw)
        # the gcd-reconstruction pool: each shard's deduplicated query
        # primes (zero-padded) — exactly the primes its chunks contain
        pools = _pad_rows([np.asarray(sorted({p for _, p in sh}),
                                      dtype=np.int64) for sh in by_shard],
                          512, 0)
        cross_limbs = (limbs[np.asarray(cross_pos, dtype=np.int64)]
                       if cross_pos else np.empty((0, Lw), np.int64))
        cross_sh = _pad_limb_stack(
            [cross_limbs[s * per:(s + 1) * per] for s in range(S)],
            1, Lw, width=per)
        mask, gcds = _scan_sharded_limbs(local_c, queries, chunks, pools,
                                         cross_sh, mesh)
        n_gcd_pairs = int(chunks.shape[1] * S * per)
    else:
        local_c = _pad_rows([arr[np.asarray(pos, dtype=np.int64)]
                             if pos else np.empty(0, np.int64)
                             for pos in local_pos], 256, 1)
        chunks = _pad_rows([np.asarray(cv, dtype=np.int64)
                            for cv in chunk_vals], 1, 1)
        cross_arr = (arr[np.asarray(cross_pos, dtype=np.int64)]
                     if cross_pos else np.empty(0, np.int64))
        cross_sh = np.ones((S, per), dtype=np.int64)
        for s in range(S):
            sl = cross_arr[s * per:(s + 1) * per]
            cross_sh[s, :sl.shape[0]] = sl

        # ---- kernel work (shard_map when the mesh matches) -------------- #
        mask, gcds = _scan_sharded(local_c, queries, chunks, cross_sh, mesh)
        n_gcd_pairs = int(chunks.shape[1] * cross_sh.size)

    if report is not None:
        report.n_shards = S
        report.used_shard_map = mesh is not None and mesh.size == S
        report.local_composites = [len(p) for p in local_pos]
        report.cross_composites = len(cross_pos)
        report.queries_per_shard = [len(sh) for sh in by_shard]
        report.gcd_pairs = n_gcd_pairs

    # ---- decode the gcd exchange: which cross composites contain which
    # owned query primes (exact — unique factorization) ------------------- #
    cross_of_prime: Dict[int, List[int]] = {}
    for s in range(S):
        if not by_shard[s] or not cross_pos:
            continue
        pool = np.asarray(sorted({p for _, p in by_shard[s]}), dtype=np.int64)
        gs = gcds[s]                        # (K, X) or (K, X, L) limb rows
        if wide:
            # value > 1 iff limb0 > 1 or any higher limb nonzero
            high = ((gs[..., 1:] != 0).any(axis=-1) if gs.shape[-1] > 1
                    else np.zeros(gs.shape[:2], dtype=bool))
            hit_k, hit_x = np.nonzero((gs[..., 0] > 1) | high)
        else:
            hit_k, hit_x = np.nonzero(gs > 1)
        valid = hit_x < len(cross_pos)      # drop padding columns
        hit_k, hit_x = hit_k[valid], hit_x[valid]
        if wide:
            hit_vals = [limbs_to_int(gs[k, x]) for k, x in zip(hit_k, hit_x)]
        else:
            hit_vals = [int(gs[k, x]) for k, x in zip(hit_k, hit_x)]
        uniq = sorted(set(hit_vals))
        if not uniq:
            continue
        facs, residual = factorize_batch_exact(uniq, pool)
        assert all(int(r) == 1 for r in residual), \
            "gcd escaped the shard's query pool"
        fac_of = {g: fs for g, fs in zip(uniq, facs)}
        for x, v in zip(hit_x, hit_vals):
            for q in fac_of[v]:
                cross_of_prime.setdefault(int(q), []).append(int(x))

    # ---- assemble rows in the oracle's exact order ---------------------- #
    out: Dict[int, List[int]] = {}
    for s in range(S):
        pos_map = local_pos[s]
        for col, (d, p) in enumerate(by_shard[s]):
            hits = [pos_map[i] for i in np.nonzero(mask[s, :len(pos_map),
                                                        col])[0]]
            hits.extend(cross_pos[x] for x in cross_of_prime.get(p, ()))
            row: List[int] = []
            seen: set = set()
            for pos in sorted(hits):        # ascending == registry order
                rel = registry.relationship_of_composite(int(arr[pos]))
                if rel is None or rel.rel_id in seen:
                    continue
                seen.add(rel.rel_id)
                for q in rel.primes:        # oracle's frozenset order
                    if q == p:
                        continue
                    succ = assigner.data_of(q)
                    if succ is not None:
                        row.append(succ)
            out[d] = row
    return out
