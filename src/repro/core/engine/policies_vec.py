"""Vectorized replacement policies: array-state twins of ``policies.py``.

Each policy is an ``(init, step)`` pair:

    init(capacity, n_keys) -> state pytree           (host, shapes only)
    step(state, key, now)  -> (state', hit: bool[])  (traced, fixed shape)

``step`` replicates the corresponding ``CachePolicy.access`` *exactly* —
same residency decisions, same evictions, same adaptive-parameter
arithmetic — so a ``lax.scan`` over a trace produces bit-identical hit
sequences to the scalar loop (property enforced by tests/test_engine.py).
State layouts follow DESIGN.md §4.1 (slot arrays for bounded lists,
per-key arrays for LIRS's unbounded stack); the equivalence arguments
for each policy are inlined below next to the code they justify.

``now`` is the per-access stamp.  Policies mutate at most one slot per
list per access, so a single stamp per access suffices here (PFCS's
multi-insert steps are the only place micro-op stamps are needed — see
``pfcs_vec.py``).
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from .layout import (EMPTY, I32MAX, count, first_empty, init_stamps,
                     masked_argmin, occupied, tree_where)

__all__ = ["VEC_POLICIES", "POLICY_TICKS", "LIRS_TICKS"]


# --------------------------------------------------------------------------- #
# LRU — also the recency-shadow primitive reused by hierarchy.py              #
# --------------------------------------------------------------------------- #

def lru_init(capacity: int, n_keys: int):
    del n_keys
    return {"keys": jnp.full((capacity,), EMPTY, jnp.int32),
            "t": init_stamps(capacity)}


def lru_step(s, key, now):
    # Oracle: hit -> move_to_end; miss -> insert, evict front if over.
    # Slot form: hit -> restamp; miss -> overwrite argmin-stamp slot
    # (empty slots carry the smallest stamps, so they fill first and a
    # genuine eviction only happens when full — identical semantics).
    match = s["keys"] == key
    hit = jnp.any(match)
    victim = jnp.argmin(s["t"])
    keys = jnp.where(hit, s["keys"], s["keys"].at[victim].set(key))
    t = jnp.where(match, now, s["t"])
    t = jnp.where(hit, t, t.at[victim].set(now))
    return {"keys": keys, "t": t}, hit


# --------------------------------------------------------------------------- #
# FIFO                                                                        #
# --------------------------------------------------------------------------- #

def fifo_init(capacity: int, n_keys: int):
    del n_keys
    return {"keys": jnp.full((capacity,), EMPTY, jnp.int32),
            "ins": init_stamps(capacity)}


def fifo_step(s, key, now):
    # Hits never restamp (insertion order, not recency, governs FIFO).
    hit = jnp.any(s["keys"] == key)
    victim = jnp.argmin(s["ins"])
    keys = jnp.where(hit, s["keys"], s["keys"].at[victim].set(key))
    ins = jnp.where(hit, s["ins"], s["ins"].at[victim].set(now))
    return {"keys": keys, "ins": ins}, hit


# --------------------------------------------------------------------------- #
# 2Q (Johnson & Shasha '94) — A1in FIFO / A1out ghosts / Am LRU               #
# --------------------------------------------------------------------------- #

def twoq_init(capacity: int, n_keys: int, kin_frac: float = 0.25,
              kout_frac: float = 0.5):
    del n_keys
    kin = max(1, int(capacity * kin_frac))
    kout = max(1, int(capacity * kout_frac))
    km = max(1, capacity - kin)
    return {"a1k": jnp.full((kin,), EMPTY, jnp.int32), "a1t": init_stamps(kin),
            "aok": jnp.full((kout,), EMPTY, jnp.int32), "aot": init_stamps(kout),
            "amk": jnp.full((km,), EMPTY, jnp.int32), "amt": init_stamps(km)}


def twoq_step(s, key, now):
    in_am = jnp.any(s["amk"] == key)
    in_a1 = jnp.any(s["a1k"] == key)
    in_ao = jnp.any(s["aok"] == key)
    hit = in_am | in_a1
    miss_hot = (~hit) & in_ao        # second touch within window -> Am
    miss_new = (~hit) & ~in_ao       # cold insert -> A1in

    # Am hit: touch (A1in hits deliberately do not restamp — classic 2Q).
    amt = jnp.where(s["amk"] == key, now, s["amt"])

    # miss_hot: drop the ghost, admit into Am replacing its LRU/empty slot.
    am_v = jnp.argmin(s["amt"])
    amk = jnp.where(miss_hot, s["amk"].at[am_v].set(key), s["amk"])
    amt = jnp.where(miss_hot, amt.at[am_v].set(now), amt)
    ghost = miss_hot & (s["aok"] == key)
    aok = jnp.where(ghost, EMPTY, s["aok"])
    # restamp the freed ghost slot below every init stamp so the next
    # push reuses it instead of evicting a live ghost (the oracle only
    # drops a ghost when A1out is actually full)
    aot_base = jnp.where(ghost, jnp.int32(-I32MAX), s["aot"])

    # miss_new: admit into A1in; a displaced occupant (oldest insertion)
    # becomes an A1out ghost, displacing the oldest ghost if full.
    a1_v = jnp.argmin(s["a1t"])
    displaced = s["a1k"][a1_v]
    spill = miss_new & (displaced != EMPTY)
    a1k = jnp.where(miss_new, s["a1k"].at[a1_v].set(key), s["a1k"])
    a1t = jnp.where(miss_new, s["a1t"].at[a1_v].set(now), s["a1t"])
    ao_v = jnp.argmin(aot_base)
    aok = jnp.where(spill, aok.at[ao_v].set(displaced), aok)
    aot = jnp.where(spill, aot_base.at[ao_v].set(now), aot_base)

    return {"a1k": a1k, "a1t": a1t, "aok": aok, "aot": aot,
            "amk": amk, "amt": amt}, hit


# --------------------------------------------------------------------------- #
# ARC (Megiddo & Modha, FAST'03)                                              #
# --------------------------------------------------------------------------- #
#
# T1/T2 resident + B1/B2 ghost lists as slot arrays.  List-size bounds
# from the published invariants (|T1|+|B1| <= c, |T1|+|T2| <= c,
# total <= 2c) size the arrays: c slots for T1/T2/B1 and 2c+1 for B2
# (the +1 absorbs the transient push-before-pop in Case III).  The
# adaptive target ``p`` is float64, matching CPython float arithmetic of
# the oracle exactly (the engine driver runs under ``jax.enable_x64``).

def _pop_slot(keys, idx, cond):
    return jnp.where(cond, keys.at[idx].set(EMPTY), keys)


def _push_slot(keys, times, k, now, cond):
    e = first_empty(keys)
    return (jnp.where(cond, keys.at[e].set(k), keys),
            jnp.where(cond, times.at[e].set(now), times))


def arc_build(capacity: int, n_keys: int):
    del n_keys
    c = capacity

    def slots(n):
        return (jnp.full((n,), EMPTY, jnp.int32),
                jnp.zeros((n,), jnp.int32))

    t1k, t1t = slots(c)
    t2k, t2t = slots(c)
    b1k, b1t = slots(c)
    b2k, b2t = slots(2 * c + 1)
    state = {"t1k": t1k, "t1t": t1t, "t2k": t2k, "t2t": t2t,
             "b1k": b1k, "b1t": b1t, "b2k": b2k, "b2t": b2t,
             "p": jnp.zeros((), jnp.float64)}

    def replace(s, in_b2, now, active):
        """ARC REPLACE: demote the LRU of T1 (-> B1 ghost) or T2 (-> B2),
        steered by the adaptive target p.  ``active`` masks the whole
        subroutine (Case IV only calls it on some paths)."""
        n_t1 = count(s["t1k"])
        n_t2 = count(s["t2k"])
        p_int = s["p"].astype(jnp.int32)   # int(p): trunc == floor, p >= 0
        cond_t1 = (n_t1 > 0) & ((in_b2 & (n_t1 == p_int)) | (n_t1 > p_int))
        do_t1 = active & (cond_t1 | ((~cond_t1) & (n_t2 == 0) & (n_t1 > 0)))
        do_t2 = active & (~cond_t1) & (n_t2 > 0)
        i1 = masked_argmin(s["t1t"], occupied(s["t1k"]))
        k1 = s["t1k"][i1]
        t1k_ = _pop_slot(s["t1k"], i1, do_t1)
        b1k_, b1t_ = _push_slot(s["b1k"], s["b1t"], k1, now, do_t1)
        i2 = masked_argmin(s["t2t"], occupied(s["t2k"]))
        k2 = s["t2k"][i2]
        t2k_ = _pop_slot(s["t2k"], i2, do_t2)
        b2k_, b2t_ = _push_slot(s["b2k"], s["b2t"], k2, now, do_t2)
        return {**s, "t1k": t1k_, "b1k": b1k_, "b1t": b1t_,
                "t2k": t2k_, "b2k": b2k_, "b2t": b2t_}

    def step(s, key, now):
        in_t1 = jnp.any(s["t1k"] == key)
        in_t2 = jnp.any(s["t2k"] == key)
        in_b1 = jnp.any(s["b1k"] == key)
        in_b2 = jnp.any(s["b2k"] == key)
        hit = in_t1 | in_t2

        def case_hit_t1(s):
            # Case I via T1: promote to T2 MRU.
            t1k_ = jnp.where(s["t1k"] == key, EMPTY, s["t1k"])
            t2k_, t2t_ = _push_slot(s["t2k"], s["t2t"], key, now, True)
            return {**s, "t1k": t1k_, "t2k": t2k_, "t2t": t2t_}

        def case_hit_t2(s):
            return {**s, "t2t": jnp.where(s["t2k"] == key, now, s["t2t"])}

        def case_ghost_b1(s):
            n_b1 = count(s["b1k"]).astype(jnp.float64)
            n_b2 = count(s["b2k"]).astype(jnp.float64)
            delta = jnp.maximum(1.0, n_b2 / jnp.maximum(n_b1, 1.0))
            s = {**s, "p": jnp.minimum(jnp.float64(c), s["p"] + delta)}
            s = replace(s, jnp.bool_(False), now, jnp.bool_(True))
            b1k_ = jnp.where(s["b1k"] == key, EMPTY, s["b1k"])
            t2k_, t2t_ = _push_slot(s["t2k"], s["t2t"], key, now, True)
            return {**s, "b1k": b1k_, "t2k": t2k_, "t2t": t2t_}

        def case_ghost_b2(s):
            n_b1 = count(s["b1k"]).astype(jnp.float64)
            n_b2 = count(s["b2k"]).astype(jnp.float64)
            delta = jnp.maximum(1.0, n_b1 / jnp.maximum(n_b2, 1.0))
            s = {**s, "p": jnp.maximum(jnp.float64(0.0), s["p"] - delta)}
            s = replace(s, jnp.bool_(True), now, jnp.bool_(True))
            b2k_ = jnp.where(s["b2k"] == key, EMPTY, s["b2k"])
            t2k_, t2t_ = _push_slot(s["t2k"], s["t2t"], key, now, True)
            return {**s, "b2k": b2k_, "t2k": t2k_, "t2t": t2t_}

        def case_miss(s):
            n_t1 = count(s["t1k"])
            n_b1 = count(s["b1k"])
            n_t2 = count(s["t2k"])
            n_b2 = count(s["b2k"])
            l1 = n_t1 + n_b1
            total = l1 + n_t2 + n_b2
            case_a = l1 == c
            drop_b1 = case_a & (n_t1 < c)
            drop_t1 = case_a & (n_t1 >= c)
            case_b = (~case_a) & (total >= c)
            drop_b2 = case_b & (total == 2 * c)
            ib1 = masked_argmin(s["b1t"], occupied(s["b1k"]))
            it1 = masked_argmin(s["t1t"], occupied(s["t1k"]))
            ib2 = masked_argmin(s["b2t"], occupied(s["b2k"]))
            s = {**s,
                 "b1k": _pop_slot(s["b1k"], ib1, drop_b1),
                 "t1k": _pop_slot(s["t1k"], it1, drop_t1),
                 "b2k": _pop_slot(s["b2k"], ib2, drop_b2)}
            s = replace(s, jnp.bool_(False), now, drop_b1 | case_b)
            t1k_, t1t_ = _push_slot(s["t1k"], s["t1t"], key, now, True)
            return {**s, "t1k": t1k_, "t1t": t1t_}

        case = jnp.where(in_t1, 0, jnp.where(in_t2, 1, jnp.where(
            in_b1, 2, jnp.where(in_b2, 3, 4))))
        s = jax.lax.switch(case, [case_hit_t1, case_hit_t2, case_ghost_b1,
                                  case_ghost_b2, case_miss], s)
        return s, hit

    return state, step


# --------------------------------------------------------------------------- #
# LIRS (Jiang & Zhang, SIGMETRICS'02)                                         #
# --------------------------------------------------------------------------- #
#
# The recency stack S is unbounded (it holds non-resident HIR ghosts), so
# LIRS is the one policy carried as *per-key* arrays over the key
# universe instead of slot arrays.  Stack membership is reconstructed
# from a threshold instead of simulating pruning:
#
#     in_S(k)  <=>  s_t[k] >= 0  and  s_t[k] >= min{ s_t[j] : j is LIR }
#
# which is exact because (a) after every oracle stack-prune the bottom of
# S is LIR, so pruning removes precisely the entries stamped below the
# oldest LIR, and (b) the oldest-LIR stamp is non-decreasing, so pruned
# entries can never re-enter.  Each access consumes 3 stamp ticks:
# +0 capacity-stage queue push, +1 stack write, +2 insert-stage queue
# push — preserving the oracle's within-access queue ordering.

_LIR, _HIR, _NONE = 0, 1, 2
LIRS_TICKS = 3


def lirs_build(capacity: int, n_keys: int, hir_frac: float = 0.05):
    lhirs = max(1, int(capacity * hir_frac))
    llirs = max(1, capacity - lhirs)
    K = n_keys
    state = {"status": jnp.full((K,), _NONE, jnp.int32),
             "s_t": jnp.full((K,), -1, jnp.int32),
             "q_t": jnp.full((K,), -1, jnp.int32),
             "res": jnp.zeros((K,), jnp.bool_),
             "n_lir": jnp.zeros((), jnp.int32),
             "n_res": jnp.zeros((), jnp.int32)}

    def lir_min(s):
        return jnp.min(jnp.where(s["status"] == _LIR, s["s_t"], I32MAX))

    def in_stack(s, key):
        st = s["s_t"][key]
        return (st >= 0) & (st >= lir_min(s))

    def demote_bottom(s, tick):
        """Bottom LIR -> HIR: leaves S; enters Q if resident."""
        do = s["n_lir"] > 0
        b = masked_argmin(s["s_t"], s["status"] == _LIR)
        res_b = s["res"][b]
        return {**s,
                "s_t": jnp.where(do, s["s_t"].at[b].set(-1), s["s_t"]),
                "status": jnp.where(do, s["status"].at[b].set(_HIR),
                                    s["status"]),
                "n_lir": s["n_lir"] - do,
                "q_t": jnp.where(do & res_b, s["q_t"].at[b].set(tick),
                                 s["q_t"])}

    def evict_resident_hir(s):
        in_q = s["q_t"] >= 0
        has = jnp.any(in_q)
        v = masked_argmin(s["q_t"], in_q)
        return {**s,
                "q_t": jnp.where(has, s["q_t"].at[v].set(-1), s["q_t"]),
                "res": jnp.where(has, s["res"].at[v].set(False), s["res"]),
                "n_res": s["n_res"] - has}

    def step(s, key, now):
        hit = s["res"][key]

        def case_lir_hit(s):
            return {**s, "s_t": s["s_t"].at[key].set(now + 1)}

        def case_resident_hir(s):
            ins = in_stack(s, key)
            # promoted: HIR with stack recency -> LIR, leaves Q
            sp = {**s,
                  "s_t": s["s_t"].at[key].set(now + 1),
                  "status": s["status"].at[key].set(_LIR),
                  "n_lir": s["n_lir"] + 1,
                  "q_t": s["q_t"].at[key].set(-1)}
            sp = tree_where(sp["n_lir"] > llirs, demote_bottom(sp, now + 2),
                            sp)
            # not in stack: re-enter S, move to Q tail
            sq = {**s,
                  "s_t": s["s_t"].at[key].set(now + 1),
                  "status": s["status"].at[key].set(_HIR),
                  "q_t": s["q_t"].at[key].set(now + 2)}
            return tree_where(ins, sp, sq)

        def case_miss(s):
            full1 = s["n_res"] >= capacity
            s = tree_where(full1, evict_resident_hir(s), s)
            # all-LIR corner: demote a LIR so Q has something to evict
            full2 = full1 & (s["n_res"] >= capacity)
            s = tree_where(full2,
                           evict_resident_hir(demote_bottom(s, now)), s)
            s = {**s, "res": s["res"].at[key].set(True),
                 "n_res": s["n_res"] + 1}
            ins = in_stack(s, key)       # after demotes moved the threshold
            cold = (s["n_lir"] < llirs) & ~ins
            # cold start: fill the LIR partition first
            sc = {**s, "status": s["status"].at[key].set(_LIR),
                  "n_lir": s["n_lir"] + 1,
                  "s_t": s["s_t"].at[key].set(now + 1)}
            # non-resident HIR ghost with recency -> promote to LIR
            sp = {**s, "s_t": s["s_t"].at[key].set(now + 1),
                  "status": s["status"].at[key].set(_LIR),
                  "n_lir": s["n_lir"] + 1}
            sp = tree_where(sp["n_lir"] > llirs, demote_bottom(sp, now + 2),
                            sp)
            # plain cold HIR: into S and Q
            sq = {**s, "s_t": s["s_t"].at[key].set(now + 1),
                  "status": s["status"].at[key].set(_HIR),
                  "q_t": s["q_t"].at[key].set(now + 2)}
            return tree_where(cold, sc, tree_where(ins, sp, sq))

        case = jnp.where(s["status"][key] == _LIR, 0,
                         jnp.where(s["res"][key], 1, 2))
        s = jax.lax.switch(case, [case_lir_hit, case_resident_hir,
                                  case_miss], s)
        return s, hit

    return state, step


# --------------------------------------------------------------------------- #
# registry                                                                    #
# --------------------------------------------------------------------------- #

def _simple_build(init, step_fn):
    def build(capacity: int, n_keys: int):
        return init(capacity, n_keys), step_fn
    return build


#: name -> build(capacity, n_keys) -> (initial_state, step)
VEC_POLICIES: Dict[str, Callable] = {
    "lru": _simple_build(lru_init, lru_step),
    "fifo": _simple_build(fifo_init, fifo_step),
    "2q": _simple_build(twoq_init, twoq_step),
    "arc": arc_build,
    "lirs": lirs_build,
}

#: stamp ticks consumed per access (worst case across policies + shadow)
POLICY_TICKS = 4

