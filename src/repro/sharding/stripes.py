"""Shared block-striping partitioner: contiguous value blocks dealt
round-robin to parts.

Two subsystems carve the PFCS prime space into *contiguous value blocks
striped round-robin*: the mesh-sharded discovery layer
(``core.engine.shard.PrimeSpacePartition`` — blocks -> shards, DESIGN.md
§6.1) and the multi-tenant namespace layer
(``tenancy.namespace.TenantNamespace`` — blocks -> tenants, DESIGN.md
§8.1).  Both need the same three properties:

  * **contiguity** — neighbouring values share a block, so Algorithm 1's
    ascending allocation keeps factorization locality inside one owner;
  * **striping** — consecutive blocks rotate owners, so ownership stays
    balanced even though allocation is ascending;
  * **pure O(1) ownership** — ``owner(value)`` is arithmetic on the
    value alone (no directory, no coordination), so any holder of a
    prime can classify any composite locally.

This module is that machinery, extracted so the two layers share one
implementation (and one set of block-width caps) instead of diverging
copies.  Ownership here is over *values*; the prime-space semantics
(which values are prime, what a block means for isolation) live with
the callers.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

__all__ = ["BlockStripes", "LEVEL_BLOCK_CAPS"]


#: per-level value-block width caps, sized so a block holds on the order
#: of 10-100 primes near the level's range start (prime gaps ~ ln p) —
#: ownership then stripes at the granularity real workloads allocate at,
#: instead of one part swallowing the whole ascending-allocation prefix.
#: Keyed by ``core.primes.CacheLevel`` ids (kept as plain ints here so
#: this module stays import-cycle-free).
LEVEL_BLOCK_CAPS: Dict[int, int] = {
    0: 64,        # L1
    1: 512,       # L2
    2: 4_096,     # L3
    3: 1 << 16,   # MEM
}


class BlockStripes:
    """Deterministic owner function: value -> part id.

    Each bounded level range ``(lo, hi)`` is split into contiguous value
    blocks of width ``min((hi - lo + 1) // (n_parts * stripes_per_part),
    cap)`` (caps per level, see ``LEVEL_BLOCK_CAPS``); block ``k``
    belongs to part ``k % n_parts``.  An unbounded range (``hi is
    None``) uses the fixed cap width.  ``n_parts == 1`` degenerates to
    "part 0 owns everything".
    """

    def __init__(self, n_parts: int,
                 ranges: Dict[int, Tuple[int, Optional[int]]],
                 caps: Optional[Dict[int, int]] = None,
                 stripes_per_part: int = 8):
        if n_parts < 1:
            raise ValueError("n_parts must be >= 1")
        if stripes_per_part < 1:
            raise ValueError("stripes_per_part must be >= 1")
        caps = caps or LEVEL_BLOCK_CAPS
        self.n_parts = int(n_parts)
        self.stripes_per_part = int(stripes_per_part)
        self.ranges = dict(ranges)
        self._blocks: Dict[int, Tuple[int, int]] = {}   # level -> (lo, width)
        for lvl, (lo, hi) in self.ranges.items():
            if hi is None:
                self._blocks[lvl] = (lo, caps[lvl])
            else:
                width = max(1, min(
                    (hi - lo + 1) // (self.n_parts * self.stripes_per_part),
                    caps[lvl]))
                self._blocks[lvl] = (lo, width)

    # ------------------------------------------------------------------ #

    def level_of(self, v: int) -> int:
        """Range containing value ``v`` (values in no declared range fall
        to the last — open-ended — level, like primes between ranges)."""
        last = None
        for lvl, (lo, hi) in self.ranges.items():
            if v >= lo and (hi is None or v <= hi):
                return lvl
            last = lvl
        return last

    def owner(self, v: int) -> int:
        """Part owning value ``v`` — pure function, O(1), no state."""
        if self.n_parts == 1:
            return 0
        lo, width = self._blocks[self.level_of(int(v))]
        return ((int(v) - lo) // width) % self.n_parts

    def owners(self, values: Sequence[int]) -> np.ndarray:
        """Vectorized ``owner`` over an int array (membership tests over
        whole registries / sieve segments in one shot)."""
        v = np.asarray(values, dtype=np.int64).reshape(-1)
        out = np.zeros(v.shape, dtype=np.int32)
        if self.n_parts == 1 or v.size == 0:
            return out
        assigned = np.zeros(v.shape, dtype=bool)
        last = None
        for lvl, (lo, hi) in self.ranges.items():
            m = (~assigned) & (v >= lo)
            if hi is not None:
                m &= v <= hi
            blo, width = self._blocks[lvl]
            out[m] = ((v[m] - blo) // width) % self.n_parts
            assigned |= m
            last = lvl
        if not assigned.all():                 # gap values -> last level
            blo, width = self._blocks[last]
            m = ~assigned
            out[m] = ((v[m] - blo) // width) % self.n_parts
        return out

    def block_of(self, lvl: int) -> Tuple[int, int]:
        """(lo, width) of a level's block grid (introspection)."""
        return self._blocks[lvl]

    def describe(self) -> str:
        parts = [f"level{lvl}:block={w}"
                 for lvl, (_, w) in sorted(self._blocks.items())]
        return (f"BlockStripes(n_parts={self.n_parts}, "
                f"stripes={self.stripes_per_part}, {', '.join(parts)})")
