"""Logical-axis sharding rules -> NamedSharding for every pytree leaf.

MaxText-style logical partitioning without the flax dependency: each
parameter / cache / optimizer-state leaf gets a PartitionSpec derived
from its path + shape, with a divisibility fallback (a dim that does not
divide the mesh axis is replicated, with an optional warning — e.g.
gemma's single KV head, xlstm's 4 heads).

Axis conventions
----------------
  mesh axes : ("pod", "data", "model")  (pod absent on single-pod)
  batch     -> ("pod", "data")          (DP across pods and data axis)
  heads/mlp/experts/vocab -> "model"    (TP / EP)
  d_model / d_ff fsdp dim -> "data"     (weight sharding for >=10B archs,
                                         gathered within a pod — never
                                         across the pod axis: cross-pod
                                         all-gathers of weights would ride
                                         the slow inter-pod links every
                                         layer)
  long-context KV seq -> ("pod", "data") (sequence parallelism for
                                          batch=1 500k decode)
"""

from __future__ import annotations

import logging
import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

log = logging.getLogger(__name__)


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def shard_stack_spec(mesh: Mesh) -> P:
    """PartitionSpec for per-shard PFCS state stacks (DESIGN.md §6).

    Sharded-cache state — per-shard registry slices, query primes, chunk
    products — stacks shards on the leading axis and partitions it over
    EVERY mesh axis (data x model flattened: one shard per device, no
    axis idle doing redundant scans), the same convention as
    ``batch_shardings(all_axes=True)`` for dp_only batches.
    """
    return P(tuple(mesh.axis_names))


def _axes_total(mesh: Mesh, axes) -> Tuple[Tuple[str, ...], int]:
    """Normalized axes tuple + the product of their mesh sizes."""
    ax = (axes,) if isinstance(axes, str) else tuple(axes)
    return ax, int(np.prod([mesh_axis_size(mesh, a) for a in ax]))


def _div(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    return dim % _axes_total(mesh, axes)[1] == 0


#: (dim, axes, axes-size) triples already reported — each distinct
#: fallback warns exactly ONCE per process, not once per layer/leaf
#: (gemma's single KV head appears in every attention block)
_WARNED_FALLBACKS: set = set()


def reset_fallback_warnings() -> None:
    """Clear the warn-once dedup state (test isolation hook)."""
    _WARNED_FALLBACKS.clear()


def _maybe(dim: int, mesh: Mesh, axes):
    """axes if divisible else None (replicate, warning once per distinct
    fallback — a silent replication of a dim the rules meant to shard is
    the kind of perf cliff that should be visible in logs)."""
    if not axes:
        return None
    ax, total = _axes_total(mesh, axes)
    if dim % total == 0:
        return axes
    key = (int(dim), ax, total)
    if key not in _WARNED_FALLBACKS:
        _WARNED_FALLBACKS.add(key)
        log.warning(
            "sharding fallback: dim %d does not divide mesh axes %s "
            "(size %d); replicating instead", dim, ax, total)
    return None


# --------------------------------------------------------------------------- #
# parameter rules                                                             #
# --------------------------------------------------------------------------- #

_RULES = [
    # (path regex, callable(shape, mesh, fsdp) -> PartitionSpec entries for
    #  the *trailing* (non-stacked) dims). Leading stack dims get None.
    # embeddings: (V, D) — vocab over model, embed over fsdp
    (r"(embed.*table|unembed)$",
     lambda s, m, f: (_maybe(s[-2], m, "model"), _maybe(s[-1], m, f))),
    # attention projections
    (r"attn.*wq$|self_attn.*wq$|cross_attn.*wq$",
     lambda s, m, f: (_maybe(s[-3], m, f), _maybe(s[-2], m, "model"), None)),
    (r"(attn|self_attn|cross_attn).*(wk|wv)$",
     lambda s, m, f: (_maybe(s[-3], m, f),
                      _maybe(s[-2], m, "model"),
                      None if _div(s[-2], m, "model") else _maybe(s[-1], m, "model"))),
    (r"(attn|self_attn|cross_attn).*wo$",
     lambda s, m, f: (_maybe(s[-3], m, "model"), None, _maybe(s[-1], m, f))),
    (r"(bq|bk|bv)$", lambda s, m, f: (_maybe(s[-2], m, "model"), None)),
    # MLA
    (r"attn.*wq_a$", lambda s, m, f: (_maybe(s[-2], m, f), None)),
    (r"attn.*wq_b$", lambda s, m, f: (None, _maybe(s[-2], m, "model"), None)),
    (r"attn.*wkv_a$", lambda s, m, f: (_maybe(s[-2], m, f), None)),
    (r"attn.*(wk_b|wv_b)$",
     lambda s, m, f: (None, _maybe(s[-2], m, "model"), None)),
    # dense FFN
    (r"(ffn|shared).*(w_gate|w_up)$",
     lambda s, m, f: (_maybe(s[-2], m, f), _maybe(s[-1], m, "model"))),
    (r"(ffn|shared).*w_down$",
     lambda s, m, f: (_maybe(s[-2], m, "model"), _maybe(s[-1], m, f))),
    # MoE experts: (E, d, ff) / (E, ff, d)
    (r"moe.*(w_gate|w_up)$",
     lambda s, m, f: (_maybe(s[-3], m, "model"), _maybe(s[-2], m, f), None)),
    (r"moe.*w_down$",
     lambda s, m, f: (_maybe(s[-3], m, "model"), None, _maybe(s[-1], m, f))),
    (r"moe.*router$", lambda s, m, f: (None, _maybe(s[-1], m, "model"))),
    # Mamba2
    (r"mix.*in_proj$",
     lambda s, m, f: (_maybe(s[-2], m, f), _maybe(s[-1], m, "model"))),
    (r"mix.*out_proj$",
     lambda s, m, f: (_maybe(s[-2], m, "model"), _maybe(s[-1], m, f))),
    (r"mix.*conv_w$", lambda s, m, f: (None, _maybe(s[-1], m, "model"))),
    (r"mix.*conv_b$", lambda s, m, f: (_maybe(s[-1], m, "model"),)),
    (r"mix.*(A_log|D|dt_bias)$", lambda s, m, f: (_maybe(s[-1], m, "model"),)),
    (r"mix.*norm.*scale$", lambda s, m, f: (_maybe(s[-1], m, "model"),)),
    # xLSTM mLSTM
    (r"cell.*w_up$",
     lambda s, m, f: (_maybe(s[-2], m, f), _maybe(s[-1], m, "model"))),
    (r"cell.*w_down$",
     lambda s, m, f: (_maybe(s[-2], m, "model"), _maybe(s[-1], m, f))),
    (r"cell.*conv_w$", lambda s, m, f: (None, _maybe(s[-1], m, "model"))),
    (r"cell.*conv_b$", lambda s, m, f: (_maybe(s[-1], m, "model"),)),
    (r"cell.*(wq|wk|wv)$",
     lambda s, m, f: (_maybe(s[-3], m, "model"), None, None)),
    (r"cell.*(w_igate|w_fgate)$",
     lambda s, m, f: (_maybe(s[-2], m, "model"), None)),
    (r"cell.*w_x$", lambda s, m, f: (_maybe(s[-3], m, f), None,
                                     _maybe(s[-1], m, "model"))),
    (r"cell.*w_r$", lambda s, m, f: (None, None, _maybe(s[-1], m, "model"))),
    (r"cell.*w_out$", lambda s, m, f: (_maybe(s[-2], m, f),
                                       _maybe(s[-1], m, "model"))),
    # frontends
    (r"(frontend_proj|vis_proj.*w1)$",
     lambda s, m, f: (None, _maybe(s[-1], m, "model"))),
    (r"vis_proj.*w2$", lambda s, m, f: (_maybe(s[-2], m, "model"), None)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_pspec(path: str, shape: Tuple[int, ...], mesh: Mesh,
                fsdp: Optional[str]) -> P:
    """PartitionSpec for one parameter leaf."""
    for pat, fn in _RULES:
        if re.search(pat, path):
            trailing = fn(shape, mesh, fsdp)
            n_lead = len(shape) - len(trailing)
            if n_lead < 0:  # unstacked variant (e.g. zamba shared blocks)
                trailing = trailing[-len(shape):]
                n_lead = 0
            return P(*([None] * n_lead), *trailing)
    return P()  # replicate (norms, biases, scalars)


def params_shardings(abstract_params, mesh: Mesh, cfg) -> Any:
    fsdp = "data" if cfg.use_fsdp else None
    dp_only = getattr(cfg, "dp_only", False)
    if dp_only:
        # Small-model mode (§Perf iteration 4b): REPLICATE weights (pure
        # data parallelism) — per-layer TP collectives vanish entirely;
        # only the end-of-step gradient all-reduce remains (amortized over
        # the whole layer stack).  Optimizer state is ZeRO-1-sharded over
        # data (see opt_state_shardings).  Iteration 4a (ZeRO-3 weight
        # sharding over data) was tried first and REFUTED — the
        # gather/reshard traffic exceeded the TP all-reduces it replaced
        # (EXPERIMENTS.md §Perf cell 4).
        fsdp = None

    def leaf(path, x):
        ps = param_pspec(_path_str(path), x.shape, mesh, fsdp)
        if dp_only:
            ps = P(*[(None if e == "model" else e) for e in ps])
        return NamedSharding(mesh, ps)
    return jax.tree_util.tree_map_with_path(leaf, abstract_params)


# --------------------------------------------------------------------------- #
# batch / cache / activations                                                 #
# --------------------------------------------------------------------------- #

def batch_shardings(abstract_batch, mesh: Mesh,
                    all_axes: bool = False) -> Any:
    """Batch sharded over (pod, data); with ``all_axes`` (dp_only mode)
    over every mesh axis — pure data parallelism, one sample slice per
    device, no idle axis doing redundant compute."""
    ba = tuple(mesh.axis_names) if all_axes else batch_axes(mesh)
    def leaf(x):
        if x.ndim >= 1 and _div(x.shape[0], mesh, ba):
            return NamedSharding(mesh, P(ba, *([None] * (x.ndim - 1))))
        return NamedSharding(mesh, P())
    return jax.tree.map(leaf, abstract_batch)


def cache_shardings(abstract_cache, mesh: Mesh, cfg,
                    seq_shard: bool = False,
                    seq_over_model: bool = False) -> Any:
    """Decode-cache sharding.

    Layout convention: (L, B, S, ...) for kv-like caches, (L, B, ...) for
    recurrent states, plus scalar 'len'.  ``seq_shard=True`` (batch=1
    long-context decode) shards S over the batch axes instead of B —
    sequence parallelism for the 500k cells.

    ``seq_over_model=True`` (§Perf optimized variant): when the KV-head
    count does not divide the model axis, shard the cache *sequence* dim
    over 'model' instead of head_dim — flash-decoding-style split-K.  The
    hd->model layout makes GSPMD replicate the whole cache at the
    attention einsum (observed: 2.7 GB all-gathers on kimi decode_32k);
    S->model keeps the cache in place and reduces tiny partial outputs.
    """
    ba = batch_axes(mesh)
    kv_like = ("k", "v", "xk", "xv", "latent", "rope")

    def leaf(path, x):
        path_s = _path_str(path)
        if x.ndim <= 1:
            return NamedSharding(mesh, P())
        spec = [None] * x.ndim
        # dim 0 is the layer stack; dim 1 batch; dim 2 seq (kv caches)
        if x.ndim >= 3 and not seq_shard and _div(x.shape[1], mesh, ba):
            spec[1] = ba
        elif seq_shard and x.ndim >= 3 and path_s.split("/")[-1] in kv_like \
                and _div(x.shape[2], mesh, ba):
            spec[2] = ba
        # last dims: shard heads over model; fall back to seq (opt) or hd
        if x.ndim >= 4 and _div(x.shape[-2], mesh, "model"):
            spec[-2] = "model"       # kv heads
        elif (seq_over_model and x.ndim >= 4 and spec[2] is None
                and path_s.split("/")[-1] in kv_like
                and _div(x.shape[2], mesh, "model")):
            spec[2] = "model"        # split-K decode
        elif _div(x.shape[-1], mesh, "model"):
            spec[-1] = "model"       # head_dim / latent / feature
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf, abstract_cache)


def opt_state_shardings(abstract_state, abstract_params, mesh: Mesh, cfg) -> Any:
    """Optimizer-state sharding derived from the matching parameter spec.

    AdamW m/v mirror the param shape -> same spec.  Adafactor vr drops the
    last dim, vc drops the second-to-last -> spec with the matching entry
    removed.  Scalars replicate.
    """
    fsdp = "data" if cfg.use_fsdp else None
    param_specs: Dict[str, P] = {}

    def record(path, x):
        param_specs[_path_str(path)] = param_pspec(_path_str(path), x.shape,
                                                   mesh, fsdp)
        return x

    jax.tree_util.tree_map_with_path(record, abstract_params)

    dp_only = getattr(cfg, "dp_only", False)

    def leaf(path, x):
        ps = _path_str(path)
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        if dp_only:
            # ZeRO-1: shard moments over data on the largest divisible dim
            for d in range(x.ndim):
                if _div(x.shape[d], mesh, "data"):
                    spec = [None] * x.ndim
                    spec[d] = "data"
                    return NamedSharding(mesh, P(*spec))
            return NamedSharding(mesh, P())
        # strip optimizer wrappers to find the param path suffix
        core = re.sub(r"^(m|v|mom|s)/", "", ps)
        core = re.sub(r"/(vr|vc|v)$", "", core)
        spec = param_specs.get(core)
        if spec is None:
            return NamedSharding(mesh, P())
        entries = list(spec)
        if ps.endswith("/vr") and len(entries) >= 1:      # param minus last dim
            entries = entries[:-1]
        elif ps.endswith("/vc") and len(entries) >= 2:    # minus 2nd-to-last
            entries = entries[:-2] + entries[-1:]
        return NamedSharding(mesh, P(*entries[: x.ndim]))

    return jax.tree_util.tree_map_with_path(leaf, abstract_state)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
