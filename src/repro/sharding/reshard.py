"""Elastic re-striping plans and the per-shard registry slice index.

The sharded discovery path (``core.engine.shard``) derives "which shard
scans which composite" from :meth:`PrimeSpacePartition.classify` on
every table refresh — an O(registry) walk that re-materializes metadata
the partition function already determines.  This module turns that
transient classification into a *maintained index*, :class:`ShardSlices`,
so elastic events can be answered incrementally:

* **Resize (re-stripe).**  A shard-count change swaps the
  :class:`~repro.sharding.stripes.BlockStripes` modulus under the same
  contiguous block grid (the per-level width caps bind for the serving
  levels, so the grid is identical at 2 and 4 shards — only ``k %
  n_parts`` changes).  :meth:`ShardSlices.restripe` re-evaluates the
  owner of every *cached* chunk-prime tuple vectorially and emits a
  :class:`ReshardPlan` listing exactly the positions whose owner
  changed — the only registry slice entries that must move.  Nothing is
  re-read from the registry and no successor row is rebuilt
  (DESIGN.md §9).

* **Shard loss (recovery-as-refactorization).**  When a shard dies, its
  slice of the index is forgotten (:meth:`forget_shard`).  Recovery
  does NOT consult any surviving metadata for the lost positions:
  :meth:`recover` re-factorizes the surviving composite values through
  :func:`repro.kernels.ops.factorize_batch` — the same Pallas-backed
  divisibility kernels the discovery scan uses — and reclassifies from
  the recovered prime factors alone.  By unique factorization (paper
  Theorem 1) the rebuilt index is bit-equal to one built from intact
  metadata; the chaos fuzz in ``tests/test_elastic.py`` pins that.

**Chunk-level ownership.**  ``PrimeSpacePartition.classify`` labels a
position by ALL primes of its relationship; this index labels it by the
primes dividing *that chunk* (recoverable from the composite value
alone, which is what survives a shard loss).  The two produce identical
scan results: a prime's divisibility/gcd hits can only come from the
chunk that contains it, so routing each chunk to its own primes' owner
preserves every (query prime, position) hit pair.  For the serving
workload — pairwise chain edges, single-chunk relationships — the two
classifications coincide exactly.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["CROSS", "LOST", "ReshardPlan", "ShardSlices"]

#: Owner code: chunk's primes span shards; scanned via the gcd exchange.
CROSS = -1
#: Owner code: entry belonged to a dead shard; must be re-factorized.
LOST = -2


@dataclass(frozen=True)
class ReshardPlan:
    """Migration plan for one live shard-count change.

    ``moved`` lists exactly the registry positions whose owner changed
    under the new striping; everything else stays in place.  Composite
    chunks are int64, so the migrated payload is ``8 * len(moved)``
    bytes versus ``8 * total`` for a naive full re-shuffle — the
    benchmark gap ``case_elastic`` reports.
    """

    n_old: int
    n_new: int
    total: int                      # live composite chunks at plan time
    moved: Tuple[int, ...]          # positions whose owner changed
    dests: Tuple[int, ...]          # new owner per moved position

    @property
    def migrated_bytes(self) -> int:
        return 8 * len(self.moved)

    @property
    def full_rebuild_bytes(self) -> int:
        return 8 * self.total

    def describe(self) -> str:
        return (f"ReshardPlan({self.n_old}->{self.n_new}: "
                f"{len(self.moved)}/{self.total} chunks move, "
                f"{self.migrated_bytes}B vs {self.full_rebuild_bytes}B "
                f"full rebuild)")


class ShardSlices:
    """Maintained position -> owner index over a registry's composites.

    ``owner[pos]`` is a shard id (>= 0) for shard-local chunks,
    :data:`CROSS` for chunks whose primes span shards, or :data:`LOST`
    for entries forgotten with a dead shard.  ``sync`` keeps the index
    current incrementally (append-only registry growth classifies only
    the tail); ``local()``/``cross()`` export the exact position lists
    :func:`repro.core.engine.shard.sharded_successor_table` consumes via
    its ``precomputed=`` argument.
    """

    def __init__(self, partition):
        self.partition = partition
        self.version: Optional[int] = None
        self._values = np.empty(0, np.int64)
        self._owner = np.empty(0, np.int32)
        self._primes: List[Tuple[int, ...]] = []

    # ------------------------------------------------------------------ #
    # classification                                                     #
    # ------------------------------------------------------------------ #

    def _owners_of(self, primes_list: Sequence[Tuple[int, ...]]
                   ) -> np.ndarray:
        """Vectorized chunk owner: single owning shard, else CROSS."""
        if not primes_list:
            return np.empty(0, np.int32)
        counts = np.fromiter((len(ps) for ps in primes_list), np.int64,
                             len(primes_list))
        flat = np.fromiter((q for ps in primes_list for q in ps), np.int64,
                           int(counts.sum()))
        owners = self.partition.owners(flat)
        out = np.full(len(primes_list), CROSS, np.int32)
        i = 0
        for j, c in enumerate(counts):
            seg = owners[i:i + c]
            i += c
            if c and bool((seg == seg[0]).all()):
                out[j] = seg[0]
        return out

    def _classify_tail(self, registry, arr: np.ndarray, lo: int) -> None:
        new_primes: List[Tuple[int, ...]] = []
        for pos in range(lo, arr.size):
            v = int(arr[pos])
            rel = registry.relationship_of_composite(v)
            if rel is None:                   # pragma: no cover - defensive
                new_primes.append(())
                continue
            # primes of THIS chunk — the ones recoverable from the value
            new_primes.append(tuple(q for q in sorted(rel.primes)
                                    if v % q == 0))
        self._primes.extend(new_primes)
        self._owner = np.concatenate(
            [self._owner, self._owners_of(new_primes)])

    def sync(self, registry) -> str:
        """Bring the index up to the registry's current version.

        Returns ``"noop"`` (already current), ``"append"`` (only the new
        tail was classified), or ``"rebuild"`` (in-place mutation —
        drops/unregisters — forced a full reclassification).
        """
        if self.version == registry.version:
            return "noop"
        arr = registry.composites_view()
        n_old = self._values.size
        if (arr.size >= n_old and n_old
                and np.array_equal(arr[:n_old], self._values)):
            mode = "append"
            self._values = arr.copy()
            self._classify_tail(registry, arr, n_old)
        else:
            mode = "rebuild" if n_old else "append"
            self._values = arr.copy()
            self._owner = np.empty(0, np.int32)
            self._primes = []
            self._classify_tail(registry, arr, 0)
        self.version = registry.version
        return mode

    # ------------------------------------------------------------------ #
    # exports for the sharded scan                                       #
    # ------------------------------------------------------------------ #

    def local(self) -> List[List[int]]:
        """Per-shard local position lists, ascending (= registry order)."""
        return [[int(p) for p in np.nonzero(self._owner == s)[0]]
                for s in range(self.partition.n_shards)]

    def cross(self) -> List[int]:
        return [int(p) for p in np.nonzero(self._owner == CROSS)[0]]

    # ------------------------------------------------------------------ #
    # elastic events                                                     #
    # ------------------------------------------------------------------ #

    def restripe(self, new_partition) -> ReshardPlan:
        """Re-own every cached entry under ``new_partition``; returns the
        migration plan (moved positions only — no registry re-read)."""
        if bool(np.any(self._owner == LOST)):
            raise RuntimeError("recover dead shards before resharding")
        old_owner = self._owner
        old_n = self.partition.n_shards
        self.partition = new_partition
        self._owner = self._owners_of(self._primes)
        moved = np.nonzero(self._owner != old_owner)[0]
        return ReshardPlan(
            n_old=old_n, n_new=new_partition.n_shards,
            total=int(old_owner.size),
            moved=tuple(int(p) for p in moved),
            dests=tuple(int(self._owner[p]) for p in moved))

    def forget_shard(self, shard: int) -> int:
        """Drop a dead shard's slice of the index (values survive — they
        are the replicated composite array; the *classification* dies).
        Returns the number of entries lost."""
        hit = np.nonzero(self._owner == shard)[0]
        self._owner[hit] = LOST
        for p in hit:
            self._primes[int(p)] = ()
        return int(hit.size)

    def recover(self, registry) -> Tuple[int, str]:
        """Rebuild lost entries purely by re-factorizing the surviving
        composite values through the factorize/divisibility kernels.

        If the registry mutated while the shard was dead (version or
        value drift), NO surviving classification is trusted: every
        position is re-factorized (mode ``"full"``); otherwise only the
        LOST positions are (mode ``"partial"``).  Returns
        ``(n_refactorized, mode)``.
        """
        from repro.kernels.ops import factorize_batch_exact

        arr = registry.composites_view()
        stale = (self.version != registry.version
                 or arr.size != self._values.size
                 or not np.array_equal(arr, self._values))
        if stale:
            self._values = arr.copy()
            self._owner = np.full(arr.size, LOST, np.int32)
            self._primes = [()] * arr.size
            mode = "full"
        else:
            mode = "partial"
        lost = np.nonzero(self._owner == LOST)[0]
        if lost.size:
            pool = registry.primes_array()
            facs, residual = factorize_batch_exact(arr[lost], pool)
            assert all(int(r) == 1 for r in residual), \
                "surviving composite escaped the prime pool (Theorem 1)"
            for pos, fs in zip(lost, facs):
                self._primes[int(pos)] = tuple(sorted(int(q) for q in fs))
            self._owner[lost] = self._owners_of(
                [self._primes[int(p)] for p in lost])
        self.version = registry.version
        return int(lost.size), mode

    # ------------------------------------------------------------------ #
    # verification                                                       #
    # ------------------------------------------------------------------ #

    def verify(self, registry) -> bool:
        """True iff the maintained index equals a from-scratch one."""
        fresh = ShardSlices(self.partition)
        fresh.sync(registry)
        return (bool(np.array_equal(fresh._owner, self._owner))
                and fresh._primes == self._primes)
