"""Attention: GQA/MQA with RoPE and qk-norm; train / prefill / decode paths.

Three execution regimes:

  * ``attention_full``     — plain einsum attention (short sequences,
                             smoke tests).
  * ``attention_chunked``  — query-block ``lax.scan``: O(chunk x S) score
                             working set instead of O(S^2).  TPU-adapted
                             flash-style streaming (online softmax is not
                             needed because each query block sees the full
                             key axis per step — one pass, exact softmax).
  * ``decode_attention``   — single-token query against a KV cache
                             (optionally sequence-sharded for 500k-token
                             decode; see sharding rules).

All paths share the GQA grouping einsum: q heads are reshaped to
(kv_heads, group) so no materialized KV repeat is needed.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import F32, apply_rope, dense_init, init_rmsnorm, rms_norm

Params = Dict[str, Any]

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# parameters                                                                  #
# --------------------------------------------------------------------------- #

def init_attention(key, cfg) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], (d, h, hd), dt),
        "wk": dense_init(ks[1], (d, kv, hd), dt),
        "wv": dense_init(ks[2], (d, kv, hd), dt),
        "wo": dense_init(ks[3], (h, hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dt)
        p["bk"] = jnp.zeros((kv, hd), dt)
        p["bv"] = jnp.zeros((kv, hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dt)
        p["k_norm"] = init_rmsnorm(hd, dt)
    return p


def qkv_project(x: jnp.ndarray, p: Params, cfg,
                positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> q (B, S, H, hd), k/v (B, S, KV, hd), RoPE applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"], preferred_element_type=F32)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"], preferred_element_type=F32)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"], preferred_element_type=F32)
    q, k, v = q.astype(x.dtype), k.astype(x.dtype), v.astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_project(attn: jnp.ndarray, p: Params) -> jnp.ndarray:
    return jnp.einsum("bshk,hkd->bsd", attn, p["wo"],
                      preferred_element_type=F32).astype(attn.dtype)


# --------------------------------------------------------------------------- #
# core attention math (GQA grouping)                                          #
# --------------------------------------------------------------------------- #

def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q (B,Sq,KV,G,hd) x k (B,Sk,KV,hd) -> scores (B,KV,G,Sq,Sk) in f32."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k, preferred_element_type=F32)


def _gqa_mix(w: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """w (B,KV,G,Sq,Sk) x v (B,Sk,KV,hd) -> (B,Sq,KV,G,hd)."""
    return jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(w.dtype))


def _split_groups(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def _merge_groups(x: jnp.ndarray) -> jnp.ndarray:
    b, s, kv, g, d = x.shape
    return x.reshape(b, s, kv * g, d)


def attention_full(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   causal: bool = True,
                   q_offset: int = 0) -> jnp.ndarray:
    """Exact attention. q (B,Sq,H,hd), k/v (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    n_kv = k.shape[2]
    qg = _split_groups(q, n_kv)
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = _gqa_scores(qg, k) * scale                   # (B,KV,G,Sq,Sk)
    if causal:
        sq, sk = scores.shape[-2], scores.shape[-1]
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = _gqa_mix(w.astype(v.dtype), v)
    return _merge_groups(out)


def attention_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      chunk: int = 1024, causal: bool = True,
                      unroll: bool = False) -> jnp.ndarray:
    """Query-chunked attention via lax.scan (self-attention, Sq == Sk).

    Working set per step: (B, KV, G, chunk, S) f32 scores — the O(S^2)
    buffer never materializes.  Each chunk is checkpointed so backward
    recomputes scores instead of saving them.
    """
    b, s, h, hd = q.shape
    if s % chunk != 0 or s <= chunk:
        return attention_full(q, k, v, causal=causal)
    n_kv = k.shape[2]
    qg = _split_groups(q, n_kv)                           # (B,S,KV,G,hd)
    n_chunks = s // chunk
    qg = qg.reshape(b, n_chunks, chunk, n_kv, h // n_kv, hd)
    qg = jnp.moveaxis(qg, 1, 0)                           # (C,B,chunk,KV,G,hd)

    def step(carry, xs):
        qc, off = xs
        scale = 1.0 / math.sqrt(hd)
        scores = _gqa_scores(qc, k) * scale               # (B,KV,G,chunk,S)
        if causal:
            qpos = jnp.arange(chunk) + off
            kpos = jnp.arange(s)
            mask = kpos[None, :] <= qpos[:, None]
            scores = jnp.where(mask, scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = _gqa_mix(w.astype(v.dtype), v)              # (B,chunk,KV,G,hd)
        return carry, out

    offsets = jnp.arange(n_chunks) * chunk
    from .unroll import scan_or_unroll
    _, outs = scan_or_unroll(jax.checkpoint(step), None, (qg, offsets), unroll)
    outs = jnp.moveaxis(outs, 0, 1)                       # (B,C,chunk,KV,G,hd)
    outs = outs.reshape(b, s, n_kv, h // n_kv, hd)
    return _merge_groups(outs)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     cache_len: jnp.ndarray) -> jnp.ndarray:
    """One-token decode. q (B,1,H,hd); caches (B,S,KV,hd); cache_len (B,)
    valid prefix lengths (the new token's k/v must already be written)."""
    n_kv = k_cache.shape[2]
    qg = _split_groups(q, n_kv)
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = _gqa_scores(qg, k_cache) * scale             # (B,KV,G,1,S)
    s = k_cache.shape[1]
    valid = jnp.arange(s)[None, :] < cache_len[:, None]   # (B,S)
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = _gqa_mix(w.astype(v_cache.dtype), v_cache)
    return _merge_groups(out)
