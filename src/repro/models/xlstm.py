"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, true recurrence).

mLSTM training uses the paper's stabilized parallel form — a decay-gated
attention-like matrix D with exponential input gates and log-sigmoid
forget-gate cumsums; decode is the O(1) recurrence over (C, n, m) state.
sLSTM is inherently sequential (recurrent weight mixing) and trains via
``lax.scan`` over time.

Simplifications vs the released stack (documented in DESIGN.md): block-
internal LayerNorm/skip placement follows the paper figure but drops
learnable per-head out-norms; the sLSTM block uses a single projection
round instead of the 4/3-factor gated MLP sandwich.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import F32, dense_init, init_rmsnorm, rms_norm

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# mLSTM                                                                       #
# --------------------------------------------------------------------------- #

def init_mlstm(key, cfg) -> Params:
    x = cfg.xlstm
    d = cfg.d_model
    d_inner = int(x.proj_factor_mlstm * d)
    h = cfg.n_heads
    hd = d_inner // h
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * d_inner), dt),      # [x_m | z]
        "conv_w": dense_init(ks[1], (x.conv_kernel, d_inner), dt, scale=0.5),
        "conv_b": jnp.zeros((d_inner,), dt),
        "wq": dense_init(ks[2], (d_inner, h, hd), dt),
        "wk": dense_init(ks[3], (d_inner, h, hd), dt),
        "wv": dense_init(ks[4], (d_inner, h, hd), dt),
        "w_igate": dense_init(ks[5], (d_inner, h), jnp.float32, scale=0.01),
        "w_fgate": dense_init(ks[6], (d_inner, h), jnp.float32, scale=0.01),
        "b_igate": jnp.zeros((h,), jnp.float32),
        "b_fgate": jnp.full((h,), 3.0, jnp.float32),   # init: remember
        "norm": init_rmsnorm(d_inner, dt),
        "w_down": dense_init(ks[7], (d_inner, d), dt),
    }


def _mlstm_qkv_gates(x_m, p, cfg):
    """x_m: (B,S,d_inner) post-conv features -> q,k,v (B,S,H,hd), i,f (B,S,H)."""
    q = jnp.einsum("bse,ehk->bshk", x_m, p["wq"], preferred_element_type=F32)
    k = jnp.einsum("bse,ehk->bshk", x_m, p["wk"], preferred_element_type=F32)
    v = jnp.einsum("bse,ehk->bshk", x_m, p["wv"], preferred_element_type=F32)
    ig = jnp.einsum("bse,eh->bsh", x_m.astype(F32), p["w_igate"]) + p["b_igate"]
    fg = jnp.einsum("bse,eh->bsh", x_m.astype(F32), p["w_fgate"]) + p["b_fgate"]
    return q, k, v, ig, fg


def mlstm_parallel(q, k, v, ig, fg):
    """Stabilized parallel mLSTM.

    q,k,v: (B,S,H,hd) f32; ig,fg: (B,S,H) raw gate pre-activations.
    Returns (B,S,H,hd).
    """
    b, s, h, hd = q.shape
    logf = jax.nn.log_sigmoid(fg)                         # (B,S,H)
    fcum = jnp.cumsum(logf, axis=1)                       # sum_{t<=i} log f_t
    # score[i,j] = fcum_i - fcum_j + ig_j   (decay from j+1..i, gate at j)
    score = (fcum[:, :, None, :] - fcum[:, None, :, :]
             + ig[:, None, :, :])                         # (B,Sq,Sk,H)
    mask = jnp.tril(jnp.ones((s, s), bool))
    score = jnp.where(mask[None, :, :, None], score, -jnp.inf)
    m = jnp.max(score, axis=2, keepdims=True)             # (B,Sq,1,H)
    d_mat = jnp.exp(score - m)                            # stabilized decays
    qk = jnp.einsum("bihd,bjhd->bijh", q, k) / math.sqrt(hd)
    w = qk * d_mat                                        # (B,Sq,Sk,H)
    num = jnp.einsum("bijh,bjhd->bihd", w, v)
    den = jnp.abs(jnp.sum(w, axis=2))                     # (B,Sq,H)
    den = jnp.maximum(den, jnp.exp(-m[:, :, 0, :]))
    return num / den[..., None]


def mlstm_chunked(q, k, v, ig, fg, chunk: int, unroll: bool = False):
    """Query-chunked stabilized parallel mLSTM (same math as
    ``mlstm_parallel``; O(chunk x S) working set via lax.scan)."""
    b, s, h, hd = q.shape
    if s % chunk != 0 or s <= chunk:
        return mlstm_parallel(q, k, v, ig, fg)
    logf = jax.nn.log_sigmoid(fg)
    fcum = jnp.cumsum(logf, axis=1)                       # (B,S,H)
    nc = s // chunk
    qc = jnp.moveaxis(q.reshape(b, nc, chunk, h, hd), 1, 0)
    fq = jnp.moveaxis(fcum.reshape(b, nc, chunk, h), 1, 0)
    offs = jnp.arange(nc) * chunk

    def step(carry, inp):
        qi, fi, off = inp
        score = (fi[:, :, None, :] - fcum[:, None, :, :]
                 + ig[:, None, :, :])                     # (B,chunk,S,H)
        mask = (jnp.arange(s)[None, :] <= (jnp.arange(chunk) + off)[:, None])
        score = jnp.where(mask[None, :, :, None], score, -jnp.inf)
        m = jnp.max(score, axis=2, keepdims=True)
        d_mat = jnp.exp(score - m)
        qk = jnp.einsum("bihd,bjhd->bijh", qi, k) / math.sqrt(hd)
        w = qk * d_mat
        num = jnp.einsum("bijh,bjhd->bihd", w, v)
        den = jnp.maximum(jnp.abs(jnp.sum(w, axis=2)),
                          jnp.exp(-m[:, :, 0, :]))
        return carry, num / den[..., None]

    from .unroll import scan_or_unroll
    _, ys = scan_or_unroll(jax.checkpoint(step), None, (qc, fq, offs),
                           unroll)
    return jnp.moveaxis(ys, 0, 1).reshape(b, s, h, hd)


def mlstm_recurrent_step(state, q, k, v, ig, fg):
    """One decode step.  state: dict(C (B,H,hd,hd), n (B,H,hd), m (B,H));
    q,k,v: (B,H,hd); ig,fg: (B,H).  Returns (y (B,H,hd), new state)."""
    C, n, m = state["C"], state["n"], state["m"]
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + m, ig)
    f_sc = jnp.exp(logf + m - m_new)[..., None]
    i_sc = jnp.exp(ig - m_new)[..., None]
    hd = q.shape[-1]
    C_new = f_sc[..., None] * C + i_sc[..., None] * \
        jnp.einsum("bhk,bhd->bhkd", k / math.sqrt(hd), v)
    n_new = f_sc * n + i_sc * k / math.sqrt(hd)
    num = jnp.einsum("bhk,bhkd->bhd", q, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n_new)),
                      jnp.exp(-m_new))
    y = num / den[..., None]
    return y, {"C": C_new, "n": n_new, "m": m_new}


def _conv_causal(x, w, b, state=None):
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :].astype(F32) * w[i].astype(F32)
            for i in range(k))
    y = jax.nn.silu(y + b.astype(F32)).astype(x.dtype)
    return y, xp[:, -(k - 1):, :]


def mlstm_block_train(xin, p, cfg):
    d_inner = p["w_down"].shape[0]
    h = cfg.n_heads
    hd = d_inner // h
    up = jnp.einsum("bsd,de->bse", xin, p["w_up"],
                    preferred_element_type=F32).astype(xin.dtype)
    x_m, z = jnp.split(up, 2, axis=-1)
    x_c, _ = _conv_causal(x_m, p["conv_w"], p["conv_b"])
    q, k, v, ig, fg = _mlstm_qkv_gates(x_c, p, cfg)
    y = mlstm_chunked(q, k, v, ig, fg, cfg.attn_chunk,
                      unroll=cfg.unroll)   # (B,S,H,hd) f32
    y = y.reshape(*y.shape[:2], d_inner).astype(xin.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(F32)).astype(xin.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["w_down"],
                      preferred_element_type=F32).astype(xin.dtype)


def mlstm_init_state(cfg, batch):
    x = cfg.xlstm
    d_inner = int(x.proj_factor_mlstm * cfg.d_model)
    h = cfg.n_heads
    hd = d_inner // h
    return {
        "conv": jnp.zeros((batch, x.conv_kernel - 1, d_inner),
                          {"bfloat16": jnp.bfloat16,
                           "float32": jnp.float32}[cfg.dtype]),
        "C": jnp.zeros((batch, h, hd, hd), F32),
        "n": jnp.zeros((batch, h, hd), F32),
        "m": jnp.full((batch, h), -1e30, F32),
    }


def mlstm_block_decode(xin, p, cfg, state):
    d_inner = p["w_down"].shape[0]
    h = cfg.n_heads
    up = jnp.einsum("bsd,de->bse", xin, p["w_up"],
                    preferred_element_type=F32).astype(xin.dtype)
    x_m, z = jnp.split(up, 2, axis=-1)
    x_c, conv_state = _conv_causal(x_m, p["conv_w"], p["conv_b"],
                                   state=state["conv"])
    q, k, v, ig, fg = _mlstm_qkv_gates(x_c, p, cfg)
    cell = {"C": state["C"], "n": state["n"], "m": state["m"]}
    y, cell = mlstm_recurrent_step(cell, q[:, 0], k[:, 0], v[:, 0],
                                   ig[:, 0], fg[:, 0])
    y = y.reshape(y.shape[0], 1, d_inner).astype(xin.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(F32)).astype(xin.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_down"],
                     preferred_element_type=F32).astype(xin.dtype)
    return out, {"conv": conv_state, **cell}


# --------------------------------------------------------------------------- #
# sLSTM                                                                       #
# --------------------------------------------------------------------------- #

def init_slstm(key, cfg) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
    ks = jax.random.split(key, 3)
    return {
        # input projection to 4 gates (i, f, z, o) per head
        "w_x": dense_init(ks[0], (d, h, 4 * hd), dt),
        # recurrent per-head mixing (block-diagonal R)
        "w_r": dense_init(ks[1], (h, hd, 4 * hd), dt, scale=0.05),
        "bias": jnp.zeros((h, 4 * hd), jnp.float32),
        "norm": init_rmsnorm(d, dt),
        "w_out": dense_init(ks[2], (d, d), dt),
    }


def slstm_step(carry, gates_x, p, cfg):
    """carry: (h_prev (B,H,hd), c, n, m); gates_x: (B,H,4hd) input part."""
    h_prev, c_prev, n_prev, m_prev = carry
    rec = jnp.einsum("bhk,hkg->bhg", h_prev.astype(F32),
                     p["w_r"].astype(F32))
    z_all = gates_x.astype(F32) + rec + p["bias"][None]
    hd = h_prev.shape[-1]
    i_raw, f_raw, z_raw, o_raw = jnp.split(z_all, 4, axis=-1)
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m_prev, i_raw)
    i_sc = jnp.exp(i_raw - m_new)
    f_sc = jnp.exp(logf + m_prev - m_new)
    z_t = jnp.tanh(z_raw)
    o_t = jax.nn.sigmoid(o_raw)
    c_new = f_sc * c_prev + i_sc * z_t
    n_new = f_sc * n_prev + i_sc
    h_new = o_t * c_new / jnp.maximum(n_new, 1.0)
    return (h_new, c_new, n_new, m_new), h_new


def slstm_block_train(xin, p, cfg):
    b, s, d = xin.shape
    h = cfg.n_heads
    hd = d // h
    gx = jnp.einsum("bsd,dhg->bshg", xin, p["w_x"],
                    preferred_element_type=F32)                # (B,S,H,4hd)
    init = (jnp.zeros((b, h, hd), F32), jnp.zeros((b, h, hd), F32),
            jnp.zeros((b, h, hd), F32), jnp.full((b, h, hd), -1e30, F32))
    step = lambda c, g: slstm_step(c, g, p, cfg)
    _, hs = jax.lax.scan(step, init, jnp.moveaxis(gx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(xin.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    return jnp.einsum("bsd,de->bse", y, p["w_out"],
                      preferred_element_type=F32).astype(xin.dtype)


def slstm_init_state(cfg, batch):
    h = cfg.n_heads
    hd = cfg.d_model // h
    z = jnp.zeros((batch, h, hd), F32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, h, hd), -1e30, F32)}


def slstm_block_decode(xin, p, cfg, state):
    b = xin.shape[0]
    gx = jnp.einsum("bsd,dhg->bshg", xin, p["w_x"],
                    preferred_element_type=F32)[:, 0]           # (B,H,4hd)
    carry = (state["h"], state["c"], state["n"], state["m"])
    (h_new, c_new, n_new, m_new), y = slstm_step(carry, gx, p, cfg)
    d = cfg.d_model
    y = y.reshape(b, 1, d).astype(xin.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, p["w_out"],
                     preferred_element_type=F32).astype(xin.dtype)
    return out, {"h": h_new, "c": c_new, "n": n_new, "m": m_new}
