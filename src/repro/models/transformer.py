"""Decoder-only transformer assembly: dense / MoE / MLA / VLM-stub.

Layers are stacked along a leading axis and executed with ``lax.scan``
(one-layer HLO regardless of depth — essential for 512-device dry-run
compile times).  Remat policy from config wraps the scanned body.

Three entry points per model:
  * ``train_logits``  — full-sequence causal forward (loss in train_loop)
  * ``prefill``       — forward + KV-cache materialization, last logits
  * ``decode_step``   — one token against the stacked KV cache

VLM ('vlm' family): precomputed patch embeddings are projected and
prepended to the token embeddings; loss masks the image positions.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from .unroll import scan_or_unroll
from . import mla as mla_mod
from . import moe as moe_mod
from .layers import (F32, apply_ffn, dense_init, embed_tokens, init_embedding,
                     init_ffn, init_rmsnorm, rms_norm, unembed, _dtype)

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# init                                                                        #
# --------------------------------------------------------------------------- #

def _init_layer(key, cfg, moe_layer: bool) -> Params:
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg.dtype)
    p: Params = {
        "ln_attn": init_rmsnorm(cfg.d_model, dt),
        "ln_ffn": init_rmsnorm(cfg.d_model, dt),
    }
    if cfg.mla is not None:
        p["attn"] = mla_mod.init_mla(ks[0], cfg)
    else:
        p["attn"] = attn.init_attention(ks[0], cfg)
    if moe_layer:
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["ffn"] = init_ffn(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dt)
    return p


def init_params(key, cfg) -> Params:
    dt = _dtype(cfg.dtype)
    n_dense = cfg.moe.first_dense_layers if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense
    keys = jax.random.split(key, 8)
    p: Params = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "ln_f": init_rmsnorm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(keys[1], (cfg.vocab_size, cfg.d_model), dt,
                                  scale=0.02)
    # dense layers (stacked)
    if n_dense > 0:
        lkeys = jax.random.split(keys[2], n_dense)
        p["dense_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, moe_layer=False))(lkeys)
    if n_moe > 0:
        lkeys = jax.random.split(keys[3], n_moe)
        p["moe_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, moe_layer=True))(lkeys)
    if cfg.family == "vlm":
        k1, k2 = jax.random.split(keys[4])
        fd = cfg.frontend.feature_dim
        p["vis_proj"] = {
            "w1": dense_init(k1, (fd, cfg.d_model), dt),
            "w2": dense_init(k2, (cfg.d_model, cfg.d_model), dt),
        }
    return p


# --------------------------------------------------------------------------- #
# layer bodies                                                                #
# --------------------------------------------------------------------------- #

def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def _layer_train(x, lp, cfg, positions, moe_layer: bool):
    h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
    if cfg.mla is not None:
        a = mla_mod.mla_attention_train(h, lp["attn"], cfg, positions)
    else:
        q, k, v = attn.qkv_project(h, lp["attn"], cfg, positions)
        o = attn.attention_chunked(q, k, v, chunk=cfg.attn_chunk, causal=True, unroll=cfg.unroll)
        a = attn.out_project(o, lp["attn"])
    x = x + a
    h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
    if moe_layer:
        f, aux = moe_mod.apply_moe(h, lp["moe"], cfg)
        return x + f, aux["moe_aux_loss"]
    return x + apply_ffn(h, lp["ffn"], cfg.act), jnp.zeros((), F32)


def _embed_inputs(params, cfg, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x (B,S,D), loss_mask (B,S))."""
    x = embed_tokens(batch["tokens"], params["embed"])
    mask = jnp.ones(batch["tokens"].shape, bool)
    if cfg.family == "vlm":
        vp = params["vis_proj"]
        pe = jnp.einsum("bnf,fd->bnd", batch["patches"], vp["w1"],
                        preferred_element_type=F32)
        pe = jax.nn.gelu(pe)
        pe = jnp.einsum("bnd,de->bne", pe, vp["w2"],
                        preferred_element_type=F32).astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros(pe.shape[:2], bool), mask], axis=1)
    return x, mask


def _run_stack(x, params, cfg, positions):
    """Scan dense layers then MoE layers.  Returns (x, total_aux_loss)."""
    aux_total = jnp.zeros((), F32)

    def make_body(moe_layer):
        def body(carry, lp):
            x, aux = carry
            x, a = _layer_train(x, lp, cfg, positions, moe_layer)
            return (x, aux + a), None
        return _remat(body, cfg)

    if "dense_layers" in params:
        (x, aux_total), _ = scan_or_unroll(
            make_body(False), (x, aux_total), params["dense_layers"],
            cfg.unroll)
    if "moe_layers" in params:
        (x, aux_total), _ = scan_or_unroll(
            make_body(True), (x, aux_total), params["moe_layers"], cfg.unroll)
    return x, aux_total


def _logits(x, params, cfg):
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    table = params["embed"]["table"] if cfg.tie_embeddings else params["unembed"]
    return unembed(x, table)


def train_logits(params: Params, cfg, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
    x, loss_mask = _embed_inputs(params, cfg, batch)
    positions = jnp.arange(x.shape[1])[None, :]
    x, aux_loss = _run_stack(x, params, cfg, positions)
    targets = batch["tokens"]
    if cfg.family == "vlm":  # align targets with the patch-prefixed stream
        pad = jnp.zeros((targets.shape[0], cfg.frontend.n_positions),
                        targets.dtype)
        targets = jnp.concatenate([pad, targets], axis=1)
    return _logits(x, params, cfg), {"aux_loss": aux_loss,
                                     "loss_mask": loss_mask,
                                     "targets": targets}


# --------------------------------------------------------------------------- #
# prefill / decode                                                            #
# --------------------------------------------------------------------------- #

def init_cache(cfg, batch: int, max_len: int) -> Dict:
    dt = _dtype(cfg.dtype)
    l = cfg.n_layers
    if cfg.mla is not None:
        m = cfg.mla
        out = {
            "latent": jnp.zeros((l, batch, max_len, m.kv_lora_rank), dt),
            "rope": jnp.zeros((l, batch, max_len, m.qk_rope_head_dim), dt),
            "len": jnp.zeros((batch,), jnp.int32),
        }
        if cfg.kv_cache_dtype == "int8":
            # KIVI/KVQuant-style quantized latent cache: int8 rows + a
            # per-position f32 scale — halves decode HBM cache traffic.
            out["latent"] = jnp.zeros((l, batch, max_len, m.kv_lora_rank),
                                      jnp.int8)
            out["latent_scale"] = jnp.zeros((l, batch, max_len), jnp.float32)
        return out
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((l, batch, max_len, kv, hd), dt),
        "v": jnp.zeros((l, batch, max_len, kv, hd), dt),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def _stacked_layer_params(params, cfg):
    """Concatenate dense+moe stacks into per-layer scan inputs, with a
    per-layer moe flag.  Layer param trees differ (ffn vs moe), so we scan
    dense and moe stacks separately but must interleave caches in layer
    order — first_dense_layers is a prefix by construction, so caches
    split cleanly at n_dense."""
    n_dense = cfg.moe.first_dense_layers if cfg.moe else cfg.n_layers
    return n_dense


def _attn_layer_decode(x, lp, cfg, k_cache, v_cache, cache_len, positions):
    """One transformer layer, one token.  Caches: (B,S,KV,hd).  For MoE
    layers the router's top-k expert indices ride along ((B,K) int32,
    the PFCS expert-cache feed); ``None`` for dense layers."""
    h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
    q, k, v = attn.qkv_project(h, lp["attn"], cfg, positions)
    # write new k/v at cache_len
    k_cache = jax.vmap(
        lambda c, pos, val: jax.lax.dynamic_update_slice(c, val, (pos, 0, 0))
    )(k_cache, cache_len, k)
    v_cache = jax.vmap(
        lambda c, pos, val: jax.lax.dynamic_update_slice(c, val, (pos, 0, 0))
    )(v_cache, cache_len, v)
    o = attn.decode_attention(q, k_cache, v_cache, cache_len + 1)
    x = x + attn.out_project(o, lp["attn"])
    h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
    top = None
    if "moe" in lp:
        f, aux = moe_mod.apply_moe(h, lp["moe"], cfg)
        x = x + f
        top = aux["router_top_idx"]           # (T=B·1, K)
    else:
        x = x + apply_ffn(h, lp["ffn"], cfg.act)
    return x, k_cache, v_cache, top


def _mla_layer_decode(x, lp, cfg, latent_c, rope_c, cache_len, positions,
                      latent_s=None):
    h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
    a, latent_c, rope_c, latent_s = mla_mod.mla_decode(
        h, lp["attn"], cfg, latent_c, rope_c, cache_len, positions,
        latent_scale=latent_s)
    x = x + a
    h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
    top = None
    if "moe" in lp:
        f, aux = moe_mod.apply_moe(h, lp["moe"], cfg)
        x = x + f
        top = aux["router_top_idx"]
    else:
        x = x + apply_ffn(h, lp["ffn"], cfg.act)
    return x, latent_c, rope_c, latent_s, top


def _decode_step(params: Params, cfg, batch: Dict, cache: Dict,
                 with_router: bool):
    """Shared decode body; ``with_router`` additionally stacks the MoE
    layers' router top-k indices ((n_moe_layers, B, K) int32) as a scan
    output — a trace-time constant, so the two public entry points jit
    to separate programs with no runtime branch."""
    x = embed_tokens(batch["tokens"], params["embed"])
    cache_len = cache["len"]
    positions = cache_len[:, None]
    n_dense = _stacked_layer_params(params, cfg)
    routers: list = []

    if cfg.mla is not None:
        int8 = cfg.kv_cache_dtype == "int8"

        def make_body(collect):
            def body(x, inp):
                if int8:
                    lp, lat, rp, ls = inp
                else:
                    (lp, lat, rp), ls = inp, None
                x, lat, rp, ls, top = _mla_layer_decode(
                    x, lp, cfg, lat, rp, cache_len, positions, ls)
                out = (lat, rp, ls) if int8 else (lat, rp)
                return x, (out + (top,) if collect else out)
            return body

        new_lat, new_rp, new_ls = [], [], []

        def run(stack, lat_sl, rp_sl, ls_sl, collect=False):
            nonlocal x
            xs = (stack, lat_sl, rp_sl, ls_sl) if int8 else \
                (stack, lat_sl, rp_sl)
            x, ys = scan_or_unroll(make_body(collect), x, xs, cfg.unroll)
            new_lat.append(ys[0])
            new_rp.append(ys[1])
            if int8:
                new_ls.append(ys[2])
            if collect:
                routers.append(ys[-1])

        ls_all = cache.get("latent_scale")
        if "dense_layers" in params:
            run(params["dense_layers"], cache["latent"][:n_dense],
                cache["rope"][:n_dense],
                ls_all[:n_dense] if int8 else None)
        if "moe_layers" in params:
            run(params["moe_layers"], cache["latent"][n_dense:],
                cache["rope"][n_dense:],
                ls_all[n_dense:] if int8 else None, collect=with_router)
        cache = {"latent": jnp.concatenate(new_lat, 0),
                 "rope": jnp.concatenate(new_rp, 0),
                 "len": cache_len + 1}
        if int8:
            cache["latent_scale"] = jnp.concatenate(new_ls, 0)
    else:
        def make_body(collect):
            def body(x, inp):
                lp, kc, vc = inp
                x, kc, vc, top = _attn_layer_decode(x, lp, cfg, kc, vc,
                                                    cache_len, positions)
                return x, ((kc, vc, top) if collect else (kc, vc))
            return body

        new_k, new_v = [], []
        if "dense_layers" in params:
            x, (k0, v0) = scan_or_unroll(
                make_body(False), x,
                (params["dense_layers"],
                 cache["k"][:n_dense], cache["v"][:n_dense]),
                cfg.unroll)
            new_k.append(k0)
            new_v.append(v0)
        if "moe_layers" in params:
            x, ys = scan_or_unroll(
                make_body(with_router), x,
                (params["moe_layers"],
                 cache["k"][n_dense:], cache["v"][n_dense:]),
                cfg.unroll)
            new_k.append(ys[0])
            new_v.append(ys[1])
            if with_router:
                routers.append(ys[2])
        cache = {"k": jnp.concatenate(new_k, 0),
                 "v": jnp.concatenate(new_v, 0),
                 "len": cache_len + 1}
    logits = _logits(x, params, cfg)
    if not with_router:
        return logits, cache
    b, k = batch["tokens"].shape[0], (cfg.moe.top_k if cfg.moe else 0)
    router = (jnp.concatenate(routers, 0) if routers
              else jnp.zeros((0, b, k), jnp.int32))
    return logits, cache, router


def decode_step(params: Params, cfg, batch: Dict, cache: Dict
                ) -> Tuple[jnp.ndarray, Dict]:
    """batch: {'tokens': (B,1)}; returns (logits (B,1,V), new cache)."""
    return _decode_step(params, cfg, batch, cache, with_router=False)


def decode_step_router(params: Params, cfg, batch: Dict, cache: Dict
                       ) -> Tuple[jnp.ndarray, Dict, jnp.ndarray]:
    """``decode_step`` that also returns the stacked MoE router top-k
    indices ((n_moe_layers, B, K) int32) — the PFCS expert-cache feed
    (``repro.serving.expert_cache``, DESIGN.md §7)."""
    return _decode_step(params, cfg, batch, cache, with_router=True)


def _attn_layer_prefill(x, lp, cfg, positions, moe_layer):
    """Full-sequence forward that also returns this layer's k/v."""
    h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
    q, k, v = attn.qkv_project(h, lp["attn"], cfg, positions)
    o = attn.attention_chunked(q, k, v, chunk=cfg.attn_chunk, causal=True, unroll=cfg.unroll)
    x = x + attn.out_project(o, lp["attn"])
    h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
    if moe_layer:
        f, _ = moe_mod.apply_moe(h, lp["moe"], cfg)
        x = x + f
    else:
        x = x + apply_ffn(h, lp["ffn"], cfg.act)
    return x, k, v


def _mla_layer_prefill(x, lp, cfg, positions, moe_layer):
    h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
    a = mla_mod.mla_attention_train(h, lp["attn"], cfg, positions)
    c_kv, k_rope = mla_mod._latent(h, lp["attn"], cfg, positions)
    x = x + a
    h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
    if moe_layer:
        f, _ = moe_mod.apply_moe(h, lp["moe"], cfg)
        x = x + f
    else:
        x = x + apply_ffn(h, lp["ffn"], cfg.act)
    return x, c_kv, k_rope[:, :, 0, :]


def prefill(params: Params, cfg, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
    """Process the prompt; returns (last-position logits (B,V), cache)."""
    x, _ = _embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    n_dense = _stacked_layer_params(params, cfg)
    mla = cfg.mla is not None
    layer_fn = _mla_layer_prefill if mla else _attn_layer_prefill

    def make_body(moe_layer):
        def body(x, lp):
            x, a, bb = layer_fn(x, lp, cfg, positions, moe_layer)
            return x, (a, bb)
        return _remat(body, cfg)

    caches_a, caches_b = [], []
    if "dense_layers" in params:
        x, (a0, b0) = scan_or_unroll(make_body(False), x, params["dense_layers"], cfg.unroll)
        caches_a.append(a0)
        caches_b.append(b0)
    if "moe_layers" in params:
        x, (a1, b1) = scan_or_unroll(make_body(True), x, params["moe_layers"], cfg.unroll)
        caches_a.append(a1)
        caches_b.append(b1)
    a = jnp.concatenate(caches_a, 0)
    bb = jnp.concatenate(caches_b, 0)
    new_len = jnp.full((b,), s, jnp.int32)
    if mla:
        cache = {"latent": a, "rope": bb, "len": new_len}
    else:
        cache = {"k": a, "v": bb, "len": new_len}
    logits = _logits(x[:, -1:, :], params, cfg)[:, 0, :]
    return logits, cache
