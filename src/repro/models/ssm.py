"""Mamba-2 (SSD) block: chunked-scan training path + recurrent decode.

Structure per arXiv:2405.21060 (state-space duality):

  in_proj -> [z | x | B | C | dt]; causal depthwise conv over (x,B,C);
  SSD with per-head scalar decay a_t = exp(dt_t * A_h); gated RMSNorm;
  out_proj.

The training path is the exact chunked algorithm: intra-chunk quadratic
attention-like term + inter-chunk recurrent state carried through a
``lax.scan`` (chunk_size from config; the (Q x Q) decay matrix is the
only quadratic buffer and never exceeds one chunk).  The decode path is
the O(1) recurrence ``S <- a S + dt B x^T; y = C.S + D x`` over state
``(B, H, N, P)``.

A hypothesis property test asserts chunked == naive recurrence.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import F32, dense_init, init_rmsnorm, rms_norm

Params = Dict[str, Any]


def ssm_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim


def init_mamba2(key, cfg) -> Params:
    s = cfg.ssm
    d_inner, h, conv_dim = ssm_dims(cfg)
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + h
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model, d_in_proj), dt),
        "conv_w": dense_init(ks[1], (s.d_conv, conv_dim), dt, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": init_rmsnorm(d_inner, dt),
        "out_proj": dense_init(ks[2], (d_inner, cfg.d_model), dt),
    }


def _split_proj(xz, cfg):
    s = cfg.ssm
    d_inner, h, _ = ssm_dims(cfg)
    gn = s.n_groups * s.d_state
    z, x, B, C, dt = jnp.split(
        xz, [d_inner, 2 * d_inner, 2 * d_inner + gn, 2 * d_inner + 2 * gn],
        axis=-1)
    return z, x, B, C, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C).  With ``state``
    ((B,K-1,C) trailing inputs) performs the streaming update and returns
    (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state, x], axis=1)
    # windowed dot: y_t = sum_k w_k * x_{t-K+1+k}
    ys = sum(xp[:, i : i + x.shape[1], :].astype(F32) * w[i].astype(F32)
             for i in range(k))
    y = jax.nn.silu(ys + b.astype(F32)).astype(x.dtype)
    new_state = xp[:, -(k - 1):, :] if k > 1 else xp[:, :0, :]
    return y, new_state


# --------------------------------------------------------------------------- #
# SSD core                                                                    #
# --------------------------------------------------------------------------- #

def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, unroll: bool = False):
    """Exact SSD via chunked scan.

    x  : (B, S, H, P)   per-head inputs
    dt : (B, S, H)      softplus'd step sizes
    A  : (H,)           negative decay rates
    Bm : (B, S, G, N)   input maps (groups broadcast over heads)
    Cm : (B, S, G, N)   output maps
    -> y (B, S, H, P)
    """
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    hg = h // g
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xdt = (x.astype(F32) * dt.astype(F32)[..., None])          # dt premultiplied
    a = dt.astype(F32) * A[None, None, :]                       # (B,S,H) log-decay

    def rs(t, extra):  # (B,S,...) -> (nc, B, chunk, ...)
        return jnp.moveaxis(t.reshape(b, nc, chunk, *extra), 1, 0)

    xc = rs(xdt, (h, p))
    ac = rs(a, (h,))
    Bc = rs(Bm.astype(F32), (g, n))
    Cc = rs(Cm.astype(F32), (g, n))

    def chunk_step(S_prev, inp):
        xk, ak, Bk, Ck = inp          # (B,chunk,H,P), (B,chunk,H), (B,chunk,G,N)
        l = jnp.cumsum(ak, axis=1)    # (B,chunk,H) cumulative log-decay
        ltot = l[:, -1, :]            # (B,H)
        # intra-chunk: scores[i,j] = exp(l_i - l_j) * (C_i . B_j), j <= i
        Bh = Bk.reshape(b, chunk, g, 1, n)
        Ch = Ck.reshape(b, chunk, g, 1, n)
        cb = jnp.einsum("bigxn,bjgxn->bgij", Ch, Bh)            # (B,G,Q,Q)
        cb = jnp.repeat(cb, hg, axis=1)                         # (B,H,Q,Q)
        decay = l[:, :, None, :].transpose(0, 3, 1, 2) - \
            l[:, None, :, :].transpose(0, 3, 1, 2)              # (B,H,Q,Q) l_i-l_j
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(mask[None, None], jnp.exp(decay) * cb, 0.0)
        y_intra = jnp.einsum("bhij,bjhp->bihp", w, xk)
        # inter-chunk: contribution of carried state, decayed to position i
        Ch_full = jnp.repeat(Ck, hg, axis=2).reshape(b, chunk, h, n)
        y_inter = jnp.einsum("bihn,bhnp->bihp",
                             Ch_full * jnp.exp(l)[..., None], S_prev)
        # new state: S = exp(ltot) S_prev + sum_j exp(ltot - l_j) B_j x_j^T
        wj = jnp.exp(ltot[:, None, :] - l)                      # (B,chunk,H)
        Bh_full = jnp.repeat(Bk, hg, axis=2).reshape(b, chunk, h, n)
        S_chunk = jnp.einsum("bjhn,bjhp->bhnp", Bh_full * wj[..., None], xk)
        S_new = jnp.exp(ltot)[..., None, None] * S_prev + S_chunk
        return S_new, y_intra + y_inter

    from .unroll import scan_or_unroll
    S0 = jnp.zeros((b, h, n, p), F32)
    _, ys = scan_or_unroll(jax.checkpoint(chunk_step), S0, (xc, ac, Bc, Cc),
                           unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y


def ssd_recurrent_step(S, x_t, dt_t, A, B_t, C_t):
    """One decode step.  S: (B,H,N,P); x_t: (B,H,P); dt_t: (B,H);
    B_t/C_t: (B,G,N) -> (y (B,H,P), S_new)."""
    b, h, n, p = S.shape
    g = B_t.shape[1]
    hg = h // g
    a = jnp.exp(dt_t.astype(F32) * A[None, :])                  # (B,H)
    Bh = jnp.repeat(B_t.astype(F32), hg, axis=1)                # (B,H,N)
    Ch = jnp.repeat(C_t.astype(F32), hg, axis=1)
    xdt = x_t.astype(F32) * dt_t.astype(F32)[..., None]         # (B,H,P)
    S_new = a[..., None, None] * S + jnp.einsum("bhn,bhp->bhnp", Bh, xdt)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, S_new)
    return y, S_new


# --------------------------------------------------------------------------- #
# full block                                                                  #
# --------------------------------------------------------------------------- #

def mamba2_block_train(xin: jnp.ndarray, p: Params, cfg) -> jnp.ndarray:
    """(B,S,D) -> (B,S,D). Pre-norm residual handled by caller."""
    s_cfg = cfg.ssm
    d_inner, h, _ = ssm_dims(cfg)
    xz = jnp.einsum("bsd,de->bse", xin, p["in_proj"],
                    preferred_element_type=F32).astype(xin.dtype)
    z, x, B, C, dt = _split_proj(xz, cfg)
    xbc, _ = _causal_conv(jnp.concatenate([x, B, C], axis=-1),
                          p["conv_w"], p["conv_b"])
    x, B, C = jnp.split(xbc, [d_inner, d_inner + s_cfg.n_groups * s_cfg.d_state],
                        axis=-1)
    b_, s_, _ = x.shape
    xh = x.reshape(b_, s_, h, s_cfg.head_dim)
    Bm = B.reshape(b_, s_, s_cfg.n_groups, s_cfg.d_state)
    Cm = C.reshape(b_, s_, s_cfg.n_groups, s_cfg.d_state)
    dt_s = jax.nn.softplus(dt.astype(F32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    y = ssd_chunked(xh, dt_s, A, Bm, Cm, s_cfg.chunk_size,
                    unroll=cfg.unroll)
    y = y + xh.astype(F32) * p["D"][None, None, :, None]
    y = y.reshape(b_, s_, d_inner).astype(xin.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(xin.dtype),
                 p["norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"],
                      preferred_element_type=F32).astype(xin.dtype)


def mamba2_init_state(cfg, batch: int, dtype):
    s = cfg.ssm
    d_inner, h, conv_dim = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, s.d_state, s.head_dim), jnp.float32),
    }


def mamba2_block_decode(xin: jnp.ndarray, p: Params, cfg, state: Dict
                        ) -> Tuple[jnp.ndarray, Dict]:
    """xin: (B,1,D) one token; streaming conv + recurrent SSD."""
    s_cfg = cfg.ssm
    d_inner, h, _ = ssm_dims(cfg)
    xz = jnp.einsum("bsd,de->bse", xin, p["in_proj"],
                    preferred_element_type=F32).astype(xin.dtype)
    z, x, B, C, dt = _split_proj(xz, cfg)
    xbc, conv_state = _causal_conv(jnp.concatenate([x, B, C], axis=-1),
                                   p["conv_w"], p["conv_b"],
                                   state=state["conv"])
    x, B, C = jnp.split(xbc, [d_inner, d_inner + s_cfg.n_groups * s_cfg.d_state],
                        axis=-1)
    b_ = x.shape[0]
    xh = x.reshape(b_, h, s_cfg.head_dim)
    Bm = B.reshape(b_, s_cfg.n_groups, s_cfg.d_state)
    Cm = C.reshape(b_, s_cfg.n_groups, s_cfg.d_state)
    dt_s = jax.nn.softplus(dt.reshape(b_, h).astype(F32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    y, ssm_state = ssd_recurrent_step(state["ssm"], xh, dt_s, A, Bm, Cm)
    y = y + xh.astype(F32) * p["D"][None, :, None]
    y = y.reshape(b_, 1, d_inner).astype(xin.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(xin.dtype),
                 p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"],
                     preferred_element_type=F32).astype(xin.dtype)
    return out, {"conv": conv_state, "ssm": ssm_state}
