"""Mixture-of-Experts FFN: top-k routing, shared experts, EP-shardable.

Dispatch uses the sort-based static-capacity formulation (MaxText /
Switch-style): tokens are permuted into an ``(E, capacity, d)`` buffer by
router assignment, each expert runs a dense GLU on its buffer, and
results scatter back weighted by router gates.  All shapes are static
(jit-friendly); tokens over capacity drop (standard capacity-factor
semantics), tracked by the aux outputs.

Sharding: the expert axis maps to the ``model`` mesh axis (expert
parallelism); with FSDP the per-expert weight matrices additionally shard
their d_model/d_ff dims over ``data``.  XLA/GSPMD inserts the all-to-all
at the (tokens -> expert buffer) boundary.

The PFCS integration (serving tier) consumes ``router_top_idx`` from the
aux dict: each token batch's active-expert set becomes a composite in the
expert-cache registry (see ``repro.serving.expert_cache``).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import F32, apply_ffn, dense_init, init_ffn

Params = Dict[str, Any]


def _constrain(x, spec_entries):
    """with_sharding_constraint against the ambient mesh; silently a no-op
    when no mesh (or no matching axes) is active (smoke tests, examples)."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec_entries))
    except Exception:
        return x


def init_moe(key, cfg) -> Params:
    m = cfg.moe
    d = cfg.d_model
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
    ks = jax.random.split(key, 6)
    p: Params = {
        "router": dense_init(ks[0], (d, m.n_experts), jnp.float32),  # f32 router
        "w_gate": dense_init(ks[1], (m.n_experts, d, m.d_ff_expert), dt),
        "w_up": dense_init(ks[2], (m.n_experts, d, m.d_ff_expert), dt),
        "w_down": dense_init(ks[3], (m.n_experts, m.d_ff_expert, d), dt),
    }
    if m.n_shared_experts > 0:
        p["shared"] = init_ffn(ks[4], d, m.d_ff_shared * m.n_shared_experts,
                               cfg.act, dt)
    return p


def _capacity(n_tokens: int, m) -> int:
    cap = int(math.ceil(n_tokens * m.top_k / m.n_experts * m.capacity_factor))
    return max(8, -(-cap // 8) * 8)  # round up to 8 for lane alignment


def apply_moe(x: jnp.ndarray, p: Params, cfg) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, S, D) -> (B, S, D), aux (load-balance loss, router stats)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    # --- routing (f32 for numerics) --------------------------------------- #
    logits = jnp.einsum("td,de->te", xt.astype(F32), p["router"])      # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, top_idx = jax.lax.top_k(probs, m.top_k)                 # (T,K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                        # renorm

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)                                            # (E,)
    onehot_top1 = jax.nn.one_hot(top_idx[:, 0], m.n_experts, dtype=F32)
    ce = onehot_top1.mean(axis=0)
    aux_loss = m.n_experts * jnp.sum(me * ce) * m.router_aux_weight

    # --- sort-based dispatch ---------------------------------------------- #
    cap = _capacity(t, m)
    flat_expert = top_idx.reshape(-1)                                  # (T*K,)
    flat_token = jnp.repeat(jnp.arange(t), m.top_k)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert)                                   # stable
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # rank within expert: position - start offset of that expert's run
    counts = jnp.bincount(se, length=m.n_experts)                      # (E,)
    starts = jnp.cumsum(counts) - counts                               # (E,)
    rank = jnp.arange(t * m.top_k) - starts[se]                        # (TK,)
    keep = rank < cap                                                  # drops
    slot = jnp.where(keep, se * cap + rank, t * m.top_k)  # overflow -> OOB

    # gather tokens into (E*cap, d); OOB slots scatter-drop
    buf = jnp.zeros((m.n_experts * cap, d), dtype=x.dtype)
    buf = buf.at[jnp.clip(slot, 0, m.n_experts * cap - 1)].add(
        jnp.where(keep[:, None], xt[st], 0).astype(x.dtype))
    buf = buf.reshape(m.n_experts, cap, d)
    if cfg.shard_moe_dispatch:
        # Keep FSDP-sharded expert weights IN PLACE: d-shard the dispatch
        # buffer so the expert matmul contracts d locally (partial sums
        # reduce over 'data') instead of all-gathering E/16 x d x 3ff of
        # weights per layer — the decode-path collective killer.
        buf = _constrain(buf, ("model", None, "data"))

    # --- expert computation (grouped GLU einsum over E) ------------------- #
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"],
                               preferred_element_type=F32))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"], preferred_element_type=F32)
    h = (g * u).astype(x.dtype)
    if cfg.shard_moe_dispatch:
        h = _constrain(h, ("model", None, None))
    eo = jnp.einsum("ecf,efd->ecd", h, p["w_down"],
                    preferred_element_type=F32).astype(x.dtype)         # (E,cap,d)

    # --- combine back ------------------------------------------------------ #
    eo_flat = eo.reshape(m.n_experts * cap, d)
    if cfg.moe_combine == "gather":
        # inverse-permutation gather + einsum combine: bf16 gather and a
        # dense (T,K,d)x(T,K) contraction replace the f32 scatter-add —
        # ~2x less combine traffic, no atomic scatter in the backward.
        inv = jnp.zeros((t * m.top_k,), jnp.int32).at[order].set(
            jnp.clip(slot, 0, m.n_experts * cap - 1).astype(jnp.int32))
        keep_tk = jnp.zeros((t * m.top_k,), bool).at[order].set(keep)
        gathered = eo_flat[inv].reshape(t, m.top_k, d)                  # bf16
        w_tk = jnp.where(keep_tk.reshape(t, m.top_k), gate_vals, 0.0)
        out = jnp.einsum("tkd,tk->td", gathered, w_tk,
                         preferred_element_type=F32)
    else:
        gathered = eo_flat[jnp.clip(slot, 0, m.n_experts * cap - 1)]    # (TK,d)
        gathered = jnp.where(keep[:, None], gathered, 0)
        weighted = gathered.astype(F32) * sg[:, None]
        out = jnp.zeros((t, d), dtype=F32).at[st].add(weighted)

    # --- shared experts (dense branch) -------------------------------------- #
    if "shared" in p:
        out = out + apply_ffn(xt, p["shared"], cfg.act).astype(F32)

    aux = {
        "moe_aux_loss": aux_loss,
        "router_top_idx": top_idx,          # (T, K) — PFCS expert-cache feed
        "dropped_frac": 1.0 - keep.mean(),
        "expert_load": counts,
    }
    return out.astype(x.dtype).reshape(b, s, d), aux
