"""Hybrid and recurrent stacks: zamba2 (Mamba-2 + shared attention) and
xlstm (mLSTM/sLSTM interleave).

zamba2 layout: ``n_layers`` Mamba-2 blocks; after every
``shared_attn_every`` Mamba layers one of ``n_shared_attn_blocks`` shared
transformer blocks (weights reused across applications, alternating) runs
on the residual stream.  Each *application* keeps its own KV cache.

xlstm layout: every ``slstm_every``-th block is an sLSTM; the rest are
mLSTM.  Contiguous mLSTM runs are scanned (stacked params); sLSTM blocks
are unrolled (they are few).
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import ssm as ssm_mod
from .unroll import scan_or_unroll
from . import xlstm as xl
from .layers import (F32, apply_ffn, dense_init, embed_tokens, init_embedding,
                     init_ffn, init_rmsnorm, rms_norm, unembed, _dtype)

Params = Dict[str, Any]


# =========================================================================== #
# zamba2                                                                      #
# =========================================================================== #

def _zamba_groups(cfg) -> List[int]:
    """Sizes of Mamba runs between shared-attn applications."""
    k = cfg.shared_attn_every
    n = cfg.n_layers
    full, rem = divmod(n, k)
    return [k] * full + ([rem] if rem else [])


def n_attn_applications(cfg) -> int:
    return len([g for g in _zamba_groups(cfg)][: cfg.n_layers // cfg.shared_attn_every])


def init_zamba(key, cfg) -> Params:
    dt = _dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    lkeys = jax.random.split(ks[0], cfg.n_layers)
    mamba = jax.vmap(lambda k: {
        "ln": init_rmsnorm(cfg.d_model, dt),
        "mix": ssm_mod.init_mamba2(k, cfg),
    })(lkeys)
    skeys = jax.random.split(ks[1], cfg.n_shared_attn_blocks)
    shared = [
        {
            "ln_attn": init_rmsnorm(cfg.d_model, dt),
            "attn": attn.init_attention(jax.random.fold_in(sk, 0), cfg),
            "ln_ffn": init_rmsnorm(cfg.d_model, dt),
            "ffn": init_ffn(jax.random.fold_in(sk, 1), cfg.d_model, cfg.d_ff,
                            cfg.act, dt),
        }
        for sk in skeys
    ]
    return {
        "embed": init_embedding(ks[2], cfg.vocab_size, cfg.d_model, dt),
        "mamba_layers": mamba,
        "shared_blocks": shared,
        "ln_f": init_rmsnorm(cfg.d_model, dt),
        "unembed": dense_init(ks[3], (cfg.vocab_size, cfg.d_model), dt, 0.02),
    }


def _shared_block_train(x, sp, cfg, positions):
    h = rms_norm(x, sp["ln_attn"], cfg.norm_eps)
    q, k, v = attn.qkv_project(h, sp["attn"], cfg, positions)
    o = attn.attention_chunked(q, k, v, chunk=cfg.attn_chunk, causal=True, unroll=cfg.unroll)
    x = x + attn.out_project(o, sp["attn"])
    h = rms_norm(x, sp["ln_ffn"], cfg.norm_eps)
    return x + apply_ffn(h, sp["ffn"], cfg.act)


def _mamba_run_train(x, stacked, cfg):
    def body(x, lp):
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        return x + ssm_mod.mamba2_block_train(h, lp["mix"], cfg), None
    body = (jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
            if cfg.remat == "full" else body)
    x, _ = scan_or_unroll(body, x, stacked, cfg.unroll)
    return x


def _slice_stack(stacked, start, size):
    return jax.tree.map(lambda a: jax.lax.dynamic_slice_in_dim(a, start, size, 0),
                        stacked)


def zamba_train_logits(params, cfg, batch):
    x = embed_tokens(batch["tokens"], params["embed"])
    positions = jnp.arange(x.shape[1])[None, :]
    off = 0
    for gi, gsize in enumerate(_zamba_groups(cfg)):
        x = _mamba_run_train(x, _slice_stack(params["mamba_layers"], off, gsize),
                             cfg)
        off += gsize
        if gsize == cfg.shared_attn_every:  # full group -> shared attn
            sp = params["shared_blocks"][gi % cfg.n_shared_attn_blocks]
            x = _shared_block_train(x, sp, cfg, positions)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(x, params["unembed"])
    return logits, {"aux_loss": jnp.zeros((), F32),
                    "loss_mask": jnp.ones(batch["tokens"].shape, bool),
                    "targets": batch["tokens"]}


def zamba_init_cache(cfg, batch, max_len):
    dt = _dtype(cfg.dtype)
    n_attn = cfg.n_layers // cfg.shared_attn_every
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    d_inner, h, conv_dim = ssm_mod.ssm_dims(cfg)
    s = cfg.ssm
    return {
        "k": jnp.zeros((n_attn, batch, max_len, kv, hd), dt),
        "v": jnp.zeros((n_attn, batch, max_len, kv, hd), dt),
        "conv": jnp.zeros((cfg.n_layers, batch, s.d_conv - 1, conv_dim), dt),
        "ssm": jnp.zeros((cfg.n_layers, batch, h, s.d_state, s.head_dim), F32),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def zamba_decode_step(params, cfg, batch, cache):
    x = embed_tokens(batch["tokens"], params["embed"])
    cache_len = cache["len"]
    positions = cache_len[:, None]

    def mamba_body(x, inp):
        lp, conv, ssm = inp
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        o, st = ssm_mod.mamba2_block_decode(h, lp["mix"], cfg,
                                            {"conv": conv, "ssm": ssm})
        return x + o, (st["conv"], st["ssm"])

    new_conv, new_ssm, new_k, new_v = [], [], [], []
    off = 0
    for gi, gsize in enumerate(_zamba_groups(cfg)):
        stacked = _slice_stack(params["mamba_layers"], off, gsize)
        conv_sl = jax.lax.dynamic_slice_in_dim(cache["conv"], off, gsize, 0)
        ssm_sl = jax.lax.dynamic_slice_in_dim(cache["ssm"], off, gsize, 0)
        x, (c_new, s_new) = scan_or_unroll(mamba_body, x,
                                           (stacked, conv_sl, ssm_sl),
                                           cfg.unroll)
        new_conv.append(c_new)
        new_ssm.append(s_new)
        off += gsize
        if gsize == cfg.shared_attn_every:
            ai = gi
            sp = params["shared_blocks"][gi % cfg.n_shared_attn_blocks]
            h = rms_norm(x, sp["ln_attn"], cfg.norm_eps)
            q, k, v = attn.qkv_project(h, sp["attn"], cfg, positions)
            kc, vc = cache["k"][ai], cache["v"][ai]
            kc = jax.vmap(lambda c, pos, val: jax.lax.dynamic_update_slice(
                c, val, (pos, 0, 0)))(kc, cache_len, k)
            vc = jax.vmap(lambda c, pos, val: jax.lax.dynamic_update_slice(
                c, val, (pos, 0, 0)))(vc, cache_len, v)
            o = attn.decode_attention(q, kc, vc, cache_len + 1)
            x = x + attn.out_project(o, sp["attn"])
            h = rms_norm(x, sp["ln_ffn"], cfg.norm_eps)
            x = x + apply_ffn(h, sp["ffn"], cfg.act)
            new_k.append(kc[None])
            new_v.append(vc[None])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(x, params["unembed"])
    cache = {
        "k": jnp.concatenate(new_k, 0),
        "v": jnp.concatenate(new_v, 0),
        "conv": jnp.concatenate(new_conv, 0),
        "ssm": jnp.concatenate(new_ssm, 0),
        "len": cache_len + 1,
    }
    return logits, cache


def zamba_prefill(params, cfg, batch):
    """Prompt pass: run train path while collecting attn KV + final SSM
    states via the decode-compatible cache layout."""
    # For the dry run we reuse the train forward and rebuild caches by
    # re-running the last position; a production serving path would fuse
    # these.  SSM/conv states come from a streaming pass (cheap: O(S)).
    x = embed_tokens(batch["tokens"], params["embed"])
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    cache = zamba_init_cache(cfg, b, s)
    off = 0
    new_k, new_v, new_conv, new_ssm = [], [], [], []

    def mamba_prefill_body(x, inp):
        lp = inp
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        y = ssm_mod.mamba2_block_train(h, lp["mix"], cfg)
        # final states via one streaming step over the tail would require
        # the recurrence; approximate final conv state exactly from inputs:
        return x + y, None

    for gi, gsize in enumerate(_zamba_groups(cfg)):
        stacked = _slice_stack(params["mamba_layers"], off, gsize)
        x = _mamba_run_train(x, stacked, cfg)
        off += gsize
        if gsize == cfg.shared_attn_every:
            sp = params["shared_blocks"][gi % cfg.n_shared_attn_blocks]
            h = rms_norm(x, sp["ln_attn"], cfg.norm_eps)
            q, k, v = attn.qkv_project(h, sp["attn"], cfg, positions)
            o = attn.attention_chunked(q, k, v, chunk=cfg.attn_chunk, causal=True, unroll=cfg.unroll)
            x = x + attn.out_project(o, sp["attn"])
            h2 = rms_norm(x, sp["ln_ffn"], cfg.norm_eps)
            x = x + apply_ffn(h2, sp["ffn"], cfg.act)
            new_k.append(k[None])
            new_v.append(v[None])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(x[:, -1:, :], params["unembed"])[:, 0]
    cache["k"] = jnp.concatenate(new_k, 0) if new_k else cache["k"]
    cache["v"] = jnp.concatenate(new_v, 0) if new_v else cache["v"]
    cache["len"] = jnp.full((b,), s, jnp.int32)
    return logits, cache


# =========================================================================== #
# xLSTM stack                                                                 #
# =========================================================================== #

def _xlstm_runs(cfg) -> List[Tuple[str, int]]:
    """[('m', run_len) | ('s', 1), ...] covering n_layers blocks."""
    k = cfg.xlstm.slstm_every
    runs: List[Tuple[str, int]] = []
    i = 0
    while i < cfg.n_layers:
        # blocks i..: (k-1) mLSTM then 1 sLSTM
        m_run = min(k - 1, cfg.n_layers - i)
        if m_run:
            runs.append(("m", m_run))
            i += m_run
        if i < cfg.n_layers:
            runs.append(("s", 1))
            i += 1
    return runs


def init_xlstm_stack(key, cfg) -> Params:
    dt = _dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    runs = _xlstm_runs(cfg)
    n_m = sum(r for t, r in runs if t == "m")
    n_s = sum(r for t, r in runs if t == "s")
    mkeys = jax.random.split(ks[0], n_m)
    m_stack = jax.vmap(lambda k: {
        "ln": init_rmsnorm(cfg.d_model, dt),
        "cell": xl.init_mlstm(k, cfg),
    })(mkeys)
    skeys = jax.random.split(ks[1], max(n_s, 1))
    s_blocks = [{"ln": init_rmsnorm(cfg.d_model, dt),
                 "cell": xl.init_slstm(skeys[i], cfg)} for i in range(n_s)]
    return {
        "embed": init_embedding(ks[2], cfg.vocab_size, cfg.d_model, dt),
        "m_stack": m_stack,
        "s_blocks": s_blocks,
        "ln_f": init_rmsnorm(cfg.d_model, dt),
        "unembed": dense_init(ks[3], (cfg.vocab_size, cfg.d_model), dt, 0.02),
    }


def xlstm_train_logits(params, cfg, batch):
    x = embed_tokens(batch["tokens"], params["embed"])

    def m_body(x, lp):
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        return x + xl.mlstm_block_train(h, lp["cell"], cfg), None
    m_body_r = (jax.checkpoint(m_body,
                               policy=jax.checkpoint_policies.nothing_saveable)
                if cfg.remat == "full" else m_body)

    m_off, s_off = 0, 0
    for kind, run in _xlstm_runs(cfg):
        if kind == "m":
            stacked = _slice_stack(params["m_stack"], m_off, run)
            x, _ = scan_or_unroll(m_body_r, x, stacked, cfg.unroll)
            m_off += run
        else:
            sp = params["s_blocks"][s_off]
            h = rms_norm(x, sp["ln"], cfg.norm_eps)
            x = x + xl.slstm_block_train(h, sp["cell"], cfg)
            s_off += 1
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return unembed(x, params["unembed"]), {
        "aux_loss": jnp.zeros((), F32),
        "loss_mask": jnp.ones(batch["tokens"].shape, bool),
        "targets": batch["tokens"]}


def xlstm_init_cache(cfg, batch, max_len):
    runs = _xlstm_runs(cfg)
    n_m = sum(r for t, r in runs if t == "m")
    n_s = sum(r for t, r in runs if t == "s")
    m0 = xl.mlstm_init_state(cfg, batch)
    s0 = xl.slstm_init_state(cfg, batch)
    stack = lambda tree, n: jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n, *a.shape)), tree)
    return {"m": stack(m0, n_m), "s": stack(s0, max(n_s, 1)),
            "len": jnp.zeros((batch,), jnp.int32)}


def xlstm_decode_step(params, cfg, batch, cache):
    x = embed_tokens(batch["tokens"], params["embed"])

    def m_body(x, inp):
        lp, st = inp
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        o, st = xl.mlstm_block_decode(h, lp["cell"], cfg, st)
        return x + o, st

    m_off, s_off = 0, 0
    new_m, new_s = [], []
    for kind, run in _xlstm_runs(cfg):
        if kind == "m":
            stacked = _slice_stack(params["m_stack"], m_off, run)
            st_sl = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, m_off, run, 0),
                cache["m"])
            x, st_new = scan_or_unroll(m_body, x, (stacked, st_sl),
                                       cfg.unroll)
            new_m.append(st_new)
            m_off += run
        else:
            sp = params["s_blocks"][s_off]
            st = jax.tree.map(lambda a: a[s_off], cache["s"])
            h = rms_norm(x, sp["ln"], cfg.norm_eps)
            o, st = xl.slstm_block_decode(h, sp["cell"], cfg, st)
            x = x + o
            new_s.append(jax.tree.map(lambda a: a[None], st))
            s_off += 1
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(x, params["unembed"])
    cat = lambda lst: jax.tree.map(lambda *a: jnp.concatenate(a, 0), *lst) \
        if lst else None
    new_cache = {"m": cat(new_m) if new_m else cache["m"],
                 "s": cat(new_s) if new_s else cache["s"],
                 "len": cache["len"] + 1}
    return logits, new_cache


def xlstm_prefill(params, cfg, batch):
    """Prompt pass for the recurrent stack: the decode cache is the final
    recurrent state; for the dry-run we run the parallel forward for
    logits and return a freshly-initialized state advanced by one batch
    scan step (production would stream the recurrence)."""
    logits, _ = xlstm_train_logits(params, cfg, batch)
    b, s = batch["tokens"].shape
    cache = xlstm_init_cache(cfg, b, s)
    cache["len"] = jnp.full((b,), s, jnp.int32)
    return logits[:, -1], cache
