"""scan-or-unroll helper.

XLA's ``cost_analysis`` counts a ``lax.scan`` body ONCE regardless of trip
count, which breaks HLO-derived rooflines for layer-stacked models.  The
dry-run probe compiles therefore run with ``cfg.unroll=True``: every scan
site unrolls to a python loop so per-layer (and per-chunk) costs appear
in full.  Production lowering keeps scans (small HLO, fast compiles).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["scan_or_unroll"]


def scan_or_unroll(body, carry, xs, unroll: bool = False):
    """Drop-in for ``jax.lax.scan(body, carry, xs)``."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    if xs is None:
        raise ValueError("unrolled scan needs explicit xs")
    length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if not ys or ys[0] is None:
        return carry, None
    stacked = jax.tree.map(lambda *a: jnp.stack(a, 0), *ys)
    return carry, stacked
