"""Shared model layers: norms, rotary embeddings, FFN variants, embeddings.

Pure-functional JAX: parameters are plain dict pytrees created by the
``init_*`` helpers, applied by the matching ``apply_*`` functions.  All
matmuls accumulate in float32 (``preferred_element_type``) regardless of
the bf16 parameter dtype — the numerically-load-bearing choice for
training at scale.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

F32 = jnp.float32


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------------- #
# init helpers                                                                #
# --------------------------------------------------------------------------- #

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    if scale is None:
        fan_in = shape[0] if len(shape) >= 2 else 1
        scale = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, F32) * scale
            ).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, F32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- #
# norms                                                                       #
# --------------------------------------------------------------------------- #

def init_rmsnorm(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rms_norm(x: jnp.ndarray, p: Params, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(F32)).astype(x.dtype)


def init_layernorm(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype),
            "bias": jnp.zeros((dim,), dtype=dtype)}


def layer_norm(x: jnp.ndarray, p: Params, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(F32) + p["bias"].astype(F32)).astype(x.dtype)


# --------------------------------------------------------------------------- #
# rotary position embedding                                                   #
# --------------------------------------------------------------------------- #

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=F32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate pairs. x: (..., S, H, D), positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)                      # (D/2,)
    ang = positions[..., None].astype(F32) * inv          # (..., S, D/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]                               # (..., S, 1, D/2)
    cos = cos[..., None, :]
    x1 = x[..., 0::2].astype(F32)
    x2 = x[..., 1::2].astype(F32)
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# FFN                                                                         #
# --------------------------------------------------------------------------- #

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def init_ffn(key, d_model: int, d_ff: int, act: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if act in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, (d_model, d_ff), dtype),
            "w_up": dense_init(k2, (d_model, d_ff), dtype),
            "w_down": dense_init(k3, (d_ff, d_model), dtype),
        }
    return {
        "w_up": dense_init(k1, (d_model, d_ff), dtype),
        "w_down": dense_init(k2, (d_ff, d_model), dtype),
    }


def apply_ffn(x: jnp.ndarray, p: Params, act: str) -> jnp.ndarray:
    if act == "swiglu":
        g = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["w_gate"],
                                   preferred_element_type=F32))
        u = jnp.einsum("...d,df->...f", x, p["w_up"], preferred_element_type=F32)
        h = (g * u).astype(x.dtype)
    elif act == "geglu":
        g = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["w_gate"],
                                   preferred_element_type=F32))
        u = jnp.einsum("...d,df->...f", x, p["w_up"], preferred_element_type=F32)
        h = (g * u).astype(x.dtype)
    else:
        h = _ACTS[act](jnp.einsum("...d,df->...f", x, p["w_up"],
                                  preferred_element_type=F32)).astype(x.dtype)
    out = jnp.einsum("...f,fd->...d", h, p["w_down"], preferred_element_type=F32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# embeddings / unembedding                                                    #
# --------------------------------------------------------------------------- #

def init_embedding(key, vocab: int, d_model: int, dtype) -> Params:
    return {"table": embed_init(key, (vocab, d_model), dtype)}


def embed_tokens(tokens: jnp.ndarray, p: Params) -> jnp.ndarray:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(x: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Logits in float32 — softmax stability at vocab 256k."""
    return jnp.einsum("...d,vd->...v", x, table, preferred_element_type=F32)
