"""Encoder-decoder transformer (seamless-m4t backbone).

Encoder: bidirectional self-attention over projected audio-frame
embeddings (the speech frontend is a stub — ``input_specs`` provides
precomputed fbank-stack features).  Decoder: causal self-attention +
cross-attention over encoder output.  Both stacks scan over layers.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from .unroll import scan_or_unroll
from .layers import (F32, apply_ffn, dense_init, embed_tokens, init_embedding,
                     init_ffn, init_rmsnorm, rms_norm, unembed, _dtype)

Params = Dict[str, Any]


def _init_enc_layer(key, cfg):
    dt = _dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": init_rmsnorm(cfg.d_model, dt),
        "attn": attn.init_attention(k1, cfg),
        "ln_ffn": init_rmsnorm(cfg.d_model, dt),
        "ffn": init_ffn(k2, cfg.d_model, cfg.d_ff, cfg.act, dt),
    }


def _init_dec_layer(key, cfg):
    dt = _dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln_self": init_rmsnorm(cfg.d_model, dt),
        "self_attn": attn.init_attention(k1, cfg),
        "ln_cross": init_rmsnorm(cfg.d_model, dt),
        "cross_attn": attn.init_attention(k2, cfg),
        "ln_ffn": init_rmsnorm(cfg.d_model, dt),
        "ffn": init_ffn(k3, cfg.d_model, cfg.d_ff, cfg.act, dt),
    }


def init_encdec(key, cfg) -> Params:
    dt = _dtype(cfg.dtype)
    e = cfg.encdec
    ks = jax.random.split(key, 6)
    ekeys = jax.random.split(ks[0], e.n_encoder_layers)
    dkeys = jax.random.split(ks[1], e.n_decoder_layers)
    return {
        "frontend_proj": dense_init(ks[2], (cfg.frontend.feature_dim,
                                            cfg.d_model), dt),
        "embed": init_embedding(ks[3], cfg.vocab_size, cfg.d_model, dt),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(ekeys),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dkeys),
        "ln_enc": init_rmsnorm(cfg.d_model, dt),
        "ln_f": init_rmsnorm(cfg.d_model, dt),
        "unembed": dense_init(ks[4], (cfg.vocab_size, cfg.d_model), dt, 0.02),
    }


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def encode(params, cfg, features: jnp.ndarray) -> jnp.ndarray:
    """features: (B, S_enc, feat) -> (B, S_enc, D)."""
    x = jnp.einsum("bsf,fd->bsd", features, params["frontend_proj"],
                   preferred_element_type=F32).astype(
        _dtype(cfg.dtype))
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, lp):
        h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
        q, k, v = attn.qkv_project(h, lp["attn"], cfg, positions)
        o = attn.attention_chunked(q, k, v, chunk=cfg.attn_chunk, causal=False, unroll=cfg.unroll)
        x = x + attn.out_project(o, lp["attn"])
        h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
        return x + apply_ffn(h, lp["ffn"], cfg.act), None

    x, _ = scan_or_unroll(_remat(body, cfg), x, params["enc_layers"],
                          cfg.unroll)
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)


def _dec_layer_train(x, lp, cfg, enc_out, positions):
    h = rms_norm(x, lp["ln_self"], cfg.norm_eps)
    q, k, v = attn.qkv_project(h, lp["self_attn"], cfg, positions)
    o = attn.attention_chunked(q, k, v, chunk=cfg.attn_chunk, causal=True, unroll=cfg.unroll)
    x = x + attn.out_project(o, lp["self_attn"])
    h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
    enc_pos = jnp.arange(enc_out.shape[1])[None, :]
    qc, _, _ = attn.qkv_project(h, lp["cross_attn"], cfg, positions)
    _, kc, vc = attn.qkv_project(enc_out, lp["cross_attn"], cfg, enc_pos)
    oc = attn.attention_full(qc, kc, vc, causal=False)
    x = x + attn.out_project(oc, lp["cross_attn"])
    h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
    return x + apply_ffn(h, lp["ffn"], cfg.act)


def encdec_train_logits(params, cfg, batch) -> Tuple[jnp.ndarray, Dict]:
    """batch: {'features': (B,S_enc,F), 'tokens': (B,S_dec)}."""
    enc_out = encode(params, cfg, batch["features"])
    x = embed_tokens(batch["tokens"], params["embed"])
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, lp):
        return _dec_layer_train(x, lp, cfg, enc_out, positions), None

    x, _ = scan_or_unroll(_remat(body, cfg), x, params["dec_layers"],
                          cfg.unroll)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return unembed(x, params["unembed"]), {
        "aux_loss": jnp.zeros((), F32),
        "loss_mask": jnp.ones(batch["tokens"].shape, bool),
        "targets": batch["tokens"]}


def encdec_init_cache(cfg, batch, max_len, enc_len):
    dt = _dtype(cfg.dtype)
    l = cfg.encdec.n_decoder_layers
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((l, batch, max_len, kv, hd), dt),
        "v": jnp.zeros((l, batch, max_len, kv, hd), dt),
        "xk": jnp.zeros((l, batch, enc_len, kv, hd), dt),   # cross K (static)
        "xv": jnp.zeros((l, batch, enc_len, kv, hd), dt),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def encdec_prefill(params, cfg, batch):
    """Encode + decoder prompt pass; returns (last logits, cache)."""
    enc_out = encode(params, cfg, batch["features"])
    x = embed_tokens(batch["tokens"], params["embed"])
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    enc_pos = jnp.arange(enc_out.shape[1])[None, :]

    def body(x, lp):
        h = rms_norm(x, lp["ln_self"], cfg.norm_eps)
        q, k, v = attn.qkv_project(h, lp["self_attn"], cfg, positions)
        o = attn.attention_chunked(q, k, v, chunk=cfg.attn_chunk, causal=True, unroll=cfg.unroll)
        x = x + attn.out_project(o, lp["self_attn"])
        h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        qc, _, _ = attn.qkv_project(h, lp["cross_attn"], cfg, positions)
        _, kc, vc = attn.qkv_project(enc_out, lp["cross_attn"], cfg, enc_pos)
        oc = attn.attention_full(qc, kc, vc, causal=False)
        x = x + attn.out_project(oc, lp["cross_attn"])
        h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
        x = x + apply_ffn(h, lp["ffn"], cfg.act)
        return x, (k, v, kc, vc)

    x, (k, v, xk, xv) = scan_or_unroll(_remat(body, cfg), x,
                                       params["dec_layers"], cfg.unroll)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(x[:, -1:, :], params["unembed"])[:, 0]
    cache = {"k": k, "v": v, "xk": xk, "xv": xv,
             "len": jnp.full((b,), s, jnp.int32)}
    return logits, cache


def encdec_decode_step(params, cfg, batch, cache):
    """One decoder token; cross-attention over the cached encoder K/V."""
    x = embed_tokens(batch["tokens"], params["embed"])
    cache_len = cache["len"]
    positions = cache_len[:, None]
    enc_len = cache["xk"].shape[2]

    def body(x, inp):
        lp, kc, vc, xk, xv = inp
        h = rms_norm(x, lp["ln_self"], cfg.norm_eps)
        q, k, v = attn.qkv_project(h, lp["self_attn"], cfg, positions)
        kc = jax.vmap(lambda c, pos, val: jax.lax.dynamic_update_slice(
            c, val, (pos, 0, 0)))(kc, cache_len, k)
        vc = jax.vmap(lambda c, pos, val: jax.lax.dynamic_update_slice(
            c, val, (pos, 0, 0)))(vc, cache_len, v)
        o = attn.decode_attention(q, kc, vc, cache_len + 1)
        x = x + attn.out_project(o, lp["self_attn"])
        h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        qc, _, _ = attn.qkv_project(h, lp["cross_attn"], cfg, positions)
        full = jnp.full((x.shape[0],), enc_len, jnp.int32)
        oc = attn.decode_attention(qc, xk, xv, full)
        x = x + attn.out_project(oc, lp["cross_attn"])
        h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
        x = x + apply_ffn(h, lp["ffn"], cfg.act)
        return x, (kc, vc)

    x, (k_new, v_new) = scan_or_unroll(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]), cfg.unroll)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(x, params["unembed"])
    cache = dict(cache, k=k_new, v=v_new, len=cache_len + 1)
    return logits, cache
