"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed to a shared latent ``c_kv`` (kv_lora_rank) plus a
single decoupled RoPE key (qk_rope_head_dim) — the decode cache stores
only ``(B, S, kv_lora_rank + rope_dim)`` instead of per-head K/V, an
~8x cache reduction at 128 heads.

Train path expands the latent to per-head K/V (cleanest for backward);
decode path keeps the latent cache and expands per step.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import F32, apply_rope, dense_init, init_rmsnorm, rms_norm

Params = Dict[str, Any]
NEG_INF = -1e30


def init_mla(key, cfg) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    return {
        # query low-rank path
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dt),
        "q_a_norm": init_rmsnorm(m.q_lora_rank, dt),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, h, qk_head), dt),
        # kv latent path: latent + decoupled rope key
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dt),
        "kv_a_norm": init_rmsnorm(m.kv_lora_rank, dt),
        "wk_b": dense_init(ks[3], (m.kv_lora_rank, h, m.qk_nope_head_dim), dt),
        "wv_b": dense_init(ks[4], (m.kv_lora_rank, h, m.v_head_dim), dt),
        "wo": dense_init(ks[5], (h, m.v_head_dim, d), dt),
    }


def _project_q(x, p, cfg, positions):
    m = cfg.mla
    cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"], preferred_element_type=F32
                    ).astype(x.dtype)
    cq = rms_norm(cq, p["q_a_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"], preferred_element_type=F32
                   ).astype(x.dtype)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent(x, p, cfg, positions):
    """Returns (c_kv (B,S,R) normalized latent, k_rope (B,S,1,rope))."""
    m = cfg.mla
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"], preferred_element_type=F32
                    ).astype(x.dtype)
    c_kv = rms_norm(kv[..., : m.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank:][:, :, None, :]       # single shared head
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope


def mla_attention_train(x: jnp.ndarray, p: Params, cfg,
                        positions: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence causal MLA (train/prefill). x: (B, S, D)."""
    m = cfg.mla
    b, s, _ = x.shape
    q_nope, q_rope = _project_q(x, p, cfg, positions)
    c_kv, k_rope = _latent(x, p, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"],
                        preferred_element_type=F32).astype(x.dtype)
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"],
                   preferred_element_type=F32).astype(x.dtype)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bqhk,bshk->bhqs", q_nope, k_nope,
                         preferred_element_type=F32)
              + jnp.einsum("bqhk,bsxk->bhqs", q_rope, k_rope,
                           preferred_element_type=F32)) * scale
    qpos = jnp.arange(s)
    mask = qpos[None, :] <= qpos[:, None]
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshk->bqhk", w.astype(v.dtype), v)
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"],
                      preferred_element_type=F32).astype(x.dtype)


def mla_decode(x: jnp.ndarray, p: Params, cfg,
               latent_cache: jnp.ndarray, rope_cache: jnp.ndarray,
               cache_len: jnp.ndarray, positions: jnp.ndarray,
               latent_scale: jnp.ndarray | None = None):
    """One-token decode with latent KV cache.

    latent_cache: (B, S, kv_lora_rank); rope_cache: (B, S, rope_dim).
    With ``latent_scale`` (B, S) the latent cache is int8 (KIVI-style
    per-position quantization; DESIGN.md §Perf) and dequantized on read.
    Returns (out (B,1,D), new latent_cache, new rope_cache[, new scale]).
    """
    m = cfg.mla
    b = x.shape[0]
    q_nope, q_rope = _project_q(x, p, cfg, positions)      # (B,1,H,*)
    c_kv, k_rope = _latent(x, p, cfg, positions)           # (B,1,R), (B,1,1,rope)
    if latent_scale is not None:
        amax = jnp.max(jnp.abs(c_kv.astype(F32)), axis=-1)         # (B,1)
        scale = jnp.maximum(amax, 1e-6) / 127.0
        c_q = jnp.clip(jnp.round(c_kv.astype(F32) / scale[..., None]),
                       -127, 127).astype(jnp.int8)
        latent_cache = jax.vmap(
            lambda cache, pos, val: jax.lax.dynamic_update_slice(
                cache, val, (pos, 0)))(latent_cache, cache_len, c_q)
        latent_scale = jax.vmap(
            lambda cache, pos, val: jax.lax.dynamic_update_slice(
                cache, val, (pos,)))(latent_scale, cache_len, scale)
    else:
        latent_cache = jax.vmap(
            lambda cache, pos, val: jax.lax.dynamic_update_slice(
                cache, val, (pos, 0)))(latent_cache, cache_len, c_kv)
    rope_cache = jax.vmap(
        lambda cache, pos, val: jax.lax.dynamic_update_slice(cache, val, (pos, 0))
    )(rope_cache, cache_len, k_rope[:, :, 0, :])
    new_len = cache_len + 1

    # absorbed attention: score against the latent cache directly
    # q_nope (B,1,H,nope) @ wk_b (R,H,nope) -> q_lat (B,1,H,R)
    if latent_scale is not None:
        lat = (latent_cache.astype(F32)
               * latent_scale[..., None]).astype(x.dtype)   # dequant on read
    else:
        lat = latent_cache
    q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["wk_b"],
                       preferred_element_type=F32).astype(x.dtype)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_lat, lat,
                         preferred_element_type=F32)
              + jnp.einsum("bqhk,bsk->bhqs", q_rope, rope_cache,
                           preferred_element_type=F32)) * scale
    s = latent_cache.shape[1]
    valid = jnp.arange(s)[None, :] < new_len[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    # mix latents, then expand through wv_b (absorbed-V form)
    mixed = jnp.einsum("bhqs,bsr->bqhr", w, lat.astype(w.dtype))
    out = jnp.einsum("bqhr,rhk->bqhk", mixed, p["wv_b"].astype(w.dtype))
    out = jnp.einsum("bqhk,hkd->bqd", out.astype(x.dtype), p["wo"],
                     preferred_element_type=F32).astype(x.dtype)
    return out, latent_cache, rope_cache, latent_scale
