"""Model zoo: every assigned architecture family, pure-functional JAX."""

from .model_zoo import Model, build_model, count_params_analytic

__all__ = ["Model", "build_model", "count_params_analytic"]
