"""Unified model facade: build any assigned architecture from its config.

``build_model(cfg)`` returns a :class:`Model` whose methods are pure
functions suitable for ``jax.jit`` / ``.lower()``:

  * ``init_params(rng)``                   — parameter pytree
  * ``train_logits(params, batch)``        — (logits, aux)
  * ``prefill(params, batch)``             — (last logits, cache)
  * ``decode_step(params, batch, cache)``  — (logits, cache)
  * ``init_cache(batch, max_len)``         — decode cache pytree
  * ``input_specs(shape)``                 — ShapeDtypeStruct stand-ins for
    every model input of an assignment shape (dry-run: zero allocation)

Modality frontends are STUBS per the assignment: ``input_specs`` provides
precomputed frame/patch embeddings.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec

from . import encdec as encdec_mod
from . import hybrid as hybrid_mod
from . import transformer as tfm

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# analytic parameter counts (roofline MODEL_FLOPS = 6 N D)                     #
# --------------------------------------------------------------------------- #

def count_params_analytic(cfg: ArchConfig, active_only: bool = False) -> int:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    glu = 3 if cfg.act in ("swiglu", "geglu") else 2

    def attn_params():
        if cfg.mla is not None:
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            return (d * m.q_lora_rank + m.q_lora_rank * h * qk
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)
                    + h * m.v_head_dim * d)
        return d * h * hd + 2 * d * kv * hd + h * hd * d

    def dense_ffn(ff):
        return glu * d * ff

    total = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)

    if cfg.family in ("dense", "vlm"):
        total += cfg.n_layers * (attn_params() + dense_ffn(cfg.d_ff))
        if cfg.family == "vlm":
            total += cfg.frontend.feature_dim * d + d * d
    elif cfg.family == "moe":
        m = cfg.moe
        nd = m.first_dense_layers
        per_moe = (attn_params() + d * m.n_experts
                   + ((m.top_k if active_only else m.n_experts)
                      * glu * d * m.d_ff_expert)
                   + glu * d * m.d_ff_shared * m.n_shared_experts)
        total += nd * (attn_params() + dense_ffn(cfg.d_ff))
        total += (cfg.n_layers - nd) * per_moe
    elif cfg.family == "audio":
        e = cfg.encdec
        enc = attn_params() + dense_ffn(cfg.d_ff)
        dec = 2 * attn_params() + dense_ffn(cfg.d_ff)
        total += e.n_encoder_layers * enc + e.n_decoder_layers * dec
        total += cfg.frontend.feature_dim * d
    elif cfg.family == "hybrid":
        s = cfg.ssm
        di = s.expand * d
        heads = di // s.head_dim
        mamba = (d * (2 * di + 2 * s.n_groups * s.d_state + heads)
                 + s.d_conv * (di + 2 * s.n_groups * s.d_state)
                 + di * d)
        total += cfg.n_layers * mamba
        total += cfg.n_shared_attn_blocks * (attn_params() + dense_ffn(cfg.d_ff))
    elif cfg.family == "ssm":
        x = cfg.xlstm
        di = int(x.proj_factor_mlstm * d)
        hd_i = di // cfg.n_heads
        mlstm = (d * 2 * di + x.conv_kernel * di + 3 * di * cfg.n_heads * hd_i
                 + 2 * di * cfg.n_heads + di * d)
        slstm = d * 4 * d + cfg.n_heads * (d // cfg.n_heads) * 4 * (d // cfg.n_heads) + d * d
        k = x.slstm_every
        n_s = cfg.n_layers // k
        total += (cfg.n_layers - n_s) * mlstm + n_s * slstm
    return int(total)


# --------------------------------------------------------------------------- #
# Model facade                                                                #
# --------------------------------------------------------------------------- #

@dataclass
class Model:
    cfg: ArchConfig
    init_params: Callable
    train_logits: Callable          # (params, batch) -> (logits, aux)
    prefill: Callable               # (params, batch) -> (last_logits, cache)
    decode_step: Callable           # (params, batch, cache) -> (logits, cache)
    init_cache: Callable            # (batch, max_len) -> cache
    #: MoE archs only: decode_step that also returns the stacked router
    #: top-k indices ((n_moe_layers, B, K) int32) — the PFCS
    #: expert-cache feed (repro.serving, DESIGN.md §7)
    decode_step_router: Optional[Callable] = None

    # -- dry-run input specs ------------------------------------------------ #

    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for the given assignment shape."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        f32 = jnp.float32
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train" or shape.kind == "prefill":
            if cfg.family == "audio":
                return {"features": sds((b, s, cfg.frontend.feature_dim), f32),
                        "tokens": sds((b, s), i32)}
            if cfg.family == "vlm":
                npatch = cfg.frontend.n_positions
                return {"tokens": sds((b, s - npatch), i32),
                        "patches": sds((b, npatch, cfg.frontend.feature_dim), f32)}
            return {"tokens": sds((b, s), i32)}
        # decode: one new token against a cache of length s
        return {"tokens": sds((b, 1), i32)}

    def cache_specs(self, shape: ShapeSpec) -> Any:
        """Shape-only decode cache (len = s - 1: the cache holds the
        seq_len-1 old tokens; the new token extends it to seq_len)."""
        b, s = shape.global_batch, shape.seq_len
        if self.cfg.family == "audio":
            return jax.eval_shape(
                lambda: encdec_mod.encdec_init_cache(self.cfg, b, s, s))
        return jax.eval_shape(lambda: self.init_cache(b, s))

    def param_specs(self, rng=None) -> Params:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.init_params, rng)


def build_model(cfg: ArchConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        return Model(
            cfg=cfg,
            init_params=lambda rng: tfm.init_params(rng, cfg),
            train_logits=lambda p, b: tfm.train_logits(p, cfg, b),
            prefill=lambda p, b: tfm.prefill(p, cfg, b),
            decode_step=lambda p, b, c: tfm.decode_step(p, cfg, b, c),
            init_cache=lambda b, m: tfm.init_cache(cfg, b, m),
            decode_step_router=(
                (lambda p, b, c: tfm.decode_step_router(p, cfg, b, c))
                if cfg.moe is not None else None),
        )
    if fam == "audio":
        return Model(
            cfg=cfg,
            init_params=lambda rng: encdec_mod.init_encdec(rng, cfg),
            train_logits=lambda p, b: encdec_mod.encdec_train_logits(p, cfg, b),
            prefill=lambda p, b: encdec_mod.encdec_prefill(p, cfg, b),
            decode_step=lambda p, b, c: encdec_mod.encdec_decode_step(p, cfg, b, c),
            init_cache=lambda b, m: encdec_mod.encdec_init_cache(cfg, b, m, m),
        )
    if fam == "hybrid":
        return Model(
            cfg=cfg,
            init_params=lambda rng: hybrid_mod.init_zamba(rng, cfg),
            train_logits=lambda p, b: hybrid_mod.zamba_train_logits(p, cfg, b),
            prefill=lambda p, b: hybrid_mod.zamba_prefill(p, cfg, b),
            decode_step=lambda p, b, c: hybrid_mod.zamba_decode_step(p, cfg, b, c),
            init_cache=lambda b, m: hybrid_mod.zamba_init_cache(cfg, b, m),
        )
    if fam == "ssm":
        return Model(
            cfg=cfg,
            init_params=lambda rng: hybrid_mod.init_xlstm_stack(rng, cfg),
            train_logits=lambda p, b: hybrid_mod.xlstm_train_logits(p, cfg, b),
            prefill=lambda p, b: hybrid_mod.xlstm_prefill(p, cfg, b),
            decode_step=lambda p, b, c: hybrid_mod.xlstm_decode_step(p, cfg, b, c),
            init_cache=lambda b, m: hybrid_mod.xlstm_init_cache(cfg, b, m),
        )
    raise ValueError(f"unknown family {fam!r}")
