"""Elastic scaling, failure handling, and straggler mitigation.

On a real 1000+-node fleet this layer sits between the scheduler and the
train loop.  The container has one process, so the *coordination logic*
is implemented and unit-tested against simulated fleet events; the jax
collectives it would drive are the same ones the dry-run compiles.

Components
----------
* :class:`FleetState` — tracks healthy/failed/slow nodes from heartbeats.
* :class:`ElasticPlanner` — given the healthy node count, picks the
  largest valid mesh (pod x data x model) that preserves the model-axis
  requirement, and emits a re-shard plan (which checkpoint to restore,
  new mesh shape, new per-device batch).  Data-parallel size changes keep
  the GLOBAL batch constant by rescaling gradient-accumulation steps —
  bit-identical optimizer trajectory across elastic events.
* :class:`StragglerMonitor` — per-step timing ring buffer; flags nodes
  whose step time exceeds median * threshold repeatedly.  Mitigation
  policy: (1) within-step, rely on backup-task semantics at the input
  pipeline level (slow host's batch is re-assigned); (2) across steps,
  if a node stays slow for ``evict_after`` windows it is treated as
  failed and the ElasticPlanner re-plans without it.

The train driver (``launch/train.py``) wires these to the checkpoint
manager: failure -> plan -> restore latest -> continue.
"""

from __future__ import annotations

import math
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

__all__ = ["FleetState", "ManualClock", "MeshPlan", "ElasticPlanner",
           "StragglerMonitor"]


class ManualClock:
    """Deterministic injectable clock for tests and chaos harnesses.

    Call it like ``time.monotonic`` (returns the current simulated
    time); ``advance(dt)`` moves time forward.  ``FleetState``,
    ``StragglerMonitor``, and ``serving.elastic.ElasticController`` all
    accept a ``clock=`` so no test path ever reads the wall clock.
    """

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


@dataclass
class FleetState:
    n_nodes: int
    chips_per_node: int = 4
    heartbeat_timeout_s: float = 30.0
    clock: Callable[[], float] = time.monotonic
    _last_seen: Dict[int, float] = field(default_factory=dict)
    _failed: set = field(default_factory=set)

    def heartbeat(self, node: int, t: Optional[float] = None) -> None:
        if node not in self._failed:
            self._last_seen[node] = t if t is not None else self.clock()

    def mark_failed(self, node: int) -> None:
        self._failed.add(node)
        self._last_seen.pop(node, None)

    def join(self, node: int, t: Optional[float] = None) -> None:
        """(Re-)admit a node — a replacement host or an elastic grow.
        Clears any failed mark and heartbeats it immediately."""
        self._failed.discard(node)
        self.n_nodes = max(self.n_nodes, node + 1)
        self.heartbeat(node, t)

    def sweep(self, now: Optional[float] = None) -> List[int]:
        """Expire silent nodes; returns newly-failed node ids."""
        now = now if now is not None else self.clock()
        newly = [n for n, t in self._last_seen.items()
                 if now - t > self.heartbeat_timeout_s]
        for n in newly:
            self.mark_failed(n)
        return newly

    @property
    def healthy_nodes(self) -> List[int]:
        return [n for n in range(self.n_nodes) if n not in self._failed]

    @property
    def healthy_chips(self) -> int:
        return len(self.healthy_nodes) * self.chips_per_node


@dataclass(frozen=True)
class MeshPlan:
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    n_chips: int
    accum_steps: int                 # rescaled to keep global batch fixed
    restore_step: Optional[int]


class ElasticPlanner:
    """Pick the largest valid mesh for the surviving fleet.

    The model axis is fixed by the sharding plan (TP degree must divide
    heads/ff); the data (and pod) axes absorb the loss.  Preference order:
    keep pods symmetric; shrink data-parallel width to the largest
    power-of-two that fits; bump accumulation to hold global batch.
    """

    def __init__(self, model_axis: int = 16, base_data_axis: int = 16,
                 base_pods: int = 2, global_batch: int = 256,
                 base_accum: int = 1):
        self.model_axis = model_axis
        self.base_data = base_data_axis
        self.base_pods = base_pods
        self.global_batch = global_batch
        self.base_accum = base_accum

    def plan(self, healthy_chips: int,
             restore_step: Optional[int] = None) -> MeshPlan:
        if healthy_chips < self.model_axis:
            raise RuntimeError(
                f"cannot build model axis {self.model_axis} from "
                f"{healthy_chips} chips")
        max_groups = healthy_chips // self.model_axis   # data*pod capacity
        # largest power-of-two group count <= capacity
        groups = 1 << (max_groups.bit_length() - 1)
        pods = self.base_pods
        while pods > 1 and groups % pods != 0:
            pods //= 2
        data = groups // pods
        base_groups = self.base_data * self.base_pods
        scale = base_groups / groups
        accum = max(1, int(math.ceil(self.base_accum * scale)))
        if pods > 1:
            shape = (pods, data, self.model_axis)
            axes = ("pod", "data", "model")
        else:
            shape = (data, self.model_axis)
            axes = ("data", "model")
        return MeshPlan(shape, axes, groups * self.model_axis, accum,
                        restore_step)


class StragglerMonitor:
    def __init__(self, threshold: float = 1.5, window: int = 20,
                 evict_after: int = 3,
                 clock: Callable[[], float] = time.monotonic):
        self.threshold = threshold
        self.window = window
        self.evict_after = evict_after
        self.clock = clock
        self._times: Dict[int, Deque[float]] = defaultdict(
            lambda: deque(maxlen=window))
        self._strikes: Dict[int, int] = defaultdict(int)
        self._last_tick: Dict[int, float] = {}

    def record(self, node: int, step_time_s: float) -> None:
        self._times[node].append(step_time_s)

    def tick(self, node: int) -> Optional[float]:
        """Record a step boundary for ``node`` from the injected clock;
        returns the measured step time (``None`` on the first tick)."""
        now = self.clock()
        last = self._last_tick.get(node)
        self._last_tick[node] = now
        if last is None:
            return None
        dt = now - last
        self.record(node, dt)
        return dt

    def _medians(self) -> Dict[int, float]:
        out = {}
        for n, ts in self._times.items():
            if ts:
                s = sorted(ts)
                out[n] = s[len(s) // 2]
        return out

    def check(self) -> Tuple[List[int], List[int]]:
        """Returns (currently_slow, evict_candidates)."""
        med = self._medians()
        if not med:
            return [], []
        fleet_median = sorted(med.values())[len(med) // 2]
        slow = [n for n, m in med.items()
                if m > self.threshold * fleet_median]
        for n in list(self._strikes):
            if n not in slow:
                self._strikes[n] = 0
        evict = []
        for n in slow:
            self._strikes[n] += 1
            if self._strikes[n] >= self.evict_after:
                evict.append(n)
        return slow, evict
