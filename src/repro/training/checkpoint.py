"""Sharded checkpointing with atomic commit, restore, and retention.

Design (tensorstore-free, production semantics):

  * Each host writes the param/optimizer shards it owns (addressable
    shards) as raw ``.npy`` files under ``step_<N>.tmp/``; a JSON manifest
    records the pytree structure, per-leaf shape/dtype/sharding, step, and
    a content checksum per file.
  * Commit is atomic: the ``.tmp`` directory is fsync'd then renamed to
    ``step_<N>/`` and ``LATEST`` is updated last — a crash mid-write can
    never leave a readable-but-corrupt checkpoint (fault tolerance:
    restart picks up the last committed step).
  * ``restore`` maps shards back onto the (possibly different) current
    mesh via ``jax.make_array_from_callback`` — elastic restarts onto a
    different device count re-shard transparently as long as the global
    shapes match.
  * ``keep_last`` retention prunes old steps after each successful commit.

Async mode: ``save(..., blocking=False)`` snapshots device arrays to host
then writes on a worker thread, overlapping I/O with the next train step
(checkpoint stalls are the classic large-fleet throughput killer).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _leaf_filename(path_s: str) -> str:
    h = hashlib.sha1(path_s.encode()).hexdigest()[:12]
    safe = path_s.replace("/", ".")[:80]
    return f"{safe}.{h}.npy"


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_last: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._worker: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ #
    # save                                                                #
    # ------------------------------------------------------------------ #

    def save(self, step: int, tree: Any, blocking: bool = True) -> Path:
        """Snapshot ``tree`` (pytree of jax/np arrays) at ``step``."""
        # snapshot to host memory first (device buffers may be donated by
        # the next step) — this is the only synchronous part of async mode.
        host_leaves: List[Tuple[str, np.ndarray]] = []

        def snap(path, x):
            host_leaves.append((_path_str(path), np.asarray(x)))
            return None

        jax.tree_util.tree_map_with_path(snap, tree)
        treedef = jax.tree_util.tree_structure(tree)

        if blocking:
            return self._write(step, host_leaves, str(treedef))
        self.wait()  # one in-flight checkpoint at a time
        self._worker = threading.Thread(
            target=self._write, args=(step, host_leaves, str(treedef)),
            daemon=True)
        self._worker.start()
        return self.dir / f"step_{step:08d}"

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _write(self, step: int, leaves, treedef_str: str) -> Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest: Dict[str, Any] = {"step": step, "treedef": treedef_str,
                                    "leaves": {}}
        for path_s, arr in leaves:
            fn = _leaf_filename(path_s)
            fp = tmp / fn
            with open(fp, "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"][path_s] = {
                "file": fn,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256_16": hashlib.sha256(arr.tobytes()).hexdigest()[:16],
            }
        mf = tmp / "manifest.json"
        mf.write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)                      # atomic commit
        (self.dir / "LATEST.tmp").write_text(str(step))
        os.replace(self.dir / "LATEST.tmp", self.dir / "LATEST")
        self._retain()
        return final

    def _retain(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep_last)]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------ #
    # restore                                                             #
    # ------------------------------------------------------------------ #

    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp") and \
                    (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        latest = self.dir / "LATEST"
        if latest.exists():
            s = int(latest.read_text().strip())
            if (self.dir / f"step_{s:08d}" / "manifest.json").exists():
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target_tree: Any, step: Optional[int] = None,
                shardings: Any = None, verify: bool = False) -> Any:
        """Restore into the structure of ``target_tree``.

        ``shardings``: optional matching pytree of NamedSharding — leaves
        are built with ``make_array_from_callback`` (elastic re-shard).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        cdir = self.dir / f"step_{step:08d}"
        manifest = json.loads((cdir / "manifest.json").read_text())

        shard_leaves = None
        if shardings is not None:
            shard_leaves = {}
            def rec(path, s):
                shard_leaves[_path_str(path)] = s
                return s
            jax.tree_util.tree_map_with_path(rec, shardings)

        def load(path, ref):
            path_s = _path_str(path)
            meta = manifest["leaves"].get(path_s)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {path_s}")
            arr = np.load(cdir / meta["file"])
            if verify:
                got = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
                if got != meta["sha256_16"]:
                    raise IOError(f"checksum mismatch for {path_s}")
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"shape mismatch {path_s}: ckpt {arr.shape} vs {ref.shape}")
            if shard_leaves is not None and path_s in shard_leaves:
                sh = shard_leaves[path_s]
                return jax.make_array_from_callback(
                    arr.shape, sh, lambda idx: arr[idx])
            return jax.numpy.asarray(arr)

        return jax.tree_util.tree_map_with_path(load, target_tree)
