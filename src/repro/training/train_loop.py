"""Train-step factory: loss, backward, clip, optimizer, microbatching.

``make_train_step(model, cfg)`` returns a pure
``train_step(state, batch) -> (state, metrics)`` suitable for jit /
``.lower()`` on any mesh.  TrainState bundles params + optimizer state +
step counter.

Cross-entropy is computed in f32 with next-token targets from the model's
aux (``targets`` / ``loss_mask`` — the VLM masks image positions, enc-dec
targets are decoder tokens).

Microbatch gradient accumulation (``accum_steps > 1``) scans over batch
slices — memory for activations drops by the accumulation factor while
the optimizer sees the full-batch gradient (needed to fit train_4k at
global_batch=256 on 16 GB chips for the bigger archs).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model_zoo import Model

from .optimizer import (Optimizer, clip_by_global_norm, cosine_schedule,
                        make_optimizer)

F32 = jnp.float32


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  mask: jnp.ndarray) -> jnp.ndarray:
    """Next-token CE.  logits (B,S,V) f32; targets/mask (B,S)."""
    lg = logits[:, :-1, :]
    tg = targets[:, 1:]
    mk = mask[:, 1:] & mask[:, :-1]
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mk
    return nll.sum() / jnp.maximum(mk.sum(), 1)


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        logits, aux = model.train_logits(params, batch)
        ce = cross_entropy(logits, aux["targets"], aux["loss_mask"])
        total = ce + aux.get("aux_loss", 0.0)
        return total, {"ce": ce, "aux": aux.get("aux_loss", 0.0)}
    return loss_fn


def make_train_step(model: Model,
                    optimizer: Optional[Optimizer] = None,
                    lr: float = 3e-4,
                    warmup: int = 100,
                    total_steps: int = 10_000,
                    max_grad_norm: float = 1.0,
                    accum_steps: int = 1) -> Callable:
    cfg = model.cfg
    opt = optimizer if optimizer is not None else make_optimizer(cfg.optimizer)
    lr_fn = cosine_schedule(lr, warmup, total_steps)
    loss_fn = make_loss_fn(model)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        if accum_steps == 1:
            (loss, parts), grads = grad_fn(state.params, batch)
        else:
            def micro(carry, mb):
                g_acc, l_acc, a_acc = carry
                (l, parts), g = grad_fn(state.params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(F32), g_acc, g)
                return (g_acc, l_acc + l, a_acc + parts["aux"]), None

            def split(x):
                b = x.shape[0]
                return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])

            mbs = jax.tree.map(split, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), state.params)
            (grads, loss, aux_l), _ = jax.lax.scan(
                micro, (g0, jnp.zeros((), F32), jnp.zeros((), F32)), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            parts = {"ce": loss, "aux": aux_l / accum_steps}

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        new_params, new_opt = opt.update(grads, state.params, state.opt_state,
                                         lr_fn(state.step))
        metrics = {"loss": loss, "ce": parts["ce"], "aux_loss": parts["aux"],
                   "grad_norm": gnorm, "lr": lr_fn(state.step)}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def init_train_state(model: Model, rng,
                     optimizer: Optional[Optimizer] = None) -> TrainState:
    opt = optimizer if optimizer is not None else make_optimizer(
        model.cfg.optimizer)
    params = model.init_params(rng)
    return TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))


def abstract_train_state(model: Model,
                         optimizer: Optional[Optimizer] = None) -> TrainState:
    """Shape-only TrainState (dry-run: no allocation)."""
    opt = optimizer if optimizer is not None else make_optimizer(
        model.cfg.optimizer)
    params = model.param_specs()
    opt_state = jax.eval_shape(opt.init, params)
    return TrainState(params, opt_state,
                      jax.ShapeDtypeStruct((), jnp.int32))
