"""Optimizers: AdamW, Adafactor (factored second moment), SGD-momentum.

Self-contained (no optax dependency).  Each optimizer is a pair of pure
functions ``(init, update)`` over parameter pytrees; state layouts are
chosen for sharding friendliness:

  * AdamW     — m, v in f32 with the same shape (and thus the same
    sharding spec) as the parameter; count scalar.
  * Adafactor — factored v_row/v_col for rank>=2 tensors (the only viable
    choice for the 1T-param MoE archs: full AdamW moments would need ~8 TB),
    full v for vectors; optional momentum off by default.
  * SGDM      — single momentum buffer.

``cosine_schedule`` and global-norm clipping included.  ``GradState``
bundles everything ``train_step`` carries between steps.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
Params = Any


class Optimizer(NamedTuple):
    init: Callable[[Params], Any]
    update: Callable[[Params, Params, Any, jnp.ndarray], Tuple[Params, Any]]
    # update(grads, params, state, lr) -> (new_params, new_state)


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        step = step.astype(F32)
        warm = base_lr * step / max(1, warmup)
        prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(F32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype),
                        grads), norm


# --------------------------------------------------------------------------- #
# AdamW                                                                       #
# --------------------------------------------------------------------------- #

def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, F32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, params, state, lr):
        count = state["count"] + 1
        c = count.astype(F32)
        bc1 = 1.0 - b1 ** c
        bc2 = 1.0 - b2 ** c

        def upd(g, p, m, v):
            g = g.astype(F32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            step = step + weight_decay * p.astype(F32)
            return (p.astype(F32) - lr * step).astype(p.dtype), m, v

        flat_g, treedef = jax.tree.flatten(grads)
        flat_p = treedef.flatten_up_to(params)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(g, p, m, v) for g, p, m, v in
               zip(flat_g, flat_p, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "count": count}

    return Optimizer(init, update)


# --------------------------------------------------------------------------- #
# Adafactor (Shazeer & Stern 2018), factored second moment                    #
# --------------------------------------------------------------------------- #

def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay_exp: float = 0.8, weight_decay: float = 0.0) -> Optimizer:
    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def st(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], F32),     # row: all but last
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32)}
            return {"v": jnp.zeros(p.shape, F32)}
        return {"s": jax.tree.map(st, params,
                                  is_leaf=lambda x: isinstance(x, jnp.ndarray)),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, params, state, lr):
        count = state["count"] + 1
        c = count.astype(F32)
        beta2 = 1.0 - c ** (-decay_exp)

        def upd(g, p, s):
            g = g.astype(F32)
            g2 = g * g + eps
            if _factored(p.shape):
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                vhat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
                u = g / jnp.sqrt(vhat + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g / jnp.sqrt(v + eps)
                new_s = {"v": v}
            # update clipping (RMS)
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            step = u + weight_decay * p.astype(F32)
            return (p.astype(F32) - lr * step).astype(p.dtype), new_s

        flat_g, treedef = jax.tree.flatten(grads)
        flat_p = treedef.flatten_up_to(params)
        flat_s = treedef.flatten_up_to(state["s"])
        out = [upd(g, p, s) for g, p, s in zip(flat_g, flat_p, flat_s)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_s = treedef.unflatten([o[1] for o in out])
        return new_p, {"s": new_s, "count": count}

    return Optimizer(init, update)


# --------------------------------------------------------------------------- #
# SGD + momentum                                                              #
# --------------------------------------------------------------------------- #

def sgdm(momentum: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"mom": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, params, state, lr):
        def upd(g, p, m):
            g = g.astype(F32) + weight_decay * p.astype(F32)
            m = momentum * m + g
            return (p.astype(F32) - lr * m).astype(p.dtype), m
        flat_g, treedef = jax.tree.flatten(grads)
        flat_p = treedef.flatten_up_to(params)
        flat_m = treedef.flatten_up_to(state["mom"])
        out = [upd(g, p, m) for g, p, m in zip(flat_g, flat_p, flat_m)]
        return (treedef.unflatten([o[0] for o in out]),
                {"mom": treedef.unflatten([o[1] for o in out]),
                 "count": state["count"] + 1})

    return Optimizer(init, update)


OPTIMIZERS = {"adamw": adamw, "adafactor": adafactor, "sgdm": sgdm}


def make_optimizer(name: str, **kw) -> Optimizer:
    return OPTIMIZERS[name](**kw)
