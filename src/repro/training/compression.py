"""Error-feedback int8 gradient compression for the data-parallel axis.

At 1000+ nodes the DP all-reduce of bf16 gradients dominates the step for
small-per-chip-batch regimes.  This module implements 1-bit-Adam-style
error-feedback quantization adapted to int8:

    q = round(clip(g / scale)) with per-tensor scale = max|g| / 127
    residual' = g - q * scale           (carried to the next step)

The quantize/dequantize pair wraps the gradient *before* the pmean-style
all-reduce; error feedback keeps the optimizer trajectory unbiased in the
long run (Karimireddy et al., 2019).  4x wire-size reduction on the
inter-pod links, which are the slowest hop in the 2x16x16 mesh.

All functions are jit-safe pure pytree transforms; ``train_step`` opts in
via ``compress_dp_grads=True`` in the trainer config.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def init_residuals(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)


def quantize(g: jnp.ndarray, residual: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (int8 q, f32 scale scalar, new residual)."""
    gf = g.astype(F32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_residual = gf - q.astype(F32) * scale
    return q, scale, new_residual


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(F32) * scale


def compress_tree(grads: Any, residuals: Any) -> Tuple[Any, Any]:
    """Quantize every leaf; returns ((q, scale) tree, residual tree)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    qs, new_r = [], []
    for g, r in zip(flat_g, flat_r):
        q, s, nr = quantize(g, r)
        qs.append((q, s))
        new_r.append(nr)
    return treedef.unflatten(qs), treedef.unflatten(new_r)


def decompress_tree(qtree: Any) -> Any:
    return jax.tree.map(lambda qs: dequantize(*qs), qtree,
                        is_leaf=lambda x: isinstance(x, tuple))


def roundtrip_error(grads: Any, residuals: Any) -> float:
    """Diagnostic: relative L2 error of one compress/decompress pass."""
    qt, _ = compress_tree(grads, residuals)
    back = decompress_tree(qt)
    num = sum(jnp.sum((a.astype(F32) - b) ** 2)
              for a, b in zip(jax.tree.leaves(grads), jax.tree.leaves(back)))
    den = sum(jnp.sum(a.astype(F32) ** 2) for a in jax.tree.leaves(grads))
    return float(jnp.sqrt(num / jnp.maximum(den, 1e-30)))
