"""Continuous-batching slot machine: prefill/decode disaggregation with
open-loop async admission over PFCS-managed KV pages (DESIGN.md §10).

``ServingEngine`` refills free slots from a closed queue and models no
prefill cost — fine for cache-parity work, blind to *arrival-process
shape*, which queueing theory says dominates hit-rate and latency
behavior.  This module is the JetStream-style front-end that makes the
serving stack measurable under realistic ragged traffic:

**Slot state as int32 arrays.**  ``phase`` (FREE/PREFILL/DECODE),
``slot_req``, ``age`` (ticks in the current phase), ``prefill_done``,
``gen``, ``need_prompt``/``need_new``/``chain_len`` are parallel arrays
of width ``max_batch``.  One engine tick is pure array arithmetic —
decode masks, chunked-prefill budget distribution (a ``cumsum``),
completion masks, token values — with **no per-slot Python branching in
the hot loop**; Python appears only at the cache-API boundary
(``register_request`` / ``release_request`` per request lifecycle
event, ONE ``touch_batch`` per tick).

**Prefill → insert-into-slot → batched decode.**  An admitted request
occupies a slot in PREFILL; each tick a shared ``prefill_tokens``
budget is distributed greedily in slot order (Sarathi-style chunking:
a long prompt streams across ticks without blocking the batch, several
short prompts batch into one tick's budget).  When its last prompt
token lands the slot flips to DECODE and emits one token per tick.
Admission is **asynchronous**: requests arrive on an open-loop clock
(``submit(..., arrival=tick)``) and enter any tick a slot frees — no
batch boundary.  The ``policy="lockstep"`` gate degrades the same
machine to the synchronous fixed-width loop (admission only when ALL
slots are free — the static-batching baseline the benchmark beats).

**Eviction/resume via factorization-recovered chains.**  Under queue
pressure (head-of-queue wait >= ``preempt_wait``) the machine preempts
the decode slot with the most remaining work — among slots that have
held their slot for at least one decode tick, a minimum quantum that
makes FIFO re-queue livelock-free; its pages cool off in
the cache's LRU while it re-queues.  On re-admission, *before the slot
re-enters decode*, the engine touches one resume anchor — the page
just ahead of the decode reread window — whose §4.2 divisibility scan
recovers the request's successor chain by factorization and prefetches
the window pages back host→HBM.  The resumed slot's first decode tick
then runs on prefetch hits instead of demand stalls (the resume-
prefetch invariant, DESIGN.md §10).

Two implementations, differentially fuzzed against each other
(``tests/test_serving_batching.py``):

  * :class:`SlotMachine` — the vectorized array-state engine above;
  * :class:`SlotOracle`  — the same scheduling semantics as per-slot
    Python loops over request objects (the lockstep oracle): bit-exact
    on every ``PARITY_COUNTERS`` field, per-touch tier, HBM LRU order,
    and prefetch log when driven on the same arrival trace.

Both compose with every cache backend (``kv="vec" | "scalar" |
"sharded" | "elastic"``, ``moe=``, ``tenants=``) through the shared
factories in ``engine.py``; ``kv="elastic"`` exposes the same
``resize`` / ``fail_shard`` chaos hooks as ``ServingEngine``.
Benchmarked by ``benchmarks.cases.case_batching`` (open-loop Poisson
arrivals, TTFT/TPOT percentiles, goodput vs the lockstep gate and vs
LRU).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.trace import (EV_ADMIT, EV_COMPLETE, EV_PREEMPT,
                             EV_PREFILL_CHUNK, EV_RESUME_PREFETCH)

from .elastic import ElasticShardedPagedKVCache
from .engine import (_STUB_VOCAB, make_expert_backend, make_kv_backend,
                     synthetic_router_groups)

__all__ = ["SlotRequest", "SlotMachine", "SlotOracle",
           "PHASE_FREE", "PHASE_PREFILL", "PHASE_DECODE",
           "poisson_arrival_ticks"]

PHASE_FREE, PHASE_PREFILL, PHASE_DECODE = 0, 1, 2


def poisson_arrival_ticks(n: int, rate: float, seed: int = 0,
                          burst_frac: float = 0.0,
                          silence_ticks: int = 0) -> np.ndarray:
    """Open-loop Poisson arrival schedule: ``n`` integer arrival ticks
    with exponential inter-arrival times at ``rate`` requests/tick.
    ``burst_frac`` front-loads that fraction of requests at tick 0 and
    inserts ``silence_ticks`` of dead air after the burst (the
    burst-then-silence adversarial shape)."""
    rng = np.random.default_rng(seed)
    n_burst = int(round(n * burst_frac))
    tail = n - n_burst
    gaps = rng.exponential(1.0 / max(rate, 1e-9), size=tail)
    ticks = np.floor(np.cumsum(gaps)).astype(np.int64) if tail else \
        np.zeros(0, np.int64)
    if n_burst:
        ticks = np.concatenate([np.zeros(n_burst, np.int64),
                                ticks + silence_ticks])
    return ticks


@dataclass
class SlotRequest:
    """One open-loop request: prompt + decode demand with an arrival
    tick; all timing fields are integer engine ticks."""
    req_id: int
    prompt: List[int]
    max_new_tokens: int = 8
    tenant: int = 0
    arrival: int = 0
    state: str = "queued"        # queued | waiting | prefill | decode | done
    generated: List[int] = field(default_factory=list)
    prefill_done: int = 0        # prompt tokens prefilled so far
    requeue_tick: int = 0        # when it last entered the waiting queue
    first_tick: Optional[int] = None   # tick of the first decoded token
    done_tick: Optional[int] = None
    preemptions: int = 0
    was_preempted: bool = False  # pending resume-prefetch on re-admission

    @property
    def n_prompt(self) -> int:
        return len(self.prompt)

    def ttft(self) -> Optional[int]:
        return None if self.first_tick is None \
            else self.first_tick - self.arrival

    def tpot(self) -> Optional[float]:
        if self.done_tick is None or self.first_tick is None:
            return None
        return (self.done_tick - self.first_tick) \
            / max(1, len(self.generated) - 1)


def _stub_tokens(req_id: int, n: int) -> List[int]:
    """The engine's deterministic pseudo-decode stream (identical to
    ``ServingEngine._stub_token`` so traces are comparable across
    engines)."""
    return [(req_id * 7919 + i * 104_729) % _STUB_VOCAB for i in range(n)]


def _ranges(starts: np.ndarray, stops: np.ndarray):
    """Vectorized ``concatenate([arange(a, b) for a, b in zip(...)])``:
    returns (row_repeat, values) with rows in input order and values
    ascending within each row — the touch-list construction primitive
    (no per-slot Python loop)."""
    counts = np.maximum(stops - starts, 0).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        z = np.zeros(0, np.int64)
        return z, z
    rows = np.repeat(np.arange(len(starts), dtype=np.int64), counts)
    excl = np.cumsum(counts) - counts
    pos = np.arange(total, dtype=np.int64) - np.repeat(excl, counts)
    return rows, np.repeat(starts.astype(np.int64), counts) + pos


class _SlotFrontEnd:
    """Shared non-hot-path plumbing: backend construction, open-loop
    submission, elastic passthrough, and end-of-run reporting.  The
    per-tick scheduling itself is implemented twice — as array math in
    :class:`SlotMachine` and as per-slot loops in :class:`SlotOracle` —
    and the two are differentially fuzzed against each other."""

    policy_choices = ("continuous", "lockstep")

    def __init__(self, max_batch: int = 8, page_size: int = 16,
                 hbm_pages: int = 256, kv: str = "vec",
                 prefetch_budget: int = 4, reread_window: int = 1,
                 prefill_tokens: int = 64, policy: str = "continuous",
                 preempt_wait: Optional[int] = None, shards: int = 2,
                 mesh="auto", moe: Optional[str] = None,
                 moe_experts: int = 64, moe_slots: int = 16,
                 moe_topk: int = 4, moe_prefetch_budget: int = 4,
                 moe_groups: int = 16, moe_seed: int = 0, tenants=None,
                 max_bits: int = 62, dedup: bool = False, obs=None):
        if policy not in self.policy_choices:
            raise ValueError(f"policy must be one of "
                             f"{self.policy_choices}, got {policy!r}")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch
        self.page_size = page_size
        self.policy = policy
        self.preempt_wait = preempt_wait
        self.prefill_tokens = max(1, int(prefill_tokens))
        self.reread_window = max(1, int(reread_window))
        self.tenants = tenants
        # dedup=True (tenants mode): shared-prefix pages discovered at
        # admission are already-computed read-only content, so their
        # prefill is skipped — identically in the machine and the
        # oracle (DESIGN.md §12)
        self.dedup = bool(dedup)
        self.pages = make_kv_backend(
            kv, hbm_pages=hbm_pages, page_size=page_size,
            prefetch_budget=prefetch_budget, shards=shards, mesh=mesh,
            tenants=tenants, max_bits=max_bits, dedup=dedup)
        self.experts = make_expert_backend(
            moe, moe_experts=moe_experts, moe_slots=moe_slots,
            moe_prefetch_budget=moe_prefetch_budget, tenants=tenants)
        self._moe_groups = synthetic_router_groups(
            moe_experts, moe_topk, moe_groups, moe_seed) \
            if self.experts is not None else None
        self.requests: List[SlotRequest] = []
        self._pending: List[SlotRequest] = []    # submitted, not arrived
        self._pending_dirty = False
        self.waiting: List[SlotRequest] = []     # arrived, not in a slot
        self.now = 0                             # current tick
        self.ticks = 0                           # ticks executed
        self.tier_log: List[str] = []            # every touch's tier
        self.preemptions = 0
        self.resumes = 0
        self.peak_in_flight = 0                  # waiting + occupied
        self.peak_live = 0                       # occupied slots
        #: observability sink — None by default (inert); attaching one
        #: also wires it into the page and expert cache tiers so the
        #: whole stack shares a single event stream
        self.obs = obs
        if obs is not None:
            self.pages.obs = obs
            if self.experts is not None:
                self.experts.obs = obs

    # ------------------------------------------------------------------ #
    # open-loop submission                                                #
    # ------------------------------------------------------------------ #

    def submit(self, prompt: Sequence[int], max_new_tokens: int = 8,
               tenant: int = 0, arrival: int = 0) -> int:
        """Queue a request that ARRIVES at tick ``arrival`` (open-loop:
        the engine sees it only once its tick comes — arrivals in the
        past arrive immediately).  Returns the request id."""
        if tenant and self.tenants is None:
            raise ValueError("tenant ids need tenants= mode (pass "
                             "tenants=N or a TenantQoSConfig)")
        if self.tenants is not None:
            n = self.pages.qos_config.n_tenants
            if not 0 <= int(tenant) < n:
                raise ValueError(f"tenant {tenant} out of range [0, {n})")
        rid = len(self.requests)
        req = SlotRequest(rid, list(prompt), max(1, int(max_new_tokens)),
                          tenant=int(tenant),
                          arrival=max(self.now, int(arrival)))
        req.requeue_tick = req.arrival
        self.requests.append(req)
        self._pending.append(req)
        self._pending_dirty = True
        return rid

    def _arrivals(self) -> None:
        """Move every pending request whose arrival tick has come into
        the waiting queue, in (arrival, req_id) order."""
        if self._pending_dirty:
            self._pending.sort(key=lambda r: (r.arrival, r.req_id))
            self._pending_dirty = False
        while self._pending and self._pending[0].arrival <= self.now:
            req = self._pending.pop(0)
            req.state = "waiting"
            self.waiting.append(req)

    # ------------------------------------------------------------------ #
    # elastic hooks (kv="elastic"; DESIGN.md §9)                          #
    # ------------------------------------------------------------------ #

    def _elastic_pages(self) -> ElasticShardedPagedKVCache:
        if not isinstance(self.pages, ElasticShardedPagedKVCache):
            raise ValueError("resize/fail_shard need kv='elastic'")
        return self.pages

    def resize(self, shards: int, mesh="auto"):
        """Live shard-count change mid-serve (returns the ReshardPlan)."""
        return self._elastic_pages().resize(shards, mesh=mesh)

    def fail_shard(self, shard: int, recover: bool = True):
        """Inject a shard loss mid-serve; recovery is immediate unless
        ``recover=False`` (then failover-on-demand rebuilds it at the
        next touch)."""
        pages = self._elastic_pages()
        pages.fail_shard(shard)
        return pages.recover_shard(shard) if recover else None

    # ------------------------------------------------------------------ #
    # driving                                                             #
    # ------------------------------------------------------------------ #

    def idle(self) -> bool:
        return not (self._pending or self.waiting or self._any_occupied())

    def run_until_idle(self, max_ticks: int = 100_000) -> List[SlotRequest]:
        """Tick until every submitted request completed; raises if the
        machine fails to drain (a starvation bug, not a load condition —
        admission is FIFO and preemption round-robins)."""
        for _ in range(max_ticks):
            if self.idle():
                return [r for r in self.requests if r.state == "done"]
            self.step()
        raise RuntimeError(f"slot machine failed to drain within "
                           f"{max_ticks} ticks "
                           f"({len(self.waiting)} waiting)")

    def latency_report(self) -> Dict[str, Any]:
        """TTFT/TPOT percentiles (ticks) + goodput over completed
        requests — the benchmark payload."""
        done = [r for r in self.requests if r.state == "done"]
        ttft = np.asarray([r.ttft() for r in done], dtype=np.float64)
        tpot = np.asarray([r.tpot() for r in done], dtype=np.float64)
        toks = sum(len(r.generated) for r in done)
        pct = lambda a, q: float(np.percentile(a, q)) if len(a) else 0.0
        return dict(
            completed=len(done),
            tokens=toks,
            ticks=self.ticks,
            goodput_tok_per_tick=toks / max(1, self.ticks),
            ttft_ticks={q: pct(ttft, q) for q in (50, 95, 99)},
            tpot_ticks={q: pct(tpot, q) for q in (50, 95, 99)},
            preemptions=self.preemptions,
            resumes=self.resumes,
            peak_in_flight=self.peak_in_flight,
            peak_live=self.peak_live,
        )

    # observability (shared emit points — both twins call these at the
    # same semantic step, so their event streams are bit-identical) ----- #

    def _note_admit(self, slot: int, req: SlotRequest, t: int) -> None:
        if self.obs is not None:
            self.obs.emit(EV_ADMIT, tick=t, slot=slot, req=req.req_id,
                          tenant=req.tenant)

    def _note_preempt(self, slot: int, req: SlotRequest, t: int) -> None:
        if self.obs is not None:
            self.obs.emit(EV_PREEMPT, tick=t, slot=slot, req=req.req_id,
                          tenant=req.tenant,
                          arg=req.max_new_tokens - len(req.generated))

    def _note_resume(self, slot: int, req: SlotRequest, t: int,
                     anchor: int) -> None:
        if self.obs is not None:
            self.obs.emit(EV_RESUME_PREFETCH, tick=t, slot=slot,
                          req=req.req_id, page=anchor, tenant=req.tenant)

    def _note_prefill_chunk(self, slot: int, req_id: int, t: int,
                            tokens: int) -> None:
        if self.obs is not None:
            self.obs.emit(EV_PREFILL_CHUNK, tick=t, slot=slot, req=req_id,
                          arg=tokens)

    def _note_complete(self, slot: int, req: SlotRequest, t: int) -> None:
        if self.obs is None:
            return
        ttft = req.ttft()
        self.obs.emit(EV_COMPLETE, tick=t, slot=slot, req=req.req_id,
                      tenant=req.tenant,
                      arg=-1 if ttft is None else int(ttft))
        tm = self.obs.telemetry
        if tm is not None:
            tpot = req.tpot()
            tm.complete(0 if ttft is None else int(ttft),
                        0 if tpot is None else int(round(tpot * 1000)))

    def _note_tick(self) -> None:
        if self.obs is not None and self.obs.telemetry is not None:
            self.obs.telemetry.tick_slots(self)

    # subclass responsibilities ----------------------------------------- #

    def step(self) -> Dict[str, Any]:            # pragma: no cover
        raise NotImplementedError

    def obs_slot_mix(self) -> Tuple[int, int, int]:  # pragma: no cover
        raise NotImplementedError

    def _any_occupied(self) -> bool:             # pragma: no cover
        raise NotImplementedError


class SlotMachine(_SlotFrontEnd):
    """The vectorized continuous-batching engine: slot occupancy, age,
    and phase live in int32 arrays; admission, chunked prefill, decode,
    and completion are masked array ops; the cache sees ONE
    ``touch_batch`` per tick."""

    def __init__(self, **kw):
        super().__init__(**kw)
        b = self.max_batch
        self.phase = np.full(b, PHASE_FREE, np.int32)
        self.slot_req = np.full(b, -1, np.int32)
        self.age = np.zeros(b, np.int32)         # ticks in current phase
        self.prefill_done = np.zeros(b, np.int32)
        self.gen = np.zeros(b, np.int32)
        self.need_prompt = np.zeros(b, np.int32)
        self.need_new = np.zeros(b, np.int32)
        self.chain_len = np.zeros(b, np.int32)

    def _any_occupied(self) -> bool:
        return bool((self.phase != PHASE_FREE).any())

    def obs_slot_mix(self) -> Tuple[int, int, int]:
        """(free, prefill, decode) slot counts — the shared telemetry
        accessor both twins implement over their own state."""
        return (int((self.phase == PHASE_FREE).sum()),
                int((self.phase == PHASE_PREFILL).sum()),
                int((self.phase == PHASE_DECODE).sum()))

    # ------------------------------------------------------------------ #

    def step(self) -> Dict[str, Any]:
        """One tick: arrivals -> (preempt) -> admit -> decode/prefill
        masks -> ONE touch_batch -> token/MoE bookkeeping -> completion
        -> ages."""
        t = self.now
        self._arrivals()
        self.peak_in_flight = max(
            self.peak_in_flight,
            len(self.waiting) + int((self.phase != PHASE_FREE).sum()))
        fresh = np.zeros(self.max_batch, bool)
        anchor_items: List[Tuple[int, int]] = []

        # -- preemption (continuous policy only): queue pressure evicts
        #    the decode slot with the most remaining work ---------------- #
        if (self.policy == "continuous" and self.preempt_wait is not None
                and self.waiting
                and t - self.waiting[0].requeue_tick >= self.preempt_wait
                and not (self.phase == PHASE_FREE).any()):
            # minimum one-tick quantum (age >= 1): every residency emits
            # at least one token before eviction, so FIFO re-queue can
            # never livelock even on a 1-slot engine
            decode = (self.phase == PHASE_DECODE) & (self.age >= 1)
            if decode.any():
                remaining = np.where(decode, self.need_new - self.gen, -1)
                i = int(np.argmax(remaining))    # ties -> lowest slot
                victim = self.requests[int(self.slot_req[i])]
                # boundary event: persist slot progress back onto the
                # request so re-admission restores it
                victim.prefill_done = int(self.prefill_done[i])
                victim.generated = _stub_tokens(victim.req_id,
                                                int(self.gen[i]))
                victim.state = "waiting"
                victim.preemptions += 1
                victim.was_preempted = True
                victim.requeue_tick = t
                self.waiting.append(victim)
                self.phase[i] = PHASE_FREE
                self.slot_req[i] = -1
                self.preemptions += 1
                self._note_preempt(i, victim, t)

        # -- admission: free slots x FIFO waiting queue ------------------ #
        gate_open = (self.policy == "continuous"
                     or not (self.phase != PHASE_FREE).any())
        if gate_open:
            for i in np.flatnonzero(self.phase == PHASE_FREE):
                if not self.waiting:
                    break
                req = self.waiting.pop(0)
                i = int(i)
                self.slot_req[i] = req.req_id
                self.need_prompt[i] = req.n_prompt
                self.need_new[i] = req.max_new_tokens
                self.gen[i] = len(req.generated)
                self.prefill_done[i] = req.prefill_done
                self.age[i] = 0
                fresh[i] = True
                self._note_admit(i, req, t)
                if req.req_id not in self.pages.chains:
                    if self.tenants is not None:
                        self.pages.register_request(
                            req.req_id, req.prompt, tenant=req.tenant)
                    else:
                        self.pages.register_request(req.req_id, req.prompt)
                    if self.dedup and req.prefill_done == 0:
                        # admission dedup: the leading shared-prefix run
                        # is already-computed read-only content — skip
                        # its prefill (the TTFT win case_dedup measures)
                        skip = self.pages.dedup_prefix.get(req.req_id, 0) \
                            * self.page_size
                        req.prefill_done = min(req.n_prompt, skip)
                        self.prefill_done[i] = req.prefill_done
                L = len(self.pages.chains[req.req_id])
                self.chain_len[i] = L
                if req.prefill_done >= req.n_prompt:
                    self.phase[i] = PHASE_DECODE
                    req.state = "decode"
                    if req.was_preempted and L > 0:
                        # resume-prefetch: touch the page just ahead of
                        # the reread window; its §4.2 scan recovers the
                        # successor chain and prefetches the window
                        # back BEFORE the slot re-enters decode
                        anchor = max(0, L - self.reread_window - 1)
                        anchor_items.append((req.req_id, anchor))
                        self.resumes += 1
                        req.was_preempted = False
                        self._note_resume(i, req, t, anchor)
                else:
                    self.phase[i] = PHASE_PREFILL
                    req.state = "prefill"
        self.peak_live = max(self.peak_live,
                             int((self.phase != PHASE_FREE).sum()))

        # -- decode mask + window touches (slots live BEFORE this tick) -- #
        decode_mask = (self.phase == PHASE_DECODE) & ~fresh
        d_idx = np.flatnonzero(decode_mask)
        L = self.chain_len[d_idx]
        rows, pages_idx = _ranges(
            np.maximum(0, L - self.reread_window).astype(np.int64),
            L.astype(np.int64))
        d_reqs = self.slot_req[d_idx]
        decode_items = list(zip(d_reqs[rows].tolist(), pages_idx.tolist()))

        # -- chunked prefill: one budget, greedy in slot order ----------- #
        p_idx = np.flatnonzero(self.phase == PHASE_PREFILL)
        prefill_items: List[Tuple[int, int]] = []
        if len(p_idx):
            need = (self.need_prompt[p_idx]
                    - self.prefill_done[p_idx]).astype(np.int64)
            excl = np.cumsum(need) - need
            give = np.clip(self.prefill_tokens - excl, 0, need)
            old = self.prefill_done[p_idx].astype(np.int64)
            new = old + give
            ps = self.page_size
            rows, pages_idx = _ranges(-(-old // ps), -(-new // ps))
            p_reqs = self.slot_req[p_idx]
            prefill_items = list(zip(p_reqs[rows].tolist(),
                                     pages_idx.tolist()))
            if self.obs is not None:
                for k, i in enumerate(p_idx):
                    if give[k] > 0:
                        self._note_prefill_chunk(int(i), int(p_reqs[k]),
                                                 t, int(give[k]))
            self.prefill_done[p_idx] = new.astype(np.int32)
            finished = p_idx[new >= self.need_prompt[p_idx]]
            self.phase[finished] = PHASE_DECODE
            fresh[finished] = True               # decode starts NEXT tick
            for i in finished:
                self.requests[int(self.slot_req[i])].state = "decode"

        # -- the tick's ONE bulk cache call ------------------------------ #
        items = anchor_items + decode_items + prefill_items
        if items:
            self.tier_log.extend(self.pages.touch_batch(items))

        # -- token + MoE bookkeeping ------------------------------------- #
        if len(d_idx):
            if self.experts is not None:
                g = (d_reqs.astype(np.int64) * 7919
                     + self.gen[d_idx].astype(np.int64) * 104_729) \
                    % len(self._moe_groups)
                sets = [self._moe_groups[i] for i in g.tolist()]
                self.experts.observe_routing(sets)
                self.experts.activate_batch(sets)
            first = d_idx[self.gen[d_idx] == 0]
            for i in first:
                self.requests[int(self.slot_req[i])].first_tick = t
            self.gen[d_idx] += 1

        # -- completion: vectorized mask, per-request release ------------ #
        done_idx = d_idx[self.gen[d_idx] >= self.need_new[d_idx]]
        for i in done_idx:
            req = self.requests[int(self.slot_req[i])]
            req.generated = _stub_tokens(req.req_id, int(self.gen[i]))
            req.state = "done"
            req.done_tick = t
            self.pages.release_request(req.req_id)
            self._note_complete(int(i), req, t)
        self.phase[done_idx] = PHASE_FREE
        self.slot_req[done_idx] = -1

        # -- ages: +1 for surviving occupants, 0 for fresh phases -------- #
        occ = self.phase != PHASE_FREE
        self.age[occ & ~fresh] += 1
        self.age[fresh & occ] = 0
        self._note_tick()
        self.now += 1
        self.ticks += 1
        out = {"live": int(occ.sum()), "waiting": len(self.waiting),
               "page_stats": self.pages.stats}
        if self.tenants is not None:
            out["tenant_stats"] = self.pages.qos.tenant_stats
        if self.experts is not None:
            out["expert_stats"] = self.experts.stats
        return out


class SlotOracle(_SlotFrontEnd):
    """The lockstep oracle: identical scheduling semantics implemented
    as per-slot Python loops over request objects — no arrays, explicit
    branching — used to pin the vectorized machine bit-exactly
    (``tests/test_serving_batching.py``)."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.slots: List[Optional[SlotRequest]] = [None] * self.max_batch
        self.slot_age: List[int] = [0] * self.max_batch

    def _any_occupied(self) -> bool:
        return any(s is not None for s in self.slots)

    def obs_slot_mix(self) -> Tuple[int, int, int]:
        """(free, prefill, decode) slot counts — must report exactly
        what the machine's phase-array histogram reports."""
        free = sum(s is None for s in self.slots)
        prefill = sum(1 for s in self.slots
                      if s is not None and s.state == "prefill")
        decode = sum(1 for s in self.slots
                     if s is not None and s.state == "decode")
        return free, prefill, decode

    def step(self) -> Dict[str, Any]:
        t = self.now
        self._arrivals()
        occupied = sum(s is not None for s in self.slots)
        self.peak_in_flight = max(self.peak_in_flight,
                                  len(self.waiting) + occupied)
        fresh: set = set()
        anchor_items: List[Tuple[int, int]] = []

        # preemption: the decode slot with the most remaining work
        if (self.policy == "continuous" and self.preempt_wait is not None
                and self.waiting
                and t - self.waiting[0].requeue_tick >= self.preempt_wait
                and all(s is not None for s in self.slots)):
            best, best_rem = -1, -1
            for i, req in enumerate(self.slots):
                if (req is not None and req.state == "decode"
                        and self.slot_age[i] >= 1):  # min one-tick quantum
                    rem = req.max_new_tokens - len(req.generated)
                    if rem > best_rem:
                        best, best_rem = i, rem
            if best >= 0:
                victim = self.slots[best]
                victim.state = "waiting"
                victim.preemptions += 1
                victim.was_preempted = True
                victim.requeue_tick = t
                self.waiting.append(victim)
                self.slots[best] = None
                self.preemptions += 1
                self._note_preempt(best, victim, t)

        # admission
        gate_open = (self.policy == "continuous"
                     or all(s is None for s in self.slots))
        if gate_open:
            for i in range(self.max_batch):
                if self.slots[i] is not None or not self.waiting:
                    continue
                req = self.waiting.pop(0)
                self.slots[i] = req
                self.slot_age[i] = 0
                fresh.add(i)
                self._note_admit(i, req, t)
                if req.req_id not in self.pages.chains:
                    if self.tenants is not None:
                        self.pages.register_request(
                            req.req_id, req.prompt, tenant=req.tenant)
                    else:
                        self.pages.register_request(req.req_id, req.prompt)
                    if self.dedup and req.prefill_done == 0:
                        # admission dedup prefill skip — must mirror the
                        # machine exactly (parity contract)
                        skip = self.pages.dedup_prefix.get(req.req_id, 0) \
                            * self.page_size
                        req.prefill_done = min(req.n_prompt, skip)
                L = len(self.pages.chains[req.req_id])
                if req.prefill_done >= req.n_prompt:
                    req.state = "decode"
                    if req.was_preempted and L > 0:
                        anchor = max(0, L - self.reread_window - 1)
                        anchor_items.append((req.req_id, anchor))
                        self.resumes += 1
                        req.was_preempted = False
                        self._note_resume(i, req, t, anchor)
                else:
                    req.state = "prefill"
        self.peak_live = max(self.peak_live,
                             sum(s is not None for s in self.slots))

        # decode touches: slots that were ALREADY decoding this tick
        decode_slots = [i for i, r in enumerate(self.slots)
                        if r is not None and r.state == "decode"
                        and i not in fresh]
        decode_items: List[Tuple[int, int]] = []
        for i in decode_slots:
            req = self.slots[i]
            L = len(self.pages.chains.get(req.req_id) or ())
            for j in range(max(0, L - self.reread_window), L):
                decode_items.append((req.req_id, j))

        # chunked prefill, greedy in slot order
        budget = self.prefill_tokens
        prefill_items: List[Tuple[int, int]] = []
        for i in range(self.max_batch):
            req = self.slots[i]
            if req is None or req.state != "prefill":
                continue
            give = min(budget, req.n_prompt - req.prefill_done)
            budget -= give
            if give > 0:
                self._note_prefill_chunk(i, req.req_id, t, give)
            old, new = req.prefill_done, req.prefill_done + give
            ps = self.page_size
            for j in range(-(-old // ps), -(-new // ps)):
                prefill_items.append((req.req_id, j))
            req.prefill_done = new
            if new >= req.n_prompt:
                req.state = "decode"
                fresh.add(i)                     # decode starts NEXT tick

        items = anchor_items + decode_items + prefill_items
        if items:
            self.tier_log.extend(self.pages.touch_batch(items))

        # token + MoE bookkeeping
        if decode_slots and self.experts is not None:
            sets = []
            for i in decode_slots:
                req = self.slots[i]
                g = (req.req_id * 7919 + len(req.generated) * 104_729) \
                    % len(self._moe_groups)
                sets.append(self._moe_groups[g])
            self.experts.observe_routing(sets)
            self.experts.activate_batch(sets)
        for i in decode_slots:
            req = self.slots[i]
            if not req.generated:
                req.first_tick = t
            req.generated.append(_stub_tokens(req.req_id,
                                              len(req.generated) + 1)[-1])
            if len(req.generated) >= req.max_new_tokens:
                req.state = "done"
                req.done_tick = t
                self.pages.release_request(req.req_id)
                self._note_complete(i, req, t)
                self.slots[i] = None

        for i in range(self.max_batch):
            if self.slots[i] is None:
                continue
            self.slot_age[i] = 0 if i in fresh else self.slot_age[i] + 1
        self._note_tick()
        self.now += 1
        self.ticks += 1
        live = sum(s is not None for s in self.slots)
        out = {"live": live, "waiting": len(self.waiting),
               "page_stats": self.pages.stats}
        if self.tenants is not None:
            out["tenant_stats"] = self.pages.qos.tenant_stats
        if self.experts is not None:
            out["expert_stats"] = self.experts.stats
        return out
