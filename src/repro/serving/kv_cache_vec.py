"""Array-state paged KV cache: the vectorized twin of ``PagedKVCache``.

The scalar cache (``kv_cache.py``, kept in the tree as the bit-exact
oracle) manages HBM residency through a Python ``OrderedDict`` and runs
one §4.2 registry divisibility scan *per touched page* — the same
scalar bottleneck the trace-simulation engine removed from the
simulator (DESIGN.md §4).  This module applies the engine's recipe to
the serving hot path (DESIGN.md §5):

**Fixed-shape array page tables.**  HBM is ``hbm_pages`` slots of
parallel arrays — ``slot_page`` (int32 page id, ``EMPTY`` = -1),
``slot_t`` (int64 monotonic stamp; stamp order IS the oracle's
``OrderedDict`` order), ``slot_pf`` (bool, brought in by prefetch and
not yet demanded).  Per-page state is ``slot_of`` (page -> slot, -1
when not HBM-resident: O(1) hit detection) and ``in_host`` (host-tier
residency bitmap).  LRU eviction is one ``argmin`` over ``slot_t``;
because stamps are unique and strictly increasing, it selects exactly
the page the oracle's ``popitem(last=False)`` evicts.

**Table-driven bulk chain discovery.**  The oracle's per-touch registry
scan collapses to a precomputed successor table — ``(P, W)`` int32
candidate rows in the oracle's exact iteration order (registry order,
then ``rel.primes``), padded with -1 and deliberately keeping repeated
targets (the dynamic residency check at touch time skips them, exactly
as the oracle's does).  Three maintenance modes:

  * ``discover="incremental"`` (default) — chain-edge registration
    appends both endpoints to each other's rows in O(1); the touch path
    performs ZERO registry scans.
  * ``discover="host"`` / ``"kernel"`` — rows are rebuilt in ONE bulk
    :func:`repro.core.engine.successor_table` call per registry change,
    at the next ``touch_batch``; ``"kernel"`` routes the scan + decode
    through the Pallas ``divisibility_scan`` / ``factorize_batch``
    kernels (the TPU registry-refresh deployment).

All three produce bit-identical rows (``tests/test_serving.py``).

**Chain registry as composite arrays.**  Each request's page chain is
held as chunked int64 composite arrays (products of page primes, each
chunk < 2**62 — ``core.composite.encode_relationship``).  Shared-prefix
discovery between two requests is then a batched gcd over the chunk
cross-product (``repro.kernels.ops.gcd_batch``) followed by one
``factorize_batch`` decode — exact by unique factorization: every
shared prime appears in exactly one chunk per side, so the union of
pairwise-gcd factors is exactly the shared page set (Theorem 1, zero
false sharing).

Every counter in ``PageStats`` (except ``registry_scans``, which counts
discovery *work* and differs by design) is bit-exact against the scalar
oracle under any interleaving of ``register_request`` / ``touch`` /
``touch_batch`` — enforced by ``tests/test_serving.py``.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.composite import encode_relationship
from repro.core.engine.tables import successor_table
from repro.obs.trace import EV_PREFETCH

from .kv_cache import PagedKVCache

__all__ = ["VectorizedPagedKVCache"]

EMPTY = -1


class VectorizedPagedKVCache(PagedKVCache):
    """Drop-in ``PagedKVCache`` with array placement state and bulk
    discovery.  Page identity, prime assignment, and the chain/composite
    registry are shared with the oracle (``_init_identity``); only the
    placement structures and the discovery path change representation.
    """

    def __init__(self, hbm_pages: int = 1024, page_size: int = 16,
                 prefetch_budget: int = 4, discover: str = "incremental",
                 max_bits: int = 62):
        if hbm_pages < 1:
            raise ValueError("hbm_pages must be >= 1")
        if discover not in ("incremental", "host", "kernel"):
            raise ValueError(f"discover must be 'incremental', 'host' or "
                             f"'kernel', got {discover!r}")
        self._init_identity(hbm_pages, page_size, prefetch_budget, max_bits)
        self.discover = discover
        # HBM slot arrays (slot-array layout, DESIGN.md §5.1)
        s = hbm_pages
        self.slot_page = np.full((s,), EMPTY, dtype=np.int32)
        self.slot_t = np.zeros((s,), dtype=np.int64)
        self.slot_pf = np.zeros((s,), dtype=np.bool_)
        self._n_occupied = 0
        self._clock = 0
        # per-page arrays (grown on demand as pages are registered)
        self.slot_of = np.full((64,), EMPTY, dtype=np.int32)
        self.in_host = np.zeros((64,), dtype=np.bool_)
        # successor table: (P, W) candidate rows, -1 padded
        self._succ = np.full((64, 4), EMPTY, dtype=np.int32)
        self._succ_len = np.zeros((64,), dtype=np.int32)
        self._table_version = self.registry.version
        self.bulk_refreshes = 0
        # chain registry as composite arrays: request -> (int64 chunk
        # array, assigner epoch at build).  The epoch guards against
        # recycled primes: Algorithm-1 recycling can free a chain
        # page's prime and hand it to a NEW page, and a chunk array
        # built before the recycle would then gcd-match the new page's
        # chain — false sharing the scalar oracle (which reads primes
        # live) never reports.  A stale epoch forces a rebuild from the
        # live chain (regression-tested in tests/test_tenancy.py).
        self._chain_chunks: Dict[int, Tuple[np.ndarray, int]] = {}

    # ------------------------------------------------------------------ #
    # array growth                                                        #
    # ------------------------------------------------------------------ #

    def _ensure_pages(self, n: int) -> None:
        cur = self.slot_of.shape[0]
        if n <= cur:
            return
        new = max(n, 2 * cur)
        grow = new - cur
        self.slot_of = np.concatenate(
            [self.slot_of, np.full((grow,), EMPTY, dtype=np.int32)])
        self.in_host = np.concatenate(
            [self.in_host, np.zeros((grow,), dtype=np.bool_)])
        self._succ = np.concatenate(
            [self._succ, np.full((grow, self._succ.shape[1]), EMPTY,
                                 dtype=np.int32)])
        self._succ_len = np.concatenate(
            [self._succ_len, np.zeros((grow,), dtype=np.int32)])

    def _succ_append(self, page: int, succ: int) -> None:
        n = int(self._succ_len[page])
        if n == self._succ.shape[1]:                      # widen columns
            pad = np.full(self._succ.shape, EMPTY, dtype=np.int32)
            self._succ = np.concatenate([self._succ, pad], axis=1)
        self._succ[page, n] = succ
        self._succ_len[page] = n + 1

    # ------------------------------------------------------------------ #
    # registration (identity path shared with the oracle)                 #
    # ------------------------------------------------------------------ #

    def _register_chain_edges(self, pages: Sequence[int]
                              ) -> List[Tuple[int, int]]:
        self._ensure_pages(self._next_page)
        # incremental maintenance is only sound if the rows were current
        # when registration started; an out-of-band registry mutation
        # (e.g. Algorithm-1 prime recycling dropping relationships)
        # leaves the version mismatched, and fast-forwarding past it
        # would mask the drop — leave the table stale instead so the
        # next touch forces a bulk rebuild
        was_current = self.registry.version == self._table_version
        edges = super()._register_chain_edges(pages)
        if self.discover == "incremental" and was_current:
            # O(1) row maintenance: appending at edge-registration time
            # reproduces the oracle's candidate order exactly (registry
            # order IS registration order)
            for a, b in edges:
                self._succ_append(a, b)
                self._succ_append(b, a)
            self._table_version = self.registry.version
        return edges

    def register_request(self, req_id: int, tokens: Sequence[int]
                         ) -> List[int]:
        pages = super().register_request(req_id, tokens)
        self._build_chunks(req_id)
        return pages

    def _assigner_epoch(self) -> int:
        return getattr(self.assigner, "epoch", 0)

    def _build_chunks(self, req_id: int) -> np.ndarray:
        primes = [p for pid in self.chains.get(req_id, ())
                  if (p := self.assigner.prime_of(pid)) is not None]
        enc = encode_relationship(primes, self.registry.max_bits) \
            if primes else []
        # wide (multi-limb) chunks exceed int64 — keep exact Python ints
        # in an object array; the flat/limb kernel split happens at the
        # gcd call (DESIGN.md §11)
        dt = object if self.registry.wide else np.int64
        chunks = np.asarray(enc, dtype=dt)
        self._chain_chunks[req_id] = (chunks, self._assigner_epoch())
        return chunks

    def _chunk_dtype(self):
        return object if self.registry.wide else np.int64

    def _chunks_of(self, req_id: int) -> np.ndarray:
        """Live chunk array for a request — rebuilt when any prime
        release happened since it was cached (see ``_chain_chunks``)."""
        if req_id not in self.chains:
            return np.empty(0, dtype=self._chunk_dtype())
        cached = self._chain_chunks.get(req_id)
        if cached is not None and cached[1] == self._assigner_epoch():
            return cached[0]
        return self._build_chunks(req_id)

    def release_request(self, req_id: int) -> None:
        super().release_request(req_id)
        self._chain_chunks.pop(req_id, None)

    # ------------------------------------------------------------------ #
    # bulk discovery table                                                #
    # ------------------------------------------------------------------ #

    def _sync_tables(self) -> None:
        """One bulk refresh when the registry changed since the last
        build (no-op in incremental mode, where rows are maintained at
        registration time)."""
        if self._table_version == self.registry.version:
            return
        self.refresh_tables()

    def refresh_tables(self, discover: Optional[str] = None) -> None:
        """Rebuild every successor row in ONE bulk discovery call
        (host replay or Pallas kernels)."""
        backend = discover or self.discover
        if backend == "incremental":
            backend = "host"   # bulk rebuild semantics == host replay
        rows = successor_table(self.registry, self.assigner,
                               range(self._next_page), discover=backend)
        self._install_rows(rows)

    def _install_rows(self, rows: Dict[int, List[int]]) -> None:
        """Replace the whole successor table with freshly-built rows and
        stamp the registry version (shared by every bulk-rebuild
        backend, including the sharded one)."""
        self._succ.fill(EMPTY)
        self._succ_len.fill(0)
        for page, row in rows.items():
            for succ in row:
                self._succ_append(page, succ)
        self.bulk_refreshes += 1
        self._table_version = self.registry.version

    def successor_rows(self) -> Dict[int, List[int]]:
        """Current table as plain lists (tests/introspection)."""
        return {p: [int(x) for x in self._succ[p, :self._succ_len[p]]]
                for p in range(self._next_page) if self._succ_len[p]}

    # ------------------------------------------------------------------ #
    # placement (array state machine)                                     #
    # ------------------------------------------------------------------ #

    def _tick(self) -> int:
        t = self._clock
        self._clock += 1
        return t

    def _insert(self, pid: int, prefetched: bool) -> None:
        """Insert a non-resident page into HBM; evict-LRU-first when
        full (identical to the oracle's add-then-evict for capacity
        >= 1, since the newest entry is never the eviction argmin)."""
        self.in_host[pid] = False
        if self._n_occupied < self.hbm_capacity:
            s = self._n_occupied
            self._n_occupied += 1
        else:
            s = int(np.argmin(self.slot_t))       # unique stamps: exact LRU
            victim = int(self.slot_page[s])
            self.slot_of[victim] = EMPTY
            self.in_host[victim] = True
            self.stats.evictions += 1
            self._note_evict(victim)
        self.slot_page[s] = pid
        self.slot_of[pid] = s
        self.slot_t[s] = self._tick()
        self.slot_pf[s] = prefetched

    def _touch_one(self, pid: int) -> str:
        s = int(self.slot_of[pid])
        if s >= 0:
            was_pf = bool(self.slot_pf[s])
            self.slot_pf[s] = False
            self.slot_t[s] = self._tick()
            self.stats.hbm_hits += 1
            if was_pf:
                self.stats.prefetch_hits += 1
            tier = "hbm"
        elif self.in_host[pid]:
            self.stats.host_hits += 1
            self._insert(pid, False)
            tier = "host"
        else:
            self.stats.misses += 1
            self._insert(pid, False)
            tier = "new"
        self._prefetch_row(pid)
        return tier

    def _prefetch_row(self, pid: int) -> None:
        """Successor prefetch from the precomputed table — no registry
        scan, no factorization on the touch path."""
        budget = self.prefetch_budget
        if budget <= 0:
            return
        row = self._succ[pid, :self._succ_len[pid]]
        for succ in row:
            succ = int(succ)
            if self.slot_of[succ] >= 0:           # already HBM-resident
                continue
            if not (self._prefetch_allowed(pid, succ)
                    and self._can_insert(succ)):  # dedup hooks (base: True)
                continue
            self._insert(succ, True)
            self.stats.prefetches += 1
            self.prefetch_log.append((pid, succ))
            if self.obs is not None:
                self.obs.emit(EV_PREFETCH, page=pid, arg=succ)
            budget -= 1
            if budget <= 0:
                return

    def touch(self, req_id: int, page_idx: int) -> str:
        return self.touch_batch([(req_id, page_idx)])[0]

    def touch_batch(self, items: Sequence[Tuple[int, int]]) -> List[str]:
        """Demand-access a whole decode batch.  Discovery for the entire
        batch is table gathers (plus at most one bulk table refresh);
        placement applies in submission order, which is what keeps every
        counter bit-exact against the oracle's sequential ``touch``
        calls."""
        self._sync_tables()
        return [self._touch_one(self.chains[r][i]) for r, i in items]

    # ------------------------------------------------------------------ #
    # deterministic shared-prefix discovery (batched gcd kernel path)     #
    # ------------------------------------------------------------------ #

    def _shared_primes(self, gcds: np.ndarray,
                       pool: np.ndarray) -> Set[int]:
        """Decode pairwise chunk gcds into the shared prime set
        (width-agnostic: exact dispatch picks flat vs limb kernels)."""
        from repro.kernels.ops import factorize_batch_exact

        gs = sorted({int(g) for g in gcds if int(g) > 1})
        if not gs:
            return set()
        facs, residual = factorize_batch_exact(gs, pool)
        assert all(int(r) == 1 for r in residual), \
            "chunk gcd escaped the chain pool"
        return {q for fs in facs for q in fs}

    def shared_prefix(self, req_a: int, req_b: int) -> List[int]:
        """Pages shared by two requests via batched gcd over the chunked
        chain composites — exact (unique factorization: each shared
        prime lives in exactly one chunk per side, so it appears in
        exactly one pairwise gcd)."""
        return self.shared_prefix_bulk([(req_a, req_b)])[(req_a, req_b)]

    def shared_prefix_bulk(self, pairs: Sequence[Tuple[int, int]]
                           ) -> Dict[Tuple[int, int], List[int]]:
        """Shared pages for many request pairs through ONE batched gcd
        call (all chunk cross-products concatenated).  Wide registries
        route through the multi-limb gcd kernel with the union of the
        side-a chain primes as the reconstruction pool (common primes of
        any pair are a subset of that side's chain — DESIGN.md §11)."""
        from repro.kernels.ops import gcd_batch, gcd_batch_limbs

        dt = self._chunk_dtype()
        blocks: List[Tuple[Tuple[int, int], np.ndarray, np.ndarray]] = []
        pools: List[List[int]] = []
        for ra, rb in pairs:
            ca, cb = self._chunks_of(ra), self._chunks_of(rb)
            blocks.append(((ra, rb), np.repeat(ca, cb.size),
                           np.tile(cb, ca.size)))
            pools.append([p for pid in self.chains.get(ra, [])
                          if (p := self.assigner.prime_of(pid)) is not None])
        flat_a = np.concatenate([a for _, a, _ in blocks]) if blocks \
            else np.empty(0, dtype=dt)
        flat_b = np.concatenate([b for _, _, b in blocks]) if blocks \
            else np.empty(0, dtype=dt)
        if not flat_a.size:
            gcds = np.empty(0, dtype=dt)
        elif self.registry.wide:
            union_pool = sorted({q for pl in pools for q in pl})
            gcds = np.asarray(
                gcd_batch_limbs(flat_a, flat_b, union_pool), dtype=object)
        else:
            gcds = gcd_batch(flat_a, flat_b)
        out: Dict[Tuple[int, int], List[int]] = {}
        lo = 0
        for ((ra, rb), aa, _), pool in zip(blocks, pools):
            g = gcds[lo:lo + aa.size]
            lo += aa.size
            shared = self._shared_primes(
                g, np.asarray(pool, dtype=np.int64)) if g.size else set()
            out[(ra, rb)] = sorted(
                pid for q in shared
                if (pid := self.assigner.data_of(int(q))) is not None)
        return out

    # ------------------------------------------------------------------ #
    # oracle-compatible views                                             #
    # ------------------------------------------------------------------ #

    @property
    def hbm(self) -> "OrderedDict[int, bool]":
        """HBM contents in exact LRU order (stamp order == the oracle's
        ``OrderedDict`` order) — read-only compatibility view."""
        order = np.argsort(self.slot_t[:self._n_occupied], kind="stable")
        return OrderedDict(
            (int(self.slot_page[s]), bool(self.slot_pf[s])) for s in order)

    @property
    def host(self) -> Set[int]:
        """Host-tier page set — read-only compatibility view."""
        return {int(p) for p in np.nonzero(self.in_host)[0]}
