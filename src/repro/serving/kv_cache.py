"""Paged KV cache with PFCS page management (the paper's technique as a
first-class serving feature).

Pages are fixed-size KV blocks (``page_size`` tokens) living in a tiered
store: HBM (hot, limited slots) and host memory (cold, large).  PFCS
assigns each page a prime; a request's page *chain* is encoded as
composites over consecutive page pairs, so

  * shared prefixes between requests are discovered deterministically —
    two chains sharing pages share primes, and ``gcd`` of their chain
    composites recovers exactly the shared pages (zero false sharing,
    Theorem 1);
  * on access to page p, the divisibility scan over the chain registry
    finds every chain through p; factorization yields the *successor*
    pages other requests needed next — those are prefetched host->HBM
    ahead of the decode step that will touch them.

The device-side block-table attention consuming these pages is standard
paged attention; here we manage placement.  Hit/miss/prefetch stats feed
the serving benchmark (case_serving).
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.assignment import PrimeAssigner
from repro.core.composite import CompositeRegistry, encode_relationship
from repro.core.factorization import Factorizer
from repro.core.primes import CacheLevel, HierarchicalPrimeAllocator
from repro.obs.trace import EV_EVICT, EV_PREFETCH

__all__ = ["PagedKVCache", "PageStats", "PARITY_COUNTERS"]


#: the counters both cache implementations must agree on bit-for-bit
#: (tests/test_serving.py parity suite); ``registry_scans`` is excluded —
#: it counts *discovery work* and differs by design between the scalar
#: per-touch scan and the vectorized table-driven path.
PARITY_COUNTERS = ("hbm_hits", "host_hits", "misses", "prefetches",
                   "prefetch_hits", "evictions", "shared_prefix_pages")


@dataclass
class PageStats:
    hbm_hits: int = 0
    host_hits: int = 0          # page had to be fetched host -> HBM on demand
    misses: int = 0             # page did not exist (fresh allocation)
    prefetches: int = 0
    prefetch_hits: int = 0      # demanded while still resident from prefetch
    evictions: int = 0
    shared_prefix_pages: int = 0
    registry_scans: int = 0     # per-page §4.2 divisibility scans performed
    # cross-tenant dedup counters (repro.serving.dedup; zero elsewhere —
    # kept OUT of PARITY_COUNTERS so per-tenant stats still sum to the
    # global parity tuple; the dedup fuzz pins them via DEDUP_COUNTERS)
    dedup_hits: int = 0         # admission reused a shared-namespace page
    dedup_promotions: int = 0   # private page content re-seen cross-tenant
    cow_copies: int = 0         # chains that diverged off a shared prefix

    @property
    def hbm_hit_rate(self) -> float:
        total = self.hbm_hits + self.host_hits + self.misses
        return self.hbm_hits / max(1, total)

    @property
    def prefetch_hit_rate(self) -> float:
        return self.prefetch_hits / max(1, self.prefetches)

    def parity_tuple(self) -> Tuple[int, ...]:
        """The counters the vectorized cache must reproduce exactly."""
        return tuple(getattr(self, f) for f in PARITY_COUNTERS)


class PagedKVCache:
    """Host-side page manager.  Page ids are globally unique ints."""

    def __init__(self, hbm_pages: int = 1024, page_size: int = 16,
                 prefetch_budget: int = 4, max_bits: int = 62):
        self._init_identity(hbm_pages, page_size, prefetch_budget, max_bits)
        self.hbm: "OrderedDict[int, bool]" = OrderedDict()  # page -> prefetched
        self.host: Set[int] = set()

    def _init_identity(self, hbm_pages: int, page_size: int,
                       prefetch_budget: int, max_bits: int = 62) -> None:
        """Page identity, prime assignment, and chain state — shared with
        the array-state implementation (``kv_cache_vec``), which replaces
        only the *placement* structures above.  ``max_bits > 63`` runs the
        registry in multi-limb wide mode (million-element universes,
        DESIGN.md §11) — chain edges are pairwise either way, so the
        placement math is identical at every width."""
        self.page_size = page_size
        self.hbm_capacity = hbm_pages
        self.prefetch_budget = prefetch_budget
        self.factorizer = Factorizer()
        self.registry = CompositeRegistry(self.factorizer, max_bits=max_bits)
        self.assigner = self._make_assigner()
        self.chains: Dict[int, List[int]] = {}              # request -> pages
        self._content: Dict[Tuple, int] = {}  # content key -> page id (prefix share)
        self._next_page = 0
        self.stats = PageStats()
        #: observability sink (repro.obs.Observability) — ``None`` by
        #: default; every hook below is ``if self.obs is not None``
        #: guarded, so the disabled path adds one attribute check and
        #: nothing else (inertness contract, tests/test_obs.py)
        self.obs = None
        #: every (source page, prefetched page) pair ever issued, in
        #: order — the zero-false-positive audit trail, and part of the
        #: scalar/vec parity contract (tests/test_serving.py,
        #: tests/test_tenancy.py)
        self.prefetch_log: List[Tuple[int, int]] = []

    def _make_assigner(self) -> PrimeAssigner:
        """Prime-assignment backend (overridden by the multi-tenant
        cache, which routes each page to its tenant's namespace —
        ``repro.tenancy``)."""
        return PrimeAssigner(HierarchicalPrimeAllocator(), self.registry)

    # ------------------------------------------------------------------ #
    # page identity & prefix sharing                                      #
    # ------------------------------------------------------------------ #

    def _page_for_tokens(self, token_block: Tuple[int, ...]) -> Tuple[int, bool]:
        """Content-addressed page id: identical prefixes share pages.

        The map is keyed on the FULL content key, not ``hash(key)``: a
        64-bit hash collision would silently alias two distinct token
        blocks to one page — a statistical false positive of exactly the
        kind Theorem 1 forbids (dict lookup already compares keys on
        hash collision, so equality here is exact)."""
        key = self._content_key(token_block)
        pid = self._content.get(key)
        if pid is not None:
            self.stats.shared_prefix_pages += 1
            return pid, True
        pid = self._next_page
        self._next_page += 1
        self._content[key] = pid
        self._assign_page(pid)
        return pid, False

    def _content_key(self, token_block: Tuple[int, ...]):
        """Content-addressing key.  The multi-tenant cache scopes it by
        tenant: identical token blocks from different tenants must NOT
        share a page (a shared page would be a cross-tenant
        relationship — the class of leak the namespace isolation theorem
        forbids, DESIGN.md §8)."""
        return token_block

    def _assign_page(self, pid: int) -> None:
        """Prime assignment for a fresh page (the multi-tenant cache
        binds the page to its tenant's namespace first)."""
        self.assigner.assign(pid, CacheLevel.L2)

    def register_request(self, req_id: int, tokens: Sequence[int]) -> List[int]:
        """Map a request's prompt onto pages; register chain relationships."""
        pages: List[int] = []
        blocks = [tuple(tokens[i:i + self.page_size])
                  for i in range(0, len(tokens), self.page_size)]
        prefix: Tuple[int, ...] = ()
        for blk in blocks:
            prefix = prefix + blk           # page identity includes prefix
            pid, _ = self._page_for_tokens(prefix)
            pages.append(pid)
        self.chains[req_id] = pages
        self._register_chain_edges(pages)
        return pages

    def _register_chain_edges(self, pages: Sequence[int]
                              ) -> List[Tuple[int, int]]:
        """Register consecutive page pairs (successor edges) as chain
        composites; returns the pairs whose composite is NEW to the
        registry, in registration order.  A pair whose composite is
        already live is skipped outright: re-registering would leave
        the §4.2 scan's discoveries unchanged (the registry keys
        relationships by composite value) while orphaning the old
        ``Relationship``, inflating prime degrees, and bumping the
        registry version — which would force the vectorized cache into
        needless table rebuilds.  The vectorized cache maintains its
        successor table incrementally from exactly the returned list."""
        edges: List[Tuple[int, int]] = []
        for a, b in zip(pages, pages[1:]):
            pa, pb = self.assigner.prime_of(a), self.assigner.prime_of(b)
            if pa is not None and pb is not None and pa != pb:
                fresh = any(
                    self.registry.relationship_of_composite(c) is None
                    for c in encode_relationship((pa, pb),
                                                 self.registry.max_bits))
                if fresh:
                    self.registry.register({pa, pb}, kind="chain")
                    edges.append((a, b))
        return edges

    # ------------------------------------------------------------------ #
    # placement                                                            #
    # ------------------------------------------------------------------ #

    def _note_evict(self, pid: int) -> None:
        """Trace one HBM eviction with tenant attribution (shared by the
        scalar and array placement paths — both call it exactly once per
        eviction, inside the insert that displaced the victim)."""
        if self.obs is not None:
            tenant = getattr(self, "tenant_of_page", lambda _p: -1)(pid)
            self.obs.emit(EV_EVICT, page=pid,
                          tenant=-1 if tenant is None else int(tenant))

    def _evict_to_host(self) -> None:
        while len(self.hbm) > self.hbm_capacity:
            pid, _ = self.hbm.popitem(last=False)
            self.host.add(pid)
            self.stats.evictions += 1
            self._note_evict(pid)

    def _insert_hbm(self, pid: int, prefetched: bool) -> None:
        self.host.discard(pid)
        self.hbm[pid] = prefetched
        self.hbm.move_to_end(pid)
        self._evict_to_host()

    def touch(self, req_id: int, page_idx: int) -> str:
        """Demand access to a request's page (decode step reads it).
        Returns the tier that served it ('hbm' | 'host' | 'new')."""
        pages = self.chains[req_id]
        pid = pages[page_idx]
        if pid in self.hbm:
            was_pf = self.hbm[pid]
            self.hbm[pid] = False
            self.hbm.move_to_end(pid)
            self.stats.hbm_hits += 1
            if was_pf:
                self.stats.prefetch_hits += 1
            tier = "hbm"
        elif pid in self.host:
            self.stats.host_hits += 1
            self._insert_hbm(pid, False)
            tier = "host"
        else:
            self.stats.misses += 1
            self._insert_hbm(pid, False)
            tier = "new"
        self._prefetch_successors(pid)
        return tier

    def touch_batch(self, items: Sequence[Tuple[int, int]]) -> List[str]:
        """Demand-access a whole decode batch: ``items`` is a sequence of
        ``(req_id, page_idx)`` pairs, processed in order.  The scalar
        implementation simply loops ``touch`` (one §4.2 registry scan per
        page); the vectorized cache overrides this with table-driven bulk
        discovery — the serving engine always goes through this entry
        point."""
        return [self.touch(r, i) for r, i in items]

    def _prefetch_allowed(self, src: int, tgt: int) -> bool:
        """Prefetch admission filter (hook).  The dedup cache restricts
        prefetch targets to the requester's tenant + the shared
        namespace; a filtered candidate is skipped WITHOUT consuming
        budget, so both twins walk the same candidate order."""
        return True

    def _can_insert(self, pid: int) -> bool:
        """Insertability filter (hook).  The dedup cache reports a page
        un-insertable when its shared-namespace quota is pinned full by
        referenced pages; such candidates are skipped without consuming
        prefetch budget."""
        return True

    def _prefetch_successors(self, pid: int) -> None:
        """§4.2 scan: chains through pid -> prefetch successor pages."""
        p = self.assigner.prime_of(pid)
        if p is None:
            return
        budget = self.prefetch_budget
        if budget <= 0:
            return
        self.stats.registry_scans += 1
        for rel in self.registry.containing(p):
            for q in rel.primes:
                if q == p:
                    continue
                succ = self.assigner.data_of(q)
                if succ is None or succ in self.hbm:
                    continue
                if not (self._prefetch_allowed(pid, succ)
                        and self._can_insert(succ)):
                    continue
                self._insert_hbm(succ, True)
                self.stats.prefetches += 1
                self.prefetch_log.append((pid, succ))
                if self.obs is not None:
                    self.obs.emit(EV_PREFETCH, page=pid, arg=succ)
                budget -= 1
                if budget <= 0:
                    return

    # ------------------------------------------------------------------ #
    # deterministic shared-prefix discovery                                #
    # ------------------------------------------------------------------ #

    def shared_prefix(self, req_a: int, req_b: int) -> List[int]:
        """Pages shared by two requests, recovered via gcd of the chain
        composites (exact — unique factorization).

        The gcd is exact Python-int arithmetic at ANY registry width;
        the factors are recovered by trial division against request a's
        own chain primes rather than a general factorization of ``g`` —
        a wide-mode (``max_bits > 63``) chain composite can exceed
        anything the budgeted :meth:`Factorizer.factorize` path fully
        factors, whereas dividing out a known pool is exact and
        width-agnostic (the same pool-reconstruction the vectorized
        ``gcd_batch_exact`` path uses)."""
        import math
        ca = self._chain_composite(req_a)
        cb = self._chain_composite(req_b)
        g = math.gcd(ca, cb)
        if g <= 1:
            return []
        out = []
        residual = g
        for pid in self.chains.get(req_a, []):
            p = self.assigner.prime_of(pid)
            if p and residual % p == 0:
                residual //= p
                out.append(pid)
        assert residual == 1, "gcd of chain composites must factor " \
            "entirely over the chain's own primes (Theorem 1)"
        return sorted(out)

    def _chain_composite(self, req_id: int) -> int:
        """Product of the chain's page primes, capped to arbitrary
        precision host int (device kernels use the chunked encoding)."""
        c = 1
        for pid in self.chains.get(req_id, []):
            p = self.assigner.prime_of(pid)
            if p:
                c *= p
        return c

    def release_request(self, req_id: int) -> None:
        self.chains.pop(req_id, None)
