"""Continuous-batching serving engine with PFCS-managed KV pages.

Request lifecycle: submit -> (queued) -> prefill -> decode slots ->
complete.  The engine keeps a fixed decode batch; finished slots are
refilled from the queue every step (continuous batching, vLLM-style).
The paged KV cache decides page placement; each decode step first
touches the pages the batch will read — PFCS prefetch means the
successor pages of every active chain are already HBM-resident with
zero false-positive traffic.

Three cache backends (``kv=``):

  * ``"vec"`` (default) — :class:`~repro.serving.kv_cache_vec.
    VectorizedPagedKVCache`: array page tables + table-driven bulk
    discovery.  The whole decode batch's demand+prefetch set is one
    ``touch_batch`` call — no per-page registry scans — which is what
    lets one engine tick drive hundreds of concurrent requests
    (DESIGN.md §5).
  * ``"scalar"`` — the oracle :class:`~repro.serving.kv_cache.
    PagedKVCache`; bit-exact same counters, one §4.2 scan per page.
  * ``"sharded"`` — :class:`~repro.serving.kv_cache_sharded.
    ShardedPagedKVCache`: PFCS state partitioned over a
    ``("data", "model")`` mesh (``shards=N``), per-shard bulk
    discovery under ``shard_map`` (DESIGN.md §6); still bit-exact
    against the scalar oracle on every counter.
  * ``"elastic"`` — :class:`~repro.serving.elastic.
    ElasticShardedPagedKVCache`: the sharded cache with live
    ``resize(shards=)`` / ``fail_shard()`` hooks (DESIGN.md §9);
    shard-count changes migrate only moved prime blocks, shard losses
    recover deterministically by re-factorization, and oracle parity
    holds across every event.

Two expert-cache backends for MoE workloads (``moe=``, default off):

  * ``moe="vec"`` — :class:`~repro.serving.expert_cache_vec.
    VectorizedExpertCache`: array residency + table-driven bulk co-fire
    discovery; the whole decode step's router output is one
    ``activate_batch`` call (DESIGN.md §7).
  * ``moe="scalar"`` — the oracle :class:`~repro.serving.expert_cache.
    ExpertCache`; bit-exact same counters, one §4.2 scan per activated
    expert.

Router feeds are dual-mode: with ``model=None`` the engine synthesizes
a deterministic co-activation-structured router schedule (the
load-generator mode ``benchmarks.cases.case_moe`` drives); with a MoE
model from the zoo, each decode step's real top-k sets flow straight
from ``models/moe.py`` ``apply_moe`` router outputs into the expert
cache (``Model.decode_step_router``).

On-device compute is the model's ``prefill`` / ``decode_step``; pass
``model=None`` to run the engine as a pure page-management load
generator (deterministic stub tokens) — the mode the serving benchmark
(``benchmarks.cases.case_serving``) uses to drive 100+ concurrent
requests per step.  With a model, the engine is model-agnostic (any
arch from the zoo) and is exercised end-to-end by
``examples/serve_lm.py`` with a smoke-sized model.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .elastic import ElasticShardedPagedKVCache
from .expert_cache import ExpertCache
from .expert_cache_vec import VectorizedExpertCache
from .kv_cache import PagedKVCache
from .kv_cache_sharded import ShardedPagedKVCache
from .kv_cache_vec import VectorizedPagedKVCache

__all__ = ["Request", "ServingEngine", "make_kv_backend",
           "make_expert_backend", "synthetic_router_groups"]

#: stub-decode vocabulary (model=None load-generator mode)
_STUB_VOCAB = 32_000


def make_kv_backend(kv: str, *, hbm_pages: int, page_size: int,
                    prefetch_budget: int, shards: int = 2, mesh="auto",
                    tenants=None, max_bits: int = 62,
                    dedup: bool = False) -> PagedKVCache:
    """Construct a paged-KV cache backend by name — the single backend
    registry every engine front-end shares (``ServingEngine`` and the
    continuous-batching :class:`~repro.serving.slots.SlotMachine`).

    ``kv`` is one of ``"vec" | "scalar" | "sharded" | "elastic"``;
    ``tenants`` (an int or a :class:`~repro.tenancy.TenantQoSConfig`)
    selects the tenant-namespaced variant of the same backend
    (DESIGN.md §8), and ``dedup=True`` (tenants mode only) the
    copy-on-write shared-prefix dedup variant on top of it
    (DESIGN.md §12).  ``max_bits > 63`` runs the registry in multi-limb
    wide mode (DESIGN.md §11) — every backend composes unchanged."""
    if dedup:
        if tenants is None:
            raise ValueError("dedup=True needs tenants= mode (the shared "
                             "namespace is a tenant-namespace extension)")
        from repro.serving.dedup import (
            DedupElasticShardedPagedKVCache, DedupOracle,
            DedupShardedPagedKVCache, DedupVectorizedPagedKVCache)
        if kv == "vec":
            return DedupVectorizedPagedKVCache(
                hbm_pages=hbm_pages, page_size=page_size,
                prefetch_budget=prefetch_budget, qos=tenants,
                max_bits=max_bits)
        if kv == "scalar":
            return DedupOracle(
                hbm_pages=hbm_pages, page_size=page_size,
                prefetch_budget=prefetch_budget, qos=tenants,
                max_bits=max_bits)
        if kv == "sharded":
            return DedupShardedPagedKVCache(
                hbm_pages=hbm_pages, page_size=page_size,
                prefetch_budget=prefetch_budget, n_shards=shards,
                mesh=mesh, qos=tenants, max_bits=max_bits)
        if kv == "elastic":
            return DedupElasticShardedPagedKVCache(
                hbm_pages=hbm_pages, page_size=page_size,
                prefetch_budget=prefetch_budget, n_shards=shards,
                mesh=mesh, qos=tenants, max_bits=max_bits)
    elif tenants is not None:
        from repro.tenancy.qos import (
            TenantedElasticShardedPagedKVCache, TenantedPagedKVCache,
            TenantedShardedPagedKVCache, TenantedVectorizedPagedKVCache)
        if kv == "vec":
            return TenantedVectorizedPagedKVCache(
                hbm_pages=hbm_pages, page_size=page_size,
                prefetch_budget=prefetch_budget, qos=tenants,
                max_bits=max_bits)
        if kv == "scalar":
            return TenantedPagedKVCache(
                hbm_pages=hbm_pages, page_size=page_size,
                prefetch_budget=prefetch_budget, qos=tenants,
                max_bits=max_bits)
        if kv == "sharded":
            return TenantedShardedPagedKVCache(
                hbm_pages=hbm_pages, page_size=page_size,
                prefetch_budget=prefetch_budget, n_shards=shards,
                mesh=mesh, qos=tenants, max_bits=max_bits)
        if kv == "elastic":
            return TenantedElasticShardedPagedKVCache(
                hbm_pages=hbm_pages, page_size=page_size,
                prefetch_budget=prefetch_budget, n_shards=shards,
                mesh=mesh, qos=tenants, max_bits=max_bits)
    elif kv == "vec":
        return VectorizedPagedKVCache(
            hbm_pages=hbm_pages, page_size=page_size,
            prefetch_budget=prefetch_budget, max_bits=max_bits)
    elif kv == "scalar":
        return PagedKVCache(hbm_pages=hbm_pages, page_size=page_size,
                            prefetch_budget=prefetch_budget,
                            max_bits=max_bits)
    elif kv == "sharded":
        return ShardedPagedKVCache(
            hbm_pages=hbm_pages, page_size=page_size,
            prefetch_budget=prefetch_budget, n_shards=shards, mesh=mesh,
            max_bits=max_bits)
    elif kv == "elastic":
        return ElasticShardedPagedKVCache(
            hbm_pages=hbm_pages, page_size=page_size,
            prefetch_budget=prefetch_budget, n_shards=shards, mesh=mesh,
            max_bits=max_bits)
    raise ValueError(f"kv must be 'vec', 'scalar', 'sharded' or "
                     f"'elastic', got {kv!r}")


def make_expert_backend(moe: Optional[str], *, moe_experts: int,
                        moe_slots: int, moe_prefetch_budget: int,
                        tenants=None) -> Optional[ExpertCache]:
    """Construct an MoE expert-cache backend by name (``None`` disables
    the tier).  Shared by every engine front-end; with ``tenants`` the
    tenant-partitioned variant splits its own slot budget evenly."""
    if moe is None:
        return None
    if tenants is not None and moe in ("vec", "scalar"):
        from repro.tenancy.qos import (TenantedExpertCache,
                                       TenantedVectorizedExpertCache)
        cls = (TenantedVectorizedExpertCache if moe == "vec"
               else TenantedExpertCache)
        # a TenantQoSConfig sizes the KV cache's HBM pages; the
        # expert tier keeps the tenant count and splits its own
        # slot budget evenly
        moe_qos = tenants if isinstance(tenants, int) else tenants.n_tenants
        return cls(moe_experts, hbm_slots=moe_slots,
                   prefetch_budget=moe_prefetch_budget, qos=moe_qos)
    if moe == "vec":
        return VectorizedExpertCache(moe_experts, hbm_slots=moe_slots,
                                     prefetch_budget=moe_prefetch_budget)
    if moe == "scalar":
        return ExpertCache(moe_experts, hbm_slots=moe_slots,
                           prefetch_budget=moe_prefetch_budget)
    raise ValueError(f"moe must be None, 'vec' or 'scalar', got {moe!r}")


def synthetic_router_groups(moe_experts: int, moe_topk: int,
                            moe_groups: int, moe_seed: int = 0):
    """Deterministic synthetic-router group pool (model=None MoE mode):
    a fixed set of co-activation groups with zipf-skewed expert
    popularity.  Every engine front-end draws from the same pool, so a
    workload replayed across engines routes identically."""
    rng = np.random.default_rng(moe_seed)
    pop = 1.0 / np.arange(1, moe_experts + 1, dtype=np.float64)
    pop /= pop.sum()
    return [tuple(int(e) for e in rng.choice(
        moe_experts, size=min(moe_topk, moe_experts),
        replace=False, p=pop))
        for _ in range(max(1, moe_groups))]


@dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_new_tokens: int = 32
    tenant: int = 0                # namespace id (tenants= mode; else 0)
    generated: List[int] = field(default_factory=list)
    state: str = "queued"          # queued | running | done
    submit_t: float = 0.0
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None


class ServingEngine:
    def __init__(self, model=None, params=None, max_batch: int = 8,
                 max_seq: int = 512, page_size: int = 16,
                 hbm_pages: int = 256, greedy: bool = True,
                 kv: str = "vec", prefetch_budget: int = 4,
                 reread_window: int = 1, shards: int = 2, mesh="auto",
                 moe: Optional[str] = None, moe_experts: int = 64,
                 moe_slots: int = 16, moe_topk: int = 4,
                 moe_prefetch_budget: int = 4, moe_groups: int = 16,
                 moe_seed: int = 0, tenants=None, max_bits: int = 62,
                 dedup: bool = False, obs=None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        # multi-tenant QoS mode (DESIGN.md §8): tenants= an int (even
        # HBM split) or a repro.tenancy.TenantQoSConfig; requests carry
        # a tenant id and the cache enforces per-tenant quotas with
        # per-tenant PageStats / prefetch logs
        self.tenants = tenants
        # dedup=True (tenants mode): cross-tenant COW shared-prefix
        # dedup — register_request runs the admission dedup probe
        # before any prefill work (DESIGN.md §12)
        self.dedup = bool(dedup)
        self.pages: PagedKVCache = make_kv_backend(
            kv, hbm_pages=hbm_pages, page_size=page_size,
            prefetch_budget=prefetch_budget, shards=shards, mesh=mesh,
            tenants=tenants, max_bits=max_bits, dedup=dedup)
        # MoE expert-weight tier (DESIGN.md §7); router feed is the real
        # model router when the model is a MoE arch, a deterministic
        # synthetic schedule in load-generator mode
        model_moe = getattr(getattr(model, "cfg", None), "moe", None)
        if model_moe is not None:
            moe_experts, moe_topk = model_moe.n_experts, model_moe.top_k
        self.experts: Optional[ExpertCache] = make_expert_backend(
            moe, moe_experts=moe_experts, moe_slots=moe_slots,
            moe_prefetch_budget=moe_prefetch_budget, tenants=tenants)
        if (self.experts is not None and model is not None
                and getattr(model, "decode_step_router", None) is None):
            raise ValueError(
                "moe= needs router output: pass a MoE model (one with "
                "decode_step_router) or model=None for the synthetic-"
                "router load-generator mode")
        if self.experts is not None and model is None:
            # synthetic router: drawn deterministically per (request,
            # position) — identical across cache backends AND engines
            self._moe_groups = synthetic_router_groups(
                moe_experts, moe_topk, moe_groups, moe_seed)
        else:
            self._moe_groups = None
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * max_batch
        self._router_decode = (self.experts is not None
                               and model is not None
                               and getattr(model, "decode_step_router", None)
                               is not None)
        if model is not None:
            import jax
            self.cache = model.init_cache(max_batch, max_seq)
            self._decode = jax.jit(model.decode_step_router
                                   if self._router_decode
                                   else model.decode_step)
        else:                       # page-management load-generator mode
            self.cache = None
            self._decode = None
        self._next_id = 0
        self.steps = 0
        self.peak_live = 0          # max concurrent requests in one step
        #: observability sink — None by default (inert); attaching one
        #: also wires the page/expert tiers into the same event stream
        self.obs = obs
        if obs is not None:
            self.pages.obs = obs
            if self.experts is not None:
                self.experts.obs = obs
        # pages of KV context each decode step demand-reads per request:
        # the last `reread_window` pages of the chain, oldest first (paged
        # attention touches the recent context window; 1 = tail only)
        self.reread_window = max(1, int(reread_window))

    # ------------------------------------------------------------------ #
    # elastic hooks (kv="elastic"; DESIGN.md §9)                          #
    # ------------------------------------------------------------------ #

    def _elastic_pages(self) -> ElasticShardedPagedKVCache:
        if not isinstance(self.pages, ElasticShardedPagedKVCache):
            raise ValueError("resize/fail_shard need kv='elastic'")
        return self.pages

    def resize(self, shards: int, mesh="auto"):
        """Live shard-count change mid-serve; returns the
        :class:`~repro.sharding.reshard.ReshardPlan` (only moved blocks'
        registry slices migrate — placement and parity are untouched)."""
        return self._elastic_pages().resize(shards, mesh=mesh)

    def fail_shard(self, shard: int, recover: bool = True):
        """Inject a shard loss mid-serve.  With ``recover=True`` the
        dead shard's discovery state is immediately rebuilt by
        re-factorizing surviving composites (returns the
        :class:`~repro.serving.elastic.RecoveryReport`); otherwise
        recovery happens automatically before the next decode step."""
        pages = self._elastic_pages()
        pages.fail_shard(shard)
        return pages.recover_shard(shard) if recover else None

    # ------------------------------------------------------------------ #

    def submit(self, prompt: Sequence[int], max_new_tokens: int = 32,
               tenant: int = 0) -> int:
        if tenant and self.tenants is None:
            raise ValueError("tenant ids need tenants= mode (pass "
                             "tenants=N or a TenantQoSConfig)")
        if self.tenants is not None:
            # validate HERE: failing later inside _admit would leave a
            # permanently-running slot holding an unregistered request
            n = self.pages.qos_config.n_tenants
            if not 0 <= int(tenant) < n:
                raise ValueError(f"tenant {tenant} out of range [0, {n})")
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, list(prompt), max_new_tokens,
                                  tenant=int(tenant),
                                  submit_t=time.monotonic()))
        return rid

    def _admit(self) -> None:
        """Fill free slots from the queue; prefill their prompts."""
        for i, slot in enumerate(self.slots):
            if slot is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            req.state = "running"
            self.slots[i] = req
            if self.tenants is not None:
                self.pages.register_request(req.req_id, req.prompt,
                                            tenant=req.tenant)
            else:
                self.pages.register_request(req.req_id, req.prompt)
            if self.model is None:
                continue            # stub mode: no device KV to prefill
            # prefill this slot: feed prompt tokens through decode steps
            # (single-slot prefill keeps the engine simple; a production
            # path would batch prefills separately — Sarathi-style chunked
            # prefill is an extension hook)
            for tok in req.prompt:
                self._step_slot(i, tok)

    def _step_slot(self, i: int, token: int) -> int:
        """Advance slot i by one token; returns the argmax next token."""
        import jax.numpy as jnp
        b = self.max_batch
        toks = np.zeros((b, 1), np.int32)
        toks[i, 0] = token
        out = self._decode(self.params, {"tokens": jnp.asarray(toks)},
                           self.cache)
        # router-decode models return a third router output; prefill
        # routing is not observed (single-slot prefill is the same
        # simplification as the prefill loop itself)
        logits, self.cache = out[0], out[1]
        # only slot i's cache_len must advance: rebuild len vector
        ln = np.array(self.cache["len"], copy=True)
        for j in range(b):
            if j != i:
                ln[j] -= 1
        self.cache = dict(self.cache, len=jnp.asarray(ln))
        return int(np.argmax(np.asarray(logits)[i, -1]))

    def _stub_token(self, req: Request) -> int:
        """Deterministic pseudo-decode for model=None mode (independent
        of cache state, so vec/scalar engine runs stay comparable)."""
        return (req.req_id * 7919 + len(req.generated) * 104_729) % _STUB_VOCAB

    def _stub_expert_set(self, req: Request):
        """Deterministic synthetic router draw for model=None MoE mode:
        each (request, position) picks one of the engine's co-activation
        groups, so the workload has learnable co-fire structure and is
        identical across expert-cache backends."""
        g = (req.req_id * 7919 + len(req.generated) * 104_729) \
            % len(self._moe_groups)
        return self._moe_groups[g]

    def step(self) -> Dict[str, Any]:
        """One engine tick: admit, decode one token for every live slot.

        Page placement for the WHOLE batch is a single ``touch_batch``
        call — with the vectorized cache that means bulk table-driven
        discovery; with the scalar oracle it degenerates to the per-page
        scan loop.
        """
        self._admit()
        live = [(i, r) for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return {"live": 0}
        self.peak_live = max(self.peak_live, len(live))
        # touch the pages each live slot's decode reads (the last
        # reread_window pages of its chain, oldest first)
        touches = [(r.req_id, j)
                   for _, r in live
                   if (n := len(self.pages.chains.get(r.req_id) or ()))
                   for j in range(max(0, n - self.reread_window), n)]
        if touches:
            self.pages.touch_batch(touches)

        router = None
        if self.model is not None:
            import jax.numpy as jnp
            b = self.max_batch
            toks = np.zeros((b, 1), np.int32)
            for i, req in live:
                toks[i, 0] = (req.generated[-1] if req.generated else
                              (req.prompt[-1] if req.prompt else 0))
            out = self._decode(self.params, {"tokens": jnp.asarray(toks)},
                               self.cache)
            logits, self.cache = out[0], out[1]
            if self._router_decode:
                router = np.asarray(out[2])       # (n_moe_layers, B, K)
            lg = np.asarray(logits)
            nxt_of = {i: int(np.argmax(lg[i, -1])) for i, _ in live}
        else:
            nxt_of = {i: self._stub_token(r) for i, r in live}

        if self.experts is not None:
            # the whole step's router output — every live slot, every MoE
            # layer — is ONE observe_routing + ONE activate_batch call;
            # with the vectorized cache that means zero per-expert
            # registry scans (DESIGN.md §7)
            if router is not None:
                sets = [[int(e) for e in router[l, i]]
                        for i, _ in live for l in range(router.shape[0])]
            else:
                sets = [self._stub_expert_set(r) for _, r in live]
            self.experts.observe_routing(sets)
            self.experts.activate_batch(sets)

        now = time.monotonic()
        for i, req in live:
            req.generated.append(nxt_of[i])
            if req.first_token_t is None:
                req.first_token_t = now
            if len(req.generated) >= req.max_new_tokens:
                req.state = "done"
                req.done_t = now
                self.pages.release_request(req.req_id)
                self.slots[i] = None
        if self.obs is not None and self.obs.telemetry is not None:
            self.obs.telemetry.tick_engine(self)
        self.steps += 1
        out = {"live": len(live), "page_stats": self.pages.stats}
        if self.tenants is not None:
            out["tenant_stats"] = self.pages.qos.tenant_stats
        if self.experts is not None:
            out["expert_stats"] = self.experts.stats
        return out

    def run_until_idle(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            before = [s for s in self.slots]
            self.step()
            for s in before:
                if s is not None and s.state == "done":
                    done.append(s)
        return done
