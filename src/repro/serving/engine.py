"""Continuous-batching serving engine with PFCS-managed KV pages.

Request lifecycle: submit -> (queued) -> prefill -> decode slots ->
complete.  The engine keeps a fixed decode batch; finished slots are
refilled from the queue every step (continuous batching, vLLM-style).
The PagedKVCache decides page placement; each decode step first touches
the pages the batch will read — PFCS prefetch means the successor pages
of every active chain are already HBM-resident with zero false-positive
traffic.

On-device compute is the model's ``prefill`` / ``decode_step``; the
engine is model-agnostic (any arch from the zoo) and is exercised
end-to-end by ``examples/serve_lm.py`` with a smoke-sized model.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kv_cache import PagedKVCache

__all__ = ["Request", "ServingEngine"]


@dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_new_tokens: int = 32
    generated: List[int] = field(default_factory=list)
    state: str = "queued"          # queued | running | done
    submit_t: float = 0.0
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None


class ServingEngine:
    def __init__(self, model, params, max_batch: int = 8,
                 max_seq: int = 512, page_size: int = 16,
                 hbm_pages: int = 256, greedy: bool = True):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.pages = PagedKVCache(hbm_pages=hbm_pages, page_size=page_size)
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.cache = model.init_cache(max_batch, max_seq)
        self._decode = jax.jit(model.decode_step)
        self._next_id = 0
        self.steps = 0

    # ------------------------------------------------------------------ #

    def submit(self, prompt: Sequence[int], max_new_tokens: int = 32) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, list(prompt), max_new_tokens,
                                  submit_t=time.monotonic()))
        return rid

    def _admit(self) -> None:
        """Fill free slots from the queue; prefill their prompts."""
        for i, slot in enumerate(self.slots):
            if slot is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            req.state = "running"
            self.slots[i] = req
            self.pages.register_request(req.req_id, req.prompt)
            # prefill this slot: feed prompt tokens through decode steps
            # (single-slot prefill keeps the engine simple; a production
            # path would batch prefills separately — Sarathi-style chunked
            # prefill is an extension hook)
            for tok in req.prompt:
                self._step_slot(i, tok)

    def _step_slot(self, i: int, token: int) -> int:
        """Advance slot i by one token; returns the argmax next token."""
        b = self.max_batch
        toks = np.zeros((b, 1), np.int32)
        toks[i, 0] = token
        logits, self.cache = self._decode(self.params,
                                          {"tokens": jnp.asarray(toks)},
                                          self.cache)
        # only slot i's cache_len must advance: rebuild len vector
        ln = np.array(self.cache["len"], copy=True)
        for j in range(b):
            if j != i:
                ln[j] -= 1
        self.cache = dict(self.cache, len=jnp.asarray(ln))
        return int(np.argmax(np.asarray(logits)[i, -1]))

    def step(self) -> Dict[str, Any]:
        """One engine tick: admit, decode one token for every live slot."""
        self._admit()
        live = [(i, r) for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return {"live": 0}
        b = self.max_batch
        toks = np.zeros((b, 1), np.int32)
        for i, req in live:
            last = (req.generated[-1] if req.generated else
                    (req.prompt[-1] if req.prompt else 0))
            toks[i, 0] = last
            # touch the page the decode reads (tail of the chain)
            chain = self.pages.chains.get(req.req_id)
            if chain:
                self.pages.touch(req.req_id, len(chain) - 1)
        logits, self.cache = self._decode(self.params,
                                          {"tokens": jnp.asarray(toks)},
                                          self.cache)
        lg = np.asarray(logits)
        now = time.monotonic()
        for i, req in live:
            nxt = int(np.argmax(lg[i, -1]))
            req.generated.append(nxt)
            if req.first_token_t is None:
                req.first_token_t = now
            if len(req.generated) >= req.max_new_tokens:
                req.state = "done"
                req.done_t = now
                self.pages.release_request(req.req_id)
                self.slots[i] = None
        self.steps += 1
        return {"live": len(live), "page_stats": self.pages.stats}

    def run_until_idle(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            before = [s for s in self.slots]
            self.step()
            for s in before:
                if s is not None and s.state == "done":
                    done.append(s)
        return done
