"""Serving tier: PFCS page/expert management for the decode hot path.

The paper's technique as a first-class serving feature: KV pages and
MoE expert weights are data elements, chains and co-activation groups
are composites, and placement (HBM vs host) is driven by deterministic
factorization-based discovery — zero false-positive prefetch traffic
(Theorem 1), exactly where statistical prefetchers waste bandwidth.

Public entry points (documented with runnable examples in docs/api.md):

  * :class:`PagedKVCache`           — scalar paged-KV page manager (the
    bit-exact oracle; per-page §4.2 scans)
  * :class:`VectorizedPagedKVCache` — array-state page tables + bulk
    table-driven chain discovery (DESIGN.md §5, the serving hot path)
  * :class:`ShardedPagedKVCache`    — mesh-partitioned PFCS state:
    per-shard prime ranges, registry slices, and ``shard_map`` bulk
    discovery with a cross-shard gcd exchange (DESIGN.md §6)
  * :class:`ServingEngine`          — continuous-batching engine over
    any of the caches; :meth:`ServingEngine.submit` /
    :meth:`ServingEngine.step` drive the request lifecycle
  * :class:`ExpertCache`            — scalar MoE expert-weight cache
    with co-activation prefetch (the bit-exact oracle; per-activation
    §4.2 scans)
  * :class:`VectorizedExpertCache`  — array expert residency + bulk
    table-driven co-fire discovery (DESIGN.md §7, the MoE serving hot
    path; ``ServingEngine`` takes it with ``moe="vec"``)
  * :class:`ElasticShardedPagedKVCache` — live resharding + shard-loss
    recovery by refactorization (DESIGN.md §9; ``ServingEngine`` takes
    it with ``kv="elastic"`` and exposes ``resize``/``fail_shard``)
  * :class:`SlotMachine`            — continuous-batching slot machine:
    prefill/decode disaggregation, open-loop async admission, chunked
    prefill, preempt/resume with factorization-recovered prefetch
    (DESIGN.md §10); :class:`SlotOracle` is its per-slot Python-loop
    twin, differentially fuzzed bit-exact
    (``tests/test_serving_batching.py``)

The vectorized and sharded caches must reproduce the oracle's
``PageStats`` / ``ExpertCacheStats`` counters bit-for-bit
(``tests/test_serving.py``, ``tests/test_serving_sharded.py``,
``tests/test_serving_moe.py``), mirroring the engine-vs-oracle
discipline of ``tests/test_engine.py``.
"""

from .dedup import (DEDUP_COUNTERS, DedupElasticShardedPagedKVCache,
                    DedupOracle, DedupShardedPagedKVCache,
                    DedupVectorizedPagedKVCache)
from .elastic import (ElasticController, ElasticShardedPagedKVCache,
                      RecoveryReport)
from .engine import Request, ServingEngine
from .expert_cache import (EXPERT_PARITY_COUNTERS, ExpertCache,
                           ExpertCacheStats)
from .expert_cache_vec import VectorizedExpertCache
from .kv_cache import PARITY_COUNTERS, PagedKVCache, PageStats
from .kv_cache_sharded import ShardedPagedKVCache
from .kv_cache_vec import VectorizedPagedKVCache
from .slots import (SlotMachine, SlotOracle, SlotRequest,
                    poisson_arrival_ticks)

__all__ = [
    "Request", "ServingEngine", "ExpertCache", "ExpertCacheStats",
    "EXPERT_PARITY_COUNTERS", "VectorizedExpertCache",
    "PagedKVCache", "PageStats", "PARITY_COUNTERS",
    "ShardedPagedKVCache", "VectorizedPagedKVCache",
    "ElasticShardedPagedKVCache", "ElasticController", "RecoveryReport",
    "SlotMachine", "SlotOracle", "SlotRequest", "poisson_arrival_ticks",
    "DEDUP_COUNTERS", "DedupOracle", "DedupVectorizedPagedKVCache",
    "DedupShardedPagedKVCache", "DedupElasticShardedPagedKVCache",
]
