"""Elastic serving: live resharding + deterministic shard-loss recovery.

``ShardedPagedKVCache`` (DESIGN.md §6) fixed its shard count at
construction; this module makes the shard dimension *elastic* while
preserving the bit-exact oracle-parity contract through every event
(DESIGN.md §9):

* **Live resize** (:meth:`ElasticShardedPagedKVCache.resize`) — a
  shard-count change (2 -> 4 -> 2) swaps the
  :class:`~repro.core.engine.shard.PrimeSpacePartition` striping and
  migrates ONLY the registry slice entries whose
  :class:`~repro.sharding.stripes.BlockStripes` block changed owner
  (the :class:`~repro.sharding.reshard.ReshardPlan`).  Successor rows
  are untouched — they are placement state, global by design — so a
  resize costs O(moved entries), not a global rebuild.

* **Shard loss** (:meth:`fail_shard` / :meth:`recover_shard`) — a dead
  shard takes its registry slice classification and every successor row
  of the pages it owned.  Recovery reconstructs both purely by
  *re-factorizing surviving composites* through the existing Pallas
  divisibility/factorize kernels: :meth:`ShardSlices.recover` decodes
  the lost chunk ownership from the replicated composite values
  (Theorem 1: exact, zero false positives), then one
  :func:`~repro.core.engine.shard.sharded_successor_table` call over
  the dead shard's pages rebuilds exactly those rows.  No snapshot, no
  replica of the lost metadata is consulted — determinism IS the
  recovery mechanism ("determinism-as-recoverability", ROADMAP item 4).

* **Failover on demand** — ``_sync_tables`` recovers any dead shard
  before the next touch, so a kill injected mid-trace can never serve
  from a hole; the chaos fuzz (``tests/test_elastic.py``) pins bit-exact
  ``PARITY_COUNTERS`` / tier / LRU / prefetch-log parity against an
  uninterrupted scalar-oracle run across randomized kill/resize
  schedules.

:class:`ElasticController` wires the dormant training-fleet pieces
(:class:`~repro.training.elastic.FleetState` heartbeats,
:class:`~repro.training.elastic.StragglerMonitor`,
:class:`~repro.training.elastic.ElasticPlanner`) to those hooks with a
deterministic injectable clock: heartbeat expiry -> fail + recover;
straggler eviction -> same; healthy-count change -> planner-driven
resize to the largest power-of-two shard count.

Entry points here are documented with runnable examples in docs/api.md:
:class:`ElasticShardedPagedKVCache`, :class:`ElasticController`,
:class:`RecoveryReport`, and :class:`~repro.sharding.reshard.ReshardPlan`.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

from repro.core.engine.shard import (PrimeSpacePartition, ShardScanReport,
                                     shard_mesh, sharded_successor_table)
from repro.obs.trace import EV_RECOVERY
from repro.sharding.reshard import ReshardPlan, ShardSlices
from repro.training.elastic import ElasticPlanner, FleetState, StragglerMonitor

from .kv_cache import PARITY_COUNTERS, PageStats
from .kv_cache_sharded import ShardedPagedKVCache
from .kv_cache_vec import EMPTY

__all__ = ["ElasticShardedPagedKVCache", "ElasticController",
           "RecoveryReport"]


@dataclass(frozen=True)
class RecoveryReport:
    """What one shard recovery did, and what it cost.

    ``refactorized`` counts composite chunks decoded through the
    factorize kernels (``mode="partial"``: just the lost slice;
    ``mode="full"``: the registry mutated while the shard was dead, so
    nothing was trusted and everything was re-derived).  ``pages`` are
    the dead shard's pages whose successor rows were rebuilt — the
    recovery-invariant test compares exactly these rows against a
    from-scratch ``successor_table``.
    """

    shard: int
    mode: str                        # "partial" | "full"
    refactorized: int
    rows_rebuilt: int
    pages: Tuple[int, ...]

    @property
    def reread_bytes(self) -> int:
        return 8 * self.refactorized


class ElasticShardedPagedKVCache(ShardedPagedKVCache):
    """``ShardedPagedKVCache`` with live ``resize``/``fail_shard``/
    ``recover_shard`` and a maintained
    :class:`~repro.sharding.reshard.ShardSlices` registry index (which
    also feeds the sharded scan via ``precomputed=``, replacing the
    per-refresh ``classify`` walk)."""

    def __init__(self, hbm_pages: int = 1024, page_size: int = 16,
                 prefetch_budget: int = 4, n_shards: int = 2,
                 mesh="auto", stripes_per_shard: int = 8,
                 max_bits: int = 62):
        super().__init__(hbm_pages=hbm_pages, page_size=page_size,
                         prefetch_budget=prefetch_budget, n_shards=n_shards,
                         mesh=mesh, stripes_per_shard=stripes_per_shard,
                         max_bits=max_bits)
        self.slices = ShardSlices(self.partition)
        self.dead_shards: set = set()
        self.recoveries = 0
        self.reshard_log: List[ReshardPlan] = []
        self.recovery_log: List[RecoveryReport] = []

    # ------------------------------------------------------------------ #
    # discovery (index-fed sharded scan)                                  #
    # ------------------------------------------------------------------ #

    def refresh_tables(self, discover: Optional[str] = None) -> None:
        if discover is not None:
            super().refresh_tables(discover)
            return
        self._recover_dead()
        self.slices.sync(self.registry)
        self.last_scan = ShardScanReport()
        rows = sharded_successor_table(
            self.registry, self.assigner, range(self._next_page),
            self.partition, mesh=self.mesh, report=self.last_scan,
            precomputed=(self.slices.local(), self.slices.cross()))
        self._ensure_pages(self._next_page)
        self._install_rows(rows)

    def _sync_tables(self) -> None:
        # failover on demand: a killed shard is recovered before any
        # touch can consult (or prefetch from) its wiped rows
        self._recover_dead()
        super()._sync_tables()

    def _recover_dead(self) -> None:
        for s in sorted(self.dead_shards):
            self.recover_shard(s)

    def _owned_pages(self, shard: int) -> List[int]:
        return [d for d in range(self._next_page)
                if (p := self.assigner.prime_of(d)) is not None
                and self.partition.owner(p) == shard]

    # ------------------------------------------------------------------ #
    # shard loss + recovery-as-refactorization                            #
    # ------------------------------------------------------------------ #

    def fail_shard(self, shard: int) -> int:
        """Kill a shard: its registry slice classification and the
        successor rows of every page it owns are dropped (per-shard
        stats survive — accounting is durable monitoring state, so the
        aggregate-parity invariant holds across failures).  Returns the
        number of registry index entries lost."""
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} out of range "
                             f"[0, {self.n_shards})")
        if shard in self.dead_shards:
            return 0
        # survivors' index state is whatever was already synced plus the
        # replicated composite values — snapshot it before the loss
        self.slices.sync(self.registry)
        lost = self.slices.forget_shard(shard)
        for pid in self._owned_pages(shard):
            self._succ[pid, :] = EMPTY
            self._succ_len[pid] = 0
        self.dead_shards.add(shard)
        return lost

    def recover_shard(self, shard: int) -> RecoveryReport:
        """Reconstruct a dead shard's discovery state purely from the
        surviving composites: re-factorize to recover the lost slice
        classification, then rebuild ONLY its pages' successor rows
        through the sharded kernel scan."""
        if shard not in self.dead_shards:
            raise ValueError(f"shard {shard} is not dead")
        n_refac, mode = self.slices.recover(self.registry)
        pages = self._owned_pages(shard)
        report = ShardScanReport()
        rows = sharded_successor_table(
            self.registry, self.assigner, pages, self.partition,
            mesh=self.mesh, report=report,
            precomputed=(self.slices.local(), self.slices.cross()))
        self._ensure_pages(self._next_page)
        for d, row in rows.items():
            self._succ[d, :] = EMPTY
            self._succ_len[d] = 0
            for succ in row:
                self._succ_append(d, succ)
        self.dead_shards.discard(shard)
        self.recoveries += 1
        rep = RecoveryReport(shard=shard, mode=mode, refactorized=n_refac,
                             rows_rebuilt=len(rows),
                             pages=tuple(sorted(int(d) for d in rows)))
        self.recovery_log.append(rep)
        if self.obs is not None:
            self.obs.emit(EV_RECOVERY, shard=shard, arg=n_refac)
        return rep

    # ------------------------------------------------------------------ #
    # live resize                                                         #
    # ------------------------------------------------------------------ #

    def resize(self, n_shards: int, mesh="auto") -> ReshardPlan:
        """Live shard-count change: re-stripe the prime space, migrating
        only the moved blocks' registry index entries.  Successor rows
        and all placement state are untouched (they are shard-count
        independent), so every placement decision after a resize is
        bit-identical to the uninterrupted run."""
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self._recover_dead()
        self.slices.sync(self.registry)
        new_part = PrimeSpacePartition(int(n_shards),
                                       self.partition.stripes_per_shard)
        plan = self.slices.restripe(new_part)
        self.partition = new_part
        old_n, self.n_shards = self.n_shards, new_part.n_shards
        if mesh == "auto":
            mesh = shard_mesh(self.n_shards)
        if mesh is not None and mesh.size != self.n_shards:
            raise ValueError(f"mesh has {mesh.size} devices, cache has "
                             f"{self.n_shards} shards")
        self.mesh = mesh
        # fold per-shard accounting so sum(shard_stats) == global stats
        # survives every resize (shard s's history lands on s % n_new)
        old_stats = self.shard_stats
        self.shard_stats = [PageStats() for _ in range(self.n_shards)]
        for s, ss in enumerate(old_stats):
            tgt = self.shard_stats[s % self.n_shards]
            for f in PARITY_COUNTERS:
                setattr(tgt, f, getattr(tgt, f) + getattr(ss, f))
        self.reshard_log.append(plan)
        return plan


class ElasticController:
    """Fleet-event loop gluing heartbeats/stragglers to the elastic
    cache.  One node == one shard-serving host (``chips_per_node=1``);
    the planner's model axis is 1, so ``plan(healthy).n_chips`` is the
    largest power-of-two shard count the surviving fleet supports —
    exactly the 2 -> 4 -> 2 resize ladder.

    ``clock`` is injectable (`ManualClock` in tests) — no wall-clock
    reads on any test path.
    """

    def __init__(self, cache: ElasticShardedPagedKVCache,
                 clock: Callable[[], float] = time.monotonic,
                 heartbeat_timeout_s: float = 30.0,
                 straggler_threshold: float = 1.5,
                 straggler_window: int = 8, evict_after: int = 3):
        self.cache = cache
        self.clock = clock
        self.fleet = FleetState(n_nodes=cache.n_shards, chips_per_node=1,
                                heartbeat_timeout_s=heartbeat_timeout_s,
                                clock=clock)
        for n in range(cache.n_shards):
            self.fleet.heartbeat(n)
        self.monitor = StragglerMonitor(threshold=straggler_threshold,
                                        window=straggler_window,
                                        evict_after=evict_after, clock=clock)
        self.planner = ElasticPlanner(model_axis=1,
                                      base_data_axis=cache.n_shards,
                                      base_pods=1,
                                      global_batch=cache.n_shards)
        self.events: List[dict] = []

    def heartbeat(self, node: Optional[int] = None) -> None:
        nodes = self.fleet.healthy_nodes if node is None else [node]
        for n in nodes:
            self.fleet.heartbeat(n)

    def join(self, node: int) -> None:
        """Admit a (new or replaced) node; the next ``tick`` may resize
        the cache back up."""
        self.fleet.join(node)

    def tick(self, replan: bool = True) -> List[dict]:
        """One control-loop step: expire silent nodes, evict confirmed
        stragglers, recover every newly-lost shard, then re-plan the
        shard count for the surviving fleet.  Returns the events taken
        (kind ``"recover"`` with latency + :class:`RecoveryReport`, or
        ``"resize"`` with the mesh plan + :class:`ReshardPlan`)."""
        out: List[dict] = []
        newly = list(self.fleet.sweep())
        _, evict = self.monitor.check()
        for n in evict:
            if n in self.fleet.healthy_nodes:
                self.fleet.mark_failed(n)
                newly.append(n)
        for node in newly:
            shard = node % self.cache.n_shards
            t0 = self.clock()
            self.cache.fail_shard(shard)
            rep = (self.cache.recover_shard(shard)
                   if shard in self.cache.dead_shards else None)
            out.append({"kind": "recover", "node": node, "shard": shard,
                        "latency_s": self.clock() - t0, "report": rep})
        healthy = len(self.fleet.healthy_nodes)
        if replan and healthy >= 1:
            plan = self.planner.plan(healthy)
            if plan.n_chips != self.cache.n_shards:
                rp = self.cache.resize(plan.n_chips)
                out.append({"kind": "resize", "mesh_plan": plan,
                            "reshard": rp})
        self.events.extend(out)
        return out
