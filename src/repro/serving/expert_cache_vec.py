"""Array-state MoE expert cache: the vectorized twin of ``ExpertCache``.

The scalar cache (``expert_cache.py``, kept in the tree as the bit-exact
oracle) manages HBM expert residency through a Python ``OrderedDict``
and runs one §4.2 registry divisibility scan *per activated expert* —
the same scalar bottleneck the paged-KV twin removed from the serving
hot path (DESIGN.md §5).  This module applies the identical recipe to
expert co-activation (DESIGN.md §7):

**Fixed-shape array residency.**  HBM is ``hbm_slots`` slots of parallel
arrays — ``slot_expert`` (int32 expert id, ``EMPTY`` = -1), ``slot_t``
(int64 monotonic stamp; stamp order IS the oracle's ``OrderedDict``
order), ``slot_pf`` (bool, prefetched and not yet demanded).  Because
the expert universe is fixed at construction, the per-element side is a
single static ``slot_of`` int32 array (expert -> slot, -1 when the
weights live on the host: O(1) residency checks, no growth path).  LRU
eviction is one ``argmin`` over ``slot_t``; unique strictly-increasing
stamps make it select exactly the expert the oracle's
``popitem(last=False)`` evicts.

**Table-driven bulk co-fire discovery.**  The oracle's per-activation
registry scan collapses to a precomputed co-fire table — ``(E, W)``
int32 candidate rows in the oracle's exact iteration order (registry
order, then ``rel.primes``), padded with -1 and deliberately keeping
repeated targets (the dynamic residency check at activation time skips
them, exactly as the oracle's does).  Three maintenance modes, shared
with the paged-KV twin through :func:`repro.core.engine.successor_table`:

  * ``discover="incremental"`` (default) — group registration appends
    every member to every co-member's row in O(group²); the activation
    path performs ZERO registry scans.
  * ``discover="host"`` / ``"kernel"`` — rows are rebuilt in ONE bulk
    :func:`repro.core.engine.successor_table` call per registry change,
    at the next ``activate_batch``; ``"kernel"`` routes the scan +
    decode through the Pallas ``divisibility_scan`` /
    ``factorize_batch`` kernels over the registry's chunked int64
    composite arrays (the TPU registry-refresh deployment).

All three produce bit-identical rows (``tests/test_serving_moe.py``).
Co-activation groups live in the shared ``CompositeRegistry`` as chunked
int64 composite arrays (``encode_relationship``; a ``max_group`` top-k
set of L2 expert primes spans several < 2**62 chunks), which is exactly
the array the kernel backend scans.

Every ``ExpertCacheStats`` counter (except ``registry_scans``, which
counts discovery *work* and differs by design), every per-expert tier,
the HBM LRU order, and the prefetch log are bit-exact against the
scalar oracle under any interleaving of ``observe_routing`` /
``activate`` / ``activate_batch`` — enforced by the differential fuzz
suite in ``tests/test_serving_moe.py``.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.engine.tables import successor_table

from .expert_cache import ExpertCache

__all__ = ["VectorizedExpertCache"]

EMPTY = -1


class VectorizedExpertCache(ExpertCache):
    """Drop-in ``ExpertCache`` with array placement state and bulk
    co-fire discovery.  Expert identity, prime assignment, and the
    co-activation registry are shared with the oracle
    (``_init_identity``); only the placement structures and the
    discovery path change representation.
    """

    def __init__(self, n_experts: int, hbm_slots: int,
                 prefetch_budget: int = 4, max_group: int = 8,
                 discover: str = "incremental"):
        if discover not in ("incremental", "host", "kernel"):
            raise ValueError(f"discover must be 'incremental', 'host' or "
                             f"'kernel', got {discover!r}")
        self._init_identity(n_experts, hbm_slots, prefetch_budget, max_group)
        self.discover = discover
        # HBM slot arrays (slot-array layout, DESIGN.md §7.1)
        s = hbm_slots
        self.slot_expert = np.full((s,), EMPTY, dtype=np.int32)
        self.slot_t = np.zeros((s,), dtype=np.int64)
        self.slot_pf = np.zeros((s,), dtype=np.bool_)
        self._n_occupied = 0
        self._clock = 0
        # per-expert residency (static shape: the universe is fixed)
        self.slot_of = np.full((n_experts,), EMPTY, dtype=np.int32)
        # co-fire table: (E, W) candidate rows, -1 padded
        self._succ = np.full((n_experts, max(4, max_group)), EMPTY,
                             dtype=np.int32)
        self._succ_len = np.zeros((n_experts,), dtype=np.int32)
        self._table_version = self.registry.version
        self.bulk_refreshes = 0

    # ------------------------------------------------------------------ #
    # co-fire table maintenance                                           #
    # ------------------------------------------------------------------ #

    def _succ_append(self, e: int, succ: int) -> None:
        n = int(self._succ_len[e])
        if n == self._succ.shape[1]:                      # widen columns
            pad = np.full(self._succ.shape, EMPTY, dtype=np.int32)
            self._succ = np.concatenate([self._succ, pad], axis=1)
        self._succ[e, n] = succ
        self._succ_len[e] = n + 1

    def observe_routing(self, expert_sets) -> List:
        # incremental maintenance is only sound if the rows were current
        # when registration started; an out-of-band registry mutation
        # (e.g. Algorithm-1 prime recycling dropping relationships)
        # leaves the version mismatched, and fast-forwarding past it
        # would mask the drop — leave the table stale instead so the
        # next activation forces a bulk rebuild
        was_current = self.registry.version == self._table_version
        new = super().observe_routing(expert_sets)
        if self.discover == "incremental" and was_current:
            # O(group²) row maintenance at registration time reproduces
            # the oracle's candidate order exactly: appending in
            # registration order IS registry order, and the inner walk
            # follows the same ``rel.primes`` iteration the oracle's
            # scan expands
            for rel in new:
                members = [(q, self.assigner.data_of(q))
                           for q in rel.primes]
                for q, e in members:
                    if e is None:
                        continue
                    for r, other in members:
                        if r == q or other is None:
                            continue
                        self._succ_append(e, other)
            self._table_version = self.registry.version
        return new

    def _sync_tables(self) -> None:
        """One bulk refresh when the registry changed since the last
        build (no-op in incremental mode, where rows are maintained at
        registration time)."""
        if self._table_version == self.registry.version:
            return
        self.refresh_tables()

    def refresh_tables(self, discover: Optional[str] = None) -> None:
        """Rebuild every co-fire row in ONE bulk discovery call (host
        replay or Pallas kernels over the chunked composite arrays)."""
        backend = discover or self.discover
        if backend == "incremental":
            backend = "host"   # bulk rebuild semantics == host replay
        rows = successor_table(self.registry, self.assigner,
                               range(self.n_experts), discover=backend)
        self._succ.fill(EMPTY)
        self._succ_len.fill(0)
        for e, row in rows.items():
            for succ in row:
                self._succ_append(e, succ)
        self.bulk_refreshes += 1
        self._table_version = self.registry.version

    def successor_rows(self) -> Dict[int, List[int]]:
        """Current co-fire table as plain lists (tests/introspection)."""
        return {e: [int(x) for x in self._succ[e, :self._succ_len[e]]]
                for e in range(self.n_experts) if self._succ_len[e]}

    # ------------------------------------------------------------------ #
    # placement (array state machine)                                     #
    # ------------------------------------------------------------------ #

    def _tick(self) -> int:
        t = self._clock
        self._clock += 1
        return t

    def _insert(self, e: int, prefetched: bool) -> None:
        """Insert a non-resident expert into HBM; evict-LRU-first when
        full (identical to the oracle's add-then-evict for capacity
        >= 1, since the newest entry is never the eviction argmin)."""
        if self._n_occupied < self.hbm_slots:
            s = self._n_occupied
            self._n_occupied += 1
        else:
            s = int(np.argmin(self.slot_t))       # unique stamps: exact LRU
            victim = int(self.slot_expert[s])
            self.slot_of[victim] = EMPTY
            self.stats.evictions += 1
        self.slot_expert[s] = e
        self.slot_of[e] = s
        self.slot_t[s] = self._tick()
        self.slot_pf[s] = prefetched

    def _activate_one(self, experts: Sequence[int]) -> Dict[int, str]:
        tiers: Dict[int, str] = {}
        for e in experts:
            e = int(e)
            s = int(self.slot_of[e])
            if s >= 0:
                was_pf = bool(self.slot_pf[s])
                self.slot_pf[s] = False
                self.slot_t[s] = self._tick()
                self.stats.hits += 1
                if was_pf:
                    self.stats.prefetch_hits += 1
                tiers[e] = "hbm"
            else:
                self.stats.misses += 1
                self._insert(e, False)
                tiers[e] = "host"
        for e in experts:
            self._prefetch_row(int(e))
        return tiers

    def _prefetch_row(self, e: int) -> None:
        """Co-fire prefetch from the precomputed table — no registry
        scan, no factorization on the activation path."""
        budget = self.prefetch_budget
        if budget <= 0:
            return
        row = self._succ[e, :self._succ_len[e]]
        for succ in row:
            succ = int(succ)
            if self.slot_of[succ] >= 0:           # already HBM-resident
                continue
            self._insert(succ, True)
            self.stats.prefetches += 1
            self.prefetch_log.append((e, succ))
            budget -= 1
            if budget <= 0:
                return

    def activate(self, experts: Sequence[int]) -> Dict[int, str]:
        return self.activate_batch([experts])[0]

    def activate_batch(self, expert_sets: Sequence[Sequence[int]]
                       ) -> List[Dict[int, str]]:
        """Activate a whole decode step's router output.  Discovery for
        the entire batch is table gathers (plus at most one bulk table
        refresh); placement applies in submission order, which is what
        keeps every counter bit-exact against the oracle's sequential
        ``activate`` calls."""
        self._sync_tables()
        return [self._activate_one(s) for s in expert_sets]

    # ------------------------------------------------------------------ #
    # oracle-compatible views                                             #
    # ------------------------------------------------------------------ #

    @property
    def hbm(self) -> "OrderedDict[int, bool]":
        """HBM contents in exact LRU order (stamp order == the oracle's
        ``OrderedDict`` order) — read-only compatibility view."""
        order = np.argsort(self.slot_t[:self._n_occupied], kind="stable")
        return OrderedDict(
            (int(self.slot_expert[s]), bool(self.slot_pf[s]))
            for s in order)
