"""Cross-tenant copy-on-write shared-prefix dedup (DESIGN.md §12).

ROADMAP item 3, the dual of the PR 5 isolation theorem: tenant
namespaces prove *private* pages never cross tenants, yet real traffic
is dominated by deliberately identical content — system prompts, RAG
documents — re-sent by millions of users.  This module shares exactly
that content, without weakening the isolation proof:

  * **Shared prime namespace.**  The tenant namespace reserves one
    extra block-stripe part (``TenantNamespace(..., shared=True)`` —
    ``n_parts = n_tenants + 1``).  Shared read-only pages draw primes
    from that part, coprime to every tenant's private block family, so
    ``check_isolation`` still proves no private data crosses tenants:
    a composite is a violation only when its primes span two distinct
    NON-shared tenants; wholly-shared and mixed shared<->private edges
    (the COW boundary) are legal and counted in ``n_shared``.
  * **Admission-time dedup.**  Page identity is content-addressed per
    tenant (isolation); a second, tenant-agnostic content map detects
    the SAME token prefix re-registered by a different tenant and
    *promotes* it: a fresh page in the shared namespace backs the
    content from then on (``dedup_promotions``), and every later
    admission of that prefix reuses the shared page (``dedup_hits``).
    Each admission with a shared run is cross-checked by the existing
    gcd machinery: ``shared_prefix`` against a live co-referencing
    request must recover the shared pages (Theorem 1 — exact, and the
    vectorized twin routes it through the batched-gcd kernels).
  * **Copy-on-write.**  The first block where a chain diverges from a
    shared prefix allocates a fresh PRIVATE page with a fresh prime
    from the requester's own namespace (``cow_copies``); the shared
    page's prime, refcounts, and existing composites are untouched.
  * **Refcounted placement.**  Shared pages are refcounted (int32
    array state in the vectorized twin) and live under a dedicated
    ``shared_quota`` HBM reservation — disjoint from every tenant
    quota, so dedup can never displace (or be displaced by) private
    pages.  A referenced shared page is never evicted: when the shared
    quota is pinned full by referenced pages, inserts degrade to host
    placement and prefetch candidates are skipped without consuming
    budget (``_can_insert``).  HBM accounting charges each tenant its
    refcount-weighted share of every resident shared page
    (:func:`repro.tenancy.qos.refcount_weighted_shares`).

The scalar :class:`DedupOracle` is the bit-exact reference: the
vectorized / sharded / elastic dedup caches must reproduce every
``DEDUP_COUNTERS`` entry, tier, LRU order, prefetch log, per-tenant
stat, and refcount map under any interleaving — the established
differential-fuzz discipline (``tests/test_dedup.py``), composed with
``SlotMachine`` continuous batching and wide (``max_bits > 63``)
registries.

Entry points, documented with runnable examples in docs/api.md:
:class:`~repro.serving.dedup.DedupOracle` and
:class:`~repro.serving.dedup.DedupVectorizedPagedKVCache`.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Dict, List, Optional, Set, Tuple, Union

import numpy as np

from repro.core.primes import CacheLevel
from repro.obs.trace import (EV_AGE_OUT, EV_COW, EV_DEDUP_HIT,
                             EV_DEDUP_PROMOTE)
from repro.serving.kv_cache import PARITY_COUNTERS, PagedKVCache
from repro.serving.kv_cache_vec import EMPTY, VectorizedPagedKVCache
from repro.tenancy.namespace import TenantNamespace
from repro.tenancy.qos import (TenantQoSConfig, TenantedElasticShardedPagedKVCache,
                               TenantedPagedKVCache, TenantedShardedPagedKVCache,
                               TenantedVectorizedPagedKVCache, _STAMP_MAX,
                               refcount_weighted_shares)

__all__ = ["DEDUP_COUNTERS", "DedupOracle", "DedupVectorizedPagedKVCache",
           "DedupShardedPagedKVCache", "DedupElasticShardedPagedKVCache"]


#: the full dedup parity contract: the base counters PLUS the dedup
#: counters (kept out of PARITY_COUNTERS so per-tenant stats still sum
#: to the global parity tuple in the non-dedup tenanted caches)
DEDUP_COUNTERS = PARITY_COUNTERS + ("dedup_hits", "dedup_promotions",
                                    "cow_copies")


class _DedupBase:
    """Admission / refcount / COW layer shared by the scalar oracle and
    the vectorized dedup caches.  Placement (shared-quota slots, pinned
    eviction protection) lives in the placement mixins below."""

    # -- construction ------------------------------------------------------

    @staticmethod
    def _dedup_config(qos: Union[int, TenantQoSConfig], capacity: int,
                      default_budget: int) -> TenantQoSConfig:
        """An int tenant count reserves ``capacity // (n + 1)`` HBM
        slots for the shared namespace and splits the rest evenly; an
        explicit config is used as-is (``shared_quota=0`` keeps shared
        pages host-resident — still bit-exact, just dedup-cold)."""
        if isinstance(qos, int):
            shared = max(1, capacity // (qos + 1))
            cfg = replace(TenantQoSConfig.even(qos, capacity - shared,
                                               default_budget),
                          shared_quota=shared)
        else:
            cfg = qos
        cfg.validate(capacity)
        return cfg

    def _dedup_normalize(self, qos, namespace, capacity: int,
                         default_budget: int
                         ) -> Tuple[TenantQoSConfig, TenantNamespace]:
        cfg = self._dedup_config(qos, capacity, default_budget)
        if namespace is None:
            namespace = TenantNamespace(cfg.n_tenants, shared=True)
        if namespace.shared_part is None:
            raise ValueError("dedup needs a shared-capable namespace: "
                             "TenantNamespace(n_tenants, shared=True)")
        return cfg, namespace

    def _setup_dedup(self, namespace: TenantNamespace,
                     shared_pf_budget: int) -> None:
        self.shared_part = int(namespace.shared_part)
        self._shared_pf_budget = int(shared_pf_budget)
        #: tenant-AGNOSTIC content map (raw token prefix -> page id) —
        #: the dedup detector; the per-tenant ``_content`` map keeps
        #: owning private isolation semantics unchanged
        self._global_content: Dict[Tuple[int, ...], int] = {}
        #: per shared page: per-tenant live reference counts
        self._tenant_refs: Dict[int, Dict[int, int]] = {}
        #: per live request: shared pages its chain references
        self._req_shared: Dict[int, List[int]] = {}
        #: per shared page: live requests referencing it (donor lookup
        #: for the admission gcd probe)
        self._shared_users: Dict[int, List[int]] = {}
        #: per live request: leading shared-page run length (pages) —
        #: the prefill the slot machine may skip
        self.dedup_prefix: Dict[int, int] = {}
        #: reverse of ``_global_content`` for shared pages only
        #: (page id -> content key), so age-out can drop the entry
        self._shared_key: Dict[int, Tuple[int, ...]] = {}
        #: (page id, prime) of every aged-out shared page, in order —
        #: the refcount-lifecycle audit trail (tests/test_dedup.py)
        self.dedup_aged: List[Tuple[int, int]] = []
        #: aged pages whose prime release is deferred to the next
        #: admission (releasing mid-touch would mutate the registry
        #: under the live §4.2 scan / successor rows)
        self._aged_pending: List[int] = []
        self._aged_pending_set: Set[int] = set()
        #: admission gcd probes run (each asserts Theorem-1 recovery)
        self.dedup_probes = 0
        self._walk_refs: List[int] = []
        self._walk_diverged = True
        self._init_ref_store()

    # -- refcount store (overridden with int32 arrays in the vec mixin) ----

    def _init_ref_store(self) -> None:
        self._page_refs: Dict[int, int] = {}

    def ref_of(self, pid: int) -> int:
        return self._page_refs.get(pid, 0)

    def _ref_store_add(self, pid: int, delta: int) -> None:
        r = self._page_refs.get(pid, 0) + delta
        assert r >= 0, f"refcount of shared page {pid} went negative"
        self._page_refs[pid] = r

    def _ref_add(self, pid: int, tenant: int, delta: int) -> None:
        self._ref_store_add(pid, delta)
        d = self._tenant_refs.setdefault(pid, {})
        r = d.get(tenant, 0) + delta
        assert r >= 0, f"tenant {tenant} refcount of page {pid} negative"
        if r:
            d[tenant] = r
        else:
            d.pop(tenant, None)
        if not d:
            del self._tenant_refs[pid]

    # -- admission ---------------------------------------------------------

    def _is_shared_page(self, pid: int) -> bool:
        return self.tenant_of_page(pid) == self.shared_part

    def _alloc_shared_page(self) -> int:
        pid = self._next_page
        self._next_page += 1
        self.assigner.bind(pid, self.shared_part)
        self.assigner.assign(pid, CacheLevel.L2)
        return pid

    def _walk_note_shared(self, pid: int) -> None:
        self._walk_refs.append(pid)

    def _walk_note_private(self, fresh: bool) -> bool:
        if not self._walk_diverged:
            self._walk_diverged = True
            if fresh and self._walk_refs:
                # the first divergent block off a shared prefix: a
                # fresh PRIVATE page with a fresh prime — the shared
                # page and its composites are untouched (tested)
                self.stats.cow_copies += 1
                return True
        return False

    def _page_for_tokens(self, token_block) -> Tuple[int, bool]:
        key = tuple(token_block)
        owner = self._global_content.get(key)
        if owner is not None and self._is_shared_page(owner):
            # content already backed by a shared read-only page
            self.stats.shared_prefix_pages += 1
            self.stats.dedup_hits += 1
            ss = getattr(self, "shard_stats", None)
            if ss is not None:        # keep sum(shard_stats) == stats
                ss[self.owner_of_page(owner)].shared_prefix_pages += 1
            self._walk_note_shared(owner)
            if self.obs is not None:
                self.obs.emit(EV_DEDUP_HIT, page=owner,
                              tenant=self._current_tenant)
            return owner, True
        if owner is not None and self.tenant_of_page(owner) \
                != self._current_tenant:
            # private content re-seen from ANOTHER tenant: promote it
            # to a fresh shared-namespace page (the donor keeps its
            # private page; the content is shared from here on)
            pid = self._alloc_shared_page()
            self._global_content[key] = pid
            self._shared_key[pid] = key
            self.stats.dedup_promotions += 1
            self._walk_note_shared(pid)
            if self.obs is not None:
                self.obs.emit(EV_DEDUP_PROMOTE, page=pid,
                              tenant=self._current_tenant)
            return pid, False
        # same-tenant reuse (owner is this tenant's private page) or a
        # fresh allocation — both through the tenant-scoped path
        cow = self._walk_note_private(fresh=owner is None)
        pid, reused = super()._page_for_tokens(token_block)
        if owner is None:
            self._global_content[key] = pid
        if cow and self.obs is not None:
            self.obs.emit(EV_COW, page=pid, tenant=self._current_tenant)
        return pid, reused

    # -- shared-page age-out (the PR 9 leak fix) ---------------------------

    def _age_out_shared(self, pid: int) -> None:
        """End-of-life for a zero-ref shared page evicted from the
        shared quota: drop its ``_global_content`` entry (these used to
        leak — the content map grew monotonically and kept resurrecting
        dead pages), bar it from prefetch resurrection, and schedule
        its prime for recycling.  The ``assigner.release`` itself is
        deferred to the next admission: running it here would drop
        composites out of the registry while the §4.2 scan (scalar) or
        a successor row (vec) of the very touch that triggered the
        eviction is still being iterated."""
        key = self._shared_key.pop(pid, None)
        if key is not None and self._global_content.get(key) == pid:
            del self._global_content[key]
        self._shared_users.pop(pid, None)
        p = self.assigner.prime_of(pid)
        self.dedup_aged.append((pid, -1 if p is None else int(p)))
        self._aged_pending.append(pid)
        self._aged_pending_set.add(pid)
        if self.obs is not None:
            self.obs.emit(EV_AGE_OUT, page=pid, tenant=self.shared_part)

    def _flush_aged(self) -> None:
        """Recycle the primes of aged-out shared pages (admission-time:
        the registry is quiescent here).  Dropping the prime purges its
        chain composites — all of them belong to dead chains or dangle
        off the dead page, since refs hit 0 only when no live chain
        contains it — and bumps the assigner epoch, which forces the
        vec twin's chunk caches and successor tables to rebuild (the
        PR 5 recycling machinery, so twin parity is preserved)."""
        if not self._aged_pending:
            return
        for pid in self._aged_pending:
            self.assigner.release(pid, CacheLevel.L2)
        self._aged_pending.clear()
        self._aged_pending_set.clear()

    # -- request lifecycle -------------------------------------------------

    def register_request(self, req_id: int, tokens, tenant: int = 0):
        self._flush_aged()
        if req_id in self.chains:             # re-register: drop old refs
            self._drop_refs(req_id)
        self._walk_refs = []
        self._walk_diverged = False
        pages = super().register_request(req_id, tokens, tenant=tenant)
        self._walk_diverged = True
        t = self.tenant_of_request(req_id)
        for pid in self._walk_refs:
            self._ref_add(pid, t, +1)
            users = self._shared_users.setdefault(pid, [])
            if req_id not in users:
                users.append(req_id)
        self._req_shared[req_id] = list(self._walk_refs)
        self.dedup_prefix[req_id] = len(self._walk_refs)
        self._admission_probe(req_id)
        return pages

    def _admission_probe(self, req_id: int) -> None:
        """Cross-check every dedup'd admission through the gcd
        machinery: against a live request co-referencing the deepest
        shared page, ``shared_prefix`` (scalar exact gcd / vectorized
        batched-gcd kernels) must recover that page — Theorem 1's
        zero-false-positive discovery applied to the dedup decision."""
        if not self._walk_refs:
            return
        last = self._walk_refs[-1]
        donor = next((r for r in self._shared_users.get(last, ())
                      if r != req_id and r in self.chains), None)
        if donor is None:
            return
        probe = self.shared_prefix(req_id, donor)
        assert last in probe, \
            "admission gcd probe failed to recover the shared prefix"
        self.dedup_probes += 1

    def _drop_refs(self, req_id: int) -> None:
        t = self.tenant_of_request(req_id)
        for pid in self._req_shared.pop(req_id, ()):
            self._ref_add(pid, t, -1)
            users = self._shared_users.get(pid)
            if users is not None:
                if req_id in users:
                    users.remove(req_id)
                if not users:
                    del self._shared_users[pid]
        self.dedup_prefix.pop(req_id, None)

    def release_request(self, req_id: int) -> None:
        self._drop_refs(req_id)
        super().release_request(req_id)

    # -- prefetch admission ------------------------------------------------

    def _part_of_page(self, pid: int) -> int:
        p = self.assigner.prime_of(pid)
        if p is not None:
            return int(self.namespace.tenant_of_value(p))
        return self.tenant_of_page(pid)

    def _prefetch_allowed(self, src: int, tgt: int) -> bool:
        # a shared page may be prefetched from anywhere; a private page
        # only along its own tenant's chain.  shared -> private is
        # blocked: the COW boundary fans out to EVERY diverging
        # tenant's private page, and the touching requester's identity
        # is not part of the §4.2 scan.
        if tgt in self._aged_pending_set:
            # dead page awaiting prime recycle: its registry edges are
            # still visible to the scan, but resurrecting it would race
            # the deferred release (skipped without consuming budget —
            # both twins walk the same candidate order)
            return False
        pt = self._part_of_page(tgt)
        return pt == self.shared_part or pt == self._part_of_page(src)

    def _can_insert(self, pid: int) -> bool:
        if not self._is_shared_page(pid) or self._resident(pid):
            return True
        q = self.qos
        return (q.shared_occupancy < q.shared_quota
                or self._has_shared_victim())

    def cross_tenant_prefetches(self) -> int:
        """Prefetch-log entries spanning two distinct NON-shared tenant
        namespaces — must be 0 (shared-namespace endpoints are the
        point of dedup, not a leak: the page is read-only and common)."""
        bad = 0
        for src, tgt in self.prefetch_log:
            ps, pt = self._part_of_page(src), self._part_of_page(tgt)
            if self.shared_part in (ps, pt):
                continue
            if ps != pt:
                bad += 1
        return bad

    # -- accounting --------------------------------------------------------

    def shared_page_refs(self, resident_only: bool = True
                         ) -> List[Dict[int, int]]:
        """Per-tenant reference maps of (HBM-resident) shared pages, in
        page-id order — the input :func:`refcount_weighted_shares`
        wants."""
        return [dict(sorted(self._tenant_refs[pid].items()))
                for pid in sorted(self._tenant_refs)
                if not resident_only or self._resident(pid)]

    def charged_shares(self) -> np.ndarray:
        """Refcount-weighted HBM pages charged to each tenant: private
        occupancy plus this tenant's fraction of every resident shared
        page (DESIGN.md §12; the HBM-bytes/user metric of
        ``case_dedup``)."""
        return refcount_weighted_shares(self.qos.occupancy,
                                        self.shared_page_refs())

    def dedup_state(self) -> Dict[str, object]:
        """Canonical dedup twin state for the differential fuzz."""
        return {
            "refs": {pid: self.ref_of(pid)
                     for pid in sorted(self._tenant_refs)},
            "tenant_refs": {pid: dict(sorted(self._tenant_refs[pid].items()))
                            for pid in sorted(self._tenant_refs)},
            "prefix": dict(sorted(self.dedup_prefix.items())),
            "shared_occupancy": int(self.qos.shared_occupancy),
            "probes": int(self.dedup_probes),
            "aged": list(self.dedup_aged),
            "aged_pending": list(self._aged_pending),
        }


class _DedupScalarPlacement(_DedupBase):
    """Shared-quota placement for the scalar oracle: dict/set tiers,
    first-matching-dict-entry eviction (== oldest stamp)."""

    def _resident(self, pid: int) -> bool:
        return pid in self.hbm

    def _has_shared_victim(self) -> bool:
        return any(self._is_shared_page(x) and self.ref_of(x) == 0
                   for x in self.hbm)

    def _insert_hbm(self, pid: int, prefetched: bool) -> None:
        if not self._is_shared_page(pid):
            super()._insert_hbm(pid, prefetched)     # tenant-confined path
            return
        q = self.qos
        if q.shared_occupancy >= q.shared_quota:
            victim = next((x for x in self.hbm
                           if self._is_shared_page(x)
                           and self.ref_of(x) == 0), None)
            if victim is None:
                # pinned full: every resident shared page is referenced
                # by a live chain — a read-only shared page is never
                # displaced, so the insert degrades to host placement
                self.host.add(pid)
                return
            del self.hbm[victim]
            self.stats.evictions += 1
            self._note_evict(victim)
            q.shared_occupancy -= 1
            # zero-ref + evicted = end of life: no host demotion — the
            # page's content entry and prime are reclaimed instead
            self._age_out_shared(victim)
        PagedKVCache._insert_hbm(self, pid, prefetched)
        q.shared_occupancy += 1

    def touch(self, req_id: int, page_idx: int) -> str:
        pid = self.chains[req_id][page_idx]
        if not self._is_shared_page(pid):
            return super().touch(req_id, page_idx)
        # shared pages run under the shared prefetch budget and charge
        # only the GLOBAL stats (per-tenant stats stay private-only —
        # refcount-weighted accounting covers the shared tier)
        self.prefetch_budget = self._shared_pf_budget
        return PagedKVCache.touch(self, req_id, page_idx)


class _DedupVecPlacement(_DedupBase):
    """Shared-quota placement for the vectorized caches: int32 refcount
    array alongside the per-page arrays, masked-argmin eviction over
    the shared slots (slot_tenant == shared_part)."""

    def _init_ref_store(self) -> None:
        self.page_refs = np.zeros((64,), dtype=np.int32)

    def ref_of(self, pid: int) -> int:
        if pid >= self.page_refs.shape[0]:
            return 0
        return int(self.page_refs[pid])

    def _ref_store_add(self, pid: int, delta: int) -> None:
        if pid >= self.page_refs.shape[0]:
            self._ensure_pages(pid + 1)
        r = int(self.page_refs[pid]) + delta
        assert r >= 0, f"refcount of shared page {pid} went negative"
        self.page_refs[pid] = r

    def _ensure_pages(self, n: int) -> None:
        super()._ensure_pages(n)
        cur = self.page_refs.shape[0]
        if self.slot_of.shape[0] > cur:
            self.page_refs = np.concatenate(
                [self.page_refs,
                 np.zeros((self.slot_of.shape[0] - cur,), dtype=np.int32)])

    def _resident(self, pid: int) -> bool:
        return pid < self.slot_of.shape[0] and self.slot_of[pid] >= 0

    def _shared_mask(self) -> np.ndarray:
        n = self._n_occupied
        pages = self.slot_page[:n]
        return ((self.slot_tenant[:n] == self.shared_part)
                & (self.page_refs[pages] == 0))

    def _has_shared_victim(self) -> bool:
        return bool(self._shared_mask().any())

    def _insert(self, pid: int, prefetched: bool) -> None:
        if not self._is_shared_page(pid):
            super()._insert(pid, prefetched)         # tenant-confined path
            return
        q = self.qos
        if q.shared_occupancy >= q.shared_quota:
            mask = self._shared_mask()
            if not mask.any():
                self.in_host[pid] = True             # pinned-full bypass
                return
            n = self._n_occupied
            stamps = np.where(mask, self.slot_t[:n], _STAMP_MAX)
            s = int(np.argmin(stamps))
            victim = int(self.slot_page[s])
            self.slot_of[victim] = EMPTY    # no host demotion: aged out
            self.stats.evictions += 1
            self._note_evict(victim)
            q.shared_occupancy -= 1
            self._age_out_shared(victim)
            self.in_host[pid] = False
            self.slot_page[s] = pid
            self.slot_of[pid] = s
            self.slot_t[s] = self._tick()
            self.slot_pf[s] = prefetched    # slot_tenant[s] stays shared
        else:
            assert self._n_occupied < self.hbm_capacity, \
                "quota invariant broken: HBM full under the shared quota"
            VectorizedPagedKVCache._insert(self, pid, prefetched)
            self.slot_tenant[self.slot_of[pid]] = self.shared_part
        q.shared_occupancy += 1

    def _touch_one(self, pid: int) -> str:
        if not self._is_shared_page(pid):
            return super()._touch_one(pid)
        self.prefetch_budget = self._shared_pf_budget
        return VectorizedPagedKVCache._touch_one(self, pid)


class DedupOracle(_DedupScalarPlacement, TenantedPagedKVCache):
    """Scalar COW shared-prefix dedup cache — the bit-exact reference
    twin for the vectorized / sharded / elastic dedup caches."""

    def __init__(self, hbm_pages: int = 1024, page_size: int = 16,
                 prefetch_budget: int = 4,
                 qos: Union[int, TenantQoSConfig] = 2,
                 namespace: Optional[TenantNamespace] = None,
                 max_bits: int = 62):
        cfg, ns = self._dedup_normalize(qos, namespace, hbm_pages,
                                        prefetch_budget)
        self._setup_dedup(ns, prefetch_budget)
        TenantedPagedKVCache.__init__(
            self, hbm_pages=hbm_pages, page_size=page_size,
            prefetch_budget=prefetch_budget, qos=cfg, namespace=ns,
            max_bits=max_bits)


class DedupVectorizedPagedKVCache(_DedupVecPlacement,
                                  TenantedVectorizedPagedKVCache):
    """Vectorized COW shared-prefix dedup cache — int32 refcount array
    state, bit-exact against :class:`DedupOracle`."""

    def __init__(self, hbm_pages: int = 1024, page_size: int = 16,
                 prefetch_budget: int = 4, discover: str = "incremental",
                 qos: Union[int, TenantQoSConfig] = 2,
                 namespace: Optional[TenantNamespace] = None,
                 max_bits: int = 62):
        cfg, ns = self._dedup_normalize(qos, namespace, hbm_pages,
                                        prefetch_budget)
        self._setup_dedup(ns, prefetch_budget)
        TenantedVectorizedPagedKVCache.__init__(
            self, hbm_pages=hbm_pages, page_size=page_size,
            prefetch_budget=prefetch_budget, discover=discover, qos=cfg,
            namespace=ns, max_bits=max_bits)


class DedupShardedPagedKVCache(_DedupVecPlacement,
                               TenantedShardedPagedKVCache):
    """Dedup composed with the mesh-sharded cache: shard ownership,
    tenant isolation, and the shared dedup namespace are three
    independent pure functions of the same prime value."""

    def __init__(self, hbm_pages: int = 1024, page_size: int = 16,
                 prefetch_budget: int = 4, n_shards: int = 2,
                 mesh="auto", stripes_per_shard: int = 8,
                 qos: Union[int, TenantQoSConfig] = 2,
                 namespace: Optional[TenantNamespace] = None,
                 max_bits: int = 62):
        cfg, ns = self._dedup_normalize(qos, namespace, hbm_pages,
                                        prefetch_budget)
        self._setup_dedup(ns, prefetch_budget)
        TenantedShardedPagedKVCache.__init__(
            self, hbm_pages=hbm_pages, page_size=page_size,
            prefetch_budget=prefetch_budget, n_shards=n_shards, mesh=mesh,
            stripes_per_shard=stripes_per_shard, qos=cfg, namespace=ns,
            max_bits=max_bits)


class DedupElasticShardedPagedKVCache(_DedupVecPlacement,
                                      TenantedElasticShardedPagedKVCache):
    """Dedup composed with the ELASTIC sharded cache: resize /
    fail_shard / recover_shard operate on shard striping only, so no
    elastic event can move a page across the tenant or shared
    namespace boundaries."""

    def __init__(self, hbm_pages: int = 1024, page_size: int = 16,
                 prefetch_budget: int = 4, n_shards: int = 2,
                 mesh="auto", stripes_per_shard: int = 8,
                 qos: Union[int, TenantQoSConfig] = 2,
                 namespace: Optional[TenantNamespace] = None,
                 max_bits: int = 62):
        cfg, ns = self._dedup_normalize(qos, namespace, hbm_pages,
                                        prefetch_budget)
        self._setup_dedup(ns, prefetch_budget)
        TenantedElasticShardedPagedKVCache.__init__(
            self, hbm_pages=hbm_pages, page_size=page_size,
            prefetch_budget=prefetch_budget, n_shards=n_shards, mesh=mesh,
            stripes_per_shard=stripes_per_shard, qos=cfg, namespace=ns,
            max_bits=max_bits)
