"""PFCS expert-weight cache for MoE serving (kimi-k2 / deepseek-v2).

Experts are data elements; HBM holds a subset (hot experts), host memory
the rest.  Each decode step's router output is a set of active experts;
PFCS encodes *co-activation* — the top-k set of a token batch — as a
composite over expert primes.  The registry accumulates the co-activation
structure of the workload, and on activation of expert e the divisibility
scan + factorization recovers exactly which experts historically co-fire
with e; those are prefetched host->HBM ahead of the expert all-to-all.

Zero false positives (Theorem 1) means no wasted host->HBM transfers on
unrelated experts — the transfers are the scarce resource when cold
experts live off-chip.

This scalar implementation is the bit-exact oracle for
:class:`~repro.serving.expert_cache_vec.VectorizedExpertCache`
(DESIGN.md §7): every ``EXPERT_PARITY_COUNTERS`` entry, every per-expert
tier decision, the HBM LRU order, and the prefetch log must match under
any interleaving of ``observe_routing`` / ``activate`` /
``activate_batch`` (``tests/test_serving_moe.py``).
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Iterable, List, Sequence, Set, Tuple

from repro.core.assignment import PrimeAssigner
from repro.core.composite import (CompositeRegistry, Relationship,
                                  encode_relationship)
from repro.core.factorization import Factorizer
from repro.core.primes import CacheLevel, HierarchicalPrimeAllocator

__all__ = ["ExpertCache", "ExpertCacheStats", "EXPERT_PARITY_COUNTERS"]


#: the counters both expert-cache implementations must agree on
#: bit-for-bit (tests/test_serving_moe.py parity suite);
#: ``registry_scans`` is excluded — it counts discovery *work* and
#: differs by design between the scalar per-activation scan and the
#: vectorized table-driven path.
EXPERT_PARITY_COUNTERS = ("hits", "misses", "prefetches", "prefetch_hits",
                          "evictions")


@dataclass
class ExpertCacheStats:
    hits: int = 0
    misses: int = 0             # demand host->HBM transfer (stalls the step)
    prefetches: int = 0
    prefetch_hits: int = 0
    evictions: int = 0
    registry_scans: int = 0     # per-activation §4.2 divisibility scans

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.hits + self.misses)

    @property
    def prefetch_precision(self) -> float:
        return self.prefetch_hits / max(1, self.prefetches)

    def parity_tuple(self) -> Tuple[int, ...]:
        """The counters the vectorized cache must reproduce exactly."""
        return tuple(getattr(self, f) for f in EXPERT_PARITY_COUNTERS)


class ExpertCache:
    def __init__(self, n_experts: int, hbm_slots: int,
                 prefetch_budget: int = 4, max_group: int = 8):
        self._init_identity(n_experts, hbm_slots, prefetch_budget, max_group)
        self.hbm: "OrderedDict[int, bool]" = OrderedDict()

    def _init_identity(self, n_experts: int, hbm_slots: int,
                       prefetch_budget: int, max_group: int) -> None:
        """Expert identity, prime assignment, and co-activation registry —
        shared with the array-state implementation
        (``expert_cache_vec``), which replaces only the placement
        structures and the discovery path."""
        if n_experts < 1:
            raise ValueError("n_experts must be >= 1")
        if hbm_slots < 1:
            raise ValueError("hbm_slots must be >= 1")
        self.n_experts = n_experts
        self.hbm_slots = hbm_slots
        self.prefetch_budget = prefetch_budget
        self.max_group = max_group
        self.factorizer = Factorizer()
        self.registry = CompositeRegistry(self.factorizer)
        self.assigner = self._make_assigner()
        for e in range(n_experts):
            self._assign_expert(e)
        self.stats = ExpertCacheStats()
        self._seen_groups: Set[frozenset] = set()
        #: every (source expert, prefetched expert) pair ever issued, in
        #: order — the zero-false-positive audit trail (Theorem 1 tests)
        self.prefetch_log: List[Tuple[int, int]] = []

    def _make_assigner(self) -> PrimeAssigner:
        """Prime-assignment backend (overridden by the multi-tenant
        cache, which routes each expert to its tenant's namespace —
        ``repro.tenancy``)."""
        return PrimeAssigner(HierarchicalPrimeAllocator(), self.registry)

    def _assign_expert(self, e: int) -> None:
        """Prime assignment for one expert (the multi-tenant cache binds
        the expert to its tenant's namespace first)."""
        self.assigner.assign(e, CacheLevel.L2)

    # ------------------------------------------------------------------ #
    # co-activation registration                                          #
    # ------------------------------------------------------------------ #

    def observe_routing(self, expert_sets: Iterable[Sequence[int]]
                        ) -> List[Relationship]:
        """Feed router top-k sets (e.g. aux['router_top_idx'] rows).

        Each new co-activation group is registered ONCE as a composite;
        returns the relationships that are new to the registry, in
        registration order (the vectorized cache maintains its co-fire
        table incrementally from exactly this list).

        Dedup happens at the *composite* level, not just on the raw
        frozenset: the ``max_group`` cap means two distinct router sets
        can collapse to the same capped group, and re-registering its
        composite would orphan the old ``Relationship``, inflate prime
        degrees, and bump the registry version (forcing the vectorized
        cache into needless table rebuilds) — the same duplicate class
        the chain-edge path dedupes
        (``PagedKVCache._register_chain_edges``).
        """
        new: List[Relationship] = []
        for s in expert_sets:
            grp = frozenset(int(e) for e in s)
            if len(grp) < 2 or grp in self._seen_groups:
                continue
            self._seen_groups.add(grp)
            # cap group size so composites stay chunk-friendly
            grp_l = sorted(grp)[: self.max_group]
            primes = {self.assigner.prime_of(e) for e in grp_l}
            primes.discard(None)
            if len(primes) < 2:
                continue
            # ALL chunks must be fresh (stricter than the chain-edge
            # `any`, where pairs are always single-chunk): a capped
            # top-k group spans several chunks, and a single colliding
            # chunk would overwrite that composite's relationship
            # mapping — orphaning the earlier group and reordering the
            # §4.2 scan's discoveries, which is exactly the divergence
            # the differential fuzz surfaced
            fresh = all(
                self.registry.relationship_of_composite(c) is None
                for c in encode_relationship(primes))
            if fresh:
                new.append(self.registry.register(primes,
                                                  kind="coactivation"))
        return new

    def coactivated(self, e: int) -> Set[int]:
        """The factorization-recovered co-fire set of expert e (§4.2 scan
        + Algorithm 2 decode) — the deterministic ground truth every
        prefetch decision must fall inside (Theorem 1: zero false
        positives)."""
        p = self.assigner.prime_of(int(e))
        if p is None:
            return set()
        out: Set[int] = set()
        for rel in self.registry.containing(p):
            for q in rel.primes:
                if q == p:
                    continue
                other = self.assigner.data_of(q)
                if other is not None:
                    out.add(other)
        return out

    # ------------------------------------------------------------------ #
    # placement                                                           #
    # ------------------------------------------------------------------ #

    def _evict(self) -> None:
        while len(self.hbm) > self.hbm_slots:
            self.hbm.popitem(last=False)
            self.stats.evictions += 1

    def _insert(self, e: int, prefetched: bool) -> None:
        self.hbm[e] = prefetched
        self.hbm.move_to_end(e)
        self._evict()

    def activate(self, experts: Sequence[int]) -> Dict[int, str]:
        """A decode step needs these experts.  Returns per-expert tier.
        Misses model a demand host->HBM weight transfer."""
        tiers: Dict[int, str] = {}
        for e in experts:
            e = int(e)
            if e in self.hbm:
                was_pf = self.hbm[e]
                self.hbm[e] = False
                self.hbm.move_to_end(e)
                self.stats.hits += 1
                if was_pf:
                    self.stats.prefetch_hits += 1
                tiers[e] = "hbm"
            else:
                self.stats.misses += 1
                self._insert(e, False)
                tiers[e] = "host"
        for e in experts:
            self._prefetch_coactivated(int(e))
        return tiers

    def activate_batch(self, expert_sets: Sequence[Sequence[int]]
                       ) -> List[Dict[int, str]]:
        """Activate a whole decode step's router output (one top-k set
        per token batch / MoE layer), in order.  The scalar
        implementation simply loops ``activate`` (one §4.2 registry scan
        per activated expert); the vectorized cache overrides this with
        table-driven bulk discovery — the serving engine always goes
        through this entry point."""
        return [self.activate(s) for s in expert_sets]

    def _prefetch_coactivated(self, e: int) -> None:
        p = self.assigner.prime_of(e)
        if p is None:
            return
        budget = self.prefetch_budget
        if budget <= 0:
            # budget 0 disables prefetch outright (the LRU-expert
            # baseline); the scan below used to run anyway and leak one
            # prefetch per scanned relationship — regression-tested in
            # tests/test_serving_moe.py
            return
        self.stats.registry_scans += 1
        for rel in self.registry.containing(p):
            for q in rel.primes:
                if q == p:
                    continue
                other = self.assigner.data_of(q)
                if other is None or other in self.hbm:
                    continue
                self._insert(other, True)
                self.stats.prefetches += 1
                self.prefetch_log.append((e, other))
                budget -= 1
                if budget <= 0:
                    return
