"""PFCS expert-weight cache for MoE serving (kimi-k2 / deepseek-v2).

Experts are data elements; HBM holds a subset (hot experts), host memory
the rest.  Each decode step's router output is a set of active experts;
PFCS encodes *co-activation* — the top-k set of a token batch — as a
composite over expert primes.  The registry accumulates the co-activation
structure of the workload, and on activation of expert e the divisibility
scan + factorization recovers exactly which experts historically co-fire
with e; those are prefetched host->HBM ahead of the expert all-to-all.

Zero false positives (Theorem 1) means no wasted host->HBM transfers on
unrelated experts — the transfers are the scarce resource when cold
experts live off-chip.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Iterable, List, Sequence, Set, Tuple

import numpy as np

from repro.core.assignment import PrimeAssigner
from repro.core.composite import CompositeRegistry
from repro.core.factorization import Factorizer
from repro.core.primes import CacheLevel, HierarchicalPrimeAllocator

__all__ = ["ExpertCache", "ExpertCacheStats"]


@dataclass
class ExpertCacheStats:
    hits: int = 0
    misses: int = 0             # demand host->HBM transfer (stalls the step)
    prefetches: int = 0
    prefetch_hits: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.hits + self.misses)


class ExpertCache:
    def __init__(self, n_experts: int, hbm_slots: int,
                 prefetch_budget: int = 4, max_group: int = 8):
        self.n_experts = n_experts
        self.hbm_slots = hbm_slots
        self.prefetch_budget = prefetch_budget
        self.max_group = max_group
        self.factorizer = Factorizer()
        self.registry = CompositeRegistry(self.factorizer)
        self.assigner = PrimeAssigner(HierarchicalPrimeAllocator(),
                                      self.registry)
        for e in range(n_experts):
            self.assigner.assign(e, CacheLevel.L2)
        self.hbm: "OrderedDict[int, bool]" = OrderedDict()
        self.stats = ExpertCacheStats()
        self._seen_groups: Set[frozenset] = set()

    # ------------------------------------------------------------------ #

    def observe_routing(self, expert_sets: Iterable[Sequence[int]]) -> None:
        """Feed router top-k sets (e.g. aux['router_top_idx'] rows).
        Each new co-activation group is registered once as a composite."""
        for s in expert_sets:
            grp = frozenset(int(e) for e in s)
            if len(grp) < 2 or grp in self._seen_groups:
                continue
            self._seen_groups.add(grp)
            # cap group size so composites stay chunk-friendly
            grp_l = sorted(grp)[: self.max_group]
            primes = {self.assigner.prime_of(e) for e in grp_l}
            primes.discard(None)
            if len(primes) >= 2:
                self.registry.register(primes, kind="coactivation")

    def _evict(self) -> None:
        while len(self.hbm) > self.hbm_slots:
            self.hbm.popitem(last=False)
            self.stats.evictions += 1

    def _insert(self, e: int, prefetched: bool) -> None:
        self.hbm[e] = prefetched
        self.hbm.move_to_end(e)
        self._evict()

    def activate(self, experts: Sequence[int]) -> Dict[int, str]:
        """A decode step needs these experts.  Returns per-expert tier.
        Misses model a demand host->HBM weight transfer."""
        tiers: Dict[int, str] = {}
        for e in experts:
            e = int(e)
            if e in self.hbm:
                was_pf = self.hbm[e]
                self.hbm[e] = False
                self.hbm.move_to_end(e)
                self.stats.hits += 1
                if was_pf:
                    self.stats.prefetch_hits += 1
                tiers[e] = "hbm"
            else:
                self.stats.misses += 1
                self._insert(e, False)
                tiers[e] = "host"
        for e in experts:
            self._prefetch_coactivated(int(e))
        return tiers

    def _prefetch_coactivated(self, e: int) -> None:
        p = self.assigner.prime_of(e)
        if p is None:
            return
        budget = self.prefetch_budget
        for rel in self.registry.containing(p):
            for q in rel.primes:
                if q == p:
                    continue
                other = self.assigner.data_of(q)
                if other is None or other in self.hbm:
                    continue
                self._insert(other, True)
                self.stats.prefetches += 1
                budget -= 1
                if budget <= 0:
                    return
