"""Mesh-sharded paged KV cache: PFCS state partitioned across devices.

``VectorizedPagedKVCache`` (DESIGN.md §5) made the serving cache an
array state machine on ONE device.  This module partitions the cache's
*PFCS state* — the prime space, the chain-composite registry, and the
bulk-discovery work — across a ``("data", "model")`` device mesh
(DESIGN.md §6):

  * **Ownership.**  Every page's prime has exactly one owner shard
    (:class:`repro.core.engine.shard.PrimeSpacePartition` — contiguous
    prime-value blocks striped round-robin).  A chain edge whose two
    page primes share an owner lives in that shard's registry slice;
    an edge straddling prime ranges is cross-shard and rides the
    collective gcd exchange.
  * **Per-shard bulk discovery.**  Successor tables are rebuilt
    per-shard through the existing Pallas divisibility kernels under
    ``shard_map`` (:func:`repro.core.engine.shard.
    sharded_successor_table`); cross-shard chains are resolved by a
    collective batched-gcd exchange (``lax.all_gather`` + the gcd
    kernel).  The assembled rows are bit-identical to the single-device
    table, so every placement decision — and therefore every
    ``PARITY_COUNTERS`` entry — stays bit-exact against the scalar
    oracle at ANY shard count (``tests/test_serving_sharded.py``).
  * **Owner-routed accounting.**  ``touch_batch`` routes each touch to
    the owner shard of the touched page: per-shard ``PageStats`` carry
    the same counters as the oracle's, and their field-wise sum equals
    the aggregate ``stats`` exactly — so existing parity checks apply
    unchanged to the sharded cache while per-shard load stays
    observable (``shard_load``).

Placement (HBM slot arrays, LRU stamps) deliberately remains ONE global
state machine: Theorem 1's zero-false-positive guarantee and the
oracle-parity contract both pin the *global* interleaving of demand and
prefetch traffic, and HBM is one physical resource per serving host.
What scales with the mesh is the discovery work — the §4.2 scans that
dominate registry-refresh cost — which drops to the per-shard slice
(see EXPERIMENTS.md, shard-scaling track).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine.shard import (PrimeSpacePartition, ShardScanReport,
                                     shard_mesh, sharded_successor_table)
from repro.obs.trace import EV_GCD_EXCHANGE

from .kv_cache import PARITY_COUNTERS, PageStats
from .kv_cache_vec import VectorizedPagedKVCache

__all__ = ["ShardedPagedKVCache"]


class ShardedPagedKVCache(VectorizedPagedKVCache):
    """Drop-in ``VectorizedPagedKVCache`` with mesh-partitioned PFCS
    state.  Tables are always maintained by per-shard bulk rebuild (the
    registry slices are the shards' source of truth; incremental
    append-maintenance is a single-device optimization), triggered at
    most once per ``touch_batch`` when the registry changed.
    """

    def __init__(self, hbm_pages: int = 1024, page_size: int = 16,
                 prefetch_budget: int = 4, n_shards: int = 2,
                 mesh="auto", stripes_per_shard: int = 8,
                 max_bits: int = 62):
        # discover="host" disables the incremental fast path, so every
        # registry change routes through the (sharded) bulk rebuild
        super().__init__(hbm_pages=hbm_pages, page_size=page_size,
                         prefetch_budget=prefetch_budget, discover="host",
                         max_bits=max_bits)
        self.partition = PrimeSpacePartition(n_shards, stripes_per_shard)
        self.n_shards = self.partition.n_shards
        if mesh == "auto":
            mesh = shard_mesh(self.n_shards)
        if mesh is not None and mesh.size != self.n_shards:
            raise ValueError(f"mesh has {mesh.size} devices, cache has "
                             f"{self.n_shards} shards")
        self.mesh = mesh
        self.shard_stats: List[PageStats] = [PageStats()
                                             for _ in range(self.n_shards)]
        self.last_scan = ShardScanReport()

    # ------------------------------------------------------------------ #
    # ownership                                                           #
    # ------------------------------------------------------------------ #

    def owner_of_page(self, pid: int) -> int:
        """Owner shard of a page (pages without a prime fall to shard 0)."""
        p = self.assigner.prime_of(pid)
        return 0 if p is None else self.partition.owner(p)

    def shard_composites(self) -> Tuple[List[np.ndarray], np.ndarray]:
        """Current registry partition: per-shard-local composite arrays
        plus the cross-shard array, in global registration order (object
        dtype when the registry is wide)."""
        arr = self.registry.composites_view()
        local_pos, cross_pos = self.partition.classify(self.registry)
        return ([arr[np.asarray(pos, dtype=np.int64)]
                 if pos else np.empty(0, arr.dtype) for pos in local_pos],
                arr[np.asarray(cross_pos, dtype=np.int64)]
                if cross_pos else np.empty(0, arr.dtype))

    # ------------------------------------------------------------------ #
    # sharded bulk discovery                                              #
    # ------------------------------------------------------------------ #

    def refresh_tables(self, discover: Optional[str] = None) -> None:
        """Rebuild every successor row by per-shard Pallas scans under
        ``shard_map`` + the cross-shard gcd exchange.  An explicit
        ``discover="host"|"kernel"`` falls back to the single-device
        bulk path (cross-check hook for the parity tests)."""
        if discover is not None:
            super().refresh_tables(discover)
            return
        self.last_scan = ShardScanReport()
        rows = sharded_successor_table(self.registry, self.assigner,
                                       range(self._next_page),
                                       self.partition, mesh=self.mesh,
                                       report=self.last_scan)
        if self.obs is not None:
            for sh, n_local in enumerate(self.last_scan.local_composites):
                self.obs.emit(EV_GCD_EXCHANGE, shard=sh, arg=n_local)
        self._ensure_pages(self._next_page)
        self._install_rows(rows)

    # ------------------------------------------------------------------ #
    # owner-routed touches and per-shard accounting                       #
    # ------------------------------------------------------------------ #

    def _page_for_tokens(self, token_block) -> Tuple[int, bool]:
        before = self.stats.shared_prefix_pages
        pid, hit = super()._page_for_tokens(token_block)
        if self.stats.shared_prefix_pages > before:
            ss = self.shard_stats[self.owner_of_page(pid)]
            ss.shared_prefix_pages += 1
        return pid, hit

    def touch_batch(self, items: Sequence[Tuple[int, int]]) -> List[str]:
        """Demand-access a decode batch, routing each touch to the owner
        shard of its page.  Placement applies in submission order (the
        parity contract pins the global interleaving); what the routing
        decides is accounting — every counter delta a touch produces,
        including evictions and prefetches it triggers, is charged to
        the serving shard."""
        self._sync_tables()
        tiers: List[str] = []
        for r, i in items:
            pid = self.chains[r][i]
            ss = self.shard_stats[self.owner_of_page(pid)]
            before = self.stats.parity_tuple()
            tiers.append(self._touch_one(pid))
            for f, b, a in zip(PARITY_COUNTERS, before,
                               self.stats.parity_tuple()):
                if a != b:
                    setattr(ss, f, getattr(ss, f) + (a - b))
        return tiers

    # ------------------------------------------------------------------ #
    # aggregation / introspection                                         #
    # ------------------------------------------------------------------ #

    def aggregate_shard_stats(self) -> PageStats:
        """Field-wise sum of the per-shard stats — equals the global
        ``stats`` on every ``PARITY_COUNTERS`` entry (tested)."""
        agg = PageStats()
        for ss in self.shard_stats:
            for f in PARITY_COUNTERS:
                setattr(agg, f, getattr(agg, f) + getattr(ss, f))
        return agg

    def shard_load(self) -> List[Dict[str, int]]:
        """Per-shard counter snapshot for the load benchmark report."""
        return [{f: getattr(ss, f) for f in PARITY_COUNTERS}
                for ss in self.shard_stats]
