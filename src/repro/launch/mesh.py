"""Production mesh construction.

Defined as a FUNCTION (never module-level) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """All locally-visible devices on a (data, model) mesh (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


class HW:
    """TPU v5e hardware constants for the roofline model (per chip)."""

    PEAK_BF16_FLOPS = 197e12     # FLOP/s
    HBM_BW = 819e9               # bytes/s
    ICI_BW = 50e9                # bytes/s per link
    HBM_BYTES = 16 * 2**30       # 16 GiB
