"""Serving launcher: batched-request engine driver.

Default front-end is the continuous-batching :class:`~repro.serving.
slots.SlotMachine` (DESIGN.md §10): open-loop Poisson arrivals, chunked
prefill, async admission, preemption/resume — the realistic-traffic
engine.  ``--front-end engine`` selects the closed-queue
``ServingEngine`` loop instead; a real model (``--arch`` without
``--null-model``) always runs through ``ServingEngine``, because the
slot machine is a page-management load generator (stub decode only).

Both front-ends share the PFCS paged KV cache backends (``--kv vec``
array-state tables by default, ``scalar`` for the oracle, ``sharded`` /
``elastic`` for the mesh-partitioned variants) through the one factory
in ``serving/engine.py``; ``--max-bits > 63`` runs the chain registry
in multi-limb wide mode (DESIGN.md §11).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --requests 16 --max-new 24
    PYTHONPATH=src python -m repro.launch.serve --null-model \
        --max-batch 128 --requests 256
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--shared-prefix", type=int, default=24,
                    help="tokens of shared prompt prefix (exercises PFCS "
                         "prefix sharing)")
    ap.add_argument("--kv", choices=("vec", "scalar", "sharded", "elastic"),
                    default="vec",
                    help="paged-KV backend (serving/engine.py factory)")
    ap.add_argument("--max-bits", type=int, default=62,
                    help="registry chunk width; > 63 selects multi-limb "
                         "wide mode (DESIGN.md §11)")
    ap.add_argument("--front-end", choices=("slots", "engine"),
                    default="slots",
                    help="continuous-batching SlotMachine (default) or "
                         "the closed-queue ServingEngine loop")
    ap.add_argument("--arrival-rate", type=float, default=2.0,
                    help="slots front-end: open-loop Poisson arrivals "
                         "per tick")
    ap.add_argument("--prefill-tokens", type=int, default=64,
                    help="slots front-end: shared chunked-prefill budget "
                         "per tick")
    ap.add_argument("--null-model", action="store_true",
                    help="no device decode: pure page-management load "
                         "generation (scales to hundreds of slots)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record the observability layer (event ring + "
                         "telemetry + kernel ledger, DESIGN.md §13) and "
                         "write the export JSON here; feed it to "
                         "tools/trace_view.py for a Chrome trace")
    args = ap.parse_args(argv)

    obs = None
    if args.trace:
        from repro.obs import Observability, profile

        obs = Observability()
        profile.reset()
        profile.enable(True)

    if args.null_model:
        model, params, vocab = None, None, 32_000
    else:
        import jax

        from repro.configs import get_smoke
        from repro.models import build_model

        cfg = get_smoke(args.arch)
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        vocab = cfg.vocab_size

    # the slot machine decodes stub tokens only — a real model needs the
    # ServingEngine's device decode step
    front_end = args.front_end if model is None else "engine"

    rng = np.random.default_rng(0)
    shared = list(rng.integers(0, vocab, size=args.shared_prefix))
    prompts = [shared + list(rng.integers(0, vocab,
                                          size=int(rng.integers(4, 12))))
               for _ in range(args.requests)]

    if front_end == "slots":
        from repro.serving.slots import SlotMachine, poisson_arrival_ticks

        machine = SlotMachine(max_batch=args.max_batch, kv=args.kv,
                              prefill_tokens=args.prefill_tokens,
                              max_bits=args.max_bits, obs=obs)
        arrivals = poisson_arrival_ticks(len(prompts), args.arrival_rate)
        for prompt, tick in zip(prompts, arrivals):
            machine.submit(prompt, max_new_tokens=args.max_new,
                           arrival=int(tick))
        t0 = time.time()
        machine.run_until_idle()
        wall = time.time() - t0
        st = machine.pages.stats
        rep = machine.latency_report()
        out = {
            "front_end": "slots",
            "kv": args.kv,
            "completed": rep["completed"],
            "decode_tokens": rep["tokens"],
            "ticks": rep["ticks"],
            "tok_per_s": round(rep["tokens"] / max(wall, 1e-9), 1),
            "goodput_tok_per_tick": round(rep["goodput_tok_per_tick"], 3),
            "ttft_p50_ticks": rep["ttft_ticks"][50],
            "peak_in_flight": rep["peak_in_flight"],
            "hbm_hit_rate": round(st.hbm_hit_rate, 4),
            "prefetches": st.prefetches,
            "prefetch_hits": st.prefetch_hits,
            "shared_prefix_pages": st.shared_prefix_pages,
            "registry_scans": st.registry_scans,
        }
        pages = machine.pages
    else:
        from repro.serving.engine import ServingEngine

        engine = ServingEngine(model, params, max_batch=args.max_batch,
                               max_seq=args.max_seq, kv=args.kv,
                               max_bits=args.max_bits, obs=obs)
        for prompt in prompts:
            engine.submit(prompt, max_new_tokens=args.max_new)
        t0 = time.time()
        done = engine.run_until_idle()
        wall = time.time() - t0
        toks = sum(len(r.generated) for r in done)
        st = engine.pages.stats
        ttfts = [r.first_token_t - r.submit_t
                 for r in done if r.first_token_t]
        out = {
            "front_end": "engine",
            "kv": args.kv,
            "completed": len(done),
            "decode_tokens": toks,
            "tok_per_s": round(toks / wall, 1),
            "mean_ttft_s": round(float(np.mean(ttfts)), 3) if ttfts else None,
            "peak_concurrency": engine.peak_live,
            "hbm_hit_rate": round(st.hbm_hit_rate, 4),
            "prefetches": st.prefetches,
            "prefetch_hits": st.prefetch_hits,
            "shared_prefix_pages": st.shared_prefix_pages,
            "registry_scans": st.registry_scans,
        }
        pages = engine.pages
    if obs is not None:
        from repro.obs import profile

        profile.enable(False)
        obs.export_json(args.trace)
        out["trace_events"] = obs.trace.total
        print(f"observability export ({obs.trace.total} events) "
              f"-> {args.trace}", flush=True)
    print(json.dumps(out, indent=1))
    # deterministic shared-prefix discovery demo
    if len(pages.chains) >= 2:
        ids = list(pages.chains)[:2]
        print("shared pages of first two live chains:",
              pages.shared_prefix(*ids))
    return out


if __name__ == "__main__":
    main()
