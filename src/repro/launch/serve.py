"""Serving launcher: batched-request engine driver.

Runs the continuous-batching engine against a smoke-scale model with
the PFCS paged KV cache (``--kv vec`` array-state tables by default,
``--kv scalar`` for the oracle), printing throughput/latency and
page-tier stats.  ``--null-model`` drops the device decode entirely and
drives the engine as a pure page-management load generator — the mode
that scales to hundreds of concurrent slots (see
``benchmarks.cases.case_serving`` for the measured load benchmark).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --requests 16 --max-new 24
    PYTHONPATH=src python -m repro.launch.serve --null-model \
        --max-batch 128 --requests 256
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--shared-prefix", type=int, default=24,
                    help="tokens of shared prompt prefix (exercises PFCS "
                         "prefix sharing)")
    ap.add_argument("--kv", choices=("vec", "scalar"), default="vec",
                    help="paged-KV backend: array-state tables (vec) or "
                         "the scalar oracle")
    ap.add_argument("--null-model", action="store_true",
                    help="no device decode: pure page-management load "
                         "generation (scales to hundreds of slots)")
    args = ap.parse_args(argv)

    from repro.serving.engine import ServingEngine

    if args.null_model:
        model, params, vocab = None, None, 32_000
    else:
        import jax

        from repro.configs import get_smoke
        from repro.models import build_model

        cfg = get_smoke(args.arch)
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        vocab = cfg.vocab_size
    engine = ServingEngine(model, params, max_batch=args.max_batch,
                           max_seq=args.max_seq, kv=args.kv)

    rng = np.random.default_rng(0)
    shared = list(rng.integers(0, vocab, size=args.shared_prefix))
    for _ in range(args.requests):
        tail = list(rng.integers(0, vocab, size=int(rng.integers(4, 12))))
        engine.submit(shared + tail, max_new_tokens=args.max_new)

    t0 = time.time()
    done = engine.run_until_idle()
    wall = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    st = engine.pages.stats
    ttfts = [r.first_token_t - r.submit_t for r in done if r.first_token_t]
    out = {
        "kv": args.kv,
        "completed": len(done),
        "decode_tokens": toks,
        "tok_per_s": round(toks / wall, 1),
        "mean_ttft_s": round(float(np.mean(ttfts)), 3) if ttfts else None,
        "peak_concurrency": engine.peak_live,
        "hbm_hit_rate": round(st.hbm_hit_rate, 4),
        "prefetches": st.prefetches,
        "prefetch_hits": st.prefetch_hits,
        "shared_prefix_pages": st.shared_prefix_pages,
        "registry_scans": st.registry_scans,
    }
    print(json.dumps(out, indent=1))
    # deterministic shared-prefix discovery demo
    if len(engine.pages.chains) >= 2:
        ids = list(engine.pages.chains)[:2]
        print("shared pages of first two live chains:",
              engine.pages.shared_prefix(*ids))
    return out


if __name__ == "__main__":
    main()
