"""Training launcher: end-to-end driver wiring every substrate together.

Runs a real training loop on the local device(s): model from ``--arch``
(smoke or full config), sharded data loader, train_step (jit, local
mesh), checkpoint/restore with atomic commit, elastic/straggler
monitoring hooks, and optional PFCS-cached data tier.

This is the driver ``examples/train_lm.py`` calls with a ~100M config;
on a real fleet the same file runs under multi-host jax with the
production mesh (the dry-run proves those shardings compile).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
        --steps 50 --batch 8 --seq 256
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M example model)")
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--pfcs-data", action="store_true",
                    help="route the data tier through the PFCS shard cache")
    args = ap.parse_args(argv)

    from repro.configs import get_config, get_smoke
    from repro.models import build_model
    from repro.data.pipeline import ByteTokenizer, ShardedLoader, SyntheticCorpus
    from repro.training.checkpoint import CheckpointManager
    from repro.training.elastic import StragglerMonitor
    from repro.training.train_loop import (TrainState, init_train_state,
                                           make_train_step)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    overrides = {}
    if args.d_model:
        overrides.update(d_model=args.d_model)
    if args.n_layers:
        overrides.update(n_layers=args.n_layers)
    if overrides:
        cfg = cfg.replace(**overrides)
    # byte-level vocab for the synthetic corpus
    cfg = cfg.replace(vocab_size=ByteTokenizer.vocab_size)
    model = build_model(cfg)

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size)
    pfcs = None
    if args.pfcs_data:
        from repro.core.pfcs_cache import PFCSCache
        pfcs = PFCSCache(capacities=(("L1", 8), ("L2", 32), ("L3", 64)))
    loader = ShardedLoader(corpus, args.batch, args.seq,
                           shard_index=jax.process_index(),
                           shard_count=jax.process_count(),
                           pfcs_cache=pfcs)

    ckpt = CheckpointManager(Path(args.ckpt_dir) / cfg.name)
    rng = jax.random.PRNGKey(0)
    state = init_train_state(model, rng)
    start_step = 0
    if args.resume:
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(state, step=latest)
            start_step = latest
            print(f"resumed from step {latest}")

    step_fn = jax.jit(make_train_step(model, lr=args.lr,
                                      total_steps=args.steps,
                                      warmup=max(1, args.steps // 10),
                                      accum_steps=args.accum),
                      donate_argnums=(0,))
    straggler = StragglerMonitor()

    losses = []
    t_start = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in loader.batch_at(step).items()}
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        dt = time.time() - t0
        straggler.record(jax.process_index(), dt)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  {dt*1e3:.0f} ms")
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state, blocking=False)
    ckpt.wait()
    ckpt.save(args.steps, state)
    out = {"first_loss": losses[0], "last_loss": losses[-1],
           "steps": args.steps, "wall_s": round(time.time() - t_start, 1)}
    if pfcs is not None:
        out["pfcs_shard_prefetches"] = pfcs.prefetches_issued
    print(json.dumps(out))
    assert losses[-1] < losses[0], "training did not reduce loss"
    return out


if __name__ == "__main__":
    main()
