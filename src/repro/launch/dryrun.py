import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the model and abstract (ShapeDtypeStruct) inputs — zero
     allocation;
  2. jits the right step (train_step / prefill / decode_step) with the
     production shardings;
  3. ``.lower().compile()`` against the 16x16 single-pod mesh and the
     2x16x16 multi-pod mesh;
  4. records ``memory_analysis()`` (bytes/device — proves it fits),
     ``cost_analysis()`` (FLOPs/bytes for the roofline), and the
     collective-op byte census parsed from the compiled HLO text;
  5. writes one JSON artifact per cell under ``artifacts/dryrun/`` —
     the run is resumable (existing artifacts are skipped unless
     ``--force``), which matters at ~80 single-core XLA compiles.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
        --shape train_4k --mesh single
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)",
    re.M)

_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|s64|u32|u8|s8|pred|f64)\[([\d,]*)\]")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "s64": 8,
                "u32": 4, "u8": 1, "s8": 1, "pred": 1, "f64": 8}


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a result-shape string like 'f32[16,128]' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str):
    """Sum result bytes per collective kind from post-SPMD HLO."""
    out = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        d = out.setdefault(kind, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += b
    return out


def probe_layer_counts(cfg):
    """Two reduced layer counts (L1 < L2) whose HLO-cost delta isolates one
    'layer period' — scan bodies are cost-counted once, so
    total(L) = cost(L1) + (cost(L2) - cost(L1)) / (L2 - L1) * (L - L1)
    reconstructs the true per-step HLO cost for the layer-linear stacks.
    Periods: dense=1 layer; moe=1 moe layer (after the dense prefix);
    zamba=one shared_attn_every group; xlstm=one (slstm_every) run;
    encdec=1 enc + 1 dec layer."""
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        return k, 2 * k
    if cfg.family == "ssm":
        k = cfg.xlstm.slstm_every
        return k, 2 * k
    if cfg.moe is not None:
        nd = cfg.moe.first_dense_layers
        return nd + 1, nd + 2
    return 1, 2


def override_layers(cfg, n: int):
    if cfg.family == "audio":
        return cfg.replace(encdec=type(cfg.encdec)(n_encoder_layers=n,
                                                   n_decoder_layers=n))
    return cfg.replace(n_layers=n)


def opt_overrides(cfg, shape_kind: str):
    """§Perf optimized-variant settings (A/B'd against the baseline):
      * gather-combine MoE + d-sharded dispatch (keeps FSDP weights in
        place) and 8x microbatch accumulation for the giant-MoE trains;
      * head padding to the TP degree for archs whose head counts do not
        divide the 16-way model axis (phi3: 40H/10KV -> 48/16) — dead
        heads cost +20% FLOPs but end 16x attention replication;
      * int8 (KIVI-style) latent KV cache for MLA decode.
    The split-K decode-cache sharding lives in cache_shardings
    (seq_over_model=True)."""
    kw = {}
    if cfg.moe is not None:
        kw.update(moe_combine="gather", shard_moe_dispatch=cfg.use_fsdp)
        if shape_kind == "train" and cfg.use_fsdp:
            kw.update(accum_steps=8)
    if cfg.mla is None and cfg.n_heads % 16:
        h_pad = -(-cfg.n_heads // 16) * 16      # next multiple of TP degree
        kv = cfg.n_kv_heads
        kv_pad = (kv if kv <= 1 else
                  next(d for d in range(kv, h_pad + 1) if h_pad % d == 0))
        kw.update(n_heads=h_pad, n_kv_heads=kv_pad)
    if cfg.mla is not None and shape_kind == "decode":
        kw.update(kv_cache_dtype="int8")
    if cfg.family in ("ssm", "audio") and shape_kind in ("train", "prefill"):
        kw.update(dp_only=True)   # <3B models: pure DP + ZeRO-1 beats forced TP
    return cfg.replace(**kw) if kw else cfg


def build_cell(arch_id: str, shape_name: str, multi_pod: bool,
               layer_override: int | None = None,
               variant: str = "base"):
    """Returns (jitted_fn, example_args) lowered-ready for one cell."""
    import jax
    from repro.configs import SHAPES, get_config
    from repro.models import build_model
    from repro.sharding import partition as pt
    from repro.training.train_loop import abstract_train_state, make_train_step
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch_id)
    shape_tmp = next(s for s in SHAPES if s.name == shape_name)
    if variant == "opt":
        cfg = opt_overrides(cfg, shape_tmp.kind)
    if layer_override is not None:
        # probe mode: reduced layers AND fully-unrolled scans — XLA's cost
        # analysis counts a scan body once regardless of trip count, so
        # only unrolled probes expose true per-layer/per-chunk HLO cost.
        # Chunked attention / mLSTM FLOPs are chunk-size invariant (every
        # chunk attends over the full key axis), so probes enlarge chunks
        # to cap unrolled bodies at <= 8 per layer; only the SSD
        # intra-chunk term shifts (~5% of a Mamba layer — noted in
        # EXPERIMENTS.md).
        import dataclasses as _dc
        cfg = override_layers(cfg, layer_override).replace(unroll=True)
        if shape_tmp.kind in ("train", "prefill"):
            big_chunk = max(cfg.attn_chunk, shape_tmp.seq_len // 8)
            cfg = cfg.replace(attn_chunk=big_chunk)
            if cfg.ssm is not None:
                cfg = cfg.replace(ssm=_dc.replace(
                    cfg.ssm,
                    chunk_size=max(cfg.ssm.chunk_size,
                                   shape_tmp.seq_len // 8)))
    shape = next(s for s in SHAPES if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    batch_abs = model.input_specs(shape)
    batch_sh = pt.batch_shardings(batch_abs, mesh,
                                  all_axes=getattr(cfg, "dp_only", False))

    if shape.kind == "train":
        state_abs = abstract_train_state(model)
        p_sh = pt.params_shardings(state_abs.params, mesh, cfg)
        o_sh = pt.opt_state_shardings(state_abs.opt_state, state_abs.params,
                                      mesh, cfg)
        state_sh = type(state_abs)(p_sh, o_sh, pt.replicated(mesh))
        step_fn = make_train_step(model, accum_steps=cfg.accum_steps)
        jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
        return mesh, jitted, (state_abs, batch_abs)

    params_abs = model.param_specs()
    p_sh = pt.params_shardings(params_abs, mesh, cfg)
    if shape.kind == "prefill":
        jitted = jax.jit(model.prefill, in_shardings=(p_sh, batch_sh))
        return mesh, jitted, (params_abs, batch_abs)

    # decode
    cache_abs = model.cache_specs(shape)
    seq_shard = shape.global_batch == 1
    c_sh = pt.cache_shardings(cache_abs, mesh, cfg, seq_shard=seq_shard,
                              seq_over_model=(variant == "opt"))
    jitted = jax.jit(model.decode_step,
                     in_shardings=(p_sh, batch_sh, c_sh),
                     donate_argnums=(2,))
    return mesh, jitted, (params_abs, batch_abs, cache_abs)


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: Path = ARTIFACT_DIR, force: bool = False,
             layer_override: int | None = None, variant: str = "base"):
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    suffix = f"__probe{layer_override}" if layer_override is not None else ""
    if variant != "base":
        suffix += f"__{variant}"
    out = out_dir / f"{arch_id}__{shape_name}__{mesh_name}{suffix}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    if out.exists() and not force:
        rec = json.loads(out.read_text())
        if rec.get("status") == "ok":
            print(f"[skip] {out.name} (cached)")
            return rec

    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "layer_override": layer_override, "variant": variant,
           "status": "error"}
    t0 = time.time()
    try:
        mesh, jitted, args = build_cell(arch_id, shape_name, multi_pod,
                                        layer_override, variant)
        with mesh:
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        n_dev = 1
        for v in mesh.shape.values():
            n_dev *= v
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            n_devices=n_dev,
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            memory={
                k: int(getattr(mem, k))
                for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                          "output_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)
            },
            collectives=coll,
            hlo_bytes=len(hlo),
        )
        print(f"[ok]   {out.name}: compile={t_compile:.0f}s "
              f"flops/dev={rec['flops']:.3g} "
              f"args/dev={rec['memory'].get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
              f"temp/dev={rec['memory'].get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {out.name}: {rec['error']}")
    rec["wall_s"] = round(time.time() - t0, 1)
    out.write_text(json.dumps(rec, indent=1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--probes", action="store_true",
                    help="run the two reduced-layer probe compiles per cell "
                         "(single-pod) used to reconstruct scan-body costs")
    ap.add_argument("--variant", default="base", choices=["base", "opt"],
                    help="'opt' applies the §Perf optimized settings")
    ap.add_argument("--out", default=str(ARTIFACT_DIR))
    args = ap.parse_args(argv)

    from repro.configs import cells, get_config

    out_dir = Path(args.out)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.all or args.probes:
        todo = [(a, s.name) for a, s in cells()]
        if args.arch:
            todo = [(a, s) for a, s in todo if a == args.arch]
    else:
        assert args.arch and args.shape, "--arch and --shape or --all"
        todo = [(args.arch, args.shape)]

    n_ok = n_fail = 0
    for arch_id, shape_name in todo:
        if args.probes:
            l1, l2 = probe_layer_counts(get_config(arch_id))
            for lo in (l1, l2):
                rec = run_cell(arch_id, shape_name, False, out_dir,
                               args.force, layer_override=lo,
                               variant=args.variant)
                n_ok += rec.get("status") == "ok"
                n_fail += rec.get("status") != "ok"
            continue
        for mp in meshes:
            rec = run_cell(arch_id, shape_name, mp, out_dir, args.force,
                           variant=args.variant)
            if rec.get("status") == "ok":
                n_ok += 1
            else:
                n_fail += 1
    print(f"\ndone: {n_ok} ok, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
