#!/usr/bin/env python
"""Docs-consistency check: every documentation cross-reference resolves.

Scans Python sources (docstrings + comments included — the whole file
text) and the markdown tree for references to documentation files
(``DESIGN.md``, ``README.md``, ``docs/api.md``, ``ROADMAP.md``, ...) and
section anchors (``DESIGN.md §3``), then verifies:

  1. every referenced file exists in the repository;
  2. every ``DESIGN.md §N`` reference has a matching ``## §N`` heading.

Run directly (CI: .github/workflows/ci.yml) or through
``tests/test_docs.py``::

    python tools/check_doc_refs.py          # exit 1 + report on failure
"""

from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import List

# all-caps markdown names anywhere, or an explicit docs/*.md path
FILE_REF = re.compile(r"\b(docs/[a-z_]+\.md|[A-Z][A-Z_]*\.md)\b")
SECTION_REF = re.compile(r"\bDESIGN\.md\s+§(\d+)")
SCAN_DIRS = ("src", "benchmarks", "tests", "examples", "tools", "docs")


def _sources(root: Path):
    for d in SCAN_DIRS:
        yield from (root / d).rglob("*.py")
        yield from (root / d).rglob("*.md")
    yield from root.glob("*.md")


def check(root: Path) -> List[str]:
    """Returns a list of human-readable problems (empty == consistent)."""
    problems: List[str] = []
    design = root / "DESIGN.md"
    design_text = design.read_text() if design.exists() else ""
    sections = set(re.findall(r"^#+\s*§(\d+)", design_text, re.MULTILINE))
    for path in sorted(set(_sources(root))):
        if not path.exists():
            continue
        text = path.read_text(errors="replace")
        rel = path.relative_to(root)
        for ref in sorted(set(FILE_REF.findall(text))):
            if ref == "CHANGES.md" and not (root / ref).exists():
                continue   # changelog appears with the first PR
            if not (root / ref).exists():
                problems.append(f"{rel}: references missing file {ref}")
        for sec in sorted(set(SECTION_REF.findall(text))):
            if sec not in sections:
                problems.append(
                    f"{rel}: references DESIGN.md §{sec}, no such heading")
    return problems


def main() -> int:
    root = Path(__file__).resolve().parents[1]
    problems = check(root)
    if problems:
        print(f"docs-consistency: {len(problems)} unresolved reference(s)")
        for p in problems:
            print(f"  {p}")
        return 1
    print("docs-consistency: all documentation cross-references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
