#!/usr/bin/env python
"""Docs-consistency check: every documentation cross-reference resolves.

Scans Python sources (docstrings + comments included — the whole file
text) and the markdown tree for references to documentation files
(``DESIGN.md``, ``README.md``, ``docs/api.md``, ``ROADMAP.md``, ...) and
section anchors (``DESIGN.md §3``), then verifies:

  1. every referenced file exists in the repository;
  2. every ``DESIGN.md §N`` reference has a matching ``## §N`` heading;
  3. every module promising "documented with runnable examples in
     docs/api.md" delivers: each ``:func:``/``:class:``/``:meth:``
     entry point its docstring names must appear in a ``docs/api.md``
     heading whose section carries a ```` ```python ```` example block
     (this is what keeps the engine's and the serving layer's entry
     point lists honest).

Run directly (CI: .github/workflows/ci.yml) or through
``tests/test_docs.py``::

    python tools/check_doc_refs.py          # exit 1 + report on failure
"""

from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import List

# all-caps markdown names anywhere, or an explicit docs/*.md path
FILE_REF = re.compile(r"\b(docs/[a-z_]+\.md|[A-Z][A-Z_]*\.md)\b")
SECTION_REF = re.compile(r"\bDESIGN\.md\s+§(\d+)")
API_PROMISE = re.compile(r"documented with runnable examples in "
                         r"docs/api\.md")
ROLE_REF = re.compile(r":(?:func|class|meth):`~?([\w.]+)`")
HEADING = re.compile(r"^#{1,6}\s")
SCAN_DIRS = ("src", "benchmarks", "tests", "examples", "tools", "docs")


def _api_sections(api_text: str):
    """Split docs/api.md into (heading-line, section-body) pairs; a
    section runs to the next heading of any level."""
    sections = []
    heading, body = None, []
    for line in api_text.splitlines():
        if HEADING.match(line):
            if heading is not None:
                sections.append((heading, "\n".join(body)))
            heading, body = line, []
        else:
            body.append(line)
    if heading is not None:
        sections.append((heading, "\n".join(body)))
    return sections


def _check_api_promises(path, text, sections, problems):
    """Rule 3: promised entry points have an example-backed heading."""
    for name in sorted(set(ROLE_REF.findall(text))):
        short = name.rsplit(".", 1)[-1]
        word = re.compile(rf"(?<!\w){re.escape(short)}(?!\w)")
        hits = [(h, b) for h, b in sections if word.search(h)]
        if not hits:
            problems.append(
                f"{path}: promises docs/api.md coverage of {short!r} "
                f"(:…:`{name}`), but docs/api.md has no heading for it")
        elif not any("```python" in b for _, b in hits):
            problems.append(
                f"{path}: docs/api.md section for {short!r} has no "
                f"runnable ```python example")


def _sources(root: Path):
    for d in SCAN_DIRS:
        yield from (root / d).rglob("*.py")
        yield from (root / d).rglob("*.md")
    yield from root.glob("*.md")


def check(root: Path) -> List[str]:
    """Returns a list of human-readable problems (empty == consistent)."""
    problems: List[str] = []
    design = root / "DESIGN.md"
    design_text = design.read_text() if design.exists() else ""
    sections = set(re.findall(r"^#+\s*§(\d+)", design_text, re.MULTILINE))
    api = root / "docs" / "api.md"
    api_sections = _api_sections(api.read_text()) if api.exists() else []
    for path in sorted(set(_sources(root))):
        if not path.exists():
            continue
        text = path.read_text(errors="replace")
        rel = path.relative_to(root)
        for ref in sorted(set(FILE_REF.findall(text))):
            if ref in ("CHANGES.md", "ISSUE.md") and not (root / ref).exists():
                continue   # per-PR working files, untracked by design
            if not (root / ref).exists():
                problems.append(f"{rel}: references missing file {ref}")
        for sec in sorted(set(SECTION_REF.findall(text))):
            if sec not in sections:
                problems.append(
                    f"{rel}: references DESIGN.md §{sec}, no such heading")
        if path.suffix == ".py" and API_PROMISE.search(text):
            _check_api_promises(rel, text, api_sections, problems)
    return problems


def main() -> int:
    root = Path(__file__).resolve().parents[1]
    problems = check(root)
    if problems:
        print(f"docs-consistency: {len(problems)} unresolved reference(s)")
        for p in problems:
            print(f"  {p}")
        return 1
    print("docs-consistency: all documentation cross-references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
