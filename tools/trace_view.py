#!/usr/bin/env python
"""Convert an ``Observability.export_json`` payload into Chrome
``trace_event`` JSON (load it in chrome://tracing or https://ui.perfetto.dev).

Mapping (one synthetic microsecond timeline; 1 engine tick = 1 ms so
tick-granular serving traces stay readable next to wall-clock kernel
spans):

  * trace ring rows   -> instant events (``ph: "i"``) on pid 0
                         ("serving"); slot-scoped events land on
                         ``tid = slot``, cache/shard events on a shared
                         "cache" track
  * telemetry gauges  -> counter events (``ph: "C"``) keyed by gauge
                         name at their recorded tick
  * kernel ledger     -> complete events (``ph: "X"``) on pid 1
                         ("kernels"), laid end to end with their
                         accumulated wall clocks

Usage:
    PYTHONPATH=src python -m repro.launch.serve --trace obs.json
    python tools/trace_view.py obs.json chrome_trace.json
"""

from __future__ import annotations

import json
import sys

TICK_US = 1000          # one serving tick rendered as 1 ms
CACHE_TID = 99          # track for events with no slot attribution

_META = [
    {"ph": "M", "pid": 0, "name": "process_name",
     "args": {"name": "serving"}},
    {"ph": "M", "pid": 0, "tid": CACHE_TID, "name": "thread_name",
     "args": {"name": "cache"}},
    {"ph": "M", "pid": 1, "name": "process_name",
     "args": {"name": "kernels"}},
]


def convert(payload: dict) -> dict:
    """Observability export dict -> Chrome trace_event dict."""
    schema = {int(k): v for k, v in payload.get("schema", {}).items()}
    out = list(_META)

    # -- trace ring rows -> instant events -------------------------------- #
    trace = payload.get("trace") or {}
    fields = trace.get("fields") or ["kind", "tick", "slot", "req", "page",
                                     "tenant", "shard", "arg"]
    now = 0
    for seq, row in enumerate(trace.get("events", [])):
        ev = dict(zip(fields, row))
        tick = ev.get("tick", -1)
        if tick >= 0:               # untick'd events ride the last tick seen
            now = tick
        slot = ev.get("slot", -1)
        args = {k: v for k, v in ev.items()
                if k not in ("kind", "tick", "slot") and v != -1}
        args["seq"] = seq
        out.append({
            "name": schema.get(ev.get("kind"), f"kind{ev.get('kind')}"),
            "ph": "i", "s": "t",
            "ts": now * TICK_US,
            "pid": 0,
            "tid": slot if slot >= 0 else CACHE_TID,
            "args": args,
        })

    # -- telemetry gauges -> counter events ------------------------------- #
    telem = payload.get("telemetry") or {}
    for name, ring in sorted((telem.get("gauges") or {}).items()):
        for tick, value in ring:
            out.append({
                "name": name, "ph": "C",
                "ts": max(int(tick), 0) * TICK_US,
                "pid": 0,
                "args": {name: value},
            })

    # -- kernel launch ledger -> complete spans --------------------------- #
    cursor = 0
    for name, rec in sorted((payload.get("kernel_launches") or {}).items()):
        dur = max(int(rec.get("wall_s", 0.0) * 1e6), 1)
        out.append({
            "name": name, "ph": "X",
            "ts": cursor, "dur": dur,
            "pid": 1, "tid": 0,
            "args": {"calls": rec.get("calls", 0),
                     "items": rec.get("items", 0),
                     "wall_s": rec.get("wall_s", 0.0)},
        })
        cursor += dur

    return {"traceEvents": out, "displayTimeUnit": "ms"}


def main(argv=None) -> dict:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        return {}
    with open(argv[0]) as fh:
        payload = json.load(fh)
    trace = convert(payload)
    if len(argv) > 1:
        with open(argv[1], "w") as fh:
            json.dump(trace, fh, indent=1)
            fh.write("\n")
        print(f"wrote {len(trace['traceEvents'])} trace events "
              f"-> {argv[1]}")
    else:
        json.dump(trace, sys.stdout, indent=1)
        print()
    return trace


if __name__ == "__main__":
    main()
