#!/usr/bin/env python
"""Benchmark trajectory regression gate over checked-in ``BENCH_*.json``.

``benchmarks.run --smoke`` writes one ``BENCH_<case>.json`` per case to
the repo root (``benchmarks.common.save_bench``); the files are checked
in, so the git history IS the performance trajectory.  This gate makes
the trajectory enforceable: CI snapshots the checked-in baselines,
re-runs ``--smoke``, and compares the fresh files key-by-key.

Comparison rules (per dotted leaf key, e.g.
``slot_vec.goodput_tok_per_tick``):

  * **time-derived metrics are skipped** — any key path containing a
    wall-clock-ish component (``wall``, ``*_s``, ``*_ms``, ``per_s``,
    ``latency``, ``speedup``) varies with machine load and would flake;
    the deterministic counters are the contract.
  * remaining numeric metrics must match within ``--rel-tol`` (default
    0: placement counters, hit rates, tick timings, and percentiles
    are fully deterministic, so ANY drift is a real behavior change);
  * a key present in the baseline but missing fresh -> FAIL (a case
    silently stopped reporting);
  * a baseline file with no fresh counterpart -> FAIL (a case silently
    stopped running);
  * a fresh file or key with no baseline -> OK with a note (new case /
    new metric: check in the new baseline with the PR that adds it).

Exit 0 = gate passes, 1 = regression.  Usage (CI)::

    mkdir /tmp/bench_baseline && cp BENCH_*.json /tmp/bench_baseline/
    PYTHONPATH=src python -m benchmarks.run --smoke
    python tools/check_bench_regression.py \
        --baseline /tmp/bench_baseline --fresh .
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

# key-path components marking wall-clock-derived metrics (machine-load
# dependent -> excluded from the deterministic contract)
TIME_MARKERS = ("wall", "per_s", "latency", "speedup", "ttft_ms",
                "tpot_ms")

# payload components excluded wholesale: the observability block
# (kernel launch ledger, progress rates — DESIGN.md §13) is wall-clock
# reporting by construction, and its counters (calls, items) depend on
# jit cache state, not on placement behavior
EXEMPT_COMPONENTS = ("obs",)


def is_time_derived(path: str) -> bool:
    for part in path.lower().split("."):
        if part in EXEMPT_COMPONENTS:
            return True
        if part.endswith(("_s", "_ms")):
            return True
        if any(marker in part for marker in TIME_MARKERS):
            return True
    return False


def flatten(obj, prefix: str = "") -> dict:
    """Nested JSON -> {dotted.path: leaf}; lists index numerically."""
    out: dict = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            out.update(flatten(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = obj
    return out


def compare_case(name: str, base: dict, fresh: dict,
                 rel_tol: float) -> tuple:
    """Returns (failures, notes) for one BENCH file pair."""
    failures, notes = [], []
    b, f = flatten(base), flatten(fresh)
    for key, bv in sorted(b.items()):
        if is_time_derived(key):
            continue
        if key not in f:
            failures.append(f"{name}: metric '{key}' missing from fresh "
                            f"run (baseline {bv!r})")
            continue
        fv = f[key]
        if isinstance(bv, bool) or not isinstance(bv, (int, float)):
            if fv != bv:
                failures.append(f"{name}: '{key}' changed "
                                f"{bv!r} -> {fv!r}")
            continue
        if not isinstance(fv, (int, float)) or isinstance(fv, bool):
            failures.append(f"{name}: '{key}' changed type "
                            f"{bv!r} -> {fv!r}")
            continue
        if not math.isclose(fv, bv, rel_tol=rel_tol,
                            abs_tol=rel_tol if bv == 0 else 0.0):
            delta = (fv - bv) / bv * 100 if bv else float("inf")
            failures.append(f"{name}: '{key}' drifted {bv!r} -> {fv!r} "
                            f"({delta:+.2f}%, tol {rel_tol:.1%})")
    for key in sorted(set(f) - set(b)):
        if not is_time_derived(key):
            notes.append(f"{name}: new metric '{key}' = {f[key]!r} "
                         f"(no baseline; will be gated once checked in)")
    return failures, notes


def run_gate(baseline_dir: Path, fresh_dir: Path,
             rel_tol: float = 0.0) -> int:
    base_files = {p.name: p for p in sorted(baseline_dir.glob(
        "BENCH_*.json"))}
    fresh_files = {p.name: p for p in sorted(fresh_dir.glob(
        "BENCH_*.json"))}
    if not base_files:
        print(f"bench gate: no BENCH_*.json baselines in {baseline_dir} "
              f"— nothing to gate")
        return 0
    failures, notes = [], []
    for name, bp in base_files.items():
        if name not in fresh_files:
            failures.append(f"{name}: baseline exists but the fresh run "
                            f"produced no file — did its case stop "
                            f"running?")
            continue
        fails, ns = compare_case(
            name, json.loads(bp.read_text()),
            json.loads(fresh_files[name].read_text()), rel_tol)
        failures.extend(fails)
        notes.extend(ns)
    for name in sorted(set(fresh_files) - set(base_files)):
        notes.append(f"{name}: new case (no baseline; check it in to "
                     f"start gating it)")
    for n in notes:
        print(f"  note: {n}")
    if failures:
        print(f"bench gate: {len(failures)} regression(s) vs checked-in "
              f"trajectory:")
        for msg in failures:
            print(f"  FAIL: {msg}")
        return 1
    n_metrics = sum(
        sum(1 for k in flatten(json.loads(p.read_text()))
            if not is_time_derived(k))
        for p in base_files.values())
    print(f"bench gate: OK — {len(base_files)} case file(s), "
          f"{n_metrics} gated metrics, {len(notes)} note(s)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="compare fresh BENCH_*.json against the checked-in "
                    "trajectory")
    ap.add_argument("--baseline", type=Path, required=True,
                    help="directory holding the checked-in BENCH_*.json "
                         "snapshot")
    ap.add_argument("--fresh", type=Path, default=Path("."),
                    help="directory the fresh --smoke run wrote "
                         "BENCH_*.json into (default: repo root)")
    ap.add_argument("--rel-tol", type=float, default=0.0,
                    help="relative tolerance for numeric metrics "
                         "(default 0: deterministic counters must match "
                         "exactly)")
    args = ap.parse_args(argv)
    return run_gate(args.baseline, args.fresh, rel_tol=args.rel_tol)


if __name__ == "__main__":
    sys.exit(main())
