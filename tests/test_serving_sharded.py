"""Mesh-sharded serving cache: differential parity + partition laws.

Discipline (extends tests/test_serving.py): the scalar ``PagedKVCache``
is the bit-exact oracle; ``VectorizedPagedKVCache`` AND
``ShardedPagedKVCache`` (at mesh sizes 1 and 2) must reproduce every
``PARITY_COUNTERS`` entry, every per-touch tier, and the exact HBM LRU
order under ANY interleaving of registration, touches, releases,
adversarial sweeps, and out-of-band registry drops — including the
1-slot-HBM eviction edge.  The same abstract op sequence
(``strategies.build_kv_ops``) replays against every implementation, so
a single drawn spec differentially exercises all four caches at once.
"""

import numpy as np
import pytest

from strategies import (ElasticEventSpec, KVWorkloadSpec, apply_kv_ops,
                        build_failure_schedule, build_kv_ops, given,
                        kv_workload_specs, settings, st)
from repro.core.engine.shard import (PrimeSpacePartition, shard_mesh,
                                     sharded_successor_table)
from repro.serving.kv_cache import PARITY_COUNTERS, PagedKVCache
from repro.serving.kv_cache_sharded import ShardedPagedKVCache
from repro.serving.kv_cache_vec import VectorizedPagedKVCache


def _differential(spec: KVWorkloadSpec, hbm: int, budget: int,
                  espec: ElasticEventSpec = None) -> None:
    """Replay one spec against oracle / vec / sharded(1) / sharded(2).

    ``espec``, when given, injects workload-mutating chaos events (prime
    drops) through the same ``build_failure_schedule`` machinery the
    elastic fuzz uses (tests/test_elastic.py) — identical schedules
    replay against every implementation."""
    ops = build_kv_ops(spec)
    schedule = (build_failure_schedule(espec, len(ops))
                if espec is not None else None)
    caches = {
        "scalar": PagedKVCache(hbm_pages=hbm, page_size=4,
                               prefetch_budget=budget),
        "vec": VectorizedPagedKVCache(hbm_pages=hbm, page_size=4,
                                      prefetch_budget=budget),
        "shard1": ShardedPagedKVCache(hbm_pages=hbm, page_size=4,
                                      prefetch_budget=budget, n_shards=1),
        "shard2": ShardedPagedKVCache(hbm_pages=hbm, page_size=4,
                                      prefetch_budget=budget, n_shards=2),
    }
    tiers = {name: apply_kv_ops(kv, ops, schedule=schedule)
             for name, kv in caches.items()}
    oracle = caches["scalar"]
    for name, kv in caches.items():
        if name == "scalar":
            continue
        assert tiers[name] == tiers["scalar"], name
        for f in PARITY_COUNTERS:
            assert getattr(kv.stats, f) == getattr(oracle.stats, f), \
                (name, f)
        assert list(kv.hbm.items()) == list(oracle.hbm.items()), name
        assert kv.host == oracle.host, name
        assert kv.stats.registry_scans == 0, name
    for name in ("shard1", "shard2"):
        kv = caches[name]
        assert (kv.aggregate_shard_stats().parity_tuple()
                == kv.stats.parity_tuple()), name


# --------------------------------------------------------------------------- #
# property-based differential fuzz (hypothesis; clean SKIP without it)        #
# --------------------------------------------------------------------------- #

@given(spec=kv_workload_specs(),
       hbm=st.sampled_from([1, 2, 8, 32]),
       budget=st.integers(min_value=0, max_value=4))
@settings(max_examples=15, deadline=None)
def test_differential_fuzz_property(spec, hbm, budget):
    """Any drawn workload: all four caches agree bit-for-bit — tiers,
    parity counters, LRU order, host tier, per-shard aggregation."""
    _differential(spec, hbm, budget)


# deterministic pinned cases: the suite exercises the edge paths even
# when hypothesis is not installed (tier-1 must not lose this coverage)
_PINNED = [
    # 1-slot HBM: every insert evicts
    (KVWorkloadSpec(seed=3, n_requests=8, n_touches=80), 1, 3, None),
    # registry drop -> bulk table rebuild path, small HBM; the drops are
    # schedule-driven chaos events (strategies.build_failure_schedule)
    (KVWorkloadSpec(seed=5, n_requests=10, n_touches=100), 4, 2,
     ElasticEventSpec(seed=5, n_events=4, kill=False, resize=False,
                      drop=True)),
    # eviction-adversarial sweeps + releases, prefetch off
    (KVWorkloadSpec(seed=7, n_requests=12, n_touches=60, sweeps=2),
     8, 0, None),
    # deep shared prefixes, dense touches
    (KVWorkloadSpec(seed=11, n_requests=9, n_touches=120, key_space=60,
                    shared_pool=32, max_tail=6), 16, 4, None),
]


@pytest.mark.parametrize("spec,hbm,budget,espec", _PINNED,
                         ids=["hbm1", "registry-drop", "sweeps", "prefix"])
def test_differential_fuzz_pinned(spec, hbm, budget, espec):
    _differential(spec, hbm, budget, espec=espec)


# --------------------------------------------------------------------------- #
# prime-space partition laws                                                  #
# --------------------------------------------------------------------------- #

def test_partition_owner_is_total_stable_and_striped():
    part = PrimeSpacePartition(n_shards=4)
    primes = [2, 997, 1009, 1523, 6007, 99991, 100003, 999983, 1000003]
    owners = [part.owner(p) for p in primes]
    assert all(0 <= o < 4 for o in owners)
    assert owners == [part.owner(p) for p in primes]      # pure function
    assert list(part.owners(primes)) == owners
    # contiguity: within one value block, ownership never changes
    lo, width = part._blocks[1]                           # L2 level
    block0 = [p for p in range(lo, lo + width) if part.owner(p) is not None]
    assert len({part.owner(p) for p in block0}) == 1
    # striping: consecutive blocks rotate shards
    assert part.owner(lo) != part.owner(lo + width)
    # a real workload spreads ownership over >1 shard
    kv = ShardedPagedKVCache(hbm_pages=8, page_size=4, n_shards=4)
    kv.register_request(0, list(range(1024)))             # 256-page chain
    spread = {kv.owner_of_page(pid) for pid in kv.chains[0]}
    assert len(spread) > 1
    assert PrimeSpacePartition(1).owner(99991) == 0       # degenerate
    with pytest.raises(ValueError):
        PrimeSpacePartition(0)


def test_classify_partitions_registry_in_order():
    kv = ShardedPagedKVCache(hbm_pages=16, page_size=4, n_shards=2)
    rng = np.random.default_rng(2)
    shared = list(rng.integers(0, 3000, size=24))
    for r in range(8):
        # long tails -> several hundred pages -> chains straddle the
        # partition's prime blocks, so the cross-shard path is live
        tail = list(rng.integers(0, 3000, size=int(rng.integers(80, 200))))
        kv.register_request(r, shared[:int(rng.integers(0, 24))] + tail)
    local, cross = kv.partition.classify(kv.registry)
    arr = kv.registry.composites_array()
    all_pos = sorted(p for sh in local for p in sh) + sorted(cross)
    assert sorted(all_pos) == list(range(arr.size))       # exact partition
    for s, sh in enumerate(local):
        assert sh == sorted(sh)                           # registry order
        for pos in sh:
            rel = kv.registry.relationship_of_composite(int(arr[pos]))
            assert {kv.partition.owner(q) for q in rel.primes} == {s}
    for pos in cross:
        rel = kv.registry.relationship_of_composite(int(arr[pos]))
        assert len({kv.partition.owner(q) for q in rel.primes}) > 1
    # at this scale chains straddle prime blocks: the exchange is live
    assert cross, "workload produced no cross-shard chains"


# --------------------------------------------------------------------------- #
# sharded bulk discovery == single-device bulk discovery                      #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("n_shards", [1, 2, 3])
def test_sharded_successor_table_matches_global(n_shards):
    from repro.core.engine import successor_table

    kv = VectorizedPagedKVCache(hbm_pages=16, page_size=4,
                                prefetch_budget=3)
    rng = np.random.default_rng(5)
    shared = list(rng.integers(0, 200, size=16))
    for r in range(8):
        tail = list(rng.integers(0, 200, size=int(rng.integers(4, 16))))
        kv.register_request(r, shared[:int(rng.integers(0, 16))] + tail)
    pages = range(kv._next_page)
    host = successor_table(kv.registry, kv.assigner, pages, discover="host")
    part = PrimeSpacePartition(n_shards)
    sharded = sharded_successor_table(kv.registry, kv.assigner, pages,
                                      part, mesh=None)
    assert sharded == host


def test_sharded_refresh_crosschecks_against_kernel_backend():
    kv = ShardedPagedKVCache(hbm_pages=16, page_size=4,
                             prefetch_budget=3, n_shards=2)
    rng = np.random.default_rng(6)
    for r in range(6):
        kv.register_request(r, list(rng.integers(0, 150,
                                                 size=int(rng.integers(8, 20)))))
    kv.refresh_tables()                       # sharded path
    sharded_rows = kv.successor_rows()
    kv.refresh_tables(discover="kernel")      # single-device Pallas bulk
    assert kv.successor_rows() == sharded_rows


# --------------------------------------------------------------------------- #
# mesh plumbing                                                               #
# --------------------------------------------------------------------------- #

def test_degenerate_single_device_mesh_uses_shard_map():
    """n_shards=1 always has enough devices: the real shard_map path
    must run (and stay bit-exact) even on a 1-device host."""
    kv = ShardedPagedKVCache(hbm_pages=8, page_size=4, n_shards=1)
    assert kv.mesh is not None and kv.mesh.size == 1
    oracle = PagedKVCache(hbm_pages=8, page_size=4)
    for c in (kv, oracle):
        c.register_request(0, list(range(32)))
        c.touch_batch([(0, j) for j in range(8)])
    assert kv.last_scan.used_shard_map
    assert kv.stats.parity_tuple() == oracle.stats.parity_tuple()


def test_multi_device_mesh_when_forced():
    """Under XLA_FLAGS=--xla_force_host_platform_device_count=2 (the CI
    mesh job) the 2-shard cache runs real shard_map + all_gather; on a
    1-device host it falls back to the bit-identical host loop."""
    import jax

    n_dev = len(jax.devices())
    mesh = shard_mesh(2)
    assert (mesh is None) == (n_dev < 2)
    kv = ShardedPagedKVCache(hbm_pages=8, page_size=4, n_shards=2)
    kv.register_request(0, list(range(64)))
    kv.touch_batch([(0, j) for j in range(16)])
    assert kv.last_scan.used_shard_map == (n_dev >= 2)
    assert kv.bulk_refreshes >= 1


def test_mesh_shard_mismatch_rejected():
    mesh = shard_mesh(1)
    with pytest.raises(ValueError):
        ShardedPagedKVCache(n_shards=2, mesh=mesh)


# --------------------------------------------------------------------------- #
# serving engine over the sharded backend                                     #
# --------------------------------------------------------------------------- #

def test_engine_sharded_scalar_parity():
    """Null-model engines over the sharded vs scalar cache produce
    identical tokens AND identical page counters (mirrors
    test_serving.py::test_engine_vec_scalar_parity)."""
    from repro.serving.engine import ServingEngine

    def workload(eng, n_req=24, seed=0):
        rng = np.random.default_rng(seed)
        shared = list(rng.integers(0, 3000, size=48))
        for r in range(n_req):
            tail = list(rng.integers(0, 3000, size=int(rng.integers(8, 32))))
            eng.submit(shared[:int(rng.integers(0, 48))] + tail,
                       max_new_tokens=4)
        return eng.run_until_idle()

    engines = {kv: ServingEngine(None, None, max_batch=8, page_size=8,
                                 hbm_pages=24, kv=kv, reread_window=2,
                                 shards=2)
               for kv in ("sharded", "scalar")}
    done = {kv: workload(e) for kv, e in engines.items()}
    gen = {kv: [(r.req_id, tuple(r.generated)) for r in sorted(
        ds, key=lambda r: r.req_id)] for kv, ds in done.items()}
    assert gen["sharded"] == gen["scalar"]
    assert (engines["sharded"].pages.stats.parity_tuple()
            == engines["scalar"].pages.stats.parity_tuple())
    assert engines["sharded"].pages.stats.registry_scans == 0
    assert engines["sharded"].pages.bulk_refreshes >= 1


def test_engine_rejects_unknown_kv_backend():
    from repro.serving.engine import ServingEngine

    with pytest.raises(ValueError):
        ServingEngine(None, None, kv="magic")
