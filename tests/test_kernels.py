"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, exact equality
(integer kernels — no tolerance)."""

import numpy as np
import pytest

import jax
from jax.experimental import enable_x64 as _enable_x64
import jax.numpy as jnp

from repro.kernels.factorize import (divisibility_mask_pallas,
                                     factorize_squarefree_pallas)
from repro.kernels.gcd import gcd_pallas
from repro.kernels.ops import divisibility_scan, factorize_batch, gcd_batch
from repro.kernels.ref import (divisibility_mask_ref,
                               factorize_squarefree_ref, gcd_ref)

PRIMES_SMALL = np.array(
    [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59, 61,
     67, 71, 73, 79, 83, 89, 97, 101, 103, 107, 109, 113], dtype=np.int64)


def _pad(x, mult, fill):
    pad = (-len(x)) % mult
    return np.concatenate([x, np.full(pad, fill, x.dtype)])


@pytest.mark.parametrize("n,p,bn,bp", [
    (256, 512, 256, 512),
    (512, 512, 256, 512),
    (256, 1024, 128, 256),
    (1024, 512, 512, 128),
])
@pytest.mark.parametrize("dtype", [np.int32, np.int64])
def test_factorize_kernel_matches_ref(n, p, bn, bp, dtype):
    rng = np.random.default_rng(n + p)
    pool = _pad(PRIMES_SMALL.astype(dtype), bp, 0)[:p]
    pairs = rng.choice(PRIMES_SMALL, size=(n, 2), replace=True)
    comps = (pairs[:, 0] * pairs[:, 1]).astype(dtype)
    ctx = _enable_x64(True) if dtype == np.int64 else _null()
    with ctx:
        cj, pj = jnp.asarray(comps), jnp.asarray(pool)
        mask, res = factorize_squarefree_pallas(cj, pj, block_n=bn, block_p=bp)
        mref, rref = factorize_squarefree_ref(cj, pj)
        assert (np.asarray(mask) == np.asarray(mref)).all()
        assert (np.asarray(res) == np.asarray(rref)).all()


@pytest.mark.parametrize("dtype", [np.int32, np.int64])
def test_divmask_kernel_matches_ref(dtype):
    rng = np.random.default_rng(0)
    comps = _pad((rng.choice(PRIMES_SMALL, size=(300, 2)).prod(axis=1)
                  ).astype(dtype), 256, 1)
    qs = _pad(PRIMES_SMALL.astype(dtype), 512, 0)
    ctx = _enable_x64(True) if dtype == np.int64 else _null()
    with ctx:
        cj, qj = jnp.asarray(comps), jnp.asarray(qs)
        mask = divisibility_mask_pallas(cj, qj)
        mref = divisibility_mask_ref(cj, qj)
        assert (np.asarray(mask) == np.asarray(mref)).all()


@pytest.mark.parametrize("n", [1024, 2048, 4096])
@pytest.mark.parametrize("dtype", [np.int32, np.int64])
def test_gcd_kernel_matches_ref(n, dtype):
    rng = np.random.default_rng(n)
    hi = 2**28 if dtype == np.int32 else 2**40
    a = rng.integers(1, hi, size=n).astype(dtype)
    b = rng.integers(1, hi, size=n).astype(dtype)
    ctx = _enable_x64(True) if dtype == np.int64 else _null()
    with ctx:
        g = gcd_pallas(jnp.asarray(a), jnp.asarray(b))
        assert (np.asarray(g) == np.gcd(a, b)).all()


def test_gcd_zero_edge():
    a = np.array([0, 5, 0, 12] + [1] * 124, dtype=np.int32)
    b = np.array([7, 0, 0, 18] + [1] * 124, dtype=np.int32)
    a, b = _pad(a, 1024, 1), _pad(b, 1024, 1)
    g = gcd_pallas(jnp.asarray(a), jnp.asarray(b))
    assert (np.asarray(g) == np.gcd(a, b)).all()


# --------------------------------------------------------------------------- #
# host wrappers (padding, dtype pick, compaction)                              #
# --------------------------------------------------------------------------- #

def test_factorize_batch_ragged():
    facs, resid = factorize_batch([6, 35, 143, 101], [2, 3, 5, 7, 11, 13])
    assert facs == [[2, 3], [5, 7], [11, 13], []]
    assert list(resid) == [1, 1, 1, 101]


def test_factorize_batch_int64_path():
    big = 1_000_003 * 1_000_033
    facs, resid = factorize_batch([big], [1_000_003, 1_000_033])
    assert facs[0] == [1_000_003, 1_000_033] and resid[0] == 1


def test_divisibility_scan_compaction():
    idx = divisibility_scan([6, 10, 15, 21], [2, 3, 5, 7])
    assert [list(i) for i in idx] == [[0, 1], [0, 2, 3], [1, 2], [3]]


def test_scan_empty_inputs():
    out = divisibility_scan([], [3])
    assert len(out) == 1 and len(out[0]) == 0
    assert gcd_batch([], []).size == 0


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
