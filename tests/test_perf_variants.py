"""§Perf optimized-variant features: function-preserving checks."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import build_model
from repro.models.moe import apply_moe, init_moe


def test_moe_gather_combine_equals_scatter():
    cfg = get_smoke("kimi-k2-1t-a32b")
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, cfg.d_model)),
                    jnp.float32)
    o1, _ = apply_moe(x, p, cfg)
    o2, _ = apply_moe(x, p, cfg.replace(moe_combine="gather"))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


def test_int8_latent_cache_accuracy():
    """Quantized MLA cache: teacher-forced decode stays close to the
    bf16 cache after 12 steps (random-weight smoke model; the full-config
    deepseek error measured 1.1% — EXPERIMENTS.md §Perf cell 3).

    The smoke bound is 8%: random weights have no trained scale
    structure, so quantization error is dominated by outlier activations
    and lands jax-version-dependent in the 4-7% range (6.2% on the
    0.4.37 CPU build); the real accuracy gate is the measured
    full-config 1.1%."""
    cfg = get_smoke("deepseek-v2-236b")
    m = build_model(cfg)
    m8 = build_model(cfg.replace(kv_cache_dtype="int8"))
    params = m.init_params(jax.random.PRNGKey(0))
    B, T = 2, 12
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(B, T)).astype(np.int32)
    cA, cB = m.init_cache(B, T + 4), m8.init_cache(B, T + 4)
    assert cB["latent"].dtype == jnp.int8
    dA, dB = jax.jit(m.decode_step), jax.jit(m8.decode_step)
    for t in range(T):
        lA, cA = dA(params, {"tokens": jnp.asarray(toks[:, t:t + 1])}, cA)
        lB, cB = dB(params, {"tokens": jnp.asarray(toks[:, t:t + 1])}, cB)
    rel = float(jnp.max(jnp.abs(lA - lB)) / (jnp.max(jnp.abs(lA)) + 1e-9))
    assert rel < 0.08, rel


def test_head_padding_rules():
    from repro.launch.dryrun import opt_overrides
    from repro.configs import get_config

    phi3 = get_config("phi3-medium-14b")
    padded = opt_overrides(phi3, "train")
    assert padded.n_heads % 16 == 0
    assert padded.n_heads % padded.n_kv_heads == 0
    assert padded.n_heads >= phi3.n_heads
    # gemma MQA stays unpadded (kv=1 replicates cheaply)
    gem = opt_overrides(get_config("gemma-2b"), "train")
    assert gem.n_kv_heads == 1
    # MLA archs are untouched (latent path has no per-head KV)
    ds = opt_overrides(get_config("deepseek-v2-236b"), "train")
    assert ds.n_heads == 128


def test_partial_factorizations_never_cached():
    """Theorem 1 vs graceful degradation (Lessons L4): a budget-exceeded
    partial result must not poison the factorization cache."""
    from repro.core import Factorizer

    f = Factorizer()
    big = 1_000_003 * 1_000_033 * 1_000_037
    partial = f.factorize(big, time_budget_s=0.0)   # forced budget blow
    assert f.cache.get(big) is None or \
        np.prod([int(x) for x in f.cache.get(big)]) == big
    full = f.factorize(big, time_budget_s=10.0)
    assert full == (1_000_003, 1_000_033, 1_000_037)


def test_split_k_cache_sharding_spec():
    from jax.sharding import AbstractMesh
    from repro.configs import SHAPES, get_config
    from repro.sharding import partition as pt

    try:   # jax 0.4.x signature; newer jax takes (shape, axis_names)
        mesh = AbstractMesh((("data", 16), ("model", 16)))
    except TypeError:
        mesh = AbstractMesh((16, 16), ("data", "model"))
    cfg = get_config("qwen3-32b")          # kv=8: cannot shard 16-way
    model = build_model(cfg)
    cache = model.cache_specs(SHAPES[2])   # decode_32k
    sh = pt.cache_shardings(cache, mesh, cfg, seq_over_model=True)
    assert sh["k"].spec[2] == "model"              # sequence split-K
    assert sh["k"].spec[1] in ("data", ("data",))  # batch over data
