"""MoE expert-serving tier: differential parity + router integration.

Discipline (mirrors tests/test_serving_sharded.py): the scalar
``ExpertCache`` is the bit-exact oracle; ``VectorizedExpertCache`` must
reproduce every ``EXPERT_PARITY_COUNTERS`` entry, every per-set tier
decision, the exact HBM LRU order, AND the full prefetch log — the
(source, target) audit trail of Theorem 1's zero-false-positive claim —
under ANY interleaving of ``observe_routing`` / ``activate`` /
``activate_batch``, including 1-slot HBM, ``max_group`` overflow,
duplicate/capped group re-registration, and prefetch-budget exhaustion.
The same concrete router schedule (``strategies.build_expert_sets``)
replays against both implementations.
"""

import numpy as np
import pytest

from strategies import (ExpertWorkloadSpec, build_expert_sets, drive_expert,
                        expert_workload_specs, given, settings, st)
from repro.serving.expert_cache import (EXPERT_PARITY_COUNTERS, ExpertCache,
                                        ExpertCacheStats)
from repro.serving.expert_cache_vec import VectorizedExpertCache


def _differential(spec: ExpertWorkloadSpec, slots: int, budget: int,
                  max_group: int = 8) -> None:
    """Replay one spec against the oracle and the vectorized twin."""
    batches = build_expert_sets(spec)
    a = ExpertCache(spec.n_experts, hbm_slots=slots,
                    prefetch_budget=budget, max_group=max_group)
    b = VectorizedExpertCache(spec.n_experts, hbm_slots=slots,
                              prefetch_budget=budget, max_group=max_group)
    ta, tb = drive_expert(a, batches), drive_expert(b, batches)
    assert ta == tb                                   # per-set tiers
    for f in EXPERT_PARITY_COUNTERS:
        assert getattr(a.stats, f) == getattr(b.stats, f), f
    assert a.prefetch_log == b.prefetch_log           # Theorem-1 audit trail
    assert list(a.hbm.items()) == list(b.hbm.items())  # exact LRU order
    # the oracle scans the registry per activated expert (when prefetch
    # is on); the vectorized cache must never scan on the hot path
    if budget > 0 and a.stats.prefetches + a.stats.hits > 0:
        assert a.stats.registry_scans > 0
    assert b.stats.registry_scans == 0


# --------------------------------------------------------------------------- #
# property-based differential fuzz (hypothesis; clean SKIP without it)        #
# --------------------------------------------------------------------------- #

@given(spec=expert_workload_specs(),
       slots=st.sampled_from([1, 2, 8, 32]),
       budget=st.integers(min_value=0, max_value=4))
@settings(max_examples=15, deadline=None)
def test_differential_fuzz_property(spec, slots, budget):
    """Any drawn router workload: both caches agree bit-for-bit —
    tiers, parity counters, LRU order, prefetch log."""
    _differential(spec, slots, budget)


# deterministic pinned cases: the suite exercises the edge paths even
# when hypothesis is not installed (tier-1 must not lose this coverage)
_PINNED = [
    # 1-slot HBM: every insert evicts
    (ExpertWorkloadSpec(seed=3, n_experts=24, n_steps=40), 1, 3, 8),
    # max_group overflow + oversized fresh draws (cap-collision dedup)
    (ExpertWorkloadSpec(seed=5, n_experts=40, group_size=12,
                        oversize_every=4), 8, 2, 4),
    # adversarial repeated-group schedule (duplicate re-registration)
    (ExpertWorkloadSpec(seed=7, n_experts=32, repeat_hot=True,
                        n_groups=4), 4, 4, 8),
    # adversarial disjoint-partition schedule, tight budget
    (ExpertWorkloadSpec(seed=9, n_experts=36, disjoint=True,
                        group_size=6), 6, 1, 8),
    # prefetch-budget exhaustion churn: big groups through tiny HBM
    (ExpertWorkloadSpec(seed=11, n_experts=16, group_size=9, batch=6), 2, 4, 8),
]


@pytest.mark.parametrize("spec,slots,budget,max_group", _PINNED,
                         ids=["hbm1", "overflow", "repeat", "disjoint",
                              "budget"])
def test_differential_fuzz_pinned(spec, slots, budget, max_group):
    _differential(spec, slots, budget, max_group)


# --------------------------------------------------------------------------- #
# the fuzz-surfaced scalar bug class: duplicate group registration            #
# --------------------------------------------------------------------------- #

def test_capped_duplicate_groups_register_once():
    """Two distinct router sets that collapse to the same ``max_group``
    cap used to re-register the composite — orphaning the first
    ``Relationship``, inflating prime degrees, and bumping the registry
    version (needless vectorized-table rebuilds).  Regression for the
    dedup fix (mirrors the PR 2 chain-edge fix)."""
    ec = ExpertCache(32, hbm_slots=8, max_group=4)
    ec.observe_routing([(0, 1, 2, 3, 9)])
    v = ec.registry.version
    new = ec.observe_routing([(0, 1, 2, 3, 17)])      # caps to the same group
    assert new == []
    assert len(ec.registry) == 1
    assert ec.registry.version == v                   # no orphaning mutation
    p0 = ec.assigner.prime_of(0)
    assert ec.registry.degree(p0) == 1                # degree not inflated
    # the vectorized twin must see zero table invalidation from the dup
    vec = VectorizedExpertCache(32, hbm_slots=8, max_group=4)
    vec.observe_routing([(0, 1, 2, 3, 9)])
    rows = vec.successor_rows()
    vec.observe_routing([(0, 1, 2, 3, 17)])
    vec.activate_batch([(0,)])
    assert vec.successor_rows() == rows
    assert vec.bulk_refreshes == 0


def test_chunk_collision_across_distinct_groups_skipped():
    """A multi-chunk group whose FIRST chunk coincides with a live
    composite of a *different* group must not register: the shared
    chunk's relationship mapping would be overwritten, reordering the
    §4.2 scan's discoveries (the divergence the differential fuzz
    originally surfaced)."""
    ec = ExpertCache(48, hbm_slots=8, max_group=8)
    big = tuple(range(8))                  # chunks into >= 2 composites
    ec.observe_routing([big])
    rel = ec.registry.relationship_of_composite(
        ec.registry.composites_array()[0])
    assert len(rel.composites) >= 2, "expected a multi-chunk group"
    # a different group that shares the first chunk's prime subset
    first_chunk_primes = sorted(
        q for q in rel.primes
        if rel.composites[0] % q == 0)
    shared = [ec.assigner.data_of(q) for q in first_chunk_primes]
    clash = tuple(shared) + (40,)          # same leading chunk, new tail
    before = len(ec.registry)
    assert ec.observe_routing([clash]) == []
    assert len(ec.registry) == before


def test_budget_zero_disables_prefetch_entirely():
    """Regression: with ``prefetch_budget=0`` the scalar cache used to
    run the §4.2 scan anyway and leak one prefetch per scanned
    relationship — the LRU-expert baseline must issue NO transfers."""
    for cls in (ExpertCache, VectorizedExpertCache):
        ec = cls(16, hbm_slots=4, prefetch_budget=0)
        ec.observe_routing([(0, 1, 2, 3)])
        for _ in range(5):
            ec.activate([0, 1, 2, 3])
        assert ec.stats.prefetches == 0
        assert ec.stats.registry_scans == 0
        assert ec.prefetch_log == []


def test_expert_cache_rejects_bad_config():
    with pytest.raises(ValueError):
        ExpertCache(8, hbm_slots=0)
    with pytest.raises(ValueError):
        ExpertCache(0, hbm_slots=4)
    with pytest.raises(ValueError):
        VectorizedExpertCache(8, hbm_slots=4, discover="magic")


# --------------------------------------------------------------------------- #
# discovery tables: incremental == bulk host == bulk Pallas kernels           #
# --------------------------------------------------------------------------- #

def test_cofire_table_backends_agree():
    from repro.core.engine import successor_table

    vec = VectorizedExpertCache(48, hbm_slots=8, prefetch_budget=3)
    batches = build_expert_sets(ExpertWorkloadSpec(
        seed=5, n_experts=48, group_size=10, oversize_every=3))
    drive_expert(vec, batches)

    inc = vec.successor_rows()
    experts = range(vec.n_experts)
    host = {k: v for k, v in successor_table(
        vec.registry, vec.assigner, experts, discover="host").items() if v}
    kern = {k: v for k, v in successor_table(
        vec.registry, vec.assigner, experts, discover="kernel").items() if v}
    assert inc == host == kern
    # a bulk kernel refresh reproduces the incrementally-maintained table
    vec.refresh_tables(discover="kernel")
    assert vec.successor_rows() == inc
    assert vec.bulk_refreshes == 1


def test_out_of_band_prime_drop_forces_rebuild():
    """An out-of-band registry mutation (Algorithm-1 prime recycling via
    ``assigner.release`` drops an expert's relationships) must not be
    masked by incremental maintenance: the next activation rebuilds in
    bulk and parity with the oracle holds."""
    from repro.core.primes import CacheLevel

    a = ExpertCache(24, hbm_slots=6, prefetch_budget=2)
    b = VectorizedExpertCache(24, hbm_slots=6, prefetch_budget=2)
    for ec in (a, b):
        ec.observe_routing([(0, 1, 2), (2, 3, 4), (5, 6, 7)])
        ec.activate_batch([(0, 2), (5,)])
        ec.assigner.release(2, CacheLevel.L2)          # drops 2's groups
        ec.observe_routing([(8, 9, 10)])
        ec.activate_batch([(0, 2), (8,)])
    assert a.stats.parity_tuple() == b.stats.parity_tuple()
    assert a.prefetch_log == b.prefetch_log
    assert list(a.hbm.items()) == list(b.hbm.items())
    assert b.bulk_refreshes >= 1


# --------------------------------------------------------------------------- #
# serving engine over the expert tier                                         #
# --------------------------------------------------------------------------- #

def test_engine_moe_load_generator_parity():
    """Null-model engines over either expert-cache backend produce
    identical tokens AND identical expert counters on the same synthetic
    router workload (mirrors test_serving.py::test_engine_vec_scalar_
    parity)."""
    from repro.serving.engine import ServingEngine

    def workload(eng, n_req=24, seed=0):
        rng = np.random.default_rng(seed)
        for r in range(n_req):
            eng.submit(list(rng.integers(0, 3000,
                                         size=int(rng.integers(8, 32)))),
                       max_new_tokens=4)
        return eng.run_until_idle()

    engines = {m: ServingEngine(None, None, max_batch=8, page_size=8,
                                hbm_pages=24, moe=m, moe_experts=32,
                                moe_slots=8, moe_topk=4, moe_groups=12)
               for m in ("vec", "scalar")}
    done = {m: workload(e) for m, e in engines.items()}
    gen = {m: [(r.req_id, tuple(r.generated)) for r in sorted(
        ds, key=lambda r: r.req_id)] for m, ds in done.items()}
    assert gen["vec"] == gen["scalar"]
    ev, es = engines["vec"].experts, engines["scalar"].experts
    assert ev.stats.parity_tuple() == es.stats.parity_tuple()
    assert ev.prefetch_log == es.prefetch_log
    assert ev.stats.registry_scans == 0
    assert es.stats.registry_scans > 0
    assert ev.stats.prefetches > 0                    # structure was learned


def test_engine_rejects_unknown_moe_backend():
    from repro.serving.engine import ServingEngine

    with pytest.raises(ValueError):
        ServingEngine(None, None, moe="magic")


def test_engine_rejects_moe_with_routerless_model():
    """A model without ``decode_step_router`` (dense / non-transformer
    family) cannot feed the expert tier — reject at construction, not
    with a TypeError mid-serving."""
    from repro.configs import get_smoke
    from repro.models import build_model
    from repro.serving.engine import ServingEngine

    model = build_model(get_smoke("gemma-2b"))         # dense: no router
    with pytest.raises(ValueError):
        ServingEngine(model, None, max_batch=2, max_seq=32, moe="vec")


@pytest.mark.parametrize("arch", ["kimi-k2-1t-a32b", "deepseek-v2-236b"],
                         ids=["attn", "mla"])
def test_engine_real_router_prefetch_is_exact_cofire_set(arch):
    """End-to-end real-router mode: a tiny MoE model's ``apply_moe``
    top-k sets feed the expert cache through
    ``Model.decode_step_router``, and every prefetched expert is inside
    the factorization-recovered co-fire set of its trigger — the
    Theorem 1 zero-false-positive check on live router traffic (kimi
    covers the standard-attention decode scan, deepseek the MLA one)."""
    import jax

    from repro.configs import get_smoke
    from repro.models import build_model
    from repro.serving.engine import ServingEngine

    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_batch=2, max_seq=64, page_size=8,
                        moe="vec", moe_slots=4, moe_prefetch_budget=4)
    assert eng.experts.n_experts == cfg.moe.n_experts
    for i in range(3):
        eng.submit(list(range(12)) + [20 + i], max_new_tokens=3)
    done = eng.run_until_idle()
    assert len(done) == 3
    ec = eng.experts
    assert ec.stats.hits + ec.stats.misses > 0        # router traffic flowed
    assert ec.stats.prefetches > 0
    for src, tgt in ec.prefetch_log:
        assert tgt != src
        assert tgt in ec.coactivated(src), (src, tgt)
    assert ec.stats.registry_scans == 0


def test_stats_hit_rate_and_precision_edges():
    st_ = ExpertCacheStats()
    assert st_.hit_rate == 0.0
    assert st_.prefetch_precision == 0.0
    st_.hits, st_.misses = 3, 1
    st_.prefetches, st_.prefetch_hits = 4, 3
    assert st_.hit_rate == 0.75
    assert st_.prefetch_precision == 0.75
