"""Replacement policies + simulator: capacity invariants (hypothesis),
LRU exactness vs brute force, Table-1-style system ordering."""

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import (DEFAULT_LEVELS, db_join_trace, derive_table1_row,
                        fast_lru_hit_rate, graph_walk_trace, make_policy,
                        run_all_systems, simulate_baseline, simulate_pfcs,
                        simulate_semantic, zipf_trace)
from repro.core.policies import POLICY_FACTORIES


@given(st.sampled_from(sorted(POLICY_FACTORIES)),
       st.integers(min_value=1, max_value=40),
       st.lists(st.integers(min_value=0, max_value=60), min_size=1,
                max_size=300))
@settings(max_examples=80, deadline=None)
def test_policy_capacity_invariant(name, cap, keys):
    pol = make_policy(name, cap)
    for k in keys:
        hit = pol.access(k)
        assert isinstance(hit, bool)
        assert len(pol) <= cap
        assert pol.contains(k)  # just-accessed key must be resident


def _brute_lru(keys, cap):
    cache, hits = [], 0
    for k in keys:
        if k in cache:
            hits += 1
            cache.remove(k)
        cache.append(k)
        if len(cache) > cap:
            cache.pop(0)
    return hits


@given(st.integers(min_value=1, max_value=20),
       st.lists(st.integers(min_value=0, max_value=30), min_size=1,
                max_size=200))
@settings(max_examples=60, deadline=None)
def test_lru_matches_bruteforce(cap, keys):
    pol = make_policy("lru", cap)
    hits = sum(pol.access(k) for k in keys)
    assert hits == _brute_lru(keys, cap)


def test_fast_lru_matches_python():
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 200, size=2000).astype(np.int64)
    for cap in (8, 32, 128):
        py = _brute_lru(list(keys), cap) / len(keys)
        jx = fast_lru_hit_rate(keys, cap)
        assert abs(py - jx) < 1e-9, (cap, py, jx)


def test_arc_adapts_better_than_fifo_on_mixed():
    """ARC should beat FIFO on a mixed recency+frequency workload."""
    rng = np.random.default_rng(1)
    hot = rng.integers(0, 50, size=4000)         # frequent set
    scan = np.arange(50, 2050)                   # one long scan
    keys = np.concatenate([hot[:2000], scan, hot[2000:]])
    cap = 100
    arc = make_policy("arc", cap)
    fifo = make_policy("fifo", cap)
    h_arc = sum(arc.access(int(k)) for k in keys)
    h_fifo = sum(fifo.access(int(k)) for k in keys)
    assert h_arc > h_fifo


def test_lirs_scan_resistance():
    """LIRS must not lose its hot set to a one-pass scan (its headline
    property vs LRU)."""
    rng = np.random.default_rng(2)
    cap = 64
    hot = list(rng.integers(0, 48, size=3000))
    scan = list(range(1000, 1000 + 400))
    tail = list(rng.integers(0, 48, size=3000))
    lirs = make_policy("lirs", cap)
    lru = make_policy("lru", cap)
    for k in hot:
        lirs.access(int(k)); lru.access(int(k))
    for k in scan:
        lirs.access(int(k)); lru.access(int(k))
    h_lirs = sum(lirs.access(int(k)) for k in tail)
    h_lru = sum(lru.access(int(k)) for k in tail)
    assert h_lirs >= h_lru


# --------------------------------------------------------------------------- #
# simulator / Table 1 ordering                                                #
# --------------------------------------------------------------------------- #

CAPS = (("L1", 32), ("L2", 128), ("L3", 512))


def test_pfcs_beats_baselines_on_relational_trace():
    tr = db_join_trace(n_orders=2000, n_customers=400, n_items=800,
                       n_queries=8000)
    res = run_all_systems(tr, capacities=CAPS,
                          systems=("lru", "arc", "semantic", "pfcs"))
    assert res["pfcs"].hit_rate > res["lru"].hit_rate
    assert res["pfcs"].hit_rate > res["arc"].hit_rate
    # PFCS relationship accuracy is exactly 100% (Theorem 1);
    # the semantic baseline must show false positives.
    assert res["pfcs"].prefetch_precision == 1.0
    assert res["semantic"].prefetch_precision < 1.0


def test_pfcs_graceful_degradation_without_relationships():
    tr = zipf_trace(n_keys=3000, n_accesses=6000)
    lru = simulate_baseline("lru", tr, CAPS)
    pfcs = simulate_pfcs(tr, CAPS)
    assert abs(pfcs.hit_rate - lru.hit_rate) < 0.02
    assert pfcs.prefetches_issued == 0


def test_fig2a_scaling_monotone():
    """PFCS advantage grows with relationship density (Fig. 2a)."""
    speedups = []
    for d in (0.1, 0.9):
        tr = graph_walk_trace(n_keys=3000, relationship_density=d,
                              n_accesses=8000)
        res = run_all_systems(tr, capacities=CAPS, systems=("lru", "pfcs"))
        row = derive_table1_row(res["pfcs"], res["lru"])
        speedups.append(row["speedup"])
    assert speedups[1] > speedups[0]


def test_latency_energy_models_positive():
    tr = db_join_trace(n_orders=500, n_customers=100, n_items=200,
                       n_queries=2000)
    s = simulate_pfcs(tr, CAPS)
    assert s.avg_latency_ns() > 0
    assert s.total_energy_nj() > 0
    assert 0 <= s.hit_rate <= 1
