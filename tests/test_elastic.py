"""Chaos fault-injection fuzz: elastic resharding + deterministic
shard-loss recovery (DESIGN.md §9).

The executable form of the paper's determinism guarantee under failure:
Theorem 1 (exact recovery of relationships by factorization) implies a
lost shard's discovery state is fully reconstructible from surviving
composites — so a serving run interrupted by kills, resizes, and
straggler evictions must end BIT-EXACT with an uninterrupted
scalar-oracle run.  Discipline (extends tests/test_serving_sharded.py):

  * the same abstract op stream (``strategies.build_kv_ops``) replays
    against the scalar oracle, the vectorized cache, and the elastic
    sharded cache; the elastic cache additionally absorbs a randomized
    fault schedule (``strategies.build_failure_schedule``) — kill with
    immediate or deferred recovery, live 2<->4 resizes, prime drops
    (drops are workload mutations and apply to every cache);
  * after every event and at the end: all ``PARITY_COUNTERS``, per-touch
    tiers, exact HBM LRU order, host set, and prefetch logs match the
    oracle; per-shard stats still aggregate to the global stats; the
    maintained slice index equals a from-scratch classification;
  * every recovery's rebuilt successor rows equal ``successor_table``
    recomputed from scratch on exactly those pages (the
    recovery-as-refactorization invariant);
  * composed with tenancy: the namespace isolation checker passes after
    EVERY op and every recovery;
  * fleet plumbing (``ElasticController`` + ``FleetState`` +
    ``StragglerMonitor`` + ``ElasticPlanner``) runs on an injectable
    ``ManualClock`` — no wall-clock reads anywhere in the test paths.
"""

import numpy as np
import pytest

from strategies import (ElasticEventSpec, KVWorkloadSpec, TenantMixSpec,
                        apply_elastic_event, apply_kv_ops,
                        build_failure_schedule, build_kv_ops,
                        build_tenant_requests, drive_tenants,
                        elastic_event_specs, given, kv_workload_specs,
                        settings, st)
from repro.core.engine import successor_table
from repro.core.engine.shard import PrimeSpacePartition
from repro.serving.elastic import ElasticController, ElasticShardedPagedKVCache
from repro.serving.kv_cache import PARITY_COUNTERS, PagedKVCache
from repro.serving.kv_cache_vec import VectorizedPagedKVCache
from repro.sharding.reshard import CROSS, LOST, ShardSlices
from repro.training.elastic import ManualClock


def _assert_state_parity(kv, oracle, name: str) -> None:
    for f in PARITY_COUNTERS:
        assert getattr(kv.stats, f) == getattr(oracle.stats, f), (name, f)
    assert list(kv.hbm.items()) == list(oracle.hbm.items()), name
    assert kv.host == oracle.host, name
    assert kv.prefetch_log == oracle.prefetch_log, name


def _assert_recovery_invariant(kv: ElasticShardedPagedKVCache) -> None:
    """The last recovery's rebuilt rows == successor_table from scratch
    on exactly those pages (recovery-as-refactorization, Theorem 1)."""
    if not kv.recovery_log or kv.dead_shards:
        return
    rep = kv.recovery_log[-1]
    fresh = successor_table(kv.registry, kv.assigner, rep.pages,
                            discover="host")
    for d in rep.pages:
        got = [int(x) for x in kv._succ[d, :kv._succ_len[d]]]
        assert got == fresh.get(d, []), (rep.shard, d)


def _chaos_differential(spec: KVWorkloadSpec, espec: ElasticEventSpec,
                        hbm: int, budget: int) -> None:
    """Replay one workload; the elastic cache absorbs the fault schedule
    while the oracle runs uninterrupted (sharing only workload-mutating
    drop events) — end state must be bit-exact."""
    ops = build_kv_ops(spec)
    schedule = build_failure_schedule(espec, len(ops))

    def elastic_event(kv, ev):
        apply_elastic_event(kv, ev)
        if ev[0] == "kill" and not ev[2]:
            _assert_recovery_invariant(kv)
            assert not kv.dead_shards
        if ev[0] == "resize":
            assert kv.n_shards == ev[1]
            assert len(kv.shard_stats) == ev[1]

    caches = {
        "scalar": PagedKVCache(hbm_pages=hbm, page_size=4,
                               prefetch_budget=budget),
        "vec": VectorizedPagedKVCache(hbm_pages=hbm, page_size=4,
                                      prefetch_budget=budget),
        "elastic": ElasticShardedPagedKVCache(hbm_pages=hbm, page_size=4,
                                              prefetch_budget=budget,
                                              n_shards=2),
    }
    tiers = {
        name: apply_kv_ops(kv, ops, schedule=schedule,
                           on_event=elastic_event if name == "elastic"
                           else None)
        for name, kv in caches.items()}
    oracle = caches["scalar"]
    for name in ("vec", "elastic"):
        kv = caches[name]
        assert tiers[name] == tiers["scalar"], name
        _assert_state_parity(kv, oracle, name)
        assert kv.stats.registry_scans == 0, name
    ekv = caches["elastic"]
    # drain any deferred kill, then the deep invariants
    ekv._sync_tables()
    _assert_recovery_invariant(ekv)
    assert not ekv.dead_shards
    assert (ekv.aggregate_shard_stats().parity_tuple()
            == ekv.stats.parity_tuple())
    ekv.slices.sync(ekv.registry)
    assert ekv.slices.verify(ekv.registry)


# --------------------------------------------------------------------------- #
# property-based chaos fuzz (hypothesis; clean SKIP without it)               #
# --------------------------------------------------------------------------- #

@given(spec=kv_workload_specs(), espec=elastic_event_specs(),
       hbm=st.sampled_from([1, 4, 16]),
       budget=st.integers(min_value=0, max_value=4))
@settings(max_examples=10, deadline=None)
def test_chaos_fuzz_property(spec, espec, hbm, budget):
    """Any workload x any kill/resize/drop schedule: the elastic cache
    ends bit-exact with the uninterrupted oracle."""
    _chaos_differential(spec, espec, hbm, budget)


# deterministic pinned cases: elastic edge paths stay covered even when
# hypothesis is not installed (tier-1 must not lose this coverage)
_PINNED = [
    # deferred-recovery kills: failover happens on the next touch
    (KVWorkloadSpec(seed=5, n_requests=10, n_touches=100),
     ElasticEventSpec(seed=1, n_events=4, resize=False, defer=True), 4, 2),
    # resize storm: repeated 2<->4 re-stripes mid-trace
    (KVWorkloadSpec(seed=7, n_requests=12, n_touches=120, sweeps=2),
     ElasticEventSpec(seed=2, n_events=6, kill=False), 8, 3),
    # kills + resizes + registry drops interleaved, 1-slot HBM
    (KVWorkloadSpec(seed=11, n_requests=9, n_touches=90, release=True),
     ElasticEventSpec(seed=3, n_events=5, drop=True), 1, 2),
    # registry drops only — the migrated registry-drop rebuild case
    (KVWorkloadSpec(seed=13, n_requests=10, n_touches=100),
     ElasticEventSpec(seed=4, n_events=4, kill=False, resize=False,
                      drop=True), 4, 2),
]


@pytest.mark.parametrize("spec,espec,hbm,budget", _PINNED,
                         ids=["kill-defer", "resize-storm", "kill+drop",
                              "drop-only"])
def test_chaos_fuzz_pinned(spec, espec, hbm, budget):
    _chaos_differential(spec, espec, hbm, budget)


# --------------------------------------------------------------------------- #
# recovery-as-refactorization invariants                                      #
# --------------------------------------------------------------------------- #

def _populated_elastic(n_shards=2, tokens_per_req=160, n_req=6,
                       **kw) -> ElasticShardedPagedKVCache:
    kv = ElasticShardedPagedKVCache(hbm_pages=16, page_size=4,
                                    prefetch_budget=2, n_shards=n_shards,
                                    **kw)
    rng = np.random.default_rng(17)
    shared = list(rng.integers(0, 4000, size=24))
    for r in range(n_req):
        tail = list(rng.integers(0, 4000, size=tokens_per_req))
        kv.register_request(r, shared[:int(rng.integers(0, 24))] + tail)
    kv.touch_batch([(0, j) for j in range(8)])
    return kv


def test_recovery_rebuilds_exactly_the_dead_shards_rows():
    """Kill each shard in turn: the rebuilt rows equal a from-scratch
    successor_table on the dead shard's pages, survivors' rows are
    untouched, and the full table equals the uninterrupted one."""
    kv = _populated_elastic()
    baseline = kv.successor_rows()
    for s in range(kv.n_shards):
        dead_pages = set(kv._owned_pages(s))
        assert dead_pages, f"shard {s} owns no pages at this scale"
        lost = kv.fail_shard(s)
        assert lost > 0
        assert s in kv.dead_shards
        # the dead shard's rows are gone, survivors' remain
        for d in dead_pages:
            assert kv._succ_len[d] == 0
        rep = kv.recover_shard(s)
        assert rep.shard == s and rep.mode == "partial"
        assert rep.refactorized == lost
        assert set(rep.pages) <= dead_pages
        _assert_recovery_invariant(kv)
        assert kv.successor_rows() == baseline
        assert kv.slices.verify(kv.registry)


def test_recovery_after_registry_mutation_refactorizes_everything():
    """A registry that mutated while the shard was dead invalidates ALL
    surviving classification: recovery must re-factorize the whole
    registry (mode="full") and still land on the from-scratch table."""
    kv = _populated_elastic()
    kv.fail_shard(0)
    kv.register_request(99, list(range(5000, 5080)))     # mutate mid-death
    kv.touch(99, 0)                                      # failover-on-demand
    assert not kv.dead_shards
    rep = kv.recovery_log[-1]
    assert rep.mode == "full"
    assert rep.refactorized == kv.registry.composites_array().size
    assert kv.slices.verify(kv.registry)
    vec = VectorizedPagedKVCache(hbm_pages=16, page_size=4,
                                 prefetch_budget=2)
    # independent from-scratch table over the same identity state
    fresh = successor_table(kv.registry, kv.assigner,
                            range(kv._next_page), discover="host")
    assert kv.successor_rows() == {d: r for d, r in fresh.items() if r}
    del vec


def test_fail_shard_validates_and_is_idempotent():
    kv = _populated_elastic()
    with pytest.raises(ValueError):
        kv.fail_shard(5)
    with pytest.raises(ValueError):
        kv.recover_shard(0)          # not dead
    kv.fail_shard(1)
    assert kv.fail_shard(1) == 0     # already dead: no-op
    kv.recover_shard(1)


# --------------------------------------------------------------------------- #
# reshard-plan laws (migrate only the moved blocks)                           #
# --------------------------------------------------------------------------- #

def test_reshard_plan_moves_exactly_the_changed_owners():
    kv = _populated_elastic(tokens_per_req=400)
    kv.slices.sync(kv.registry)
    before = np.array(kv.slices._owner, copy=True)
    plan = kv.resize(4)
    after = kv.slices._owner
    changed = set(int(p) for p in np.nonzero(before != after)[0])
    assert set(plan.moved) == changed
    assert plan.n_old == 2 and plan.n_new == 4
    assert plan.moved, "workload too small to move any block"
    # strictly below the naive full re-shuffle
    assert 0 < plan.migrated_bytes < plan.full_rebuild_bytes
    assert plan.migrated_bytes == 8 * len(plan.moved)
    # the maintained index matches a from-scratch classification at 4
    assert kv.slices.verify(kv.registry)


def test_resize_roundtrip_restores_ownership_and_keeps_rows():
    kv = _populated_elastic(tokens_per_req=400)
    kv.slices.sync(kv.registry)
    rows = kv.successor_rows()
    own2 = np.array(kv.slices._owner, copy=True)
    up = kv.resize(4)
    assert kv.n_shards == 4 and len(kv.shard_stats) == 4
    assert kv.successor_rows() == rows         # NO global rebuild
    down = kv.resize(2)
    assert kv.n_shards == 2
    assert np.array_equal(kv.slices._owner, own2)   # exact roundtrip
    assert set(down.moved) == set(up.moved)         # same blocks move back
    assert kv.successor_rows() == rows
    # accounting folded, aggregate invariant intact
    assert (kv.aggregate_shard_stats().parity_tuple()
            == kv.stats.parity_tuple())


def test_restripe_refuses_with_dead_shard():
    kv = _populated_elastic()
    kv.slices.sync(kv.registry)
    kv.slices.forget_shard(0)
    with pytest.raises(RuntimeError):
        kv.slices.restripe(PrimeSpacePartition(4))


def test_shard_slices_incremental_sync_modes():
    kv = _populated_elastic()
    sl = ShardSlices(kv.partition)
    assert sl.sync(kv.registry) == "append"          # first build
    assert sl.sync(kv.registry) == "noop"
    n = sl._owner.size
    kv.register_request(50, list(range(7000, 7040)))
    assert sl.sync(kv.registry) == "append"          # tail-only classify
    assert sl._owner.size > n
    kv.registry.drop_prime(int(kv.registry.primes_array()[0]))
    assert sl.sync(kv.registry) == "rebuild"         # in-place mutation
    assert sl.verify(kv.registry)
    # owner codes partition the index: every entry local or cross
    assert set(np.unique(sl._owner)) <= set(range(kv.n_shards)) | {CROSS}
    assert LOST not in sl._owner


# --------------------------------------------------------------------------- #
# fleet controller on an injectable clock                                     #
# --------------------------------------------------------------------------- #

def test_controller_heartbeat_expiry_recovers_and_resizes_down():
    clk = ManualClock()
    kv = _populated_elastic(n_shards=4)
    ctl = ElasticController(kv, clock=clk, heartbeat_timeout_s=10.0)
    clk.advance(5.0)
    ctl.heartbeat()                                  # all 4 alive at t=5
    assert ctl.tick() == []                          # nothing expired
    clk.advance(11.0)                                # t=16
    ctl.heartbeat(0)
    ctl.heartbeat(1)                                 # 2, 3 stay silent
    events = ctl.tick()
    kinds = [e["kind"] for e in events]
    assert kinds.count("recover") == 2 and kinds.count("resize") == 1
    for e in events:
        if e["kind"] == "recover":
            assert e["node"] in (2, 3)
            assert e["latency_s"] >= 0.0
            assert e["report"] is not None
    assert kv.n_shards == 2                          # planner: pow2(2) = 2
    assert not kv.dead_shards
    assert ctl.fleet.healthy_nodes == [0, 1]
    # a replacement node joins -> planner resizes back up is impossible
    # at 3 healthy (pow2(3) = 2); a 4th restores the full ladder
    ctl.join(2)
    assert ctl.tick() == []
    ctl.join(3)
    events = ctl.tick()
    assert [e["kind"] for e in events] == ["resize"]
    assert kv.n_shards == 4


def test_controller_straggler_eviction_uses_injected_clock():
    clk = ManualClock()
    kv = _populated_elastic(n_shards=4)
    ctl = ElasticController(kv, clock=clk, heartbeat_timeout_s=1e9,
                            straggler_threshold=1.5, evict_after=3)
    # nodes 0-2 step every 1s; node 3 every 4s — all measured through
    # monitor.tick() off the injected clock, never the wall clock
    for step in range(16):
        clk.advance(1.0)
        for n in (0, 1, 2):
            ctl.monitor.tick(n)
        if step % 4 == 3:
            ctl.monitor.tick(3)
        ctl.heartbeat()
        events = ctl.tick()
        if any(e["kind"] == "recover" for e in events):
            break
    else:
        pytest.fail("straggler never evicted")
    assert 3 not in ctl.fleet.healthy_nodes
    assert not kv.dead_shards                        # recovered in-tick
    assert kv.n_shards == 2                          # pow2(3 healthy) = 2


def test_engine_elastic_hooks_and_parity():
    """ServingEngine(kv="elastic"): resize + fail_shard mid-serve keep
    generated tokens and page counters identical to the scalar engine;
    the hooks reject non-elastic backends."""
    from repro.serving.engine import ServingEngine

    def workload(eng, elastic: bool):
        rng = np.random.default_rng(3)
        shared = list(rng.integers(0, 3000, size=48))
        for r in range(20):
            tail = list(rng.integers(0, 3000, size=int(rng.integers(8, 32))))
            eng.submit(shared[:int(rng.integers(0, 48))] + tail,
                       max_new_tokens=4)
        done = []
        step = 0
        while eng.queue or any(s is not None for s in eng.slots):
            if elastic and step == 2:
                eng.resize(4)
            if elastic and step == 4:
                rep = eng.fail_shard(1)
                assert rep is not None and rep.rows_rebuilt >= 0
            if elastic and step == 6:
                eng.fail_shard(0, recover=False)     # failover-on-demand
                eng.resize(2)                        # recovers first
            before = list(eng.slots)
            eng.step()
            done.extend(s for s in before
                        if s is not None and s.state == "done")
            step += 1
        return done

    engines = {kv: ServingEngine(None, None, max_batch=8, page_size=8,
                                 hbm_pages=24, kv=kv, reread_window=2,
                                 shards=2)
               for kv in ("elastic", "scalar")}
    done = {kv: workload(e, kv == "elastic") for kv, e in engines.items()}
    gen = {kv: [(r.req_id, tuple(r.generated)) for r in sorted(
        ds, key=lambda r: r.req_id)] for kv, ds in done.items()}
    assert gen["elastic"] == gen["scalar"]
    _assert_state_parity(engines["elastic"].pages, engines["scalar"].pages,
                         "engine")
    assert engines["elastic"].pages.recoveries >= 2
    assert engines["elastic"].pages.reshard_log
    with pytest.raises(ValueError):
        engines["scalar"].resize(4)
    with pytest.raises(ValueError):
        engines["scalar"].fail_shard(0)


# --------------------------------------------------------------------------- #
# composition with tenancy: isolation through every elastic event             #
# --------------------------------------------------------------------------- #

def _tenanted_chaos(spec: TenantMixSpec, espec: ElasticEventSpec,
                    hbm: int = 12) -> None:
    from repro.tenancy.qos import (TenantedElasticShardedPagedKVCache,
                                   TenantedPagedKVCache)

    ops = build_tenant_requests(spec)
    schedule = build_failure_schedule(espec, len(ops))
    oracle = TenantedPagedKVCache(hbm_pages=hbm, page_size=4,
                                  prefetch_budget=2, qos=spec.n_tenants)
    ekv = TenantedElasticShardedPagedKVCache(hbm_pages=hbm, page_size=4,
                                             prefetch_budget=2, n_shards=2,
                                             qos=spec.n_tenants)

    def elastic_event(kv, ev):
        apply_elastic_event(kv, ev)
        # the isolation checker after EVERY event — recovery and resize
        # must never move a page across a tenant boundary
        kv.namespace.assert_isolated(kv.registry)

    def step_hook(kv):
        kv.namespace.assert_isolated(kv.registry)

    t0 = drive_tenants(oracle, ops, schedule=schedule)
    t1 = drive_tenants(ekv, ops, step_hook=step_hook, schedule=schedule,
                       on_event=elastic_event)
    assert t0 == t1
    _assert_state_parity(ekv, oracle, "tenanted-elastic")
    for t in range(spec.n_tenants):
        a, b = oracle.qos.tenant_stats[t], ekv.qos.tenant_stats[t]
        for f in PARITY_COUNTERS:
            assert getattr(a, f) == getattr(b, f), (t, f)
    assert ekv.cross_tenant_prefetches() == 0
    ekv._sync_tables()
    assert not ekv.dead_shards


def test_tenancy_composition_chaos_pinned():
    _tenanted_chaos(
        TenantMixSpec(seed=9, n_tenants=2, n_requests=10, n_touches=100,
                      hot_tenant=True),
        ElasticEventSpec(seed=21, n_events=5, defer=True))
    _tenanted_chaos(
        TenantMixSpec(seed=23, n_tenants=4, n_requests=12, n_touches=80,
                      scanner_tenant=True, cross_prefix=True),
        ElasticEventSpec(seed=8, n_events=4, resize=True, drop=True))
