"""Differential fuzz of the multi-limb wide-composite path (ISSUE 8
tentpole, DESIGN.md §11): LimbComposite encode/decode and the limb
divisibility/factorize/gcd kernels against the exact Python-int oracle.

Every kernel assertion here is bit-exactness — the limb path must agree
with arbitrary-precision host arithmetic on every element, with zero
false positives (asserted by re-factorization, Theorem 1)."""

import math

import numpy as np
import pytest
from hypothesis_compat import given, settings, st
from strategies import (LimbUniverseSpec, build_limb_universe,
                        limb_universe_specs)

from repro.core.composite import (LIMB_BASE, LIMB_BITS, CompositeRegistry,
                                  LimbComposite, int_to_limbs, limbs_to_int,
                                  n_limbs_for_bits, pack_limbs, unpack_limbs)
from repro.kernels import (divisibility_scan_limbs, factorize_batch_exact,
                           factorize_batch_limbs, gcd_batch_exact,
                           gcd_batch_limbs)
from repro.kernels.ref import (divisibility_mask_limbs_ref,
                               factorize_limbs_ref, gcd_limbs_ref)

# widths covering 1 limb, a non-power-of-2 limb count, and deep chains
WIDTHS = (64, 96, 256, 1024)


# --------------------------------------------------------------------------- #
# encoding                                                                    #
# --------------------------------------------------------------------------- #

def test_limb_encoding_roundtrip_deterministic():
    vals = [0, 1, 2, LIMB_BASE - 1, LIMB_BASE, LIMB_BASE + 1,
            2**63 - 1, 2**63, 2**64, 2**200 + 12345, 2**1023]
    L = n_limbs_for_bits(1024)
    for v in vals:
        limbs = int_to_limbs(v, L)
        assert len(limbs) == L
        assert all(0 <= x < LIMB_BASE for x in limbs)
        assert limbs_to_int(limbs) == v
    arr = pack_limbs(vals, L)
    assert arr.shape == (len(vals), L) and arr.dtype == np.int64
    assert unpack_limbs(arr) == vals


def test_limb_composite_dataclass():
    c = LimbComposite.encode(2**100 + 7, n_limbs_for_bits(128))
    assert c.value == 2**100 + 7
    assert int(c) == 2**100 + 7
    assert c.n_limbs == 4
    with pytest.raises(OverflowError):
        LimbComposite.encode(2**64, 2)       # needs 3 limbs
    with pytest.raises(ValueError):
        int_to_limbs(-1, 4)


@given(st.integers(min_value=0, max_value=2**1024 - 1))
@settings(max_examples=200, deadline=None)
def test_limb_roundtrip_property(v):
    L = n_limbs_for_bits(max(1, v.bit_length()))
    assert limbs_to_int(int_to_limbs(v, L)) == v


# --------------------------------------------------------------------------- #
# kernels vs the Python-int oracle                                            #
# --------------------------------------------------------------------------- #

def _universe(seed, max_bits, **kw):
    spec = LimbUniverseSpec(seed=seed, max_bits=max_bits, **kw)
    return build_limb_universe(spec)


def _check_universe(pool, comps, max_bits):
    """One full differential pass: scan + factorize + gcd, kernel vs
    exact host arithmetic, plus the ref-oracle cross-check."""
    L = n_limbs_for_bits(max_bits)
    limbs = pack_limbs(comps, L)
    qs = pool[:: max(1, len(pool) // 64)]

    # §4.2 divisibility scan
    idx = divisibility_scan_limbs(limbs, qs)
    ref_mask = divisibility_mask_limbs_ref(limbs, np.asarray(qs))
    for j, q in enumerate(qs):
        want = [i for i, c in enumerate(comps) if c % q == 0]
        assert list(idx[j]) == want, (q, max_bits)
        assert list(np.nonzero(ref_mask[:, j])[0]) == want

    # Algorithm 2 factorize: mask + exact residual
    facs, residual = factorize_batch_limbs(limbs, pool)
    _, ref_res = factorize_limbs_ref(limbs, np.asarray(pool))
    for c, fs, r, rr in zip(comps, facs, residual, unpack_limbs(ref_res)):
        rem = c
        for p in fs:
            assert rem % p == 0, "false positive factor (Theorem 1)"
            rem //= p
        assert r == rem == rr
        # re-factorization: the recovered factors reproduce the composite
        prod = 1
        for p in fs:
            prod *= p
        assert prod * r == c

    # pairwise gcd via pool reconstruction
    a = comps
    b = comps[1:] + comps[:1]
    gs = gcd_batch_limbs(a, b, pool)
    ref_gs = unpack_limbs(gcd_limbs_ref(pack_limbs(a, L), pack_limbs(b, L)))
    for x, y, g, rg in zip(a, b, gs, ref_gs):
        assert g == math.gcd(x, y) == rg, (max_bits,)


@pytest.mark.parametrize("max_bits", WIDTHS)
def test_limb_kernels_match_oracle(max_bits):
    pool, comps = _universe(seed=max_bits, max_bits=max_bits)
    _check_universe(pool, comps, max_bits)


def test_limb_kernels_narrow_width_agrees_with_flat_path():
    """At values that fit int64, the exact dispatchers take the flat
    kernels — and the limb kernels must agree with them anyway."""
    pool, comps = _universe(seed=3, max_bits=62, big_primes=False,
                            max_factors=3)
    assert max(comps) < 2**63
    facs_e, res_e = factorize_batch_exact(comps, pool)
    facs_l, res_l = factorize_batch_limbs(comps, pool)
    assert facs_e == facs_l and [int(r) for r in res_e] == res_l
    b = comps[1:] + comps[:1]
    assert gcd_batch_exact(comps, b, pool) == \
        gcd_batch_limbs(comps, b, pool) == \
        [math.gcd(x, y) for x, y in zip(comps, b)]


def test_partial_pool_residual_is_exact():
    """A pool missing some member primes leaves the EXACT cofactor as
    residual — never a wrapped or truncated value."""
    known = [10007, 10009, 999_983]
    hidden = [1_000_003, 2**31 - 1]          # absent from the pool
    c = 1
    for p in known + hidden:
        c *= p
    facs, residual = factorize_batch_limbs([c], known)
    assert facs == [known]
    assert residual == [hidden[0] * hidden[1]]


@given(limb_universe_specs())
@settings(max_examples=25, deadline=None)
def test_limb_kernels_match_oracle_fuzz(spec):
    pool, comps = build_limb_universe(spec)
    _check_universe(pool, comps, spec.max_bits)


# --------------------------------------------------------------------------- #
# wide registry end to end                                                    #
# --------------------------------------------------------------------------- #

def test_wide_registry_scan_tables_match_host():
    """kernel successor tables over a wide registry == the host oracle's
    (the §4.2 scan routed through the limb kernels)."""
    from repro.core.assignment import PrimeAssigner
    from repro.core.engine.tables import successor_table
    from repro.core.primes import CacheLevel, HierarchicalPrimeAllocator

    reg = CompositeRegistry(max_bits=640)
    assigner = PrimeAssigner(HierarchicalPrimeAllocator(), reg)
    rng = np.random.default_rng(0)
    ids = list(range(60))
    for d in ids:
        assigner.assign(d, CacheLevel.MEM)   # primes >= 1e6: deep chains
    # one 19-deep group relationship (single wide chunk) + chain edges
    deep = [assigner.prime_of(d) for d in ids[:19]]
    reg.register(deep, kind="group")
    for a, b in zip(ids, ids[1:]):
        reg.register({assigner.prime_of(a), assigner.prime_of(b)},
                     kind="chain")
    host = successor_table(reg, assigner, ids, discover="host")
    kern = successor_table(reg, assigner, ids, discover="kernel")
    assert host == kern
    # sanity: the group relationship is one composite wider than int64
    assert any(c > 2**63 for c in reg.composites_list())
    with pytest.raises(OverflowError):
        reg.composites_array()


def test_wide_sharded_table_matches_host():
    """The collective gcd exchange (limb variant) produces the same
    successor rows as the single-device host table at 2 and 4 shards."""
    from repro.core.assignment import PrimeAssigner
    from repro.core.engine.shard import (PrimeSpacePartition,
                                         sharded_successor_table)
    from repro.core.engine.tables import successor_table
    from repro.core.primes import CacheLevel, HierarchicalPrimeAllocator

    reg = CompositeRegistry(max_bits=640)
    assigner = PrimeAssigner(HierarchicalPrimeAllocator(), reg)
    ids = list(range(40))
    for d in ids:
        assigner.assign(d, CacheLevel.MEM)
    deep = [assigner.prime_of(d) for d in ids[:15]]
    reg.register(deep, kind="group")
    for a, b in zip(ids, ids[1:]):
        reg.register({assigner.prime_of(a), assigner.prime_of(b)},
                     kind="chain")
    host = successor_table(reg, assigner, ids, discover="host")
    for n_shards in (2, 4):
        part = PrimeSpacePartition(n_shards)
        rows = sharded_successor_table(reg, assigner, ids, part, mesh=None)
        assert rows == host, f"{n_shards} shards"


def test_wide_serving_parity_all_backends():
    """kv="vec"|"sharded"|"elastic" at wide widths stay bit-exact with
    the narrow scalar oracle — chain placement is width-independent."""
    from repro.serving.engine import make_kv_backend

    def drive(kv, **kw):
        c = make_kv_backend(kv, hbm_pages=24, page_size=4,
                            prefetch_budget=4, **kw)
        rng = np.random.default_rng(1)
        for r in range(8):
            toks = [int(t) for t in
                    rng.integers(0, 40, size=rng.integers(8, 30))]
            if r % 2 == 0:
                toks[:8] = list(range(8))
            c.register_request(r, toks)
        items = []
        for _ in range(120):
            r = int(rng.integers(0, 8))
            n = len(c.chains.get(r, ()))
            if n:
                items.append((r, int(rng.integers(0, n))))
        tiers = c.touch_batch(items)
        return (c.stats.parity_tuple(), tiers, tuple(c.prefetch_log),
                c.shared_prefix(0, 2))

    base = drive("scalar")
    assert drive("scalar", max_bits=128) == base
    assert drive("vec", max_bits=128) == base
    assert drive("sharded", max_bits=128, mesh=None) == base
    assert drive("elastic", max_bits=1024, mesh=None) == base


def test_wide_tenancy_composes():
    from repro.serving.engine import make_kv_backend

    t = make_kv_backend("vec", hbm_pages=32, page_size=4,
                        prefetch_budget=4, tenants=2, max_bits=128)
    t.register_request(0, list(range(20)), tenant=0)
    t.register_request(1, list(range(20)), tenant=1)
    t.touch_batch([(0, 0), (1, 0), (0, 3), (1, 3)])
    assert t.cross_tenant_prefetches() == 0
    assert t.namespace.check_isolation(t.registry, pairwise_gcd=True).ok


def test_wide_shared_prefix_parity():
    """Scalar ``shared_prefix`` under a wide registry: deep chains whose
    chain composites exceed int64 (and any budgeted factorization) must
    still recover the exact shared page run — pool trial division over
    the chain's own primes is width-agnostic — and agree with the
    narrow scalar result and the vectorized batched-gcd twin."""
    from repro.serving.engine import make_kv_backend

    def drive(kv, max_bits):
        c = make_kv_backend(kv, hbm_pages=64, page_size=1,
                            prefetch_budget=0, max_bits=max_bits,
                            **({"mesh": None} if kv == "sharded" else {}))
        shared = list(range(40))                 # 40-page shared run
        c.register_request(0, shared + [100, 101])
        c.register_request(1, shared + [200])
        c.register_request(2, [300, 301, 302])   # disjoint control
        return c

    narrow = drive("scalar", 62)
    want = narrow.shared_prefix(0, 1)
    assert len(want) == 40                       # the whole shared run
    assert narrow.shared_prefix(0, 2) == []
    for kv in ("scalar", "vec", "sharded"):
        for max_bits in (128, 1024):
            c = drive(kv, max_bits)
            # the 40-prime chain composite genuinely exceeds int64
            comp = 1
            for pid in c.chains[0]:
                comp *= c.assigner.prime_of(pid)
            assert comp.bit_length() > 63
            assert c.shared_prefix(0, 1) == want, (kv, max_bits)
            assert c.shared_prefix(0, 2) == [], (kv, max_bits)
