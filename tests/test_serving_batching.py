"""Differential load-fuzz: the continuous-batching slot machine
(DESIGN.md §10).

The paper's determinism claims only matter under realistic ragged
traffic, so the slot machine is pinned the same way every other tier
is — an independent per-slot Python-loop oracle replays the IDENTICAL
open-loop arrival trace and must end bit-exact:

  * ``strategies.build_poisson_arrivals`` expands an ``ArrivalSpec``
    into concrete (arrival, prompt, max_new, tenant) tuples;
    ``drive_slots`` submits them into :class:`SlotMachine` (vectorized
    int32 slot arrays) and :class:`SlotOracle` (per-slot loops) and
    ticks both to idle;
  * parity surface: all ``PARITY_COUNTERS``, per-touch tier log, exact
    HBM LRU order, host set, prefetch log, per-request token streams
    and tick timings (TTFT/completion), preemption/resume counts — and
    the expert-cache counters when ``moe=`` composes;
  * cross-stack: the machine on the vectorized cache must also match
    the oracle on the SCALAR cache (engine parity composed with cache
    parity), and sharded/elastic backends replay the same traces;
  * invariants checked at every tick: no slot double-occupancy, slot
    ages monotone within a phase, drain guarantee (no starvation even
    under preemption thrash);
  * adversarial mixes: all-short, all-long, burst-then-silence, 1-slot
    engines, preemption pressure;
  * chaos composition: elastic ``kill``/``resize`` events
    (``strategies.build_failure_schedule``) injected mid-Poisson-load
    must be invisible to placement — bit-exact vs an uninterrupted
    oracle — with tenancy isolation proven after every tick.
"""

import numpy as np
import pytest

from strategies import (ArrivalSpec, ElasticEventSpec, arrival_specs,
                        build_failure_schedule, build_poisson_arrivals,
                        drive_slots, elastic_event_specs, given, settings,
                        st)
from repro.serving.expert_cache import EXPERT_PARITY_COUNTERS
from repro.serving.kv_cache import PARITY_COUNTERS
from repro.serving.slots import (PHASE_FREE, SlotMachine, SlotOracle,
                                 poisson_arrival_ticks)

# (max_batch, hbm_pages, prefetch_budget, reread_window, prefill_tokens,
#  preempt_wait) — includes the degenerate 1-slot engine and thrash-level
# preemption pressure
ENGINE_CONFIGS = [
    (4, 32, 2, 2, 12, 3),
    (1, 8, 1, 1, 4, 2),          # 1-slot engine, tiny HBM
    (8, 64, 4, 3, 32, None),     # no preemption
    (3, 16, 0, 2, 8, 1),         # LRU-mode (budget 0), aggressive preempt
]


def _mk(cls, cfg, **kw):
    b, hbm, budget, w, pf, pw = cfg
    base = dict(max_batch=b, page_size=4, hbm_pages=hbm,
                prefetch_budget=budget, reread_window=w,
                prefill_tokens=pf, preempt_wait=pw)
    base.update(kw)
    return cls(**base)


def _assert_parity(m, o, name):
    assert m.tier_log == o.tier_log, name
    for f in PARITY_COUNTERS:
        assert getattr(m.pages.stats, f) == getattr(o.pages.stats, f), \
            (name, f)
    assert list(m.pages.hbm.items()) == list(o.pages.hbm.items()), name
    assert m.pages.host == o.pages.host, name
    assert m.pages.prefetch_log == o.pages.prefetch_log, name
    assert (m.ticks, m.preemptions, m.resumes) \
        == (o.ticks, o.preemptions, o.resumes), name
    assert len(m.requests) == len(o.requests)
    for rm, ro in zip(m.requests, o.requests):
        assert rm.state == ro.state == "done", (name, rm.req_id)
        assert rm.generated == ro.generated, (name, rm.req_id)
        assert (rm.first_tick, rm.done_tick, rm.preemptions, rm.ttft(),
                rm.tpot()) == (ro.first_tick, ro.done_tick, ro.preemptions,
                               ro.ttft(), ro.tpot()), (name, rm.req_id)
    if m.experts is not None:
        for f in EXPERT_PARITY_COUNTERS:
            assert getattr(m.experts.stats, f) \
                == getattr(o.experts.stats, f), (name, f)
        assert m.experts.prefetch_log == o.experts.prefetch_log, name


def _run_pair(spec, cfg, mkv="vec", okv="vec", policy="continuous",
              moe_pair=(None, None), tenants=None, name=""):
    arrivals = build_poisson_arrivals(spec)
    m = _mk(SlotMachine, cfg, kv=mkv, policy=policy, moe=moe_pair[0],
            tenants=tenants, moe_experts=16, moe_slots=6, moe_groups=8)
    o = _mk(SlotOracle, cfg, kv=okv, policy=policy, moe=moe_pair[1],
            tenants=tenants, moe_experts=16, moe_slots=6, moe_groups=8)
    drive_slots(m, arrivals)
    drive_slots(o, arrivals)
    _assert_parity(m, o, name or f"{mkv}-vs-{okv}")
    return m, o


# --------------------------------------------------------------------------- #
# differential parity: machine == oracle, across backends and policies        #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("cfg", ENGINE_CONFIGS)
@pytest.mark.parametrize("seed", [0, 7])
def test_machine_matches_oracle_pinned(cfg, seed):
    spec = ArrivalSpec(seed=seed, n_requests=22, rate=1.5, burst_frac=0.25,
                       silence_ticks=4, max_prompt=20, max_new=9)
    _run_pair(spec, cfg, mkv="vec", okv="vec")


@pytest.mark.parametrize("mkv,okv", [
    ("vec", "scalar"),           # engine parity composed with cache parity
    ("sharded", "scalar"),
    ("elastic", "vec"),
])
def test_machine_matches_oracle_cross_stack(mkv, okv):
    spec = ArrivalSpec(seed=3, n_requests=20, rate=2.0, max_prompt=24,
                       max_new=8)
    _run_pair(spec, ENGINE_CONFIGS[0], mkv=mkv, okv=okv)


def test_lockstep_policy_parity_and_moe_tenancy_composition():
    cfg = (4, 32, 2, 2, 12, None)
    _run_pair(ArrivalSpec(seed=11, n_requests=18, rate=1.0, max_prompt=16,
                          max_new=7),
              cfg, policy="lockstep", name="lockstep")
    _run_pair(ArrivalSpec(seed=11, n_requests=18, rate=1.0, max_prompt=16,
                          max_new=7, n_tenants=2),
              cfg, moe_pair=("vec", "scalar"), tenants=2,
              name="moe+tenants")


@given(spec=arrival_specs(), cfg=st.sampled_from(ENGINE_CONFIGS),
       okv=st.sampled_from(["vec", "scalar"]))
@settings(max_examples=10, deadline=None)
def test_machine_matches_oracle_fuzz(spec, cfg, okv):
    tenants = spec.n_tenants if spec.n_tenants > 1 else None
    _run_pair(spec, cfg, mkv="vec", okv=okv, tenants=tenants,
              name=f"fuzz-{okv}")


@pytest.mark.parametrize("spec,label", [
    (ArrivalSpec(seed=1, n_requests=24, rate=4.0, min_prompt=1,
                 max_prompt=5, max_new=4), "all-short"),
    (ArrivalSpec(seed=2, n_requests=8, rate=0.4, min_prompt=40,
                 max_prompt=90, max_new=12), "all-long"),
    (ArrivalSpec(seed=3, n_requests=20, rate=2.0, burst_frac=1.0,
                 silence_ticks=0, max_new=10), "burst"),
    (ArrivalSpec(seed=4, n_requests=16, rate=3.0, burst_frac=0.5,
                 silence_ticks=20, max_new=8), "burst-then-silence"),
])
def test_adversarial_mixes(spec, label):
    _run_pair(spec, ENGINE_CONFIGS[0], name=label)
    _run_pair(spec, ENGINE_CONFIGS[1], name=f"{label}-1slot")


# --------------------------------------------------------------------------- #
# invariants: occupancy, ages, drain                                          #
# --------------------------------------------------------------------------- #

def test_slot_invariants_every_tick():
    """No double occupancy; ages monotone within a (slot, request,
    phase) span; slot_req <-> phase consistency."""
    spec = ArrivalSpec(seed=9, n_requests=26, rate=2.5, burst_frac=0.4,
                       max_prompt=22, max_new=9)
    m = _mk(SlotMachine, ENGINE_CONFIGS[0], kv="vec")
    prev = {}

    def hook(eng):
        occ = eng.phase != PHASE_FREE
        rids = eng.slot_req[occ]
        assert (eng.slot_req[~occ] == -1).all()
        assert (rids >= 0).all()
        assert len(set(rids.tolist())) == len(rids), "double occupancy"
        for i in np.flatnonzero(occ):
            i = int(i)
            key = (int(eng.slot_req[i]), int(eng.phase[i]))
            if prev.get(i) == key:
                assert eng.age[i] == prev[f"age{i}"] + 1, \
                    "age not monotone within phase"
            else:
                assert eng.age[i] == 0, "fresh phase must reset age"
            prev[i] = key
            prev[f"age{i}"] = int(eng.age[i])
        for i in np.flatnonzero(~occ):
            prev.pop(int(i), None)

    drive_slots(m, build_poisson_arrivals(spec), step_hook=hook)
    assert all(r.state == "done" for r in m.requests)


def test_drain_guarantee_under_preemption_thrash():
    """Heavy overload + aggressive preemption still completes every
    request (FIFO re-queue means no starvation) — and the report sees
    the preemptions."""
    spec = ArrivalSpec(seed=5, n_requests=40, rate=8.0, burst_frac=1.0,
                       max_prompt=12, max_new=14)
    m = _mk(SlotMachine, (2, 8, 2, 2, 8, 1), kv="vec")
    drive_slots(m, build_poisson_arrivals(spec))
    rep = m.latency_report()
    assert rep["completed"] == 40
    assert rep["preemptions"] > 0
    assert rep["tokens"] == sum(len(r.generated) for r in m.requests)


def test_resume_prefetch_recovers_window_before_decode():
    """The resume-prefetch invariant: a preempted request's re-admission
    anchor touch factorization-recovers its successor pages, so its
    first decode tick back hits prefetched pages instead of missing."""
    m = SlotMachine(max_batch=1, page_size=2, hbm_pages=64,
                    prefetch_budget=4, reread_window=2, prefill_tokens=32,
                    preempt_wait=1, kv="vec")
    m.submit(list(range(100, 116)), max_new_tokens=30, arrival=0)
    m.submit(list(range(200, 208)), max_new_tokens=2, arrival=2)
    m.run_until_idle()
    assert m.preemptions >= 1 and m.resumes >= 1
    assert m.pages.stats.prefetch_hits > 0
    # the anchor's §4.2 scan produced real prefetch traffic
    assert m.pages.prefetch_log
    o = SlotOracle(max_batch=1, page_size=2, hbm_pages=64,
                   prefetch_budget=4, reread_window=2, prefill_tokens=32,
                   preempt_wait=1, kv="vec")
    o.submit(list(range(100, 116)), max_new_tokens=30, arrival=0)
    o.submit(list(range(200, 208)), max_new_tokens=2, arrival=2)
    o.run_until_idle()
    _assert_parity(m, o, "resume")


def test_continuous_beats_lockstep_on_ragged_demand():
    """The scheduling claim itself: same trace, same cost model —
    continuous admission drains in fewer ticks (higher goodput) than
    the gang-scheduled lockstep gate."""
    spec = ArrivalSpec(seed=13, n_requests=30, rate=2.0, max_prompt=16,
                       max_new=20)
    arrivals = build_poisson_arrivals(spec)
    cont = _mk(SlotMachine, (4, 64, 2, 2, 16, None), kv="vec")
    lock = _mk(SlotMachine, (4, 64, 2, 2, 16, None), kv="vec",
               policy="lockstep")
    drive_slots(cont, arrivals)
    drive_slots(lock, arrivals)
    rc, rl = cont.latency_report(), lock.latency_report()
    assert rc["tokens"] == rl["tokens"]
    assert rc["goodput_tok_per_tick"] > rl["goodput_tok_per_tick"]
    assert rc["ttft_ticks"][99] <= rl["ttft_ticks"][99]


# --------------------------------------------------------------------------- #
# chaos composition: elastic events + tenancy mid-Poisson-load                #
# --------------------------------------------------------------------------- #

def _chaos_pair(spec, espec, tenants=None, n_ticks_hint=200):
    arrivals = build_poisson_arrivals(spec)
    schedule = build_failure_schedule(espec, n_ticks_hint)
    m = _mk(SlotMachine, ENGINE_CONFIGS[0], kv="elastic", tenants=tenants)
    o = _mk(SlotOracle, ENGINE_CONFIGS[0], kv="vec", tenants=tenants)
    hooks = []
    if tenants is not None:
        hooks.append(lambda eng: eng.pages.namespace.assert_isolated(
            eng.pages.registry))
    hook = (lambda eng: [h(eng) for h in hooks]) if hooks else None
    # the oracle replays the SAME schedule: kill/resize no-op on its
    # non-elastic cache (events must be invisible to placement), drop
    # events mutate the workload identically on both
    drive_slots(m, arrivals, schedule=schedule, step_hook=hook)
    drive_slots(o, arrivals, schedule=schedule, step_hook=hook)
    _assert_parity(m, o, "chaos")
    return m


@pytest.mark.parametrize("eseed", [0, 4])
def test_elastic_chaos_mid_load_bit_exact(eseed):
    spec = ArrivalSpec(seed=21, n_requests=24, rate=1.2, burst_frac=0.3,
                       max_prompt=20, max_new=10)
    espec = ElasticEventSpec(seed=eseed, n_events=5, kill=True, defer=True,
                             resize=True, drop=True)
    m = _chaos_pair(spec, espec)
    assert m.pages.n_shards in (2, 4)


@given(spec=arrival_specs(), espec=elastic_event_specs())
@settings(max_examples=6, deadline=None)
def test_elastic_chaos_fuzz(spec, espec):
    _chaos_pair(spec, espec,
                tenants=spec.n_tenants if spec.n_tenants > 1 else None)


def test_chaos_with_tenancy_isolation_every_tick():
    spec = ArrivalSpec(seed=31, n_requests=20, rate=1.5, n_tenants=2,
                       max_prompt=18, max_new=8)
    espec = ElasticEventSpec(seed=2, n_events=4, kill=True, resize=True)
    m = _chaos_pair(spec, espec, tenants=2)
    for t in range(2):
        assert m.pages.qos.tenant_stats[t].prefetches == len(
            m.pages.qos.tenant_logs[t])


# --------------------------------------------------------------------------- #
# arrival-trace builder + API edges                                           #
# --------------------------------------------------------------------------- #

def test_poisson_arrival_ticks_shapes():
    t = poisson_arrival_ticks(50, rate=2.0, seed=1)
    assert len(t) == 50 and (np.diff(t) >= 0).all() and (t >= 0).all()
    b = poisson_arrival_ticks(40, rate=2.0, seed=1, burst_frac=0.5,
                              silence_ticks=10)
    assert (b[:20] == 0).all() and b[20:].min() >= 10
    assert len(poisson_arrival_ticks(0, rate=1.0)) == 0


def test_slot_api_edges():
    with pytest.raises(ValueError):
        SlotMachine(policy="nope")
    with pytest.raises(ValueError):
        SlotMachine(max_batch=0)
    m = SlotMachine(max_batch=2, kv="vec")
    with pytest.raises(ValueError):
        m.submit([1, 2], tenant=1)          # tenants mode not enabled
    with pytest.raises(ValueError):
        m.resize(4)                          # needs kv="elastic"
    mt = SlotMachine(max_batch=2, kv="vec", tenants=2)
    with pytest.raises(ValueError):
        mt.submit([1, 2], tenant=5)
    # empty prompt goes straight to decode and still completes
    m.submit([], max_new_tokens=3)
    done = m.run_until_idle()
    assert len(done) == 1 and len(done[0].generated) == 3
    # drain guard trips instead of hanging
    m.submit([1, 2, 3], max_new_tokens=4)
    with pytest.raises(RuntimeError):
        m.run_until_idle(max_ticks=1)
