"""The BENCH trajectory regression gate (tools/check_bench_regression.py).

The checked-in ``BENCH_*.json`` files are the performance trajectory;
the gate is what makes them enforceable in CI (snapshot baselines ->
re-run ``--smoke`` -> compare).  Covered paths: pass (exact and
within-tolerance), numeric regression, missing metric, missing case
file, new case / new metric (note, not failure), time-derived metric
exemption, and the flattening of nested payloads."""

import json
import sys
from pathlib import Path

import pytest

TOOLS = Path(__file__).resolve().parents[1] / "tools"
sys.path.insert(0, str(TOOLS))

from check_bench_regression import (flatten, is_time_derived, main,
                                    run_gate)


def _write(d: Path, name: str, payload: dict) -> None:
    (d / name).write_text(json.dumps(payload))


@pytest.fixture()
def dirs(tmp_path):
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    base.mkdir()
    fresh.mkdir()
    return base, fresh


PAYLOAD = {
    "slot_vec": {"goodput_tok_per_tick": 28.5, "ticks": 250,
                 "ttft_ticks": {"50": 120.0, "99": 241.0},
                 "wall_s": 1.93},
    "lockstep": {"goodput_tok_per_tick": 18.4, "ticks": 388},
}


def test_gate_passes_on_identical_files(dirs, capsys):
    base, fresh = dirs
    _write(base, "BENCH_case_batching.json", PAYLOAD)
    _write(fresh, "BENCH_case_batching.json", PAYLOAD)
    assert run_gate(base, fresh) == 0
    assert "OK" in capsys.readouterr().out


def test_gate_ignores_time_derived_drift(dirs):
    base, fresh = dirs
    _write(base, "BENCH_case_batching.json", PAYLOAD)
    noisy = json.loads(json.dumps(PAYLOAD))
    noisy["slot_vec"]["wall_s"] = 97.0          # machine-load noise
    _write(fresh, "BENCH_case_batching.json", noisy)
    assert run_gate(base, fresh) == 0


def test_gate_fails_on_numeric_regression(dirs, capsys):
    base, fresh = dirs
    _write(base, "BENCH_case_batching.json", PAYLOAD)
    worse = json.loads(json.dumps(PAYLOAD))
    worse["slot_vec"]["goodput_tok_per_tick"] = 20.0
    _write(fresh, "BENCH_case_batching.json", worse)
    assert run_gate(base, fresh) == 1
    out = capsys.readouterr().out
    assert "goodput_tok_per_tick" in out and "FAIL" in out
    # ... but passes inside an explicit tolerance band
    assert run_gate(base, fresh, rel_tol=0.5) == 0


def test_gate_fails_on_missing_metric_and_missing_case(dirs, capsys):
    base, fresh = dirs
    _write(base, "BENCH_case_batching.json", PAYLOAD)
    _write(base, "BENCH_case_serving.json", {"tok": 1})
    dropped = json.loads(json.dumps(PAYLOAD))
    del dropped["lockstep"]["ticks"]
    _write(fresh, "BENCH_case_batching.json", dropped)
    # no fresh BENCH_case_serving.json at all
    assert run_gate(base, fresh) == 1
    out = capsys.readouterr().out
    assert "missing from fresh" in out
    assert "produced no file" in out


def test_gate_notes_new_case_and_new_metric_without_failing(dirs, capsys):
    base, fresh = dirs
    _write(base, "BENCH_case_batching.json", PAYLOAD)
    extra = json.loads(json.dumps(PAYLOAD))
    extra["slot_vec"]["resumes"] = 3            # new metric
    _write(fresh, "BENCH_case_batching.json", extra)
    _write(fresh, "BENCH_case_new.json", {"x": 1})   # new case
    assert run_gate(base, fresh) == 0
    out = capsys.readouterr().out
    assert "new metric 'slot_vec.resumes'" in out
    assert "new case" in out


def test_gate_fails_on_type_change_and_non_numeric_drift(dirs):
    base, fresh = dirs
    _write(base, "BENCH_x.json", {"mode": "partial", "n": 2})
    _write(fresh, "BENCH_x.json", {"mode": "full", "n": "2"})
    assert run_gate(base, fresh) == 1


def test_gate_empty_baseline_is_noop(dirs):
    base, fresh = dirs
    _write(fresh, "BENCH_case_batching.json", PAYLOAD)
    assert run_gate(base, fresh) == 0


def test_flatten_and_time_markers():
    flat = flatten({"a": {"b": [1, {"c": 2}]}, "d": True})
    assert flat == {"a.b.0": 1, "a.b.1.c": 2, "d": True}
    assert is_time_derived("slot_vec.wall_s")
    assert is_time_derived("pfcs_vec.tok_per_s")
    assert is_time_derived("recovery_latency_mean_s")
    assert is_time_derived("vec_vs_scalar_speedup")
    assert not is_time_derived("slot_vec.ttft_ticks.99")
    assert not is_time_derived("hbm_hit_rate")
    assert not is_time_derived("migrated_bytes")
    # the observability block is exempt wholesale — its launch ledger
    # (calls/items included) is reporting, not a gated contract
    assert is_time_derived("obs.kernel_launches.gcd_batch.calls")
    assert is_time_derived("obs.registry_build.n")
    assert not is_time_derived("jobs.0.n")      # only the exact component


def test_gate_ignores_obs_block_drift(dirs):
    base, fresh = dirs
    withobs = json.loads(json.dumps(PAYLOAD))
    withobs["obs"] = {"kernel_launches": {
        "divisibility_scan": {"calls": 4, "items": 1024, "wall_s": 0.5}}}
    _write(base, "BENCH_case_batching.json", withobs)
    drifted = json.loads(json.dumps(withobs))
    drifted["obs"]["kernel_launches"]["divisibility_scan"] = {
        "calls": 9, "items": 4096, "wall_s": 12.0}
    _write(fresh, "BENCH_case_batching.json", drifted)
    assert run_gate(base, fresh) == 0


def test_cli_entry(dirs, capsys):
    base, fresh = dirs
    _write(base, "BENCH_case_batching.json", PAYLOAD)
    _write(fresh, "BENCH_case_batching.json", PAYLOAD)
    assert main(["--baseline", str(base), "--fresh", str(fresh)]) == 0
    assert main(["--baseline", str(base), "--fresh", str(base),
                 "--rel-tol", "0.01"]) == 0


def test_gate_against_checked_in_trajectory():
    """The real checked-in BENCH files always gate cleanly against
    themselves (guards the tool against schema drift in the payloads
    the cases actually emit)."""
    root = Path(__file__).resolve().parents[1]
    if not list(root.glob("BENCH_*.json")):     # pragma: no cover
        pytest.skip("no checked-in BENCH files")
    assert run_gate(root, root) == 0
