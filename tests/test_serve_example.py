"""Smoke tests for the serving launcher + example (ISSUE 8 satellite:
``examples/serve_lm.py`` must drive the SlotMachine front-end by
default and can't silently rot again)."""

import ast
import pathlib

from repro.launch.serve import main as serve_main

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_launcher_defaults_to_slot_machine():
    out = serve_main(["--null-model", "--requests", "24", "--max-new", "4",
                      "--max-batch", "8", "--shared-prefix", "16"])
    assert out["front_end"] == "slots"
    assert out["completed"] == 24
    assert out["decode_tokens"] == 24 * 4
    assert out["ticks"] > 0
    # the whole point of the PFCS cache: prefix sharing + prefetch fire
    assert out["shared_prefix_pages"] > 0
    assert out["prefetches"] > 0


def test_launcher_engine_front_end_still_available():
    out = serve_main(["--null-model", "--front-end", "engine",
                      "--requests", "8", "--max-new", "4",
                      "--max-batch", "4", "--shared-prefix", "16"])
    assert out["front_end"] == "engine"
    assert out["completed"] == 8


def test_launcher_slots_wide_registry():
    # --max-bits > 63: the SlotMachine composes with the multi-limb
    # wide registry (DESIGN.md §11) — same counters as narrow
    narrow = serve_main(["--null-model", "--requests", "16",
                         "--max-new", "4", "--max-batch", "8",
                         "--shared-prefix", "16"])
    wide = serve_main(["--null-model", "--requests", "16",
                       "--max-new", "4", "--max-batch", "8",
                       "--shared-prefix", "16", "--max-bits", "128"])
    for k in ("completed", "decode_tokens", "ticks", "hbm_hit_rate",
              "prefetches", "prefetch_hits", "shared_prefix_pages"):
        assert narrow[k] == wide[k], k


def test_example_script_drives_the_launcher():
    """The example must keep routing through ``launch.serve.main`` (so
    the launcher smoke tests above cover it) and must not pin
    ``--front-end engine`` on its load-generator pass."""
    src = (ROOT / "examples" / "serve_lm.py").read_text()
    tree = ast.parse(src)        # it parses
    assert "serve_main" in src
    null_model_calls = [n for n in ast.walk(tree)
                        if isinstance(n, ast.Call)
                        and any(isinstance(a, ast.List) and any(
                            isinstance(e, ast.Constant)
                            and e.value == "--null-model"
                            for e in a.elts) for a in n.args)]
    assert null_model_calls, "example lost its load-generator pass"
    for call in null_model_calls:
        flags = [e.value for a in call.args if isinstance(a, ast.List)
                 for e in a.elts if isinstance(e, ast.Constant)]
        assert "--front-end" not in flags, \
            "load-generator pass must use the default (slots) front-end"
