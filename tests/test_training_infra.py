"""Training substrate: optimizers, checkpoint atomicity/restore, gradient
compression, elastic planning, data determinism."""

import os
import shutil
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.pipeline import ByteTokenizer, ShardedLoader, SyntheticCorpus
from repro.training.checkpoint import CheckpointManager
from repro.training.compression import (compress_tree, decompress_tree,
                                        init_residuals, roundtrip_error)
from repro.training.elastic import (ElasticPlanner, FleetState,
                                    ManualClock, StragglerMonitor)
from repro.training.optimizer import (adafactor, adamw, clip_by_global_norm,
                                      cosine_schedule, sgdm)


# --------------------------------------------------------------------------- #
# optimizers                                                                  #
# --------------------------------------------------------------------------- #

def _quad_problem(opt, steps=300, lr=0.05):
    """Minimize ||x - t||^2; any reasonable optimizer converges."""
    t = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)),
                    jnp.float32)
    params = {"w": jnp.zeros((4, 8), jnp.float32)}
    state = opt.init(params)
    for _ in range(steps):
        g = {"w": 2 * (params["w"] - t)}
        params, state = opt.update(g, params, state, jnp.asarray(lr))
    return float(jnp.mean((params["w"] - t) ** 2))


def test_adamw_converges():
    assert _quad_problem(adamw(weight_decay=0.0)) < 1e-3


def test_adafactor_converges():
    assert _quad_problem(adafactor()) < 1e-2


def test_sgdm_converges():
    assert _quad_problem(sgdm(), lr=0.01) < 1e-3


def test_adamw_first_step_is_lr_sized():
    opt = adamw(weight_decay=0.0)
    p = {"w": jnp.ones((3,), jnp.float32)}
    s = opt.init(p)
    g = {"w": jnp.asarray([1.0, -1.0, 0.5])}
    p2, _ = opt.update(g, p, s, jnp.asarray(0.1))
    # bias-corrected first Adam step = lr * sign(g)
    np.testing.assert_allclose(np.asarray(p["w"] - p2["w"]),
                               [0.1, -0.1, 0.1], rtol=1e-4)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.asarray(100))) < 1e-6


# --------------------------------------------------------------------------- #
# checkpointing                                                               #
# --------------------------------------------------------------------------- #

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"layer": {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
                      "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(5, tree)
    restored = mgr.restore(jax.tree.map(lambda x: x, tree), step=5,
                           verify=True)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.latest_step() == 4
    assert mgr.all_steps() == [3, 4]        # pruned to keep_last


def test_checkpoint_ignores_partial_tmp(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    # simulate a crash mid-write: orphan .tmp directory
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000002.tmp" / "garbage.npy").write_bytes(b"xx")
    assert mgr.latest_step() == 1           # partial write invisible


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(9, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 9


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    bad = {"layer": {"w": jnp.zeros((9, 4)), "b": jnp.zeros((4,))},
           "step": jnp.asarray(0)}
    with pytest.raises(ValueError):
        mgr.restore(bad, step=1)


# --------------------------------------------------------------------------- #
# gradient compression                                                        #
# --------------------------------------------------------------------------- #

def test_compression_roundtrip_error_small():
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    res = init_residuals(grads)
    err = roundtrip_error(grads, res)
    assert err < 0.01                        # int8: <1% L2 error per step


def test_error_feedback_accumulates():
    """Residual carries quantization error: sum of dequantized updates
    converges to the true gradient sum."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
    grads = {"w": g}
    res = init_residuals(grads)
    total = jnp.zeros_like(g)
    for _ in range(50):
        qt, res = compress_tree(grads, res)
        total = total + decompress_tree(qt)["w"]
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                               rtol=0, atol=0.02)


# --------------------------------------------------------------------------- #
# elastic / stragglers                                                        #
# --------------------------------------------------------------------------- #

def test_fleet_heartbeats_and_sweep():
    fs = FleetState(n_nodes=8, heartbeat_timeout_s=10.0)
    for n in range(8):
        fs.heartbeat(n, t=100.0)
    fs.heartbeat(3, t=150.0)
    newly = fs.sweep(now=115.0)
    assert set(newly) == {0, 1, 2, 4, 5, 6, 7}
    assert fs.healthy_nodes == [3]


def test_elastic_planner_shrinks_preserving_model_axis():
    pl = ElasticPlanner(model_axis=16, base_data_axis=16, base_pods=2,
                        global_batch=256)
    full = pl.plan(512)
    assert full.mesh_shape == (2, 16, 16) and full.accum_steps == 1
    # lose a pod's worth of chips
    half = pl.plan(300)
    assert np.prod(half.mesh_shape) <= 300
    assert half.mesh_shape[-1] == 16
    assert half.accum_steps >= 2            # global batch preserved
    with pytest.raises(RuntimeError):
        pl.plan(8)


def test_straggler_eviction():
    mon = StragglerMonitor(threshold=1.5, window=10, evict_after=3)
    evicted = []
    for step in range(6):
        for n in range(4):
            mon.record(n, 1.0 if n != 2 else 3.0)
        slow, ev = mon.check()
        evicted.extend(ev)
    assert 2 in evicted


def test_fleet_injected_clock_heartbeat_expiry_edges():
    """No wall-clock reads: FleetState on a ManualClock, exercising the
    exact boundary — a node silent for exactly timeout_s is still
    healthy; one instant past, it expires."""
    clk = ManualClock(t=100.0)
    fs = FleetState(n_nodes=3, heartbeat_timeout_s=10.0, clock=clk)
    for n in range(3):
        fs.heartbeat(n)                       # timestamps from the clock
    clk.advance(10.0)
    fs.heartbeat(0)
    assert fs.sweep() == []                   # now - t == timeout: alive
    clk.advance(0.5)
    assert set(fs.sweep()) == {1, 2}          # strictly past: expired
    assert fs.healthy_nodes == [0]
    # heartbeats from failed nodes are ignored until they rejoin
    fs.heartbeat(1)
    assert fs.healthy_nodes == [0]
    fs.join(1)
    assert fs.healthy_nodes == [0, 1]
    clk.advance(10.5)
    fs.heartbeat(0)                           # 0 stays chatty
    assert fs.sweep() == [1]                  # stale join expires again too
    # join can also grow the fleet past its original size
    fs.join(5)
    assert fs.n_nodes == 6 and 5 in fs.healthy_nodes


def test_straggler_tick_measures_injected_clock_and_evict_after_edge():
    """tick() derives step times purely from the injected clock, and a
    node is evicted on exactly the ``evict_after``-th consecutive slow
    check — not one earlier, with the strike count reset by a fast
    window."""
    clk = ManualClock()
    mon = StragglerMonitor(threshold=1.5, window=8, evict_after=3,
                           clock=clk)
    assert mon.tick(0) is None                # first tick: no interval yet
    clk.advance(2.0)
    assert mon.tick(0) == 2.0
    # nodes 0-2 step 1s, node 3 steps 4s; strikes accrue once 3 has data
    mon2 = StragglerMonitor(threshold=1.5, window=8, evict_after=3,
                            clock=clk)
    checks_while_slow = 0
    for step in range(12):
        clk.advance(1.0)
        for n in (0, 1, 2):
            mon2.tick(n)
        if step % 4 == 3:
            mon2.tick(3)
        slow, evict = mon2.check()
        if 3 in slow:
            checks_while_slow += 1
            if checks_while_slow < 3:
                assert 3 not in evict         # edge: not before the 3rd
            else:
                assert 3 in evict
                break
    else:
        pytest.fail("straggler never evicted")
    # a fast window resets the strike counter
    mon3 = StragglerMonitor(threshold=1.5, window=8, evict_after=2)
    for n in range(3):
        mon3.record(n, 1.0)
    mon3.record(3, 4.0)
    assert mon3.check() == ([3], [])          # strike 1
    mon3._times[3].clear()
    mon3.record(3, 1.0)                       # back to fleet speed
    assert mon3.check() == ([], [])           # reset
    mon3.record(3, 4.0)
    assert mon3.check() == ([3], [])          # strike 1 again, not 2


# --------------------------------------------------------------------------- #
# data pipeline                                                               #
# --------------------------------------------------------------------------- #

def test_loader_deterministic_restart():
    corpus = SyntheticCorpus()
    l1 = ShardedLoader(corpus, global_batch=8, seq_len=32)
    l2 = ShardedLoader(corpus, global_batch=8, seq_len=32)
    b1 = l1.batch_at(17)
    b2 = l2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_loader_shards_disjoint_streams():
    corpus = SyntheticCorpus()
    a = ShardedLoader(corpus, 8, 32, shard_index=0, shard_count=2)
    b = ShardedLoader(corpus, 8, 32, shard_index=1, shard_count=2)
    assert not np.array_equal(a.batch_at(0)["tokens"],
                              b.batch_at(0)["tokens"])


def test_byte_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "PFCS: café ≠ cache"
    assert tok.decode(tok.encode(s)) == s
