"""Vectorized engine == scalar oracle, bit for bit.

The engine (repro.core.engine) must reproduce ``simulate_baseline`` /
``simulate_pfcs`` exactly — per-level hit counts, misses, and every
prefetch counter — on every workload shape, plus hold its batching
contract: a ``vmap``-batched run equals the per-trace runs, including
ragged (padded) batches.  Discovery-table backends (host replay vs bulk
Pallas kernels) must build identical tables.
"""

import numpy as np
import pytest

from strategies import adversarial_trace, trace_zoo
from repro.core import (simulate_baseline, simulate_pfcs, db_join_trace,
                        graph_walk_trace, run_all_systems, zipf_trace)
from repro.core.engine import (pfcs_tables, related_bulk, simulate_batch,
                               simulate_trace)
from repro.core.engine.tables import make_pfcs_cache

CAPS = (("L1", 8), ("L2", 24), ("L3", 64))
T = 1200   # shared length -> slot-array policies share one compile


def _traces():
    # shared covering set (zipf / db-join / adversarial scan) from
    # tests/strategies.py — the same builders the property tests sample
    return trace_zoo(T)


def _assert_same(a, b, *, prefetch=False):
    assert a.hits_per_level == b.hits_per_level
    assert a.misses == b.misses
    assert a.demand_accesses == b.demand_accesses
    assert a.hit_rate == b.hit_rate
    if prefetch:
        assert a.prefetches_issued == b.prefetches_issued
        assert a.prefetches_used == b.prefetches_used
        assert a.prefetches_true == b.prefetches_true


@pytest.mark.parametrize("policy", ["lru", "fifo", "2q", "arc", "lirs"])
def test_baseline_bit_equivalence(policy):
    for tr in _traces():
        a = simulate_baseline(policy, tr, CAPS)
        b = simulate_trace(tr, policy, CAPS)
        _assert_same(a, b)


@pytest.mark.parametrize("caps", [
    (("L1", 3), ("L2", 29), ("L3", 7)),     # unequal, non-monotone tiers
    (("ONLY", 16),),                        # degenerate single level, L=1
], ids=["unequal-tiers", "single-level"])
@pytest.mark.parametrize("policy", ["lru", "fifo", "2q", "arc", "lirs"])
def test_hierarchy_tier_attribution_matches_oracle(policy, caps):
    """``engine.hierarchy.build_hierarchy``'s shadow-rank tier
    attribution must equal ``simulator._BaselineHierarchy`` per level —
    including tier sizes that are NOT ascending (an L3 smaller than L2
    shifts every cumulative shadow boundary) and the L=1 hierarchy
    (where every resident hit lands in the only shadow or MEM)."""
    total = sum(c for _, c in caps)
    for tr in [zipf_trace(n_keys=200, n_accesses=600, seed=11),
               adversarial_trace(length=600, capacity=total, seed=3)]:
        a = simulate_baseline(policy, tr, caps)
        b = simulate_trace(tr, policy, caps)
        _assert_same(a, b)


def test_pfcs_bit_equivalence():
    for tr in [db_join_trace(n_orders=150, n_customers=40, n_items=80,
                             n_queries=T, seed=3),
               graph_walk_trace(n_keys=300, relationship_density=0.7,
                                n_accesses=T, seed=4),
               zipf_trace(n_keys=400, n_accesses=T, seed=5)]:
        a = simulate_pfcs(tr, CAPS)
        b = simulate_trace(tr, "pfcs", CAPS)
        _assert_same(a, b, prefetch=True)
        # the host discovery backend reproduces the oracle's
        # factorization stage mix exactly as well
        assert a.factor_ops == b.factor_ops


def test_pfcs_variant_flags_equivalence():
    """Non-default PFCS knobs flow through the engine identically."""
    tr = graph_walk_trace(n_keys=300, relationship_density=0.5,
                          n_accesses=T, seed=6)
    for kw in (dict(prefetch_budget=2, victim_window=1),
               dict(enable_prefetch=False),
               dict(prefetch_trigger="always", prefetch_budget=8)):
        a = simulate_pfcs(tr, CAPS, **kw)
        b = simulate_trace(tr, "pfcs", CAPS, **kw)
        _assert_same(a, b, prefetch=True)


# --------------------------------------------------------------------------- #
# batching                                                                    #
# --------------------------------------------------------------------------- #

def test_vmap_batch_matches_single():
    trs = [zipf_trace(n_keys=400, n_accesses=T, seed=s) for s in range(3)]
    for system in ("arc", "pfcs"):
        batch = simulate_batch(trs, system, CAPS)
        assert len(batch) == len(trs)
        for tr, st_b in zip(trs, batch):
            st_s = simulate_trace(tr, system, CAPS)
            _assert_same(st_s, st_b, prefetch=(system == "pfcs"))


def test_ragged_batch_pads_exactly():
    """Shorter traces are padded with no-op steps, not truncated state."""
    trs = [zipf_trace(n_keys=300, n_accesses=n, seed=s)
           for s, n in ((0, 900), (1, 1200), (2, 500))]
    batch = simulate_batch(trs, "lirs", CAPS)
    for tr, st_b in zip(trs, batch):
        assert st_b.demand_accesses == tr.length    # padding not counted
        _assert_same(simulate_baseline("lirs", tr, CAPS), st_b)


def test_engine_rejects_unknown_system():
    tr = zipf_trace(n_keys=100, n_accesses=200, seed=0)
    with pytest.raises(ValueError):
        simulate_trace(tr, "semantic", CAPS)


def test_run_all_systems_backend_agreement():
    """run_all_systems dispatches to the engine by default and the
    result is indistinguishable from the scalar backend."""
    tr = db_join_trace(n_orders=150, n_customers=40, n_items=80,
                       n_queries=T, seed=7)
    auto = run_all_systems(tr, CAPS, systems=("lru", "pfcs"))
    scal = run_all_systems(tr, CAPS, systems=("lru", "pfcs"),
                           engine="scalar")
    for s in ("lru", "pfcs"):
        _assert_same(auto[s], scal[s], prefetch=(s == "pfcs"))
    with pytest.raises(ValueError):
        run_all_systems(tr, CAPS, systems=("semantic",), engine="vectorized")


# --------------------------------------------------------------------------- #
# discovery tables: host replay vs bulk Pallas kernels                        #
# --------------------------------------------------------------------------- #

def test_kernel_and_host_tables_agree():
    tr = db_join_trace(n_orders=150, n_customers=40, n_items=80,
                       n_queries=T, seed=8)
    host = pfcs_tables(tr, CAPS, discover="host")
    kern = pfcs_tables(tr, CAPS, discover="kernel")
    np.testing.assert_array_equal(host.targets, kern.targets)
    np.testing.assert_array_equal(host.truth, kern.truth)
    np.testing.assert_array_equal(host.degree, kern.degree)
    # and the simulated result is identical under either backend
    a = simulate_trace(tr, "pfcs", CAPS, tables=host)
    b = simulate_trace(tr, "pfcs", CAPS, tables=kern)
    _assert_same(a, b, prefetch=True)


def test_related_bulk_matches_prefetcher():
    """The Pallas bulk-discovery path recovers exactly the related sets
    the host prefetcher computes by per-prime factorization."""
    tr = graph_walk_trace(n_keys=300, relationship_density=0.8,
                          n_accesses=T, seed=9)
    cache = make_pfcs_cache(tr, CAPS)
    keys = sorted({int(k) for k in np.unique(tr.accesses)})
    bulk = related_bulk(cache, keys)
    for k in keys:
        host = cache.prefetcher.related_elements(k)
        assert bulk.get(k, []) == host, k
