"""Per-arch smoke tests (reduced configs, CPU) + model-math equivalences.

Every assigned architecture: one forward pass (shape + finite check) and
one train step (loss finite, params change).  Equivalence tests pin the
decode paths to the train paths — the property that makes the serving
tier trustworthy.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke
from repro.models import build_model
from repro.training.train_loop import init_train_state, make_train_step

B, S = 2, 64


def _batch_for(cfg):
    rng = np.random.default_rng(0)
    if cfg.family == "audio":
        return {"features": jnp.asarray(
                    rng.normal(size=(B, S, cfg.frontend.feature_dim))
                    .astype(np.float32)),
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, size=(B, S))
                    .astype(np.int32))}
    if cfg.family == "vlm":
        npatch = cfg.frontend.n_positions
        return {"tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, size=(B, S - npatch))
                    .astype(np.int32)),
                "patches": jnp.asarray(
                    rng.normal(size=(B, npatch, cfg.frontend.feature_dim))
                    .astype(np.float32))}
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32))}


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_forward_shapes_and_finite(arch_id):
    cfg = get_smoke(arch_id)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits, aux = jax.jit(model.train_logits)(params, batch)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert logits.shape[1] == aux["targets"].shape[1]
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch_id


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_train_step(arch_id):
    cfg = get_smoke(arch_id)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, lr=1e-3, warmup=0, total_steps=10))
    batch = _batch_for(cfg)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch_id
    assert int(new_state.step) == 1
    # at least one parameter leaf moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(new_state.params)))
    assert moved, arch_id


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_decode_step(arch_id):
    cfg = get_smoke(arch_id)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    cache = model.init_cache(B, S)
    cache = dict(cache, len=jnp.full((B,), S - 1, jnp.int32))
    logits, cache2 = jax.jit(model.decode_step)(
        params, {"tokens": jnp.zeros((B, 1), jnp.int32)}, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch_id
    assert int(cache2["len"][0]) == S


# --------------------------------------------------------------------------- #
# decode == train consistency (dense family)                                   #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("arch_id", ["qwen2.5-3b", "gemma-2b",
                                     "deepseek-v2-236b"])
def test_prefill_matches_train_last_position(arch_id):
    """prefill(prompt) last-position logits == train forward at the last
    position — the contract between training and serving."""
    cfg = get_smoke(arch_id)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    batch = _batch_for(cfg)
    full, _ = jax.jit(model.train_logits)(params, batch)
    last, cache = jax.jit(model.prefill)(params, batch)
    np.testing.assert_allclose(np.asarray(full[:, -1, :], np.float32),
                               np.asarray(last, np.float32),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch_id", ["qwen2.5-3b", "xlstm-1.3b"])
def test_decode_matches_train_next_position(arch_id):
    """Teacher-forced decode after prefill reproduces the train forward's
    next-position logits (KV-cache correctness end to end)."""
    cfg = get_smoke(arch_id)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2))
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
    full, _ = jax.jit(model.train_logits)(params,
                                          {"tokens": jnp.asarray(toks)})
    if cfg.family == "ssm":
        # recurrent stack: feed tokens one by one from scratch
        cache = model.init_cache(B, S + 1)
        logits = None
        dec = jax.jit(model.decode_step)
        for t in range(S):
            logits, cache = dec(params,
                                {"tokens": jnp.asarray(toks[:, t:t + 1])},
                                cache)
        np.testing.assert_allclose(np.asarray(full[:, -1, :], np.float32),
                                   np.asarray(logits[:, 0], np.float32),
                                   rtol=5e-3, atol=5e-3)
    else:
        # prefill the first S-1 tokens, decode token S-1, compare
        prompt = {"tokens": jnp.asarray(toks[:, :-1])}
        _, cache = jax.jit(model.prefill)(params, prompt)
        # extend cache capacity by re-initializing a bigger one
        big = model.init_cache(B, S + 1)
        for k in ("k", "v"):
            big[k] = big[k].at[:, :, : S - 1].set(cache[k])
        big["len"] = cache["len"]
        logits, _ = jax.jit(model.decode_step)(
            params, {"tokens": jnp.asarray(toks[:, -1:])}, big)
        np.testing.assert_allclose(np.asarray(full[:, -1, :], np.float32),
                                   np.asarray(logits[:, 0], np.float32),
                                   rtol=5e-3, atol=5e-3)
