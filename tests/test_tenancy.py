"""Multi-tenant QoS serving: namespace laws, quota parity, isolation.

Discipline (extends tests/test_serving.py / test_serving_sharded.py):
``TenantedPagedKVCache`` is the bit-exact oracle;
``TenantedVectorizedPagedKVCache`` AND ``TenantedShardedPagedKVCache``
(1 and 2 shards) must reproduce every ``PARITY_COUNTERS`` entry, every
per-touch tier, the exact HBM LRU order, the prefetch log, and every
per-tenant stat under ANY interleaving of tenant-tagged registration,
touches, sweeps, releases, and out-of-band prime drops — at 1, 2, and
4 tenants.  On top of parity, the namespace isolation invariant
(every live composite factors inside ONE tenant's blocks; cross-tenant
composites are coprime) is proven after EVERY fuzzed step, and the
prefetch log is audited for zero cross-tenant traffic.
"""

import numpy as np
import pytest

from strategies import (TenantMixSpec, build_tenant_requests, drive_tenants,
                        given, settings, st, tenant_mix_specs)
from repro.core.primes import CacheLevel, LEVEL_PRIME_RANGES
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PARITY_COUNTERS, PagedKVCache
from repro.serving.kv_cache_vec import VectorizedPagedKVCache
from repro.tenancy import (TenantNamespace, TenantQoSConfig,
                           TenantedExpertCache, TenantedPagedKVCache,
                           TenantedShardedPagedKVCache,
                           TenantedVectorizedExpertCache,
                           TenantedVectorizedPagedKVCache, weighted_quotas)


# --------------------------------------------------------------------------- #
# namespace laws                                                              #
# --------------------------------------------------------------------------- #

def test_namespace_membership_total_vectorized_and_disjoint():
    ns = TenantNamespace(3)
    rng = np.random.default_rng(0)
    vals = np.concatenate([
        rng.integers(2, 1000, size=50),            # L1 range
        rng.integers(1009, 100_000, size=50),      # L2
        rng.integers(100_003, 1_000_000, size=50), # L3
        rng.integers(1_000_003, 3_000_000, size=50),  # MEM
        np.asarray([998, 1000, 1])])               # gap / degenerate values
    vec = ns.tenant_of_values(vals)
    assert vec.dtype == np.int32
    # vectorized membership == the scalar pure function, and total
    assert vec.tolist() == [ns.tenant_of_value(int(v)) for v in vals]
    assert ((vec >= 0) & (vec < 3)).all()
    # pure/stable
    assert ns.tenant_of_values(vals).tolist() == vec.tolist()
    # is_member mask agrees
    assert (ns.is_member(1, vals) == (vec == 1)).all()
    # 1-tenant degenerate: tenant 0 owns everything
    assert (TenantNamespace(1).tenant_of_values(vals) == 0).all()
    with pytest.raises(ValueError):
        TenantNamespace(0)


def test_namespace_allocators_disjoint_and_in_own_blocks():
    ns = TenantNamespace(4)
    allocs = [ns.make_allocator(t) for t in range(4)]
    got = {}
    for t, al in enumerate(allocs):
        for lvl in (CacheLevel.L1, CacheLevel.L2):
            got[(t, lvl)] = [al.allocate(lvl) for _ in range(12)]
            # every allocated prime falls in the tenant's own blocks
            assert (ns.tenant_of_values(got[(t, lvl)]) == t).all()
    # pairwise disjoint across tenants (same level ranges!)
    all_primes = [p for ps in got.values() for p in ps]
    assert len(set(all_primes)) == len(all_primes)
    with pytest.raises(ValueError):
        ns.make_allocator(4)


def test_one_tenant_allocator_matches_global_pool():
    """The 1-tenant namespace degenerates to the untenanted prime space:
    allocation order is value-for-value the global allocator's."""
    from repro.core.primes import HierarchicalPrimeAllocator

    ns = TenantNamespace(1)
    a, b = ns.make_allocator(0), HierarchicalPrimeAllocator()
    for lvl in CacheLevel.ALL:
        assert [a.allocate(lvl) for _ in range(32)] == \
               [b.allocate(lvl) for _ in range(32)]


def test_isolation_checker_proves_and_detects():
    from repro.core.composite import CompositeRegistry

    ns = TenantNamespace(2)
    reg = CompositeRegistry()
    a0, a1 = ns.make_allocator(0), ns.make_allocator(1)
    p0 = [a0.allocate(CacheLevel.L2) for _ in range(6)]
    p1 = [a1.allocate(CacheLevel.L2) for _ in range(6)]
    for i in range(0, 6, 2):
        reg.register({p0[i], p0[i + 1]})
        reg.register({p1[i], p1[i + 1]})
    rep = ns.check_isolation(reg, pairwise_gcd=True)
    assert rep.ok and not rep.violations
    assert rep.n_composites == 6 and rep.per_tenant == [3, 3]
    # the theorem, literally: every cross-tenant composite pair coprime
    assert rep.coprime_pairs_checked == 9
    ns.assert_isolated(reg)
    # inject a cross-tenant relationship -> checker must flag it
    reg.register({p0[0], p1[0]})
    bad = ns.check_isolation(reg)
    assert not bad.ok
    assert bad.violations and bad.violations[0][1] == (0, 1)
    with pytest.raises(AssertionError):
        ns.assert_isolated(reg)


def test_weighted_quotas_apportionment():
    assert weighted_quotas(10, [3, 1, 1]) == [5, 3, 2]
    assert weighted_quotas(4, [100, 1, 1, 1]) == [1, 1, 1, 1]
    assert sum(weighted_quotas(17, [5, 2, 1])) == 17
    assert min(weighted_quotas(7, [1000, 1, 1])) >= 1
    with pytest.raises(ValueError):
        weighted_quotas(2, [1, 1, 1])       # capacity < n_tenants
    with pytest.raises(ValueError):
        weighted_quotas(4, [1, 0])          # zero priority
    cfg = TenantQoSConfig.weighted(12, [2, 1, 1], prefetch_budget=3)
    assert sum(cfg.hbm_quota) == 12 and cfg.prefetch_budget == (3, 3, 3)
    with pytest.raises(ValueError):
        TenantQoSConfig(2, (8, 8), (1, 1), (1, 1)).validate(12)  # over cap
    with pytest.raises(ValueError):
        TenantQoSConfig(2, (8,), (1, 1), (1, 1)).validate(12)    # len
    with pytest.raises(ValueError):
        TenantedVectorizedPagedKVCache(hbm_pages=4, qos=8)       # cap < T


def test_namespace_and_assigner_introspection():
    from repro.core.composite import CompositeRegistry
    from repro.tenancy.namespace import TenantAssigner

    ns = TenantNamespace(2)
    assert "TenantNamespace" in ns.describe()
    assert ns.stripes.block_of(CacheLevel.L2)[1] >= 1
    ta = TenantAssigner(ns, CompositeRegistry())
    with pytest.raises(KeyError):
        ta.assign("unbound", CacheLevel.L2)     # must bind() first
    ta.release("unbound", CacheLevel.L2)        # unbound release: no-op
    assert ta.tenant_of("unbound") is None
    assert ta.data_of(1009) is None             # prime no one allocated
    assert ta.epoch == 0
    kv = TenantedVectorizedPagedKVCache(hbm_pages=8, page_size=4, qos=2)
    kv.register_request(0, list(range(8)), tenant=1)
    kv.touch(0, 0)
    assert len(kv.tenant_hit_rates()) == 2


def test_expert_custom_tenant_mapping_and_errors():
    mapping = [0, 1, 0, 1, 0, 1]                # interleaved ownership
    ec = TenantedExpertCache(6, hbm_slots=4, prefetch_budget=2, qos=2,
                             tenant_of_expert=mapping)
    assert ec.tenant_of_expert.tolist() == mapping
    ec.observe_routing([(0, 2, 4), (1, 3)])
    ec.activate_batch([(0, 2), (1, 3)])
    ec.namespace.assert_isolated(ec.registry)
    assert ec.cross_tenant_prefetches() == 0
    with pytest.raises(ValueError):
        TenantedExpertCache(6, hbm_slots=4, qos=2,
                            tenant_of_expert=[0, 1])        # wrong shape
    with pytest.raises(ValueError):
        TenantedExpertCache(6, hbm_slots=4, qos=2,
                            tenant_of_expert=[0, 1, 2, 0, 1, 5])  # range
    with pytest.raises(ValueError):
        TenantedVectorizedPagedKVCache(
            hbm_pages=8, qos=2, namespace=TenantNamespace(3))  # mismatch


# --------------------------------------------------------------------------- #
# differential fuzz: scalar oracle == vec == sharded, per-tenant              #
# --------------------------------------------------------------------------- #

def _assert_tenant_parity(oracle, kv, name):
    for f in PARITY_COUNTERS:
        assert getattr(kv.stats, f) == getattr(oracle.stats, f), (name, f)
    assert list(kv.hbm.items()) == list(oracle.hbm.items()), name
    assert kv.host == oracle.host, name
    assert kv.prefetch_log == oracle.prefetch_log, name
    T = oracle.qos_config.n_tenants
    for t in range(T):
        for f in PARITY_COUNTERS:
            assert getattr(kv.qos.tenant_stats[t], f) \
                == getattr(oracle.qos.tenant_stats[t], f), (name, t, f)
        assert kv.qos.tenant_logs[t] == oracle.qos.tenant_logs[t], (name, t)
        assert kv.qos.occupancy[t] == oracle.qos.occupancy[t], (name, t)
        assert kv.qos.occupancy[t] <= kv.qos.quota[t], (name, t)
    assert kv.cross_tenant_prefetches() == 0, name


def _differential(spec: TenantMixSpec, hbm: int, budget: int,
                  shards=()) -> None:
    ops = build_tenant_requests(spec)
    T = spec.n_tenants
    caches = {
        "scalar": TenantedPagedKVCache(hbm_pages=hbm, page_size=4,
                                       prefetch_budget=budget, qos=T),
        "vec": TenantedVectorizedPagedKVCache(hbm_pages=hbm, page_size=4,
                                              prefetch_budget=budget, qos=T),
    }
    for n in shards:
        caches[f"shard{n}"] = TenantedShardedPagedKVCache(
            hbm_pages=hbm, page_size=4, prefetch_budget=budget,
            n_shards=n, qos=T)

    def isolated(kv):
        kv.namespace.assert_isolated(kv.registry)

    tiers = {name: drive_tenants(kv, ops,
                                 step_hook=isolated if name == "vec"
                                 else None)
             for name, kv in caches.items()}
    oracle = caches["scalar"]
    assert oracle.cross_tenant_prefetches() == 0
    for name, kv in caches.items():
        if name == "scalar":
            continue
        assert tiers[name] == tiers["scalar"], name
        _assert_tenant_parity(oracle, kv, name)
    for n in shards:
        kv = caches[f"shard{n}"]
        assert (kv.aggregate_shard_stats().parity_tuple()
                == kv.stats.parity_tuple())


@given(spec=tenant_mix_specs(),
       hbm=st.sampled_from([4, 8, 24]),
       budget=st.integers(min_value=0, max_value=4))
@settings(max_examples=10, deadline=None)
def test_differential_fuzz_property(spec, hbm, budget):
    """Any drawn tenant mix: oracle and vec agree bit-for-bit — tiers,
    global and per-tenant counters, LRU order, prefetch logs — and the
    isolation theorem holds after every single step."""
    _differential(spec, hbm, budget)


# deterministic pinned cases: the edge paths stay covered when
# hypothesis is not installed (tier-1 must not lose this coverage)
_PINNED = [
    # 1-tenant degenerate, quota == whole HBM
    (TenantMixSpec(seed=3, n_tenants=1, n_requests=8, n_touches=90), 8, 3),
    # 1-page-per-tenant quota: every insert evicts the tenant's own page
    (TenantMixSpec(seed=5, n_tenants=4, n_requests=10, n_touches=100), 4, 2),
    # quota exhaustion under a hot tenant + releases
    (TenantMixSpec(seed=7, n_tenants=2, n_requests=12, n_touches=120,
                   hot_tenant=True), 6, 2),
    # adversarial scanner tenant sweeping long chains
    (TenantMixSpec(seed=9, n_tenants=3, n_requests=10, n_touches=80,
                   scanner_tenant=True), 9, 2),
    # identical cross-tenant prefixes (content-isolation path) + drops
    (TenantMixSpec(seed=11, n_tenants=2, n_requests=9, n_touches=90,
                   cross_prefix=True, drop_primes=True), 8, 3),
]
_PIN_IDS = ["degenerate-1", "quota-1page", "hot-exhaustion", "scanner",
            "cross-prefix-drops"]


@pytest.mark.parametrize("spec,hbm,budget", _PINNED, ids=_PIN_IDS)
def test_differential_fuzz_pinned(spec, hbm, budget):
    _differential(spec, hbm, budget)


@pytest.mark.parametrize("spec,hbm,budget", [_PINNED[2], _PINNED[3]],
                         ids=["hot-exhaustion", "scanner"])
def test_tenancy_composes_with_sharded(spec, hbm, budget):
    """Tenant namespaces x mesh-sharded cache (1 and 2 shards): the two
    stripings of the same prime space compose without breaking parity,
    per-tenant accounting, or per-shard aggregation (runs under
    shard_map on the forced-2-device CI mesh)."""
    _differential(spec, hbm, budget, shards=(1, 2))


# --------------------------------------------------------------------------- #
# degenerate and quota semantics                                              #
# --------------------------------------------------------------------------- #

def test_one_tenant_equals_untenanted_cache():
    """tenants=1 with quota == whole HBM is the untenanted cache, bit
    for bit: same pages, tiers, counters, LRU order, prefetch log."""
    spec = TenantMixSpec(seed=13, n_tenants=1, n_requests=10, n_touches=120)
    ops = build_tenant_requests(spec)
    a = VectorizedPagedKVCache(hbm_pages=8, page_size=4, prefetch_budget=3)
    b = TenantedVectorizedPagedKVCache(hbm_pages=8, page_size=4,
                                       prefetch_budget=3, qos=1)
    ta = drive_tenants(_Untenanted(a), ops)
    tb = drive_tenants(b, ops)
    assert ta == tb
    assert a.stats.parity_tuple() == b.stats.parity_tuple()
    assert list(a.hbm.items()) == list(b.hbm.items())
    assert a.host == b.host
    assert a.prefetch_log == b.prefetch_log
    # the whole workload charged to tenant 0
    assert b.qos.tenant_stats[0].parity_tuple() == b.stats.parity_tuple()


class _Untenanted:
    """Adapter: drives an untenanted cache with tenant-tagged ops (the
    tenant tag is dropped — only valid for 1-tenant specs)."""

    def __init__(self, kv):
        self._kv = kv

    def register_request(self, rid, tokens, tenant=0):
        assert tenant == 0
        return self._kv.register_request(rid, tokens)

    def __getattr__(self, name):
        return getattr(self._kv, name)


def test_quota_exhaustion_confines_evictions():
    """A tenant churning far past its quota evicts ONLY its own pages:
    the victim is never another tenant's, occupancy never exceeds
    quota, and a bystander's resident pages stay resident."""
    cfg = TenantQoSConfig(2, (2, 6), (2, 2), (1, 3))
    kv = TenantedVectorizedPagedKVCache(hbm_pages=8, page_size=4,
                                        prefetch_budget=2, qos=cfg)
    kv.register_request(100, list(range(12)), tenant=1)     # 3 pages
    for j in range(3):
        kv.touch(100, j)
    resident_b = [pid for pid in kv.chains[100] if pid in kv.hbm]
    assert len(resident_b) == 3
    # hammer tenant 0 with 25 distinct single-page requests (quota 2)
    for r in range(25):
        kv.register_request(r, [1000 + 4 * r + k for k in range(4)],
                            tenant=0)
        kv.touch(r, 0)
        assert kv.qos.occupancy[0] <= 2
        assert all(pid in kv.hbm for pid in resident_b)     # untouched
    assert kv.stats.evictions >= 23
    # every eviction was charged to (and suffered by) tenant 0
    assert kv.qos.tenant_stats[0].evictions == kv.stats.evictions
    assert kv.qos.tenant_stats[1].evictions == 0


def test_scanner_tenant_cannot_thrash_hot_tenant():
    """The QoS claim end-to-end: an adversarial scanner sweeping long
    chains destroys a hot tenant's hit rate in a shared (untenanted)
    cache, but cannot touch it under per-tenant quotas."""
    def run(tenanted: bool) -> float:
        if tenanted:
            kv = TenantedVectorizedPagedKVCache(
                hbm_pages=8, page_size=4, prefetch_budget=0,
                qos=TenantQoSConfig(2, (4, 4), (0, 0), (1, 1)))
            kv.register_request(0, list(range(16)), tenant=0)   # 4 pages
            kv.register_request(1, list(range(100, 196)), tenant=1)
        else:
            kv = VectorizedPagedKVCache(hbm_pages=8, page_size=4,
                                        prefetch_budget=0)
            kv.register_request(0, list(range(16)))
            kv.register_request(1, list(range(100, 196)))       # 24 pages
        hot_hits = hot_total = 0
        for i in range(30):
            tier = kv.touch(0, i % 4)                # hot working set
            hot_hits += tier == "hbm"
            hot_total += 1
            kv.touch_batch([(1, j) for j in range(len(kv.chains[1]))])
        return hot_hits / hot_total

    protected, shared = run(tenanted=True), run(tenanted=False)
    assert shared < 0.2          # LRU sweep thrash: hot set evicted
    assert protected > 0.85      # quota confinement: hot set survives


def test_per_tenant_prefetch_budget_enforced():
    """Tenant budgets replace the global one: a 0-budget tenant never
    prefetches while its neighbour does, and tenant logs say whose
    prefetch was whose."""
    cfg = TenantQoSConfig(2, (6, 6), (0, 3), (1, 1))
    for cls in (TenantedPagedKVCache, TenantedVectorizedPagedKVCache):
        kv = cls(hbm_pages=12, page_size=4, prefetch_budget=4, qos=cfg)
        kv.register_request(0, list(range(32)), tenant=0)       # 8 pages
        kv.register_request(1, list(range(100, 132)), tenant=1)
        kv.touch(0, 0)
        kv.touch(1, 0)
        assert not kv.qos.tenant_logs[0]
        assert kv.qos.tenant_logs[1]
        assert kv.qos.tenant_stats[0].prefetches == 0
        assert kv.qos.tenant_stats[1].prefetches == len(
            kv.qos.tenant_logs[1])
        assert kv.cross_tenant_prefetches() == 0


def test_tenant_binding_and_bad_inputs():
    kv = TenantedVectorizedPagedKVCache(hbm_pages=8, page_size=4, qos=2)
    with pytest.raises(ValueError):
        kv.register_request(0, [1, 2, 3], tenant=2)
    pages = kv.register_request(0, [1, 2, 3, 4, 5], tenant=1)
    assert all(kv.tenant_of_page(p) == 1 for p in pages)
    assert kv.tenant_of_request(0) == 1
    # same tokens, other tenant: pages must NOT be shared
    pages2 = kv.register_request(1, [1, 2, 3, 4, 5], tenant=0)
    assert not (set(pages) & set(pages2))
    assert kv.stats.shared_prefix_pages == 0
    # ... but the SAME tenant does share them
    pages3 = kv.register_request(2, [1, 2, 3, 4, 5], tenant=1)
    assert pages3 == pages
    assert kv.stats.shared_prefix_pages > 0


# --------------------------------------------------------------------------- #
# recycled primes (per-namespace recycling + the stale-chunk regression)      #
# --------------------------------------------------------------------------- #

def test_shared_prefix_after_prime_recycle_matches_oracle():
    """Regression: the vectorized cache cached chain-composite chunks
    forever, so a prime freed by Algorithm-1 recycling and reassigned
    to a NEW page still gcd-matched the old chain — false sharing the
    scalar oracle (reading primes live) never reports.  The chunk
    arrays now rebuild when the assigner epoch moves."""
    a = PagedKVCache(hbm_pages=8, page_size=4)
    b = VectorizedPagedKVCache(hbm_pages=8, page_size=4)
    for kv in (a, b):
        kv.register_request(0, [1, 2, 3, 4])          # page 0, prime p
        kv.assigner.release(0, CacheLevel.L2)         # free p
        kv.register_request(1, [9, 9, 9, 9])          # page 1 reuses p
    assert a.assigner.prime_of(1) == b.assigner.prime_of(1)
    assert a.shared_prefix(0, 1) == []
    assert b.shared_prefix(0, 1) == []                # used to diverge


def test_noisy_tenant_recycling_stays_in_its_namespace():
    """Per-namespace prime recycling: a tenant exhausting its pools
    recycles its OWN LRU elements; the other tenant's bindings,
    composites, and prefetch behavior are untouched."""
    ns = TenantNamespace(2, ranges={
        CacheLevel.L1: (2, 13), CacheLevel.L2: (17, 97),
        CacheLevel.L3: (101, 199), CacheLevel.MEM: (211, None)})
    caches = [cls(hbm_pages=8, page_size=4, prefetch_budget=2, qos=2,
                  namespace=TenantNamespace(2, ranges=ns.ranges))
              for cls in (TenantedPagedKVCache,
                          TenantedVectorizedPagedKVCache)]
    tiers = []
    for kv in caches:
        kv.register_request(1000, list(range(500, 516)), tenant=1)
        quiet = {pid: kv.assigner.prime_of(pid)
                 for pid in kv.chains[1000]}
        t = []
        for r in range(30):          # churn tenant 0 through its pools
            # mark the upcoming pages hot so exhaustion takes the
            # recycle path (freq > 0.3 needs two records)
            for k in range(6):
                kv.assigner.per_tenant[0].tracker.record(kv._next_page + k)
                kv.assigner.per_tenant[0].tracker.record(kv._next_page + k)
            kv.register_request(r, [r * 40 + k for k in range(16)],
                                tenant=0)
            t.extend(kv.touch_batch(
                [(r, j) for j in range(len(kv.chains[r]))]))
        tiers.append(t)
        assert kv.assigner.per_tenant[0].stats.recycle_events > 0
        assert kv.assigner.per_tenant[1].stats.recycle_events == 0
        # tenant 1's bindings survived tenant 0's churn exactly
        assert {pid: kv.assigner.prime_of(pid)
                for pid in kv.chains[1000]} == quiet
        kv.namespace.assert_isolated(kv.registry)
    assert tiers[0] == tiers[1]
    a, b = caches
    assert a.stats.parity_tuple() == b.stats.parity_tuple()
    assert a.prefetch_log == b.prefetch_log
    assert list(a.hbm.items()) == list(b.hbm.items())


# --------------------------------------------------------------------------- #
# tenanted MoE expert tier                                                    #
# --------------------------------------------------------------------------- #

def test_expert_tenancy_differential_and_isolation():
    """Tenanted expert caches: scalar oracle == vec on counters, tiers,
    LRU order, and prefetch log; router sets spanning tenants are split
    before registration so the registry stays isolated."""
    from strategies import ExpertWorkloadSpec, build_expert_sets

    spec = ExpertWorkloadSpec(seed=2, n_experts=24, n_steps=50, batch=3,
                              group_size=5, n_groups=10, oversize_every=4)
    batches = build_expert_sets(spec)
    a = TenantedExpertCache(24, hbm_slots=9, prefetch_budget=3, qos=3)
    b = TenantedVectorizedExpertCache(24, hbm_slots=9, prefetch_budget=3,
                                      qos=3)
    tiers = []
    for ec in (a, b):
        t = []
        for batch in batches:
            ec.observe_routing(batch)
            for d in ec.activate_batch(batch):
                t.append(tuple(sorted(d.items())))
        tiers.append(t)
    assert tiers[0] == tiers[1]
    assert a.stats.parity_tuple() == b.stats.parity_tuple()
    assert list(a.hbm.items()) == list(b.hbm.items())
    assert a.prefetch_log == b.prefetch_log
    assert a.cross_tenant_groups == b.cross_tenant_groups > 0
    assert a.cross_tenant_prefetches() == 0 == b.cross_tenant_prefetches()
    for ec in (a, b):
        ec.namespace.assert_isolated(ec.registry)
        assert (ec.qos.occupancy <= ec.qos.quota).all()
    # Theorem 1, tenant-scoped: every prefetch target is in the
    # factorization-recovered co-fire set of its source
    for src, tgt in a.prefetch_log:
        assert tgt in a.coactivated(src)


def test_expert_quota_one_slot_per_tenant():
    a = TenantedExpertCache(8, hbm_slots=2, prefetch_budget=2, qos=2)
    b = TenantedVectorizedExpertCache(8, hbm_slots=2, prefetch_budget=2,
                                      qos=2)
    sets = [(0, 1, 2), (4, 5, 6), (2, 3), (6, 7), (0, 1), (5, 4)]
    for ec in (a, b):
        ec.observe_routing(sets)
        ec.activate_batch(sets)
        assert (ec.qos.occupancy <= 1).all()
    assert a.stats.parity_tuple() == b.stats.parity_tuple()
    assert list(a.hbm.items()) == list(b.hbm.items())
    assert a.prefetch_log == b.prefetch_log


# --------------------------------------------------------------------------- #
# serving engine tenants= mode                                                #
# --------------------------------------------------------------------------- #

def _tenant_engine_workload(eng, n_req=24, seed=0, tenants=3):
    rng = np.random.default_rng(seed)
    for r in range(n_req):
        eng.submit(list(rng.integers(0, 500,
                                     size=int(rng.integers(8, 48)))),
                   max_new_tokens=6, tenant=r % tenants)
    return eng.run_until_idle()


def test_engine_tenants_mode_vec_scalar_parity():
    engines = {kv: ServingEngine(None, None, max_batch=8, page_size=8,
                                 hbm_pages=24, kv=kv, prefetch_budget=3,
                                 reread_window=2, tenants=3)
               for kv in ("vec", "scalar")}
    done = {kv: _tenant_engine_workload(e) for kv, e in engines.items()}
    gen = {kv: [(r.req_id, tuple(r.generated)) for r in
                sorted(ds, key=lambda r: r.req_id)]
           for kv, ds in done.items()}
    assert gen["vec"] == gen["scalar"]
    ev, es = engines["vec"].pages, engines["scalar"].pages
    assert ev.stats.parity_tuple() == es.stats.parity_tuple()
    assert ev.prefetch_log == es.prefetch_log
    assert ev.stats.registry_scans == 0
    for t in range(3):
        assert (ev.qos.tenant_stats[t].parity_tuple()
                == es.qos.tenant_stats[t].parity_tuple())
    assert ev.cross_tenant_prefetches() == 0
    ev.namespace.assert_isolated(ev.registry)
    # per-tenant stats partition the engine-visible totals
    for f in PARITY_COUNTERS:
        assert sum(getattr(s, f) for s in ev.qos.tenant_stats) \
            == getattr(ev.stats, f), f


def test_engine_tenants_mode_rejects_bad_usage():
    eng = ServingEngine(None, None, max_batch=4, hbm_pages=16)
    with pytest.raises(ValueError):
        eng.submit([1, 2, 3], tenant=1)      # tenants= mode not enabled
    with pytest.raises(ValueError):
        ServingEngine(None, None, max_batch=4, hbm_pages=16, kv="magic",
                      tenants=2)
    # out-of-range tenant must fail AT SUBMIT: failing later inside
    # _admit left a permanently-running slot holding an unregistered
    # request (regression)
    eng2 = ServingEngine(None, None, max_batch=4, hbm_pages=16, tenants=2)
    with pytest.raises(ValueError):
        eng2.submit([1, 2, 3], tenant=2)
    assert not eng2.queue                    # nothing half-enqueued
    eng2.submit([1, 2, 3], max_new_tokens=2, tenant=1)
    assert len(eng2.run_until_idle()) == 1   # engine still serves


def test_engine_tenants_step_reports_tenant_stats():
    eng = ServingEngine(None, None, max_batch=4, page_size=8, hbm_pages=16,
                        tenants=2)
    eng.submit(list(range(24)), max_new_tokens=3, tenant=1)
    out = {}
    while eng.queue or any(s is not None for s in eng.slots):
        out = eng.step()
    assert "tenant_stats" in out and len(out["tenant_stats"]) == 2
    st1 = out["tenant_stats"][1]
    assert st1.hbm_hits + st1.host_hits + st1.misses > 0
